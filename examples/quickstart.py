"""Quickstart: a complete FDJ semantic join in ~20 lines.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.configs.fdj_join import smoke_config
from repro.core.costs import naive_join_cost
from repro.core.join import fdj_join
from repro.data import synth
from repro.data.simulated_llm import SimulatedExtractor, SimulatedProposer


def main():
    # a self-join over synthetic police reports: "same incident?"
    ds = synth.police_records(n_incidents=150, reports_per_incident=3)
    oracle = ds.make_oracle()                 # simulated LLM (paper §8.1)
    res = fdj_join(ds, oracle, SimulatedProposer(ds), SimulatedExtractor(ds),
                   smoke_config())
    naive = naive_join_cost(ds.texts_l, ds.texts_r)
    print(f"dataset: {ds.n_l} x {ds.n_r} records, {ds.n_positive} true matches")
    print(f"featurizations: {[s.key for s in res.specs]}")
    print(f"decomposition (CNF clause feature-indices): {res.scaffold.clauses}")
    print(f"thresholds: {res.theta.round(3).tolist()}  (adjusted target T'={res.t_prime:.3f})")
    print(f"recall={res.recall:.3f} precision={res.precision:.3f} "
          f"(targets: 0.9 / 1.0, met={res.met_target})")
    print(f"cost: ${res.cost.total:.2f} vs naive ${naive:.2f} "
          f"-> ratio {res.cost.total/naive:.1%}")


if __name__ == "__main__":
    main()
