"""End-to-end driver (serve kind): batched request serving with a reduced
backbone, then a fault-tolerant mini-training run with injected failure —
the two runtime paths a production deployment exercises.

  PYTHONPATH=src python examples/serve_fleet.py [--arch phi4-mini-3.8b]
"""
import argparse
import shutil

from repro.launch.serve import serve
from repro.launch.train import SimulatedFailure, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi4-mini-3.8b")
    args = ap.parse_args()
    print("== batched serving ==")
    out = serve(args.arch, n_requests=12, max_new=10, batch_slots=4)
    print(out)
    print("== fault-tolerant training (crash at step 9, auto-resume) ==")
    ckpt = "/tmp/repro_example_ckpt"
    shutil.rmtree(ckpt, ignore_errors=True)
    try:
        train(args.arch, steps_n=16, batch=2, seq=64, ckpt_dir=ckpt,
              ckpt_every=4, fail_at=9)
    except SimulatedFailure as e:
        print(f"crashed as planned: {e}")
    out = train(args.arch, steps_n=16, batch=2, seq=64, ckpt_dir=ckpt,
                ckpt_every=4)
    print(f"resumed and finished: {out}")


if __name__ == "__main__":
    main()
