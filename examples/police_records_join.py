"""The paper's running example end-to-end, with the Pallas CNF engine and
the Fig-9-style cost breakdown.

  PYTHONPATH=src python examples/police_records_join.py [--engine pallas]
"""
import argparse
import json

from repro.engine import ENGINES
from repro.launch.join import run_join


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", default="numpy", choices=list(ENGINES))
    ap.add_argument("--target", type=float, default=0.9)
    ap.add_argument("--size", type=float, default=0.6)
    args = ap.parse_args()
    out = run_join("police_records", target=args.target, engine=args.engine,
                   size=args.size)
    print(json.dumps(out, indent=1))
    assert out["precision"] == 1.0, "refinement must guarantee precision 1"


if __name__ == "__main__":
    main()
