"""Entity matching (Products analogue): FDJ vs the BARGAIN cascade vs the
oracle-threshold optimal cascade, with a relaxed precision target variant.

  PYTHONPATH=src python examples/entity_matching.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import baselines as bl
from repro.data import synth


def main():
    ds = synth.products(n_products=500)
    print(f"products: {ds.n_l} x {ds.n_r} listings, {ds.n_positive} matches")
    for name, fn in [("FDJ", bl.run_fdj), ("BARGAIN", bl.run_bargain),
                     ("optimal-cascade", bl.run_optimal_cascade)]:
        r = fn(ds)
        print(f"{name:16s} cost_ratio={r['cost_ratio']:.1%} "
              f"recall={r['recall']:.3f} precision={r['precision']:.3f}")
    r = bl.run_fdj(synth.products(n_products=500), precision_target=0.9)
    print(f"{'FDJ (T_P=0.9)':16s} cost_ratio={r['cost_ratio']:.1%} "
          f"recall={r['recall']:.3f} precision={r['precision']:.3f}")


if __name__ == "__main__":
    main()
