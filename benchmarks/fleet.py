"""Fleet regime: multi-tenant serving on one shared store + mesh.

Three phases through one ``JoinFleet`` (sharded engine — the mesh the
band-step scheduler interleaves on):

  * **dedup** — tenant 0 pays the cold query; tenant 1's cold query over
    the SAME corpus must charge $0 extraction and move 0 plane bytes H2D
    (content-hash plane dedup + PlanLibrary plan dedup), returning pairs
    identical to tenant 0's.  Gate: zero-baseline fields must stay zero.
  * **serial** — every stream's queries run warm at concurrency 1: the
    K× per-query baseline.
  * **concurrent** — the same query streams submitted together, admitted
    round-robin across tenants onto ``max_concurrent`` workers, band
    steps interleaved on the mesh by the fleet scheduler.  Acceptance:
    aggregate wall strictly below the serial aggregate (interleaving
    actually overlapped oracle waits and device work), scheduler
    ``interleaves`` > 0 (steps really alternated queries), and every
    stream's observed recall holds its floor.

The oracle runs with a small simulated API latency
(``SimulatedOracle.latency_s``): refinement waits release the GIL the
way a real L_p backend's round-trips do, so the serial-vs-concurrent
comparison measures the overlap the fleet actually buys in deployment
rather than a pure-Python GIL fight.  Latency never changes answers or
dollar charges.

Reported rows are gated by ``benchmarks/run.py``: p50/p99 latency are
wall-banded ceilings, ``cost_per_query`` is dollar-banded, ``recall`` is
a floor, and the dedup phase's extraction/H2D are zero-invariants.

Usage:  PYTHONPATH=src python -m benchmarks.run --fast --only fleet
"""

from __future__ import annotations

import time

from repro.core.join import FDJConfig
from repro.data import synth
from repro.obs.metrics import MetricsRegistry
from repro.serving.fleet import JoinFleet

# interpret-mode tiles, as in the serving regime; small bands give each
# query several band steps for the scheduler to interleave
_SHARDED_OPTS = dict(tl=32, tr=32, r_chunk=64)

# simulated L_p round-trip per labeled pair (see module docstring)
_ORACLE_LATENCY_S = 3e-4


def run(fast: bool = True):
    n_tenants = 4 if fast else 10
    streams_per_tenant = 3 if fast else 25        # non-fast: 250 streams
    queries_per_stream = 2
    concurrency = 4 if fast else 8
    n = 40 if fast else 60

    ds = synth.movies_pages(n_movies=n, cast_size=4, filler_sentences=1,
                            seed=0)
    cfg = FDJConfig(engine="sharded", engine_opts=dict(_SHARDED_OPTS),
                    seed=0, mc_trials=6000)
    fleet = JoinFleet(max_concurrent=concurrency)
    for t in range(n_tenants):
        fleet.add_tenant(
            f"t{t}", ds, cfg,
            oracle_factory=lambda: ds.make_oracle(_ORACLE_LATENCY_S))

    rows = []

    # --- phase 1: shared-corpus dedup --------------------------------------
    cold = fleet.query("t0")
    rows.append({"phase": "cold_first_tenant",
                 "wall_s": round(cold.wall_s, 4),
                 "extraction_cost": cold.cost.inference,
                 "bytes_to_device": cold.cost.bytes_h2d,
                 "pairs": len(cold.pairs),
                 "recall": round(cold.join.recall, 4)})
    second = fleet.query("t1")
    assert second.cost.inference == 0.0, \
        f"second tenant's cold query charged ${second.cost.inference} " \
        f"extraction over a shared corpus"
    assert second.cost.bytes_h2d == 0, \
        f"second tenant's cold query moved {second.cost.bytes_h2d} plane " \
        f"bytes H2D over a shared corpus"
    assert second.cost.labeling == 0.0 and second.cost.construction == 0.0, \
        "second tenant re-paid planning despite the shared PlanLibrary"
    assert second.cost.plane_dedup_hits > 0, \
        "second tenant's plane hits were not attributed as dedup"
    assert second.pairs == cold.pairs, \
        "shared-corpus tenants disagree on the join result"
    rows.append({"phase": "dedup_second_tenant",
                 "wall_s": round(second.wall_s, 4),
                 "extraction_cost": second.cost.inference,
                 "bytes_to_device": second.cost.bytes_h2d,
                 "plan_cost": second.cost.labeling + second.cost.construction,
                 "dedup_hits": second.cost.plane_dedup_hits,
                 "pairs": len(second.pairs),
                 "agrees_with_first": True,
                 "recall": round(second.join.recall, 4)})
    print(f"fleet,dedup,second_tenant_extraction=$0.0000,bytes_h2d=0,"
          f"dedup_hits={second.cost.plane_dedup_hits},"
          f"agrees_with_first=True")

    # warm every remaining tenant once (all dedup against the residents)
    for t in range(2, n_tenants):
        fleet.query(f"t{t}")

    tenants = fleet.tenants
    n_streams = n_tenants * streams_per_tenant
    n_queries = n_streams * queries_per_stream

    # --- phase 2: serial baseline (concurrency 1, warm) --------------------
    t0 = time.perf_counter()
    for s in range(streams_per_tenant):
        for name in tenants:
            for _ in range(queries_per_stream):
                r = fleet.query(name)
                assert r.cost.inference == 0.0
    serial_wall = time.perf_counter() - t0
    rows.append({"phase": "serial", "concurrency": 1,
                 "streams": n_streams, "queries": n_queries,
                 "wall_s": round(serial_wall, 4),
                 "per_query_wall_s": round(serial_wall / n_queries, 5)})
    print(f"fleet,serial,streams={n_streams},queries={n_queries},"
          f"wall_s={serial_wall:.3f}")

    # --- phase 3: concurrent streams ---------------------------------------
    sched = fleet.scheduler
    steps0, inter0 = sched.band_steps, sched.interleaves
    lat = MetricsRegistry()            # phase-scoped latency histogram
    t0 = time.perf_counter()
    futures = [fleet.submit(name)
               for s in range(streams_per_tenant)
               for name in tenants
               for _ in range(queries_per_stream)]
    results = [f.result() for f in futures]
    concurrent_wall = time.perf_counter() - t0
    fleet.drain()
    interleaves = sched.interleaves - inter0
    band_steps = sched.band_steps - steps0

    min_recall, total_cost = 1.0, 0.0
    for r in results:
        assert r.cost.inference == 0.0, \
            "a concurrent warm stream re-paid extraction"
        assert r.pairs == cold.pairs, \
            "a concurrent stream diverged from the serial result"
        min_recall = min(min_recall, r.join.recall)
        total_cost += r.cost.total
        lat.observe("fleet.query_wall_s", r.wall_s)
    hist = lat.histogram("fleet.query_wall_s")

    assert concurrent_wall < serial_wall, \
        f"{concurrency}-way concurrent streams took {concurrent_wall:.3f}s " \
        f">= the serial aggregate {serial_wall:.3f}s: band-step " \
        f"interleaving bought no overlap"
    assert interleaves > 0, \
        "no band step was ever granted to a different query than its " \
        "predecessor: the scheduler never interleaved"

    rows.append({"phase": "concurrent", "concurrency": concurrency,
                 "streams": n_streams, "queries": n_queries,
                 "wall_s": round(concurrent_wall, 4),
                 "speedup_vs_serial": round(serial_wall / concurrent_wall, 3),
                 "p50_wall_s": round(hist.quantile(0.5), 5),
                 "p99_wall_s": round(hist.quantile(0.99), 5),
                 "cost_per_query": total_cost / n_queries,
                 "recall": round(min_recall, 4),
                 "band_steps": band_steps,
                 "interleaved": interleaves > 0,
                 "agrees_with_serial": True})
    print(f"fleet,concurrent,streams={n_streams},conc={concurrency},"
          f"wall_s={concurrent_wall:.3f},"
          f"speedup={serial_wall / concurrent_wall:.2f}x,"
          f"interleaves={interleaves},min_recall={min_recall:.3f},"
          f"cost_per_query=${total_cost / n_queries:.4f}")
    fleet.close()
    return rows


def main(fast: bool):
    from benchmarks.run import _emit
    rows = run(fast)
    _emit(rows, "fleet")


if __name__ == "__main__":
    main(fast=True)
