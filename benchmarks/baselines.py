"""Method runners shared by the benchmark harnesses.

Each runner returns a dict with recall / precision / cost ratio / breakdown,
using the paper's §8.1 methodology: simulated LLM, token-priced costs, and
the cost ratio normalized by the naive all-pairs join cost.
"""

from __future__ import annotations

import math
import time

import numpy as np

from repro.core.bargain import (optimal_cascade_threshold,
                                recall_guarded_threshold, supg_threshold)
from repro.core.costs import CostLedger, naive_join_cost
from repro.core.join import FDJConfig, fdj_join
from repro.core.llm import HashedNgramEmbedder, semantic_distance_matrix
from repro.data.simulated_llm import SimulatedExtractor, SimulatedProposer


def _proxy_distances(ds, ledger: CostLedger, dim: int = 256) -> np.ndarray:
    emb = HashedNgramEmbedder(dim=dim, ledger=ledger)
    e_l = emb.embed(ds.texts_l)
    e_r = e_l if ds.self_join else emb.embed(ds.texts_r)
    return semantic_distance_matrix(e_l, e_r)


def _sample(ds, k: int, rng) -> list:
    n = ds.n_l * ds.n_r
    idx = rng.choice(n, size=min(k, n), replace=False)
    return [(int(i // ds.n_r), int(i % ds.n_r)) for i in idx]


def _metrics(ds, out_pairs: set, ledger: CostLedger, extra=None) -> dict:
    truth = ds.truth_set
    tp = len(out_pairs & truth)
    naive = naive_join_cost(ds.texts_l, ds.texts_r)
    d = {
        "recall": tp / max(len(truth), 1),
        "precision": tp / max(len(out_pairs), 1) if out_pairs else 1.0,
        "cost": ledger.total,
        "cost_ratio": ledger.total / naive,
        "breakdown": {k: v / naive for k, v in ledger.breakdown().items()},
    }
    if extra:
        d.update(extra)
    return d


def run_fdj(ds, target: float = 0.9, delta: float = 0.1, seed: int = 0,
            mc_trials: int = 8000, precision_target: float = 1.0) -> dict:
    oracle = ds.make_oracle()
    prop = SimulatedProposer(ds)
    ext = SimulatedExtractor(ds, seed=seed)
    cfg = FDJConfig(recall_target=target, precision_target=precision_target,
                    delta=delta, mc_trials=mc_trials, seed=seed, block=2048)
    t0 = time.time()
    res = fdj_join(ds, oracle, prop, ext, cfg)
    return _metrics(ds, res.pairs, res.cost, extra={
        "t_prime": res.t_prime, "clauses": res.scaffold.clauses,
        "candidates": res.candidate_count, "wall_s": time.time() - t0,
        "serving": res.cost.serving_summary()})


def run_bargain(ds, target: float = 0.9, delta: float = 0.1, seed: int = 0,
                k_positives: int = 250, mc_trials: int = 8000) -> dict:
    """BARGAIN applied to joins: embedding-distance proxy + guaranteed
    1-D threshold (adj-target r=1), refine every kept pair."""
    rng = np.random.default_rng(seed)
    oracle = ds.make_oracle()
    ledger = oracle.ledger
    dists = _proxy_distances(ds, ledger)
    rate = max(ds.n_positive, 1) / (ds.n_l * ds.n_r)
    k = min(int(math.ceil(k_positives / rate * 1.25)), ds.n_l * ds.n_r)
    pairs = _sample(ds, k, rng)
    labels = oracle.label_pairs(pairs, kind="labeling")
    sd = np.asarray([dists[i, j] for i, j in pairs])
    cas = recall_guarded_threshold(sd, labels, target, delta,
                                   n_pairs=ds.n_l * ds.n_r, n_trials=mc_trials)
    keep = np.argwhere(dists <= cas.tau)
    cand = [(int(i), int(j)) for i, j in keep]
    labs = oracle.label_pairs(cand, kind="refinement")
    out = {p for p, l in zip(cand, labs) if l}
    return _metrics(ds, out, ledger, extra={
        "tau": cas.tau, "t_prime": cas.t_prime, "candidates": len(cand)})


def run_supg(ds, target: float = 0.9, seed: int = 0, k_positives: int = 250) -> dict:
    """LOTUS/SUPG-style: sample threshold at observed recall = T (no
    finite-sample adjustment) — reproduces the Table-2 failure mode."""
    rng = np.random.default_rng(seed)
    oracle = ds.make_oracle()
    ledger = oracle.ledger
    dists = _proxy_distances(ds, ledger)
    rate = max(ds.n_positive, 1) / (ds.n_l * ds.n_r)
    k = min(int(math.ceil(k_positives / rate * 1.25)), ds.n_l * ds.n_r)
    pairs = _sample(ds, k, rng)
    labels = oracle.label_pairs(pairs, kind="labeling")
    sd = np.asarray([dists[i, j] for i, j in pairs])
    tau = supg_threshold(sd, labels, target)
    keep = np.argwhere(dists <= tau)
    cand = [(int(i), int(j)) for i, j in keep]
    labs = oracle.label_pairs(cand, kind="refinement")
    out = {p for p, l in zip(cand, labs) if l}
    return _metrics(ds, out, ledger, extra={"tau": tau, "candidates": len(cand)})


def run_optimal_cascade(ds, target: float = 0.9) -> dict:
    """Oracle threshold from full ground truth (lower bound for cascades);
    threshold-finding is free, join cost = embeddings + refinement."""
    oracle = ds.make_oracle()
    ledger = oracle.ledger
    dists = _proxy_distances(ds, ledger)
    labels = np.zeros(dists.shape, bool)
    for (i, j) in ds.truth_set:
        labels[i, j] = True
    tau = optimal_cascade_threshold(dists.ravel(), labels.ravel(), target)
    keep = np.argwhere(dists <= tau)
    cand = [(int(i), int(j)) for i, j in keep]
    labs = oracle.label_pairs(cand, kind="refinement")
    out = {p for p, l in zip(cand, labs) if l}
    return _metrics(ds, out, ledger, extra={"tau": tau, "candidates": len(cand)})
