"""Pipeline regime: streaming candidate→refinement overlap vs the barrier.

For one CI-shaped table, materialize the representative CNF once, then for
each engine run step ②+⑨ two ways through the *same* RefinementPump (same
worker thread, same oracle batching — the only variable is when candidate
chunks become available):

  * **barrier** — ``evaluate()`` to completion, then the pump refines one
    big chunk (the pre-streaming fdj_join shape: total = step2 + refine);
  * **stream**  — ``evaluate_stream()`` chunks land in the pump as the
    engine produces (total → max(step2, refine) as overlap improves).

The oracle here is simulated, so refinement charges dollars but takes no
wall time; to measure *pipeline* behavior we model LLM service latency as
``per_pair_s`` of sleep per refined pair (sized so total refine latency ≈
the engine's own step-② wall — the regime where overlap matters).  Reported
per row:

  * ``t_first_s`` — time to first candidate chunk (barrier: the full
    evaluate wall; the headline latency win of streaming);
  * ``step2_wall`` / ``refine_wall`` / ``overlap_wall`` / ``total_wall``.

Usage:  PYTHONPATH=src python -m benchmarks.run --fast --only pipeline
"""

from __future__ import annotations

import time

from repro.core.costs import CostLedger
from repro.core.refine import RefinementPump
from repro.data import synth
from repro.data.cnf_fixtures import representative_cnf
from repro.data.simulated_llm import SimulatedExtractor
from repro.engine import get_engine

# small tiles/blocks: many chunks on the CI shape, interpret-mode tractable
_CPU_OPTS = {
    "numpy": dict(block=32),
    "pallas": dict(tl=32, tr=64, l_block=32),
    "sharded": dict(tl=32, tr=32, r_chunk=64),
}
_BATCH_PAIRS = 128


def _refine_fn(per_pair_s: float):
    def refine(batch):
        time.sleep(per_pair_s * len(batch))   # modeled LLM service latency
        return set(batch)
    return refine


def run(fast: bool = True):
    n = 50 if fast else 100
    ds = synth.police_records(n_incidents=n, reports_per_incident=2, seed=0)
    ext = SimulatedExtractor(ds)
    specs, clauses, thetas = representative_cnf(ds)
    feats = ext.materialize(specs, CostLedger())

    rows = []
    totals = {"barrier": 0.0, "stream": 0.0}
    for ename in ("numpy", "pallas", "sharded"):
        opts = _CPU_OPTS[ename]
        # warm the jit/program caches so neither mode pays compile time
        warm = get_engine(ename, **opts).evaluate(feats, clauses, thetas)
        n_cands = warm.stats.n_candidates

        # size refine latency to the engine's own step-② wall: the regime
        # where pipelining matters (capped so the numpy path stays fast)
        per_pair_s = min(max(warm.stats.wall_s, 0.25) / max(n_cands, 1), 2e-3)

        # -- barrier: evaluate to completion, then pump one big chunk ------
        pump = RefinementPump(_refine_fn(per_pair_s),
                              batch_pairs=_BATCH_PAIRS, max_queue_chunks=4)
        t0 = time.perf_counter()
        res = get_engine(ename, **opts).evaluate(feats, clauses, thetas)
        step2 = time.perf_counter() - t0
        from repro.engine.base import CandidateChunk
        pr = pump.run(iter([CandidateChunk(res.candidates, res.stats, 0)]))
        barrier_total = time.perf_counter() - t0
        totals["barrier"] += barrier_total
        rows.append({"engine": ename, "mode": "barrier",
                     "candidates": n_cands, "t_first_s": round(step2, 4),
                     "step2_wall": round(step2, 4),
                     "refine_wall": round(pr.stats.refine_wall, 4),
                     "overlap_wall": 0.0,
                     "total_wall": round(barrier_total, 4)})

        # -- stream: pump refines chunks while the engine produces ---------
        t_first = [None]

        def tap(stream, t0):
            for ch in stream:
                if t_first[0] is None:
                    t_first[0] = time.perf_counter() - t0
                yield ch

        pump = RefinementPump(_refine_fn(per_pair_s),
                              batch_pairs=_BATCH_PAIRS, max_queue_chunks=4)
        t0 = time.perf_counter()
        stream = get_engine(ename, **opts).evaluate_stream(
            feats, clauses, thetas)
        pr = pump.run(tap(stream, t0))
        stream_total = time.perf_counter() - t0
        totals["stream"] += stream_total
        assert sorted(pr.candidates) == res.candidates, \
            f"stream/batch candidate mismatch on {ename}"
        rows.append({"engine": ename, "mode": "stream",
                     "candidates": len(pr.candidates),
                     "t_first_s": round(t_first[0], 4),
                     "step2_wall": round(pr.stats.step2_wall, 4),
                     "refine_wall": round(pr.stats.refine_wall, 4),
                     "overlap_wall": round(pr.stats.overlap_wall, 4),
                     "total_wall": round(stream_total, 4)})

        for row in rows[-2:]:
            print(f"pipeline,{row['engine']},{row['mode']},"
                  f"candidates={row['candidates']},"
                  f"t_first_s={row['t_first_s']},"
                  f"step2_wall={row['step2_wall']},"
                  f"refine_wall={row['refine_wall']},"
                  f"overlap_wall={row['overlap_wall']},"
                  f"total_wall={row['total_wall']}")
        print(f"pipeline,{ename},speedup,"
              f"total={barrier_total / max(stream_total, 1e-9):.2f}x,"
              f"t_first={step2 / max(t_first[0], 1e-9):.2f}x")
    print(f"pipeline,ALL,summary,"
          f"stream_total={totals['stream']:.3f},"
          f"barrier_total={totals['barrier']:.3f},"
          f"streaming_wins={totals['stream'] <= totals['barrier']}")
    rows.append({"engine": "ALL", "mode": "summary", **{
        k + "_total": round(v, 4) for k, v in totals.items()}})
    return rows


def main(fast: bool):
    from benchmarks.run import _emit
    rows = run(fast)
    _emit(rows, "pipeline")


if __name__ == "__main__":
    main(fast=True)
