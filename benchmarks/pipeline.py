"""Pipeline regime: streaming candidate→refinement overlap vs the barrier.

For one CI-shaped table, materialize the representative CNF once, then for
each engine run step ②+⑨ two ways through the *same* RefinementPump (same
worker thread, same oracle batching — the only variable is when candidate
chunks become available):

  * **barrier** — ``evaluate()`` to completion, then the pump refines one
    big chunk (the pre-streaming fdj_join shape: total = step2 + refine);
  * **stream**  — ``evaluate_stream()`` chunks land in the pump as the
    engine produces (total → max(step2, refine) as overlap improves).

The oracle here is simulated, so refinement charges dollars but takes no
wall time; to measure *pipeline* behavior we model LLM service latency as
``per_pair_s`` of sleep per refined pair (sized so total refine latency ≈
the engine's own step-② wall — the regime where overlap matters).  Reported
per row:

  * ``t_first_s`` — time to first candidate chunk (barrier: the full
    evaluate wall; the headline latency win of streaming);
  * ``step2_wall`` / ``refine_wall`` / ``overlap_wall`` / ``total_wall``;
  * on stream rows, the engine-internal pipeline split
    (``engine_dispatch_s`` / ``engine_pull_s`` / ``engine_overlap_s``).

The regime then A/Bs the sharded engine's **double-buffered band loop**
(DESIGN.md §3) against the forced-serial loop on a larger corpus, both
through an identical pump: with double buffering, step k+1's kernel runs
while the pump refines chunk k, so the engine's host-observed busy time
(dispatch + pull walls) must come out strictly below the serial run's —
asserted here, and the ``overlap_s`` baseline field lets ``run.py
--check-against`` catch the pipeline silently degrading to serial.

Usage:  PYTHONPATH=src python -m benchmarks.run --fast --only pipeline
"""

from __future__ import annotations

import time

from repro.core.costs import CostLedger
from repro.core.refine import RefinementPump
from repro.data import synth
from repro.data.cnf_fixtures import representative_cnf
from repro.data.simulated_llm import SimulatedExtractor
from repro.engine import get_engine

# small tiles/blocks: many chunks on the CI shape, interpret-mode tractable
_CPU_OPTS = {
    "numpy": dict(block=32),
    "pallas": dict(tl=32, tr=64, l_block=32),
    "sharded": dict(tl=32, tr=32, r_chunk=64),
}
_BATCH_PAIRS = 128


def _refine_fn(per_pair_s: float):
    def refine(batch):
        time.sleep(per_pair_s * len(batch))   # modeled LLM service latency
        return set(batch)
    return refine


def run(fast: bool = True):
    n = 50 if fast else 100
    ds = synth.police_records(n_incidents=n, reports_per_incident=2, seed=0)
    ext = SimulatedExtractor(ds)
    specs, clauses, thetas = representative_cnf(ds)
    feats = ext.materialize(specs, CostLedger())

    rows = []
    totals = {"barrier": 0.0, "stream": 0.0}
    for ename in ("numpy", "pallas", "sharded"):
        opts = _CPU_OPTS[ename]
        # warm the jit/program caches so neither mode pays compile time
        warm = get_engine(ename, **opts).evaluate(feats, clauses, thetas)
        n_cands = warm.stats.n_candidates

        # size refine latency to the engine's own step-② wall: the regime
        # where pipelining matters (capped so the numpy path stays fast)
        per_pair_s = min(max(warm.stats.wall_s, 0.25) / max(n_cands, 1), 2e-3)

        # -- barrier: evaluate to completion, then pump one big chunk ------
        pump = RefinementPump(_refine_fn(per_pair_s),
                              batch_pairs=_BATCH_PAIRS, max_queue_chunks=4)
        t0 = time.perf_counter()
        res = get_engine(ename, **opts).evaluate(feats, clauses, thetas)
        step2 = time.perf_counter() - t0
        from repro.engine.base import CandidateChunk
        pr = pump.run(iter([CandidateChunk(res.candidates, res.stats, 0)]))
        barrier_total = time.perf_counter() - t0
        totals["barrier"] += barrier_total
        rows.append({"engine": ename, "mode": "barrier",
                     "candidates": n_cands, "t_first_s": round(step2, 4),
                     "step2_wall": round(step2, 4),
                     "refine_wall": round(pr.stats.refine_wall, 4),
                     "overlap_wall": 0.0,
                     "total_wall": round(barrier_total, 4)})

        # -- stream: pump refines chunks while the engine produces ---------
        t_first = [None]

        def tap(stream, t0):
            for ch in stream:
                if t_first[0] is None:
                    t_first[0] = time.perf_counter() - t0
                yield ch

        pump = RefinementPump(_refine_fn(per_pair_s),
                              batch_pairs=_BATCH_PAIRS, max_queue_chunks=4)
        t0 = time.perf_counter()
        stream = get_engine(ename, **opts).evaluate_stream(
            feats, clauses, thetas)
        pr = pump.run(tap(stream, t0))
        stream_total = time.perf_counter() - t0
        totals["stream"] += stream_total
        assert sorted(pr.candidates) == res.candidates, \
            f"stream/batch candidate mismatch on {ename}"
        rows.append({"engine": ename, "mode": "stream",
                     "candidates": len(pr.candidates),
                     "t_first_s": round(t_first[0], 4),
                     "step2_wall": round(pr.stats.step2_wall, 4),
                     "refine_wall": round(pr.stats.refine_wall, 4),
                     "overlap_wall": round(pr.stats.overlap_wall, 4),
                     "total_wall": round(stream_total, 4),
                     "engine_dispatch_s": round(pr.stats.engine_dispatch_s, 4),
                     "engine_pull_s": round(pr.stats.engine_pull_s, 4),
                     "engine_overlap_s": round(pr.stats.engine_overlap_s, 4),
                     "conjunct_evals": pr.stats.engine_conjunct_evals,
                     "flops_per_candidate": round(
                         pr.stats.engine_conjunct_evals
                         / max(len(pr.candidates), 1), 2)})

        for row in rows[-2:]:
            print(f"pipeline,{row['engine']},{row['mode']},"
                  f"candidates={row['candidates']},"
                  f"t_first_s={row['t_first_s']},"
                  f"step2_wall={row['step2_wall']},"
                  f"refine_wall={row['refine_wall']},"
                  f"overlap_wall={row['overlap_wall']},"
                  f"total_wall={row['total_wall']}")
        print(f"pipeline,{ename},speedup,"
              f"total={barrier_total / max(stream_total, 1e-9):.2f}x,"
              f"t_first={step2 / max(t_first[0], 1e-9):.2f}x")
    print(f"pipeline,ALL,summary,"
          f"stream_total={totals['stream']:.3f},"
          f"barrier_total={totals['barrier']:.3f},"
          f"streaming_wins={totals['stream'] <= totals['barrier']}")
    rows.append({"engine": "ALL", "mode": "summary", **{
        k + "_total": round(v, 4) for k, v in totals.items()}})
    rows.append(run_double_buffer_ab(fast))
    rows.extend(run_conjunct_order_ab())
    return rows


def _skewed_selectivity_fixture():
    """33 x 128, 2-clause CNF with skewed selectivity: the clause listed
    first passes every pair, the second matches only R band [64, 96) —
    the regime where selectivity ordering + the conjunct short-circuit
    pay (3 of 4 r_chunk=32 bands die after one conjunct when the banded
    clause is evaluated first)."""
    from repro.core.featurize import FeaturizationSpec, vectorize
    n_l, n_r = 33, 128
    tag = FeaturizationSpec("tag", "", "word_overlap", "llm", "tag")
    name = FeaturizationSpec("name", "", "word_overlap", "llm", "name")
    feats = [vectorize(tag, ["x"] * n_l, ["x"] * n_r),
             vectorize(name, ["same text"] * n_l,
                       ["zzz yyy"] * 64 + ["same text"] * 32
                       + ["zzz yyy"] * 32)]
    return feats, [[0], [1]], [0.5, 0.25]


def run_conjunct_order_ab() -> list:
    """Ordered short-circuit vs unordered full width, per backend.

    Acceptance (the ISSUE's headline property, CI-gated through the
    ``flops_per_candidate`` ceiling in the committed baseline): on the
    skewed-selectivity regime every backend returns the *identical*
    candidate set while the ordered + early-reject arm charges strictly
    fewer ``conjunct_evals`` than the full-width control.
    """
    import numpy as np
    from repro.core.join import apply_conjunct_order
    from repro.core.scaffold import ordered_conjuncts

    feats, clauses, thetas = _skewed_selectivity_fixture()
    # what the plan measures for free on S': the banded clause goes first
    cd = np.array([[0.0, 1.0]] * 6 + [[0.0, 0.0]] * 2)
    order = ordered_conjuncts(cd, np.asarray(thetas, float), clauses)
    assert order == [1, 0], f"skew fixture mis-ordered: {order}"
    oc, ot = apply_conjunct_order(clauses, np.asarray(thetas, float), order)

    opts = {"numpy": dict(block=32),
            "pallas": dict(tl=32, tr=64, l_block=32),
            "sharded": dict(tl=32, tr=32, r_chunk=32, capacity=2048)}
    rows = []
    for ename in ("numpy", "pallas", "sharded"):
        full = get_engine(ename, early_reject=False, **opts[ename]).evaluate(
            feats, clauses, thetas)
        ordered = get_engine(ename, **opts[ename]).evaluate(
            feats, oc, list(ot))
        assert ordered.candidates == full.candidates, (
            f"conjunct order changed the candidate set on {ename}")
        assert 0 < ordered.stats.conjunct_evals < full.stats.conjunct_evals, (
            f"short-circuit saved nothing on {ename}: "
            f"{ordered.stats.conjunct_evals} vs {full.stats.conjunct_evals}")
        row = {"engine": ename, "mode": "conjunct_order_ab",
               "candidates": ordered.stats.n_candidates,
               "conjunct_evals": ordered.stats.conjunct_evals,
               "full_width_evals": full.stats.conjunct_evals,
               "flops_per_candidate": round(
                   ordered.stats.flops_per_candidate, 2),
               "full_flops_per_candidate": round(
                   full.stats.flops_per_candidate, 2),
               "evals_saved_pct": round(
                   100.0 * (1 - ordered.stats.conjunct_evals
                            / full.stats.conjunct_evals), 1)}
        rows.append(row)
        print(f"pipeline,{ename},conjunct_order_ab,"
              f"candidates={row['candidates']},"
              f"conjunct_evals={row['conjunct_evals']},"
              f"full_width_evals={row['full_width_evals']},"
              f"flops_per_candidate={row['flops_per_candidate']},"
              f"evals_saved_pct={row['evals_saved_pct']}")
    return rows


def run_double_buffer_ab(fast: bool = True) -> dict:
    """Sharded double-buffered vs forced-serial band loop, same pump.

    The corpus is sized so the R sweep takes several band steps (the
    regime where the pipeline matters); refine latency is per-pair sleep
    as above.  Acceptance (CI, via the committed baseline): the
    double-buffered run's engine busy wall — the serial sum of its
    dispatch + pull walls — is strictly below the serial run's, and its
    ``overlap_s`` stays well clear of 0.
    """
    n = 100 if fast else 200
    ds = synth.police_records(n_incidents=n, reports_per_incident=2, seed=0)
    ext = SimulatedExtractor(ds)
    specs, clauses, thetas = representative_cnf(ds)
    feats = ext.materialize(specs, CostLedger())
    opts = dict(tl=32, tr=32, r_chunk=32)      # ~7 band steps at n=100

    out = {"engine": "sharded", "mode": "double_buffer_ab"}
    oracle = get_engine("numpy", block=2048).evaluate(feats, clauses, thetas)
    per_pair_s = min(0.25 / max(oracle.stats.n_candidates, 1), 2e-3)

    def arm(label, db):
        # warm the program cache so neither arm pays compile time
        get_engine("sharded", **opts, double_buffer=db).evaluate(
            feats, clauses, thetas)
        eng = get_engine("sharded", **opts, double_buffer=db)
        pump = RefinementPump(_refine_fn(per_pair_s),
                              batch_pairs=_BATCH_PAIRS, max_queue_chunks=2)
        t0 = time.perf_counter()
        pr = pump.run(eng.evaluate_stream(feats, clauses, thetas))
        total = time.perf_counter() - t0
        assert sorted(pr.candidates) == oracle.candidates, \
            f"double-buffer A/B ({label}) diverged from numpy"
        es = pr.engine_stats
        out[f"{label}_busy_s"] = round(es.dispatch_wall_s + es.pull_wall_s, 4)
        out[f"{label}_total_wall"] = round(total, 4)
        out[f"{label}_overlap_s"] = round(es.overlap_s, 4)
        out["candidates"] = len(pr.candidates)

    # the busy comparison is two host wall timings tens of ms apart, so a
    # scheduler hiccup on a loaded CI box could invert a single-shot
    # measurement: best-of-2 per arm before the strict assert (the
    # *deterministic* degradation signal is the overlap_s floor, which no
    # amount of machine noise can fake — serial scores exactly 0)
    for attempt in range(2):
        for label, db in (("db", True), ("serial", False)):
            arm(label, db)
        assert out["serial_overlap_s"] == 0.0, \
            "forced-serial band loop reported overlap"
        assert out["db_overlap_s"] > 0.0, \
            "double-buffered band loop reported zero overlap"
        if out["db_busy_s"] < out["serial_busy_s"]:
            break
    # the headline claim: overlapped engine wall strictly below the
    # serial sum of dispatch + pull walls
    assert out["db_busy_s"] < out["serial_busy_s"], (
        f"double buffering did not beat the serial loop: "
        f"{out['db_busy_s']}s vs {out['serial_busy_s']}s")
    print(f"pipeline,sharded,double_buffer_ab,"
          f"db_busy_s={out['db_busy_s']},"
          f"serial_busy_s={out['serial_busy_s']},"
          f"db_overlap_s={out['db_overlap_s']},"
          f"db_total={out['db_total_wall']},"
          f"serial_total={out['serial_total_wall']}")
    return out


def main(fast: bool):
    from benchmarks.run import _emit
    rows = run(fast)
    _emit(rows, "pipeline")


if __name__ == "__main__":
    main(fast=True)
