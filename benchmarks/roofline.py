import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + \
    " --xla_force_host_platform_device_count=512"

"""Roofline analysis (assignment deliverable g).

Per (arch x shape) on the single-pod mesh:
  compute term    = HLO_FLOPs/device / 197 TFLOP/s (bf16, v5e)
  memory term     = HLO_bytes/device / 819 GB/s HBM
  collective term = collective_bytes/device / 50 GB/s link

XLA's cost_analysis counts a while-loop (scan) body ONCE, so raw numbers
undercount scanned stacks ~n_periods-fold.  We correct by Δ-extrapolation:
lower the same cell unrolled with prefix+1 and prefix+2 periods; the
difference is one period's true cost; corrected = raw + (n_periods−1)·Δ.
Collective bytes are already trip-count-corrected by the HLO parser.

MODEL_FLOPS = 6·N_active·D (train) / 2·N_active·D (inference) — the
useful-work yardstick; ratio MODEL_FLOPS/HLO_FLOPs exposes remat/padding/
redundancy waste.

  PYTHONPATH=src python -m benchmarks.roofline [--cells arch:shape,...]
"""

import argparse
import json

PEAK_FLOPS = 197e12          # bf16 / chip (TPU v5e)
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / link (ICI)

RESULTS = os.path.join(os.path.dirname(__file__), "results")
DRYRUN = os.path.join(RESULTS, "dryrun", "single")


def _delta_costs(arch: str, shape: str, mesh) -> tuple:
    """(flops_delta, bytes_delta) for ONE scanned period, by unroll diff."""
    from repro.configs import get_config
    from repro.launch.cells import build_cell
    from repro.models.transformer import plan_segments
    cfg = get_config(arch)
    segs = plan_segments(cfg)
    if segs.n_periods <= 1:
        return 0.0, 0.0, 1
    pre, per = len(segs.prefix), len(segs.period)
    out = []
    for k in (1, 2):
        cell = build_cell(arch, shape, mesh, layers_override=pre + k * per,
                          scan_override=False)
        cost = cell.fn.lower(*cell.abstract_args).compile().cost_analysis()
        out.append((cost.get("flops", 0.0), cost.get("bytes accessed", 0.0)))
    return out[1][0] - out[0][0], out[1][1] - out[0][1], segs.n_periods


def _model_flops_per_device(arch: str, shape: str, n_chips: int) -> float:
    from repro.common.config import SHAPES
    from repro.configs import get_config
    from repro.models.transformer import active_param_count
    cfg = get_config(arch)
    sc = SHAPES[shape]
    n_active = active_param_count(cfg)
    if sc.kind == "train":
        tokens = sc.global_batch * sc.seq_len
        total = 6.0 * n_active * tokens
    elif sc.kind == "prefill":
        tokens = sc.global_batch * sc.seq_len
        total = 2.0 * n_active * tokens
    else:
        total = 2.0 * n_active * sc.global_batch     # one token per sequence
    return total / n_chips


def analyze_cell(arch: str, shape: str, *, correct_scan: bool = True) -> dict:
    from repro.launch.mesh import make_production_mesh
    path = os.path.join(DRYRUN, f"{arch}__{shape}.json")
    with open(path) as f:
        rec = json.load(f)
    if rec["status"] != "ok":
        return {"arch": arch, "shape": shape, "status": rec["status"],
                "reason": rec.get("reason", rec.get("error", ""))}
    mesh = make_production_mesh()
    n_chips = 256
    flops = rec["flops_per_device"]
    byts = rec["bytes_per_device"]
    if correct_scan:
        df, db, n_per = _delta_costs(arch, shape, mesh)
        flops = flops + (n_per - 1) * df
        byts = byts + (n_per - 1) * db
    coll = rec["collective_bytes_per_device"]
    t_c = flops / PEAK_FLOPS
    t_m = byts / HBM_BW
    t_x = coll / LINK_BW
    dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))[1]
    mf = _model_flops_per_device(arch, shape, n_chips)
    out = {
        "arch": arch, "shape": shape, "status": "ok",
        "flops_per_device": flops, "bytes_per_device": byts,
        "collective_bytes_per_device": coll,
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "dominant": dom,
        "model_flops_per_device": mf,
        "useful_ratio": mf / max(flops, 1.0),
        "roofline_fraction": t_c / max(t_c, t_m, t_x),
        "temp_bytes": rec["memory"]["temp_bytes"],
    }
    return out


def suggestion(row: dict) -> str:
    if row.get("status") != "ok":
        return ""
    d = row["dominant"]
    if d == "collective":
        return ("cast params to compute dtype before FSDP gather; "
                "reduce-scatter gradients instead of all-reduce")
    if d == "memory":
        if row["shape"].startswith("decode"):
            return "KV/state-cache bytes dominate: quantize cache, batch wider"
        return "fuse attention (blockwise) to avoid S^2 score materialization"
    return "compute-bound: raise MXU utilization (tile alignment, bf16 accum)"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cells", default="")
    ap.add_argument("--no-correct", action="store_true")
    args = ap.parse_args()
    from repro.common.config import SHAPES
    from repro.configs import ARCH_IDS
    cells = ([tuple(c.split(":")) for c in args.cells.split(",") if c]
             or [(a, s) for a in ARCH_IDS for s in SHAPES])
    rows = []
    hdr = ("arch,shape,compute_s,memory_s,collective_s,dominant,"
           "useful_ratio,roofline_fraction")
    print(hdr)
    for arch, shape in cells:
        try:
            row = analyze_cell(arch, shape, correct_scan=not args.no_correct)
        except FileNotFoundError:
            continue
        rows.append(row)
        if row["status"] != "ok":
            print(f"{arch},{shape},SKIP({row['reason'][:40]})")
            continue
        row["suggestion"] = suggestion(row)
        print(f"{arch},{shape},{row['compute_s']:.3f},{row['memory_s']:.3f},"
              f"{row['collective_s']:.3f},{row['dominant']},"
              f"{row['useful_ratio']:.2f},{row['roofline_fraction']:.2f}")
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "roofline.json"), "w") as f:
        json.dump(rows, f, indent=1)
    print(f"# wrote {len(rows)} rows to results/roofline.json")


if __name__ == "__main__":
    main()
