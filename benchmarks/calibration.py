"""Calibration regime: the recall guarantee as a *serving-time* invariant.

The delta-join contract (DESIGN.md §4) carries a cached plan's theta
forward across appends on the assumption that appended rows match the
distribution the plan was calibrated on.  This regime scripts the case
where that assumption breaks — the held-out delta rows are perturbed
(``perturb_rows``: junk tokens inflate token-overlap / embed distances
for the appended rows only) — and measures observed recall through the
serving path in three phases per dataset:

  * **cold**     — first query, plan freshly calibrated: the plan-time
    guarantee, recall >= T expected;
  * **shifted**  — query after the perturbed append with online
    recalibration ON (the default): the reservoir refresh must detect
    the broken invariant, re-sweep theta on device, and restore
    recall >= T.  This is the acceptance gate — the row asserts it;
  * **control**  — same append stream with ``recalibrate=False``: the
    historical carry-forward behavior, demonstrating the guarantee
    silently voids without recalibration (recall typically < T).

Reported per row: observed recall vs target, recalibration counters
(checks run, theta hot-swaps, summed theta drift) and the reservoir
labeling dollars that keeping the guarantee live cost.  Under
``--check-against`` the recall column is gated as a *floor* alongside
the wall/dollar bands — a fresh run whose shifted-phase recall drops
below the committed baseline fails CI even if it got faster.

Usage:  PYTHONPATH=src python -m benchmarks.run --fast --only calibration
"""

from __future__ import annotations

import time

from repro.core.join import FDJConfig
from repro.data import synth
from repro.serving.join_service import (JoinService, hold_out_right,
                                        perturb_rows)


def _gen(fast: bool):
    n = 30 if fast else 60
    return {
        # embed-only planes: the shift is purely distributional
        "movies": lambda: synth.movies_pages(
            n_movies=n, cast_size=4, filler_sentences=1, seed=3),
        # scalar date plane: appends can also rescale normalization
        "police_records": lambda: synth.police_records(
            n_incidents=n, reports_per_incident=2, seed=3),
    }


def _row(dataset, phase, r, target, t0):
    led = r.cost
    return {
        "dataset": dataset, "phase": phase,
        "recall": round(r.join.recall, 4), "recall_target": target,
        "met_target": bool(r.join.recall >= target - 1e-12),
        "wall_s": round(time.perf_counter() - t0, 4),
        "recalibrations": led.recalibrations,
        "theta_swaps": led.theta_swaps,
        "theta_drift": round(led.theta_drift, 4),
        "reservoir_cost": led.reservoir_cost,
        "delta_rows": r.delta_rows,
        "pairs": len(r.pairs),
    }


def run(fast: bool = True):
    rows = []
    target = 0.9
    for name, mk in _gen(fast).items():
        ds = mk()
        base, delta = hold_out_right(ds, n_delta=ds.n_r // 4)
        shifted = perturb_rows(delta, seed=1)
        cfg = FDJConfig(engine="numpy", recall_target=target, seed=0,
                        mc_trials=4000 if fast else 8000)

        svc = JoinService(base, cfg)
        t0 = time.perf_counter()
        cold = svc.query()
        rows.append(_row(name, "cold", cold, target, t0))

        svc.append_right(shifted)
        t0 = time.perf_counter()
        post = svc.query()
        rows.append(_row(name, "shifted", post, target, t0))

        # control: identical stream, recalibration gated off
        ctl = JoinService(base, FDJConfig(engine="numpy",
                                          recall_target=target, seed=0,
                                          mc_trials=cfg.mc_trials,
                                          recalibrate=False))
        ctl.query()
        ctl.append_right(shifted)
        t0 = time.perf_counter()
        drifted = ctl.query()
        rows.append(_row(name, "control", drifted, target, t0))

        for row in rows[-3:]:
            print(f"calibration,{row['dataset']},{row['phase']},"
                  f"recall={row['recall']},met={row['met_target']},"
                  f"swaps={row['theta_swaps']},"
                  f"drift={row['theta_drift']},"
                  f"reservoir=${row['reservoir_cost']:.4f}")
        # --- acceptance gate: recalibration keeps the guarantee live ------
        assert post.join.recall >= target - 1e-12, \
            f"{name}: recalibrated serving recall {post.join.recall} " \
            f"fell below the target {target} after the scripted shift"
        assert post.cost.recalibrations >= 1, \
            f"{name}: the post-append query never ran a recalibration check"
    return rows


def main(fast: bool):
    from benchmarks.run import _emit
    rows = run(fast)
    _emit(rows, "calibration")


if __name__ == "__main__":
    main(fast=True)
