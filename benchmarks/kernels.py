"""Kernel benchmark: fused CNF-join vs unfused XLA reference.

CPU container ⇒ no wall-clock TPU numbers; instead we compare the HBM
traffic and FLOPs of (a) the unfused XLA lowering (``ref.cnf_join_ref`` via
``.lower().compile().cost_analysis()``) against (b) the fused kernel's
analytic traffic model (each operand block is read once per grid step, the
packed bitmask written once — the quantities the BlockSpecs pin down).

Derived column: traffic reduction factor — the §Perf headline for the
paper-technique cell.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.fused_cnf_join import ref as cref
from repro.kernels.fused_cnf_join.kernel import VEC


def analyze(n: int, f_vec: int, d: int, tl: int, tr: int):
    clauses = tuple(((VEC, i),) for i in range(f_vec))
    thetas = tuple(0.4 for _ in range(f_vec))
    el = jnp.zeros((f_vec, n, d), jnp.float32)
    er = jnp.zeros((f_vec, n, d), jnp.float32)
    sl = jnp.zeros((1, n), jnp.float32)
    sr = jnp.zeros((1, n), jnp.float32)

    def unfused(el, er, sl, sr):
        ok = cref.cnf_join_ref(el, er, sl, sr, clauses, thetas)
        return cref.pack_mask(ok)

    lowered = jax.jit(unfused).lower(el, er, sl, sr)
    cost = lowered.compile().cost_analysis()
    ref_bytes = cost.get("bytes accessed", 0.0)
    ref_flops = cost.get("flops", 0.0)

    # fused kernel traffic model (reads per grid step x steps + output)
    steps_i, steps_j = n // tl, n // tr
    k_bytes = 4 * (f_vec * tl * d * steps_i * steps_j          # emb_l blocks
                   + f_vec * tr * d * steps_i * steps_j)       # emb_r blocks
    k_bytes += n * (n // 32) * 4                               # packed out
    k_flops = 2.0 * f_vec * n * n * d                          # MXU dots
    return ref_bytes, ref_flops, k_bytes, k_flops


def main(fast: bool = False) -> None:
    print("# kernels: fused CNF-join traffic vs unfused XLA reference")
    print("name,bytes_unfused,bytes_fused,traffic_reduction,flops")
    shapes = [(2048, 2, 128, 256, 512), (4096, 4, 128, 256, 512)]
    if not fast:
        shapes.append((8192, 6, 256, 256, 512))
    for n, f, d, tl, tr in shapes:
        rb, rf, kb, kf = analyze(n, f, d, tl, tr)
        print(f"cnf_join_n{n}_f{f}_d{d},{rb:.3e},{kb:.3e},{rb/max(kb,1):.2f}x,{kf:.3e}")


if __name__ == "__main__":
    main()
