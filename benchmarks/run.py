"""Benchmark harness — one function per paper table/figure.

Usage:  PYTHONPATH=src python -m benchmarks.run [--only table3,fig10] [--fast]

Prints ``name,value,derived`` CSV lines and writes JSON artifacts to
benchmarks/results/.  --fast shrinks datasets/trials for CI-style runs
(the default sizes reproduce the paper's regimes; see DESIGN.md §6).

``--check-against DIR`` is the CI regression gate: after the requested
regimes run, each fresh ``benchmarks/results/<regime>.json`` is compared
row by row against the committed baseline ``DIR/<regime>.json``
(``benchmarks/baseline/`` in the tree, regenerated with
``--fast`` + copy when a change legitimately moves the numbers).  Wall
seconds get a wide band (machines differ); transfer bytes and dollars get
a tight one; counts and agreement flags must match exactly.  Any
regression prints a ``regression,...`` line and the process exits nonzero
— ``scripts/ci.sh`` runs the engines/pipeline/serving regimes through
this gate, so a PR cannot silently slow an engine or re-inflate the warm
serving path.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from benchmarks import baselines as bl
from repro.data import synth

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def _datasets(fast: bool):
    s = 0.35 if fast else 0.7
    return {
        "citations": lambda: synth.citations(n_docs=int(1200 * s)),
        "police_records": lambda: synth.police_records(
            n_incidents=int(400 * s), reports_per_incident=3),
        "categorize": lambda: synth.categorize(n_items=int(2500 * s)),
        "biodex": lambda: synth.biodex(n_notes=int(2000 * s)),
        "movies": lambda: synth.movies_pages(n_movies=int(500 * s)),
        "products": lambda: synth.products(n_products=int(800 * s)),
    }


def _emit(rows, name):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(rows, f, indent=1, default=str)


def table2_guarantees(fast: bool):
    """Table 2: observed recall + failure rate, T=90%, delta=10%."""
    print("# table2: avg recall and failure rate over trials (biodex analogue)")
    trials = 8 if fast else 15
    rows = []
    for method, fn in [("SUPG(LOTUS)", bl.run_supg),
                       ("BARGAIN", bl.run_bargain),
                       ("FDJ", bl.run_fdj)]:
        recalls, fails = [], 0
        for t in range(trials):
            ds = synth.biodex(n_notes=400 if fast else 700, n_terms=60, seed=t)
            r = fn(ds, target=0.9, seed=t)
            recalls.append(r["recall"])
            fails += int(r["recall"] < 0.9)
        row = {"method": method, "avg_recall": float(np.mean(recalls)),
               "pct_failed": 100.0 * fails / trials, "trials": trials}
        rows.append(row)
        print(f"table2,{method},avg_recall={row['avg_recall']:.3f},"
              f"pct_failed={row['pct_failed']:.0f}")
    _emit(rows, "table2")


def table3_cost_ratio(fast: bool):
    """Table 3: cost ratio (%) at T=90% across the 6 dataset analogues."""
    print("# table3: cost ratio (% of naive) at T=0.9")
    rows = []
    for name, mk in _datasets(fast).items():
        for method, fn in [("BARGAIN", bl.run_bargain), ("FDJ", bl.run_fdj),
                           ("optimal_cascade", bl.run_optimal_cascade)]:
            ds = mk()
            t0 = time.time()
            r = fn(ds) if method != "optimal_cascade" else fn(ds, target=0.9)
            row = {"dataset": name, "method": method,
                   "cost_ratio_pct": 100 * r["cost_ratio"],
                   "recall": r["recall"], "precision": r["precision"],
                   "wall_s": time.time() - t0}
            rows.append(row)
            print(f"table3,{name},{method},cost_ratio_pct={row['cost_ratio_pct']:.1f},"
                  f"recall={r['recall']:.3f}")
    _emit(rows, "table3")


def fig7_datasize(fast: bool):
    """Fig 7: cost ratio vs |L| (police analogue)."""
    print("# fig7: cost ratio vs data size")
    sizes = [100, 200, 400] if fast else [100, 200, 400, 600]
    rows = []
    for n in sizes:
        ds = synth.police_records(n_incidents=n, reports_per_incident=3)
        for method, fn in [("BARGAIN", bl.run_bargain), ("FDJ", bl.run_fdj)]:
            r = fn(ds)
            rows.append({"n_records": ds.n_l, "method": method,
                         "cost_ratio_pct": 100 * r["cost_ratio"],
                         "recall": r["recall"]})
            print(f"fig7,n={ds.n_l},{method},cost_ratio_pct={100*r['cost_ratio']:.1f}")
    _emit(rows, "fig7")


def fig8_targets(fast: bool):
    """Fig 8: cost ratio vs recall target (one dataset per category)."""
    print("# fig8: cost ratio vs recall target")
    targets = [0.8, 0.9] if fast else [0.75, 0.8, 0.85, 0.9, 0.95]
    gens = {"movies": lambda: synth.movies_pages(n_movies=250 if fast else 400),
            "police_records": lambda: synth.police_records(
                n_incidents=150 if fast else 300, reports_per_incident=3),
            "categorize": lambda: synth.categorize(n_items=600 if fast else 1200)}
    rows = []
    for dname, mk in gens.items():
        for t in targets:
            for method, fn in [("BARGAIN", bl.run_bargain), ("FDJ", bl.run_fdj)]:
                ds = mk()
                r = fn(ds, target=t)
                rows.append({"dataset": dname, "target": t, "method": method,
                             "cost_ratio_pct": 100 * r["cost_ratio"],
                             "recall": r["recall"]})
                print(f"fig8,{dname},T={t},{method},"
                      f"cost_ratio_pct={100*r['cost_ratio']:.1f},recall={r['recall']:.3f}")
    _emit(rows, "fig8")


def fig9_breakdown(fast: bool):
    """Fig 9: FDJ cost breakdown across datasets and targets."""
    print("# fig9: FDJ cost breakdown (percent of naive cost)")
    targets = [0.8, 0.9] if fast else [0.8, 0.9, 0.95]
    rows = []
    for name, mk in list(_datasets(fast).items()):
        for t in targets:
            ds = mk()
            r = bl.run_fdj(ds, target=t)
            row = {"dataset": name, "target": t,
                   **{k: 100 * v for k, v in r["breakdown"].items()},
                   **r.get("serving", {})}
            rows.append(row)
            print(f"fig9,{name},T={t}," + ",".join(
                f"{k}={100*v:.2f}" for k, v in r["breakdown"].items())
                + "," + ",".join(f"{k}={v}"
                                 for k, v in r.get("serving", {}).items()))
    _emit(rows, "fig9")


def fig10_characteristics(fast: bool):
    """Fig 10: synthetic sweeps — entities per sentence; filler length."""
    print("# fig10: data-characteristic sweeps (movie-likes generator)")
    rows = []
    n = 150 if fast else 300
    for p in ([1, 3] if fast else [1, 2, 3, 4]):
        ds = synth.movie_likes(n=n, persons_per_sentence=p, filler_sentences=1)
        for method, fn in [("FDJ", bl.run_fdj),
                           ("optimal_cascade", bl.run_optimal_cascade)]:
            r = fn(ds)
            rows.append({"sweep": "persons", "value": p, "method": method,
                         "cost_ratio_pct": 100 * r["cost_ratio"],
                         "recall": r["recall"]})
            print(f"fig10,persons={p},{method},cost_ratio_pct={100*r['cost_ratio']:.1f}")
    for f in ([0, 4] if fast else [0, 2, 4, 8]):
        ds = synth.movie_likes(n=n, persons_per_sentence=1, filler_sentences=f)
        for method, fn in [("FDJ", bl.run_fdj),
                           ("optimal_cascade", bl.run_optimal_cascade)]:
            r = fn(ds)
            rows.append({"sweep": "filler", "value": f, "method": method,
                         "cost_ratio_pct": 100 * r["cost_ratio"],
                         "recall": r["recall"]})
            print(f"fig10,filler={f},{method},cost_ratio_pct={100*r['cost_ratio']:.1f}")
    _emit(rows, "fig10")


def kernel_bench(fast: bool):
    """Systems table: fused CNF kernel vs unfused XLA reference (FLOPs/bytes
    from cost_analysis; see EXPERIMENTS.md §Perf)."""
    from benchmarks import kernels as kb
    kb.main(fast)


def engine_bench(fast: bool):
    """Step-② engine comparison: wall-clock + bytes-to-host per backend
    (numpy / pallas / sharded; see DESIGN.md §6)."""
    from benchmarks import engines as eb
    eb.main(fast)


def pipeline_bench(fast: bool):
    """Streaming candidate→refinement pipeline vs barrier: time-to-first-
    candidate and total wall per backend (see DESIGN.md §6)."""
    from benchmarks import pipeline as pb
    pb.main(fast)


def serving_bench(fast: bool):
    """Join-serving regime: cold vs warm vs delta-append through the
    FeaturePlaneStore — asserts the warm path charges zero extraction and
    moves zero plane bytes to device (see DESIGN.md §4)."""
    from benchmarks import serving as sv
    sv.main(fast)


def calibration_bench(fast: bool):
    """Serving-time guarantee calibration: observed recall through a
    scripted distribution-shifting append stream, with and without online
    reservoir recalibration — asserts the recalibrated path keeps
    recall >= T (see DESIGN.md §4a)."""
    from benchmarks import calibration as cb
    cb.main(fast)


def fleet_bench(fast: bool):
    """Multi-tenant fleet regime: shared-corpus plane/plan dedup across
    tenants, then serial-vs-concurrent query streams through one fleet —
    asserts the second tenant's cold query is free, concurrent aggregate
    wall beats the serial aggregate, and every stream holds its recall
    floor (see DESIGN.md §8a)."""
    from benchmarks import fleet as fl
    fl.main(fast)


ALL = {
    "table2": table2_guarantees,
    "table3": table3_cost_ratio,
    "fig7": fig7_datasize,
    "fig8": fig8_targets,
    "fig9": fig9_breakdown,
    "fig10": fig10_characteristics,
    "kernels": kernel_bench,
    "engines": engine_bench,
    "pipeline": pipeline_bench,
    "serving": serving_bench,
    "calibration": calibration_bench,
    "fleet": fleet_bench,
}


# --- regression gate --------------------------------------------------------
#
# Per-regime row identity + which fields are gated.  Comparison rules are
# derived from the field name: wall seconds get a wide band (CI machines
# vary), transfer bytes / dollars a tight one, counts and flags must match
# exactly.  A baseline row with no fresh counterpart is itself a
# regression (coverage silently lost).

_GATES = {
    "engines": {
        "key": ("table", "engine"),
        "metrics": ("wall_s", "bytes_to_host", "candidates",
                    "agrees_with_numpy", "cross_pod_collective_bytes",
                    "max_cross_pod_op_bytes", "warm_reshard_bytes",
                    "warm_extraction_cost", "overlap_s",
                    "flops_per_candidate"),
    },
    "pipeline": {
        "key": ("engine", "mode"),
        "metrics": ("candidates", "t_first_s", "total_wall",
                    "db_busy_s", "serial_busy_s", "db_overlap_s",
                    "engine_overlap_s", "flops_per_candidate"),
    },
    "serving": {
        "key": ("engine", "mode"),
        "metrics": ("wall_s", "extraction_cost", "bytes_to_device",
                    "bytes_reshard", "pairs", "agrees_with_cold",
                    "recalibrations", "theta_swaps", "reservoir_cost",
                    "p50_wall_s"),
    },
    "calibration": {
        "key": ("dataset", "phase"),
        "metrics": ("recall", "met_target", "wall_s", "recalibrations",
                    "theta_swaps", "reservoir_cost"),
    },
    "fleet": {
        # the dedup row's extraction/H2D/plan dollars are zero baselines
        # (invariants, not measurements); p50/p99 latency ride the wall
        # band; recall is the per-stream floor; counts/flags are exact.
        # speedup_vs_serial is deliberately ungated — it is a ratio of
        # two walls and the in-benchmark assert already enforces > 1.
        "key": ("phase",),
        "metrics": ("wall_s", "extraction_cost", "bytes_to_device",
                    "plan_cost", "dedup_hits", "pairs",
                    "agrees_with_first", "recall",
                    "streams", "queries", "per_query_wall_s",
                    "p50_wall_s", "p99_wall_s", "cost_per_query",
                    "band_steps", "interleaved", "agrees_with_serial"),
    },
}

# (relative factor, absolute slack) — regression iff now > base*rel + abs.
# Walls are the one machine-dependent metric: the committed baselines were
# measured on the dev container, so slower CI runners override the band via
# FDJ_GATE_WALL_BAND="rel,abs" (.github/workflows/ci.yml sets 6.0,30.0);
# bytes/dollars/counts are hardware-independent and stay tight everywhere.
_WALL_BAND = (2.5, 1.0)
_BYTE_BAND = (1.10, 1024)
_COST_BAND = (1.10, 1e-9)


def _wall_band():
    override = os.environ.get("FDJ_GATE_WALL_BAND", "")
    if override:
        rel, slack = override.split(",")
        return (float(rel), float(slack))
    return _WALL_BAND


def _metric_band(field: str):
    """(kind, rel, slack) for banded fields; None = exact match."""
    if field == "recall":
        # a floor, like overlap_s but with tolerance: observed recall is
        # the guarantee itself — a fresh run may beat the baseline freely,
        # but dropping more than the slack below it means the calibration
        # path regressed, regardless of how fast or cheap the run got.
        return ("recall", 0.0, 0.02)
    if field == "flops_per_candidate":
        # a ceiling: (pair, clause) work per emitted candidate.  Dropping
        # below the baseline is free (a better short-circuit); creeping
        # above it means the selectivity ordering / early-reject path
        # silently regressed toward full-width evaluation — a compute
        # regression the wall band on an interpret-mode CPU run would
        # never resolve.
        return ("ceil", 1.10, 0.5)
    if field.endswith("overlap_s"):
        # a floor, not a ceiling: overlap seconds measure whether the
        # double-buffered band loop actually kept a step in flight during
        # host work.  The absolute value is machine-dependent, but the
        # serial loop scores *exactly* 0 by construction — so a nonzero
        # baseline collapsing to 0 means the pipeline silently degraded
        # to serial, a perf regression the wall band alone may miss.
        return ("floor", 0.0, 0.0)
    if "wall" in field or field.endswith("_s"):
        return ("wall",) + _wall_band()
    if "bytes" in field:
        return ("bytes",) + _BYTE_BAND
    if "cost" in field:
        return ("cost",) + _COST_BAND
    return None                       # exact match (counts, flags)


def check_against(baseline_dir: str, regimes, crashed=()) -> list:
    """Compare fresh results to committed baselines; returns regression
    strings (empty = gate passed).  ``crashed`` regimes (requested but
    died before emitting results) are themselves regressions for any
    gated regime — otherwise a crash in non-strict mode would silently
    drop its rows from the comparison and the gate would pass."""
    bad = [f"{name}: regime crashed before emitting results"
           for name in crashed if name in _GATES]
    for name in regimes:
        gate = _GATES.get(name)
        base_path = os.path.join(baseline_dir, f"{name}.json")
        if gate is None or not os.path.exists(base_path):
            continue
        fresh_path = os.path.join(RESULTS_DIR, f"{name}.json")
        if not os.path.exists(fresh_path):
            bad.append(f"{name}: no fresh results to check")
            continue
        with open(base_path) as f:
            base_rows = json.load(f)
        with open(fresh_path) as f:
            fresh = {tuple(r.get(k) for k in gate["key"]): r
                     for r in json.load(f)}
        for brow in base_rows:
            key = tuple(brow.get(k) for k in gate["key"])
            now = fresh.get(key)
            if now is None:
                bad.append(f"{name}{list(key)}: row missing from fresh "
                           f"results (coverage lost)")
                continue
            for field in gate["metrics"]:
                if field not in brow:
                    continue
                b, n = brow[field], now.get(field)
                band = _metric_band(field)
                if band is None:
                    if n != b:
                        bad.append(f"{name}{list(key)}.{field}: "
                                   f"{b!r} -> {n!r} (must match exactly)")
                    continue
                kind, rel, slack = band
                if kind == "recall":
                    if n is None or float(n) < float(b) - slack:
                        bad.append(f"{name}{list(key)}.{field}: {b} -> {n} "
                                   f"(recall floor: may only drop by "
                                   f"{slack})")
                    continue
                if kind == "floor":
                    if float(b) > 0.0 and (n is None or float(n) <= 0.0):
                        bad.append(f"{name}{list(key)}.{field}: {b} -> {n} "
                                   f"(overlap collapsed to 0: pipeline "
                                   f"degraded to the serial loop)")
                    continue
                if kind != "wall" and float(b) == 0.0:
                    # a zero byte/dollar baseline is an invariant (warm
                    # reshard, warm extraction), not a measurement — the
                    # slack would let ~1 KiB of warm traffic creep back in
                    if n is None or float(n) != 0.0:
                        bad.append(f"{name}{list(key)}.{field}: 0 -> {n} "
                                   f"(zero baseline must stay zero)")
                    continue
                if n is None or float(n) > float(b) * rel + slack:
                    bad.append(f"{name}{list(key)}.{field}: {b} -> {n} "
                               f"(band {rel}x + {slack})")
    for msg in bad:
        print(f"regression,{msg}")
    return bad


def _git_sha() -> str:
    """Short HEAD SHA, or "" outside a checkout / without git."""
    import subprocess
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10,
        ).stdout.strip()
    except (OSError, subprocess.SubprocessError):
        return ""


def write_trajectory(pr: str, ran, crashed, run_date: str = "") -> str:
    """Write ``BENCH_<pr>.json`` at the repo root: a per-PR snapshot of
    every regime's fresh rows, so the repo accumulates a perf *history*
    (one artifact per PR) rather than only the latest rolling baseline —
    trajectory regressions ("each PR 5% slower") are invisible to a
    baseline that moves with every merge.

    The header block pins provenance: artifact schema version, the git
    SHA the rows were measured at, the backend list, and the run date —
    passed in by the caller (``--run-date`` / ``$FDJ_RUN_DATE``), never
    sampled here, so re-running the harness against an old checkout
    cannot silently restamp history."""
    from repro.engine import ENGINES
    regimes = {}
    for name in ran:
        path = os.path.join(RESULTS_DIR, f"{name}.json")
        if os.path.exists(path):
            with open(path) as f:
                regimes[name] = json.load(f)
    art = {"schema_version": 1, "pr": pr, "git_sha": _git_sha(),
           "backends": list(ENGINES), "run_date": run_date,
           "regimes_run": list(ran), "regimes_crashed": list(crashed),
           "regimes": regimes}
    out = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), f"BENCH_{pr}.json")
    with open(out, "w") as f:
        json.dump(art, f, indent=1, default=str)
    print(f"# trajectory artifact: {out}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--strict", action="store_true",
                    help="re-raise regime failures (CI gates, e.g. the "
                         "serving warm-path zero-extraction assertion)")
    ap.add_argument("--check-against", default="", metavar="DIR",
                    help="after running, compare fresh results to the "
                         "baseline JSONs in DIR and exit nonzero on any "
                         "perf/cost regression (see module docstring)")
    ap.add_argument("--pr", default=os.environ.get("FDJ_PR", ""),
                    help="PR number/tag: write a BENCH_<pr>.json "
                         "trajectory artifact at the repo root (default: "
                         "$FDJ_PR; empty = skip)")
    ap.add_argument("--run-date", default=os.environ.get("FDJ_RUN_DATE", ""),
                    help="provenance date stamped into the trajectory "
                         "header (default: $FDJ_RUN_DATE; never sampled "
                         "from the clock)")
    args = ap.parse_args()
    only = [s for s in args.only.split(",") if s]
    unknown = [s for s in only if s not in ALL]
    if unknown:
        # a typo'd regime name would otherwise silently skip both the
        # regime and its regression gate while still printing gate OK
        raise SystemExit(
            f"unknown regime(s) {unknown}; choose from {sorted(ALL)}")
    t0 = time.time()
    ran, crashed = [], []
    for name, fn in ALL.items():
        if only and name not in only:
            continue
        try:
            fn(args.fast)
            ran.append(name)
        except Exception as e:  # keep the suite running (unless --strict)
            import traceback
            traceback.print_exc()
            print(f"{name},ERROR,{type(e).__name__}: {e}")
            if args.strict:
                raise
            crashed.append(name)
    print(f"# total wall time: {time.time()-t0:.0f}s")
    if args.pr:
        write_trajectory(args.pr, ran, crashed, run_date=args.run_date)
    if args.check_against:
        bad = check_against(args.check_against, ran, crashed=crashed)
        if bad:
            print(f"# regression gate FAILED: {len(bad)} regression(s) vs "
                  f"{args.check_against}")
            raise SystemExit(2)
        print(f"# regression gate OK vs {args.check_against}")


if __name__ == "__main__":
    main()
