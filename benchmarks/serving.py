"""Serving regime: plane-store amortization across repeated joins.

For each engine, run the same query stream through one ``JoinService``:

  * **cold**  — first query: plans, extracts, uploads (the fdj_join price);
  * **warm**  — identical repeat: plan-cache + plane-store hit.  Gate:
    extraction charges and plane H2D bytes MUST be zero, and pairs must
    equal the cold query's — this is the CI acceptance check
    (``scripts/ci.sh`` runs this regime with ``--strict``);
  * **delta** — append held-out R rows, query again: only L × ΔR is
    extracted/evaluated; reported wall + extraction are the incremental
    price.

Reported per row: wall seconds, extraction dollars, plane-store hit rate,
bytes to device, output-pair agreement with cold.  The warm/cold wall
ratio is the serving win; on this CPU container the absolute walls are
interpret-mode artifacts for the pallas paths, but the *charge* and
*byte* columns are hardware-independent.

Usage:  PYTHONPATH=src python -m benchmarks.run --fast --only serving
"""

from __future__ import annotations

import time

from repro.core.join import FDJConfig
from repro.data import synth
from repro.engine import ENGINES
from repro.serving.join_service import JoinService, hold_out_right

# small tiles keep interpret-mode pallas tractable on the CI shape
_CPU_OPTS = {
    "numpy": dict(block=2048),
    "pallas": dict(tl=32, tr=64),
    "sharded": dict(tl=32, tr=32, r_chunk=64),
}


def _row(name, mode, r, agree):
    st = r.store
    looked = st["hits"] + st["misses"]
    return {
        "engine": name, "mode": mode, "wall_s": round(r.wall_s, 4),
        "extraction_cost": r.cost.inference,
        "plane_hit_rate": round(st["hits"] / looked, 3) if looked else None,
        "bytes_to_device": r.cost.bytes_h2d,
        "bytes_reshard": r.cost.bytes_reshard,
        "plan_hit": r.plan_hit, "delta_rows": r.delta_rows,
        "pairs": len(r.pairs), "recall": round(r.join.recall, 4),
        "agrees_with_cold": agree,
        # guarantee upkeep (DESIGN.md §4a): the delta query runs one
        # reservoir check; on this stable append stream the cached theta
        # must survive it (theta_swaps stays 0 — gated), so the only
        # upkeep price is the reservoir top-up labels
        "recalibrations": r.cost.recalibrations,
        "theta_swaps": r.cost.theta_swaps,
        "theta_drift": round(r.cost.theta_drift, 4),
        "reservoir_cost": r.cost.reservoir_cost,
    }


def run(fast: bool = True):
    # movies: embed-only planes keep the append on the incremental path
    # (a data-dependent scalar scale, as in police_records, can shift on
    # append and force the exact-fallback full re-evaluation instead)
    n = 40 if fast else 90
    ds = synth.movies_pages(n_movies=n, cast_size=4, filler_sentences=1,
                            seed=0)
    base, delta_rows = hold_out_right(ds, n_delta=ds.n_r // 5)
    rows = []
    for ename in ENGINES:
        cfg = FDJConfig(engine=ename, engine_opts=_CPU_OPTS[ename], seed=0,
                        mc_trials=6000)
        svc = JoinService(base, cfg)

        cold = svc.query()
        rows.append(_row(ename, "cold", cold, True))

        warm = svc.query()
        agree = warm.pairs == cold.pairs
        rows.append(_row(ename, "warm", warm, agree))
        # --- acceptance gate: the warm path re-pays nothing ---------------
        assert warm.cost.inference == 0.0, \
            f"warm {ename} query charged ${warm.cost.inference} extraction"
        assert warm.cost.bytes_h2d == 0, \
            f"warm {ename} query moved {warm.cost.bytes_h2d} plane bytes H2D"
        assert warm.cost.bytes_reshard == 0, \
            f"warm {ename} query paid {warm.cost.bytes_reshard} plane " \
            f"reshard bytes (sharded mesh layout must be memoized)"
        assert agree, f"warm {ename} pairs diverge from cold"

        t0 = time.perf_counter()
        info = svc.append_right(delta_rows)
        append_wall = time.perf_counter() - t0
        dq = svc.query()
        drow = _row(ename, "delta", dq, None)
        drow["append_wall_s"] = round(append_wall, 4)
        drow["append_extraction_cost"] = info["ledger"].inference
        rows.append(drow)
        assert dq.delta_rows == len(delta_rows.texts), \
            f"delta {ename} query re-evaluated the full corpus"
        assert dq.cost.recalibrations == 1 and dq.cost.theta_swaps == 0, \
            f"delta {ename}: stable-distribution append must pass the " \
            f"reservoir invariant check without a theta swap " \
            f"(got {dq.cost.recalibrations} checks, " \
            f"{dq.cost.theta_swaps} swaps)"

        for row in rows[-3:]:
            print(f"serving,{row['engine']},{row['mode']},"
                  f"wall_s={row['wall_s']},"
                  f"extraction=${row['extraction_cost']:.4f},"
                  f"hit_rate={row['plane_hit_rate']},"
                  f"bytes_to_device={row['bytes_to_device']},"
                  f"delta_rows={row['delta_rows']},pairs={row['pairs']}")
        cold_w, warm_w = rows[-3]["wall_s"], rows[-2]["wall_s"]
        print(f"serving,{ename},speedup,warm={cold_w / max(warm_w, 1e-9):.1f}x,"
              f"delta_vs_cold={cold_w / max(rows[-1]['wall_s'], 1e-9):.1f}x")

        # per-query latency quantiles from the service's streaming
        # histogram (obs.metrics) — the p50 is gated like any other wall
        hist = svc.metrics.histogram("serve.query_wall_s").summary()
        lrow = {"engine": ename, "mode": "latency",
                "p50_wall_s": round(hist["p50"], 4),
                "p99_wall_s": round(hist["p99"], 4),
                "queries": int(hist["count"])}
        rows.append(lrow)
        print(f"serving,{ename},latency,p50_wall_s={lrow['p50_wall_s']},"
              f"p99_wall_s={lrow['p99_wall_s']},queries={lrow['queries']}")
    return rows


def main(fast: bool):
    from benchmarks.run import _emit
    rows = run(fast)
    _emit(rows, "serving")


if __name__ == "__main__":
    main(fast=True)
