"""Engine-comparison benchmark: step-② backends head to head.

For each synth table, materialize the used featurizations once, then run
the same CNF through every ``repro.engine`` backend and report

  * wall-clock seconds (CPU container: the Pallas paths run in interpret
    mode, so treat their wall numbers as correctness-path overhead, not
    TPU projections — the transfer-byte columns are the portable signal);
  * bytes moved device->host to recover the candidate set;
  * the O(n_l·n_r) boolean-plane size that the sharded backend's
    O(candidates) transfer replaces.

The regime then runs the **multi-pod dry-run** (``launch/multipod_dryrun``
as a subprocess — the XLA device-count override must precede jax init) on
the (2, 16, 16) mesh: pod-axis L sharding with cross-pod collectives
asserted candidate-count sized via ``distributed.hlo_analysis`` and warm
sharded serving asserted at zero plane-reshard bytes.  A failed dry-run
fails the regime (CI gates this via ``run.py --strict``).

Usage:  PYTHONPATH=src python -m benchmarks.run --fast --only engines
"""

from __future__ import annotations


from repro.core.costs import CostLedger
from repro.data import synth
from repro.data.cnf_fixtures import representative_cnf
from repro.data.simulated_llm import SimulatedExtractor
from repro.engine import ENGINES, get_engine

# engine construction options tuned for the CPU container: small tiles keep
# interpret-mode pallas tractable; on TPU the defaults (256/512) apply.
_CPU_OPTS = {
    "numpy": dict(block=2048),
    "pallas": dict(tl=64, tr=64),
    "sharded": dict(tl=32, tr=32, r_chunk=128),
}


def _tables(fast: bool):
    s = 1 if fast else 2
    return {
        "police_records": lambda: synth.police_records(
            n_incidents=60 * s, reports_per_incident=2),
        "citations": lambda: synth.citations(n_docs=150 * s),
    }


def run(fast: bool = True):
    rows = []
    for name, mk in _tables(fast).items():
        ds = mk()
        ext = SimulatedExtractor(ds)
        specs, clauses, thetas = representative_cnf(ds)
        feats = ext.materialize(specs, CostLedger())
        baseline = None
        for ename in ENGINES:
            eng = get_engine(ename, **_CPU_OPTS.get(ename, {}))
            res = eng.evaluate(feats, clauses, thetas)
            if baseline is None:
                baseline = res.candidates
            agree = res.candidates == baseline
            row = {"table": name, "engine": ename, "n_l": res.stats.n_l,
                   "n_r": res.stats.n_r, "candidates": res.stats.n_candidates,
                   "wall_s": round(res.stats.wall_s, 3),
                   "dispatch_wall_s": round(res.stats.dispatch_wall_s, 4),
                   "pull_wall_s": round(res.stats.pull_wall_s, 4),
                   "overlap_s": round(res.stats.overlap_s, 4),
                   "bytes_to_host": res.stats.bytes_to_host,
                   "bytes_reshard": res.stats.bytes_reshard,
                   "plane_bytes": res.stats.plane_bytes,
                   "conjunct_evals": res.stats.conjunct_evals,
                   "flops_per_candidate": round(
                       res.stats.flops_per_candidate, 2),
                   "agrees_with_numpy": agree}
            rows.append(row)
            print(f"engines,{name},{ename},candidates={row['candidates']},"
                  f"bytes_to_host={row['bytes_to_host']},"
                  f"plane_bytes={row['plane_bytes']},wall_s={row['wall_s']},"
                  f"overlap_s={row['overlap_s']},"
                  f"flops_per_candidate={row['flops_per_candidate']},"
                  f"agree={agree}")
            if not agree:
                raise AssertionError(
                    f"engine {ename} disagrees with numpy on {name}")
    rows.extend(run_multipod())
    return rows


def run_multipod(mesh: str = "2,16,16") -> list:
    """The (2, 16, 16) dry-run gate, reported as benchmark rows."""
    from repro.launch.dryrun_client import run_dryrun
    rep = run_dryrun(mesh, timeout=560)
    p, h, s = rep["parity"], rep["hlo"], rep["serving"]
    row = {"table": "multipod_dryrun", "engine": f"sharded@{mesh}",
           "n_l": p["n_l"], "n_r": p["n_r"], "candidates": p["candidates"],
           "wall_s": rep["wall_s"], "bytes_to_host": p["bytes_to_host"],
           "dispatch_wall_s": p["dispatch_wall_s"],
           "pull_wall_s": p["pull_wall_s"],
           "overlap_s": p["overlap_s"],
           "prefetch_depth": p["prefetch_depth"],
           "conjunct_evals": p["conjunct_evals"],
           "flops_per_candidate": p["flops_per_candidate"],
           "plane_bytes": p["plane_bytes"], "agrees_with_numpy": True,
           "cross_pod_collective_bytes": h["cross_pod_bytes"],
           "max_cross_pod_op_bytes": h["max_cross_op_bytes"],
           "cold_reshard_bytes": s["cold_reshard_bytes"],
           "warm_reshard_bytes": s["warm_reshard_bytes"],
           "warm_extraction_cost": s["warm_extraction_cost"]}
    print(f"engines,multipod_dryrun,mesh={mesh},"
          f"candidates={row['candidates']},"
          f"bytes_to_host={row['bytes_to_host']},"
          f"plane_bytes={row['plane_bytes']},"
          f"cross_pod_bytes={row['cross_pod_collective_bytes']},"
          f"warm_reshard_bytes={row['warm_reshard_bytes']},"
          f"overlap_s={row['overlap_s']},"
          f"prefetch_depth={row['prefetch_depth']},"
          f"flops_per_candidate={row['flops_per_candidate']},"
          f"wall_s={row['wall_s']}")
    return [row]


def main(fast: bool):
    from benchmarks.run import _emit
    rows = run(fast)
    _emit(rows, "engines")


if __name__ == "__main__":
    main(fast=True)
