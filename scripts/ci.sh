#!/usr/bin/env bash
# CI gate: tier-1 test subset + a smoke benchmark on one small table.
#
#   tier-1:  python -m pytest -q -m "not slow"     (< 1 minute)
#   smoke:   engine-comparison benchmark, fast sizes (DESIGN.md §5)
#
# The slow suite (system joins, per-arch smoke tests) runs separately:
#   python -m pytest -q -m slow
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: fast test subset =="
python -m pytest -q -m "not slow"

echo "== smoke benchmark: step-2 engines on one small table =="
python -m benchmarks.run --fast --only engines

echo "CI OK"
