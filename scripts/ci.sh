#!/usr/bin/env bash
# CI gate: tier-1 test subset + smoke benchmarks on one small table.
#
#   tier-1:   python -m pytest -q -m "not slow"     (< 1 minute)
#   smoke:    engine-comparison benchmark, fast sizes (DESIGN.md §6)
#   pipeline: streaming-vs-barrier refinement overlap, fast sizes (§6)
#   serving:  plane-store cold/warm/delta regime (§4) — runs --strict and
#             FAILS CI if the warm path reports nonzero extraction charges
#             or nonzero plane H2D bytes
#
# The slow suite (system joins, ≥50-trial guarantee sweep, per-arch smoke
# tests) runs separately:
#   python -m pytest -q -m slow
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: fast test subset =="
python -m pytest -q -m "not slow"

echo "== smoke benchmark: step-2 engines on one small table =="
python -m benchmarks.run --fast --only engines

echo "== smoke benchmark: streaming refinement pipeline =="
python -m benchmarks.run --fast --only pipeline

echo "== smoke benchmark: join-serving plane store (strict warm-path gate) =="
python -m benchmarks.run --fast --strict --only serving

echo "CI OK"
