#!/usr/bin/env bash
# CI gate: lint + tier-1 test subset + smoke benchmarks + regression gate.
#
#   lint:     ruff check (no autofix), config in ruff.toml; skipped with a
#             loud warning when ruff is not installed (the container image
#             may not ship it — the GitHub workflow always does)
#   tier-1:   python -m pytest -q -m "not slow"     (~2 minutes, incl. the
#             small pod-mesh subprocess dry-runs; --strict-markers via
#             pytest.ini: unknown marks fail collection)
#   smoke:    engine-comparison benchmark, fast sizes (DESIGN.md §6) —
#             includes the (2, 16, 16) multi-pod dry-run (pod-axis L
#             sharding; cross-pod collectives asserted candidate-count
#             sized, warm sharded serving asserted at zero reshard bytes)
#   pipeline: streaming-vs-barrier refinement overlap, fast sizes (§6)
#   serving:  plane-store cold/warm/delta regime (§4) — runs --strict and
#             FAILS CI if the warm path reports nonzero extraction charges,
#             nonzero plane H2D bytes, or nonzero plane reshard bytes
#   calibration: serving-time guarantee regime (§4a) — scripted
#             distribution-shifting append; FAILS CI if the recalibrated
#             path's observed recall drops below the target
#   gate:     every regime above is compared against the committed
#             baselines in benchmarks/baseline/ (--check-against): wall
#             regressions beyond the band, byte/dollar inflations, recall
#             floors, or lost coverage exit nonzero
#
# The slow suite (system joins, ≥50-trial guarantee sweep, the full
# 512-device multipod dry-run test, per-arch smoke tests) runs separately:
#   python -m pytest -q -m slow
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
# adj_target failure curves are data-independent and cached on disk
# (core/adj_target.py cache_dir()): pin the cache to a workspace-relative
# dir so CI runners can persist it across runs (the workflow restores it
# via actions/cache) instead of recomputing the Monte-Carlo curves
export REPRO_ADJ_CACHE="${REPRO_ADJ_CACHE:-$PWD/.cache/adj_target}"

echo "== lint: ruff check (no autofix) =="
if command -v ruff >/dev/null 2>&1; then
    ruff check .
else
    echo "WARNING: ruff not installed; skipping lint (CI workflow runs it)"
fi

echo "== tier-1: fast test subset =="
python -m pytest -q -m "not slow"

echo "== smoke benchmarks + regression gate (engines incl. multipod dry-run, pipeline, serving, calibration) =="
python -m benchmarks.run --fast --strict \
    --only engines,pipeline,serving,calibration \
    --check-against benchmarks/baseline

echo "CI OK"
