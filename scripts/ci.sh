#!/usr/bin/env bash
# CI gate: lint + tier-1 test subset + smoke benchmarks + regression gate.
#
#   lint:     ruff check (no autofix), config in ruff.toml; skipped with a
#             loud warning when ruff is not installed (the container image
#             may not ship it — the GitHub workflow always does)
#   analyze:  repo-invariant static analysis (python -m repro.analysis
#             --check, DESIGN.md §9): static lock-order graph with cycle
#             + blocking-under-lock detection across the threaded stack,
#             repo-specific AST lint (tracer guards, legacy-kwarg ban,
#             metric-name declarations, monotonic-clock-only span paths),
#             and HLO contract-manifest validation; renders the lock
#             graph as DOT into benchmarks/results/ for artifact upload
#   tier-1:   python -m pytest -q -m "not slow"     (~2 minutes, incl. the
#             small pod-mesh subprocess dry-runs; --strict-markers via
#             pytest.ini: unknown marks fail collection)
#   smoke:    engine-comparison benchmark, fast sizes (DESIGN.md §6) —
#             includes the (2, 16, 16) multi-pod dry-run (pod-axis L
#             sharding; cross-pod collectives asserted candidate-count
#             sized, warm sharded serving asserted at zero reshard bytes)
#   pipeline: streaming-vs-barrier refinement overlap, fast sizes (§6)
#   serving:  plane-store cold/warm/delta regime (§4) — runs --strict and
#             FAILS CI if the warm path reports nonzero extraction charges,
#             nonzero plane H2D bytes, or nonzero plane reshard bytes
#   calibration: serving-time guarantee regime (§4a) — scripted
#             distribution-shifting append; FAILS CI if the recalibrated
#             path's observed recall drops below the target
#   fleet:    multi-tenant regime (§8a) — shared-corpus plane/plan dedup
#             ($0 extraction + 0 plane H2D for the second tenant, gated as
#             zero invariants), then serial-vs-concurrent query streams:
#             FAILS CI if the concurrent aggregate wall is not strictly
#             below the serial aggregate, no band step ever interleaved,
#             or any stream's observed recall drops below its floor
#             (p50/p99 latency ride the wall band in the gate)
#   trace:    small traced sharded join (prefetch ring depth 2) exported
#             as Perfetto trace-event JSON; launch/trace_report --check
#             gates the schema and the span-vs-ledger reconciliation
#   gate:     every regime above is compared against the committed
#             baselines in benchmarks/baseline/ (--check-against): wall
#             regressions beyond the band, byte/dollar inflations, recall
#             floors, or lost coverage exit nonzero
#
# The slow suite (system joins, ≥50-trial guarantee sweep, the full
# 512-device multipod dry-run test, per-arch smoke tests) runs separately:
#   python -m pytest -q -m slow
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
# adj_target failure curves are data-independent and cached on disk
# (core/adj_target.py cache_dir()): pin the cache to a workspace-relative
# dir so CI runners can persist it across runs (the workflow restores it
# via actions/cache) instead of recomputing the Monte-Carlo curves
export REPRO_ADJ_CACHE="${REPRO_ADJ_CACHE:-$PWD/.cache/adj_target}"

echo "== lint: ruff check (no autofix) =="
if command -v ruff >/dev/null 2>&1; then
    ruff check .
else
    # containers without ruff still gate the one mechanical rule (E501):
    # stdlib check against the line-length pinned in ruff.toml
    echo "WARNING: ruff not installed; stdlib E501 check only (CI runs ruff)"
    python - <<'PYEOF'
import os, sys
LIMIT = 100                                 # keep in sync with ruff.toml
bad = []
for root, dirs, files in os.walk("."):
    dirs[:] = [d for d in dirs
               if d not in (".git", "__pycache__", ".cache", "results")]
    for fn in files:
        if fn.endswith(".py"):
            p = os.path.join(root, fn)
            with open(p, encoding="utf-8", errors="replace") as f:
                for i, line in enumerate(f, 1):
                    if len(line.rstrip("\n")) > LIMIT:
                        bad.append(f"{p}:{i}: E501 line too long "
                                   f"({len(line.rstrip())} > {LIMIT})")
print("\n".join(bad) if bad else f"E501 clean (<= {LIMIT} cols)")
sys.exit(1 if bad else 0)
PYEOF
fi

echo "== analyze: lock graph + invariant lint + HLO manifest =="
mkdir -p benchmarks/results
python -m repro.analysis --check --dot benchmarks/results/lockgraph.dot

echo "== tier-1: fast test subset =="
python -m pytest -q -m "not slow"

echo "== smoke benchmarks + regression gate (engines incl. multipod dry-run, pipeline, serving, calibration, fleet) =="
python -m benchmarks.run --fast --strict \
    --only engines,pipeline,serving,calibration,fleet \
    --check-against benchmarks/baseline

echo "== traced join: Perfetto export + schema/ledger reconciliation gate =="
# small sharded run with the prefetch ring at depth 2, traced end to end;
# trace_report --check validates the trace-event schema (same-track span
# nesting included) and reconciles span sums against the CostLedger wall
# summary within 5%.  The trace lands in benchmarks/results/ so the
# workflow's artifact upload keeps it inspectable (ui.perfetto.dev).
python -m repro.launch.join --dataset police_records --size 0.25 \
    --engine sharded --stream --prefetch-depth 2 --r-chunk 128 \
    --trace-out benchmarks/results/trace_join.json > /dev/null
python -m repro.launch.trace_report benchmarks/results/trace_join.json --check
python -m repro.launch.trace_report benchmarks/results/trace_join.json

echo "CI OK"
