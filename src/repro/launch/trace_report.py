"""Trace report — text rendering + schema check for exported FDJ traces.

  PYTHONPATH=src python -m repro.launch.trace_report trace.json
  PYTHONPATH=src python -m repro.launch.trace_report trace.json --check

Consumes the Perfetto/Chrome trace-event JSON written by
``launch/join.py --trace-out`` / ``launch/serve_join.py --trace-out``
(obs.export) and prints what a viewer would show, for terminals and CI:

  * per-category slice totals (``band_step[7]`` aggregates as
    ``band_step``);
  * an ASCII timeline, one row per track (tid), so prefetch-ring overlap
    — ``band_step[k+1]``'s in-flight dispatch window riding over
    ``band_step[k]``'s pull — is visible without a browser;
  * the measured cross-track dispatch∩pull overlap seconds (exactly the
    thing ``prefetch_depth >= 2`` buys and depth 1 must score 0 on);
  * the critical path: the chain of longest children from the longest
    root span (the tree is reconstructed from span_id/parent_id in
    ``args`` — the flat trace-event format carries it through);
  * reconciliation of span sums against the CostLedger wall summary the
    exporter embedded under the top-level ``"fdj"`` key: Σ pull slices
    vs ``step2_pull_wall``, Σ dispatch ``enqueue_s`` vs
    ``step2_dispatch_wall`` — the spans and the ledger measure the same
    perf_counter reads, so they must agree within ``RECONCILE_TOL``.

``--check`` validates instead of rendering: obs.export.validate_trace
(envelope, phases, same-track nesting) plus the reconciliation bound,
exit 1 on any failure — the CI gate behind scripts/ci.sh.
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.obs.export import validate_trace

RECONCILE_TOL = 0.05                   # ledger-vs-span agreement bound
_TIMELINE_COLS = 60


def _slices(obj) -> list:
    """[{name, cat, tid, t0, t1, args}] for every complete slice."""
    out = []
    for ev in obj.get("traceEvents", []):
        if ev.get("ph") != "X":
            continue
        t0 = float(ev["ts"])
        out.append({"name": ev["name"],
                    "cat": ev.get("cat") or ev["name"].split("[", 1)[0],
                    "tid": ev["tid"], "t0": t0,
                    "t1": t0 + float(ev.get("dur", 0.0)),
                    "args": ev.get("args", {})})
    return out


def _track_names(obj) -> dict:
    return {ev["tid"]: ev["args"].get("name", f"tid{ev['tid']}")
            for ev in obj.get("traceEvents", [])
            if ev.get("ph") == "M" and ev.get("name") == "thread_name"}


def _categories(slices) -> list:
    agg: dict = {}
    for s in slices:
        a = agg.setdefault(s["cat"], [0, 0.0, 0.0])
        dur = s["t1"] - s["t0"]
        a[0] += 1
        a[1] += dur
        a[2] = max(a[2], dur)
    return sorted(agg.items(), key=lambda kv: -kv[1][1])


def _timeline(slices, tracks) -> list:
    if not slices:
        return []
    lo = min(s["t0"] for s in slices)
    hi = max(s["t1"] for s in slices)
    span = max(hi - lo, 1e-9)
    width = max(len(n) for n in tracks.values()) if tracks else 8
    lines = []
    for tid in sorted({s["tid"] for s in slices}):
        cells = [" "] * _TIMELINE_COLS
        for s in (x for x in slices if x["tid"] == tid):
            c0 = int((s["t0"] - lo) / span * (_TIMELINE_COLS - 1))
            c1 = int((s["t1"] - lo) / span * (_TIMELINE_COLS - 1))
            for c in range(c0, c1 + 1):
                cells[c] = "#"
        name = tracks.get(tid, f"tid{tid}")
        lines.append(f"  {name:<{width}} |{''.join(cells)}|")
    return lines


def ring_overlap_s(slices) -> float:
    """Seconds during which one band step's in-flight dispatch window and
    a *different* band step's pull window coincide — the prefetch ring's
    achieved concurrency (0 by construction at depth 1)."""
    disp = [s for s in slices if s["name"] == "dispatch"]
    pull = [s for s in slices if s["name"] == "pull"]
    tot = 0.0
    for d in disp:
        for p in pull:
            if d["args"].get("parent_id") == p["args"].get("parent_id"):
                continue               # same band step: serial by definition
            tot += max(0.0, min(d["t1"], p["t1"]) - max(d["t0"], p["t0"]))
    return tot / 1e6


def critical_path(slices) -> list:
    """Longest root, then its longest child, recursively."""
    by_id = {s["args"]["span_id"]: s for s in slices
             if "span_id" in s["args"]}
    kids: dict = {}
    for s in by_id.values():
        pid = s["args"].get("parent_id")
        if pid in by_id:
            kids.setdefault(pid, []).append(s)
    roots = [s for s in by_id.values()
             if s["args"].get("parent_id") not in by_id]
    path = []
    cur = max(roots, key=lambda s: s["t1"] - s["t0"], default=None)
    while cur is not None:
        path.append(cur)
        cur = max(kids.get(cur["args"]["span_id"], []),
                  key=lambda s: s["t1"] - s["t0"], default=None)
    return path


def reconcile(obj, slices) -> list:
    """[(label, span_sum_s, ledger_s, rel_err, ok)] for every wall the
    trace can cross-check against the embedded ledger summary."""
    walls = (obj.get("fdj") or {}).get("wall_summary") or {}
    checks = []

    def add(label, span_sum, key):
        ledger = walls.get(key)
        if ledger is None:
            return
        rel = abs(span_sum - ledger) / max(abs(ledger), 1e-9)
        # sub-millisecond walls reconcile on absolute error: relative
        # error on a 50µs wall is pure scheduler noise
        ok = rel <= RECONCILE_TOL or abs(span_sum - ledger) < 1e-3
        checks.append((label, span_sum, ledger, rel, ok))

    add("Σ pull slices vs step2_pull_wall",
        sum(s["t1"] - s["t0"] for s in slices if s["name"] == "pull") / 1e6,
        "step2_pull_wall")
    add("Σ dispatch enqueue_s vs step2_dispatch_wall",
        sum(s["args"].get("enqueue_s", 0.0)
            for s in slices if s["name"] == "dispatch"),
        "step2_dispatch_wall")
    add("Σ refine_batch slices vs refine_wall",
        sum(s["t1"] - s["t0"]
            for s in slices if s["cat"] in ("refine_batch", "refine_final"))
        / 1e6,
        "refine_wall")
    return checks


def report(obj) -> str:
    slices = _slices(obj)
    tracks = _track_names(obj)
    lines = []
    if slices:
        span = (max(s["t1"] for s in slices)
                - min(s["t0"] for s in slices)) / 1e6
        lines.append(f"trace: {len(slices)} slices, "
                     f"{len({s['tid'] for s in slices})} tracks, "
                     f"{span:.3f} s")
    else:
        lines.append("trace: empty")
    lines.append("")
    lines.append(f"  {'category':<16} {'count':>5} {'total_s':>9} "
                 f"{'max_ms':>9}")
    for cat, (n, tot, mx) in _categories(slices):
        lines.append(f"  {cat:<16} {n:>5} {tot / 1e6:>9.4f} "
                     f"{mx / 1e3:>9.2f}")
    lines.append("")
    lines.extend(_timeline(slices, tracks))
    lines.append("")
    lines.append(f"ring overlap (dispatch-in-flight ∩ other steps' pulls): "
                 f"{ring_overlap_s(slices):.4f} s")
    path = critical_path(slices)
    if path:
        lines.append("critical path: " + " > ".join(
            f"{s['name']} ({(s['t1'] - s['t0']) / 1e6:.3f}s)" for s in path))
    checks = reconcile(obj, slices)
    if checks:
        lines.append("")
        lines.append("reconciliation vs ledger wall summary:")
        for label, span_s, ledger_s, rel, ok in checks:
            lines.append(f"  {label}: {span_s:.4f}s vs {ledger_s:.4f}s "
                         f"({rel * 100:.1f}%) {'OK' if ok else 'FAIL'}")
    return "\n".join(lines)


def check(obj) -> list:
    """Schema + reconciliation errors (empty = trace passes the CI gate)."""
    errs = list(validate_trace(obj))
    for label, span_s, ledger_s, rel, ok in reconcile(obj, _slices(obj)):
        if not ok:
            errs.append(f"reconciliation: {label}: span sum {span_s:.4f}s "
                        f"vs ledger {ledger_s:.4f}s "
                        f"({rel * 100:.1f}% > {RECONCILE_TOL * 100:.0f}%)")
    return errs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("trace", help="trace-event JSON file (--trace-out)")
    ap.add_argument("--check", action="store_true",
                    help="validate schema + ledger reconciliation instead "
                         "of rendering; exit 1 on any failure")
    args = ap.parse_args(argv)
    with open(args.trace) as f:
        obj = json.load(f)
    if args.check:
        errs = check(obj)
        for e in errs:
            print(f"FAIL: {e}")
        n = len(_slices(obj))
        if not errs:
            print(f"OK: {args.trace}: {n} slices, schema valid, "
                  f"ledger reconciled")
        return 1 if errs else 0
    print(report(obj))
    return 0


if __name__ == "__main__":
    sys.exit(main())
