import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + \
    " --xla_force_host_platform_device_count=512"
# ^ MUST precede any other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (architecture x shape) cell on
the production meshes and record memory / cost / collective analyses.

  PYTHONPATH=src python -m repro.launch.dryrun --arch starcoder2-3b \
      --shape train_4k --mesh single            # one cell
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh multi    # sweep

Artifacts: benchmarks/results/dryrun/<mesh>/<arch>__<shape>.json, consumed
by the roofline builder (benchmarks/roofline.py) and EXPERIMENTS.md.
"""

import argparse
import json
import time
import traceback


from repro.common.config import SHAPES
from repro.configs import ARCH_IDS
from repro.distributed.hlo_analysis import collective_bytes
from repro.launch.cells import build_cell, cell_supported
from repro.launch.mesh import make_production_mesh

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "benchmarks", "results", "dryrun")


def run_cell(arch: str, shape: str, mesh_kind: str, *, out_dir: str = RESULTS,
             verbose: bool = True, **cell_kw) -> dict:
    ok, why = cell_supported(arch, shape)
    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind}
    if not ok:
        rec.update(status="skipped", reason=why)
        _save(rec, out_dir, mesh_kind, arch, shape)
        return rec
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
        cell = build_cell(arch, shape, mesh, **cell_kw)
        lowered = cell.fn.lower(*cell.abstract_args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)
        rec.update(
            status="ok",
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            flops_per_device=cost.get("flops", 0.0),
            bytes_per_device=cost.get("bytes accessed", 0.0),
            collective_bytes_per_device=coll.total_bytes,
            collective_by_kind=coll.by_kind,
            collective_ops=coll.n_ops,
            memory={
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
            },
            hlo_bytes=len(hlo),
        )
        if verbose:
            m = rec["memory"]
            print(f"[{mesh_kind}] {arch} x {shape}: OK "
                  f"flops/dev={rec['flops_per_device']:.3e} "
                  f"bytes/dev={rec['bytes_per_device']:.3e} "
                  f"coll/dev={rec['collective_bytes_per_device']:.3e} "
                  f"temp={_gb(m['temp_bytes'])} args={_gb(m['argument_bytes'])} "
                  f"(lower {rec['lower_s']}s compile {rec['compile_s']}s)")
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc())
        if verbose:
            print(f"[{mesh_kind}] {arch} x {shape}: ERROR {rec['error']}")
    _save(rec, out_dir, mesh_kind, arch, shape)
    return rec


def _gb(x):
    return f"{x/2**30:.2f}GiB" if x is not None else "?"


def _save(rec, out_dir, mesh_kind, arch, shape):
    d = os.path.join(out_dir, mesh_kind)
    os.makedirs(d, exist_ok=True)
    rec = dict(rec)
    rec.pop("traceback", None)
    with open(os.path.join(d, f"{arch}__{shape}.json"), "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="")
    ap.add_argument("--shape", default="")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--remat-policy", default=None)
    ap.add_argument("--param-cast", default=None)
    args = ap.parse_args()
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    archs = ARCH_IDS if (args.all or not args.arch) else args.arch.split(",")
    shapes = list(SHAPES) if (args.all or not args.shape) else args.shape.split(",")
    n_ok = n_skip = n_err = 0
    for mesh_kind in meshes:
        for arch in archs:
            for shape in shapes:
                rec = run_cell(arch, shape, mesh_kind,
                               remat_policy=args.remat_policy,
                               param_cast=args.param_cast)
                n_ok += rec["status"] == "ok"
                n_skip += rec["status"] == "skipped"
                n_err += rec["status"] == "error"
    print(f"dryrun done: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    raise SystemExit(1 if n_err else 0)


if __name__ == "__main__":
    main()
