"""Shared launcher wiring: dataset generators + the common flag set.

Every join launcher (``launch/join.py``, ``launch/serve_join.py``,
``launch/serve_fleet.py``) takes the same core knobs — dataset/size/seed,
engine selection, streaming, the sharded engine's prefetch-depth and
R-band width, and trace output.  They were once duplicated per launcher
by hand; this module is the single place a new flag (or dataset) is
added so every launcher inherits it.
"""

from __future__ import annotations

import argparse
from typing import Optional

from repro.data import synth
from repro.engine import ENGINES


def make_dataset(name: str, *, size: float = 1.0, seed: int = 0,
                 scale: float = 1.0):
    """The benchmark corpora at launcher scale.  ``size`` is the user's
    CLI multiplier; ``scale`` is the launcher's own base factor (the
    one-shot join launcher runs 2x the serving launchers' corpora)."""
    def n(base: int) -> int:
        return int(base * size * scale)

    gens = {
        "police_records": lambda: synth.police_records(
            n_incidents=n(300), reports_per_incident=3, seed=seed),
        "citations": lambda: synth.citations(n_docs=n(900), seed=seed),
        "movies": lambda: synth.movies_pages(n_movies=n(400), seed=seed),
        "products": lambda: synth.products(n_products=n(700), seed=seed),
        "categorize": lambda: synth.categorize(n_items=n(2000), seed=seed),
        "biodex": lambda: synth.biodex(n_notes=n(1500), seed=seed),
    }
    return gens[name]()


def add_common_flags(ap: argparse.ArgumentParser, *,
                     engine_default: str = "numpy"
                     ) -> argparse.ArgumentParser:
    """The flag set every join launcher shares."""
    ap.add_argument("--dataset", default="police_records")
    ap.add_argument("--engine", default=engine_default,
                    choices=list(ENGINES))
    ap.add_argument("--stream", action="store_true",
                    help="pipeline refinement over the step-② candidate "
                         "stream (FDJConfig.stream_refinement)")
    ap.add_argument("--size", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--target", type=float, default=0.9)
    ap.add_argument("--delta", type=float, default=0.1)
    ap.add_argument("--prefetch-depth", type=int, default=None,
                    help="sharded engine: band steps in flight at once "
                         "(FDJConfig.prefetch_depth; 1 = serial)")
    ap.add_argument("--r-chunk", type=int, default=None,
                    help="R-band width in columns (engine_opts; smaller = "
                         "more band steps, e.g. to exercise the prefetch "
                         "ring on a small corpus)")
    ap.add_argument("--trace-out", default=None, metavar="FILE",
                    help="write a Perfetto/Chrome trace-event JSON of the "
                         "run (load in ui.perfetto.dev, or summarize with "
                         "python -m repro.launch.trace_report FILE)")
    return ap


def engine_opts_from(r_chunk: Optional[int]) -> dict:
    """engine_opts for the common flags (--r-chunk is the only one that
    rides in engine_opts; prefetch_depth is a first-class cfg field)."""
    return {"r_chunk": r_chunk} if r_chunk else {}
