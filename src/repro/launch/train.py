"""Training launcher with checkpoint/restart fault tolerance.

  PYTHONPATH=src python -m repro.launch.train --arch mistral-nemo-12b --smoke \
      --steps 50 --checkpoint-dir /tmp/ckpt

Restarts resume from the latest checkpoint automatically; ``--fail-at N``
injects a crash at step N to exercise the restart path (examples/ and tests/
use it).  On the CPU container the mesh is the host mesh; on real hardware
pass --mesh single|multi for the production meshes.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import checkpoint as ckpt
from repro.common.config import TrainConfig
from repro.configs import get_config, get_smoke
from repro.data.pipeline import PackedLMConfig, PackedLMDataset, PrefetchLoader
from repro.distributed.mesh import make_host_mesh, make_production_mesh
from repro.models import steps, transformer
from repro.optim import adamw


class SimulatedFailure(RuntimeError):
    pass


def corpus_texts():
    from repro.data import synth
    ds = synth.police_records(n_incidents=150, reports_per_incident=2)
    return ds.texts_l


def train(arch: str, *, smoke: bool = True, steps_n: int = 50, batch: int = 8,
          seq: int = 128, ckpt_dir: str = "/tmp/repro_ckpt", ckpt_every: int = 20,
          fail_at: int = -1, mesh_kind: str = "host", seed: int = 0,
          grad_compression: str = "none", log_every: int = 10) -> dict:
    cfg = get_smoke(arch) if smoke else get_config(arch)
    mesh = (make_host_mesh() if mesh_kind == "host"
            else make_production_mesh(multi_pod=(mesh_kind == "multi")))
    tcfg = TrainConfig(total_steps=steps_n, warmup_steps=max(steps_n // 10, 1),
                       checkpoint_dir=ckpt_dir, checkpoint_every=ckpt_every,
                       grad_compression=grad_compression)
    data = PackedLMDataset(
        corpus_texts(),
        PackedLMConfig(seq_len=seq, batch_size=batch, seed=seed),
        vocab_size=cfg.vocab_size)
    loader = PrefetchLoader(data)

    params = transformer.init_params(cfg, jax.random.PRNGKey(seed))
    opt = adamw.init_opt_state(params)
    start = 0
    latest = ckpt.latest_step(ckpt_dir)
    if latest is not None:
        (params, opt), start = ckpt.restore(ckpt_dir, (params, opt))
        print(f"[train] resumed from checkpoint step {start}")

    train_step = jax.jit(steps.make_train_step(cfg, tcfg))
    metrics = {}
    t0 = time.time()
    with mesh:
        for step in range(start, steps_n):
            if step == fail_at:
                raise SimulatedFailure(f"injected failure at step {step}")
            b = loader.next()
            batch_dev = {k: jnp.asarray(v) for k, v in b.items()}
            params, opt, metrics = train_step(params, opt, batch_dev)
            if (step + 1) % ckpt_every == 0 or step + 1 == steps_n:
                ckpt.save(ckpt_dir, step + 1, (params, opt))
            if (step + 1) % log_every == 0:
                print(f"[train] step {step+1}/{steps_n} "
                      f"loss={float(metrics['loss']):.4f} "
                      f"lr={float(metrics['lr']):.2e} "
                      f"({(time.time()-t0)/max(step+1-start,1):.2f}s/step, "
                      f"backup_batches={loader.backup_batches})")
    loader.close()
    return {"loss": float(metrics.get("loss", float("nan"))), "steps": steps_n,
            "params": transformer.count_params(cfg)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mistral-nemo-12b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=20)
    ap.add_argument("--fail-at", type=int, default=-1)
    ap.add_argument("--mesh", default="host")
    ap.add_argument("--grad-compression", default="none")
    args = ap.parse_args()
    out = train(args.arch, smoke=args.smoke, steps_n=args.steps,
                batch=args.batch, seq=args.seq, ckpt_dir=args.checkpoint_dir,
                ckpt_every=args.checkpoint_every, fail_at=args.fail_at,
                mesh_kind=args.mesh, grad_compression=args.grad_compression)
    print(f"[train] done: {out}")


if __name__ == "__main__":
    main()
