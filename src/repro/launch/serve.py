"""Serving launcher: batched generation with any --arch backbone.

  PYTHONPATH=src python -m repro.launch.serve --arch phi4-mini-3.8b --smoke \
      --requests 16 --max-new 12
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke
from repro.data.pipeline import ByteTokenizer
from repro.models import transformer
from repro.serving.engine import Request, ServeEngine


def serve(arch: str, *, smoke: bool = True, n_requests: int = 16,
          max_new: int = 12, batch_slots: int = 8, capacity: int = 256,
          seed: int = 0) -> dict:
    cfg = get_smoke(arch) if smoke else get_config(arch)
    params = transformer.init_params(cfg, jax.random.PRNGKey(seed))
    tok = ByteTokenizer(cfg.vocab_size)
    engine = ServeEngine(cfg, params, batch_slots=batch_slots, capacity=capacity)
    rng = np.random.default_rng(seed)
    prompts = [
        f"Do records {int(rng.integers(1e4))} and {int(rng.integers(1e4))} "
        f"refer to the same incident?" for _ in range(n_requests)]
    reqs = [Request(np.clip(tok.encode(p), 0, cfg.vocab_size - 1),
                    max_new_tokens=max_new) for p in prompts]
    t0 = time.time()
    engine.run(reqs)
    dt = time.time() - t0
    toks = sum(len(r.out_tokens) for r in reqs)
    return {"requests": n_requests, "tokens_generated": toks,
            "wall_s": round(dt, 2), "tok_per_s": round(toks / max(dt, 1e-9), 1)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi4-mini-3.8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--batch-slots", type=int, default=8)
    args = ap.parse_args()
    print(serve(args.arch, smoke=args.smoke, n_requests=args.requests,
                max_new=args.max_new, batch_slots=args.batch_slots))


if __name__ == "__main__":
    main()
