"""Dry-run cell construction: (architecture x input shape x mesh) -> a jitted
step function plus abstract (ShapeDtypeStruct) inputs with shardings.

``input_specs(arch, shape)`` follows the assignment contract: weak-type
correct ShapeDtypeStruct stand-ins for every model input, no allocation.
Modality frontends are stubs — the VLM cell's image memory arrives as
precomputed patch embeddings (B, M, F).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.common.config import FFNKind, ModelConfig, SHAPES, TrainConfig
from repro.configs import LONG_CONTEXT_ARCHS, get_config
from repro.distributed.mesh import AxisEnv, axis_size, batch_spec
from repro.models import steps, transformer


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    fn: object                   # jitted, ready to .lower(*abstract_args)
    abstract_args: tuple
    cfg: ModelConfig
    note: str = ""


def cell_supported(arch: str, shape: str) -> tuple:
    """(supported, reason)."""
    if shape == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
        return False, "long_500k requires sub-quadratic mixing (skip: full attention)"
    return True, ""


def tp_pad_config(cfg: ModelConfig, tp: int) -> ModelConfig:
    """Function-preserving head padding for tensor parallelism.

    Q heads are zero-padded to a multiple of tp (padded heads have null
    output projections ⇒ identical function); KV heads are value-duplicated
    to a multiple of tp (standard KV replication when tp > n_kv) with the
    GQA group mapping preserved.  Archs without attention blocks are
    untouched (their TP lands on head_dim/inner dims instead).
    """
    if tp <= 1:
        return cfg
    kinds = set(cfg.pattern)
    if not ({"attn", "cross_attn", "mla"} & kinds):
        return cfg
    nh, nkv = cfg.num_heads, cfg.num_kv_heads
    nh2 = -(-nh // tp) * tp
    if "mla" in kinds:
        nkv2 = nkv if nh2 == nh else nh2       # MLA: latent cache, per-q heads
    else:
        nkv2 = nkv if nkv % tp == 0 else -(-nkv // tp) * tp
        if nh2 % nkv2:
            nkv2 = nh2                          # degenerate to MHA padding
    if nh2 == nh and nkv2 == nkv:
        return cfg
    return dataclasses.replace(cfg, num_heads=nh2, num_kv_heads=nkv2)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _sharded_sds(mesh, shape, dtype, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def _bspec(env: AxisEnv, mesh, b: int):
    name = batch_spec(env, mesh, b)
    return env.resolve((name,))[0] if name else None


def input_specs(arch: str, shape: str, mesh) -> dict:
    """Abstract model inputs for the cell (assignment deliverable)."""
    cfg = get_config(arch)
    sc = SHAPES[shape]
    env = AxisEnv.from_mesh(mesh)
    b = sc.global_batch
    bs = _bspec(env, mesh, b)
    sp = "model" if sc.seq_len % max(axis_size(mesh, env.sp), 1) == 0 else None
    out = {}
    if sc.kind == "train":
        out["tokens"] = _sharded_sds(mesh, (b, sc.seq_len), jnp.int32, P(bs, sp))
        out["labels"] = _sharded_sds(mesh, (b, sc.seq_len), jnp.int32, P(bs, sp))
    elif sc.kind == "prefill":
        out["tokens"] = _sharded_sds(mesh, (b, sc.seq_len), jnp.int32, P(bs, sp))
    else:  # decode
        out["tokens"] = _sharded_sds(mesh, (b, 1), jnp.int32, P(bs, None))
        out["positions"] = _sharded_sds(mesh, (b, 1), jnp.int32, P(bs, None))
    if cfg.cross_attn_every:
        out["memory"] = _sharded_sds(
            mesh, (b, cfg.cross_attn_memory_len, cfg.frontend_embed_dim),
            jnp.float32, P(bs, None, None))
    return out


def _abstract_opt(aparams):
    return {
        "m": jax.tree.map(lambda a: _sds(a.shape, jnp.float32), aparams),
        "v": jax.tree.map(lambda a: _sds(a.shape, jnp.float32), aparams),
        "step": _sds((), jnp.int32),
    }


def _opt_specs(pspecs):
    return {"m": pspecs, "v": pspecs, "step": P()}


def build_cell(arch: str, shape: str, mesh, *,
               remat_policy: Optional[str] = None,
               use_ep: Optional[bool] = None,
               mla_absorb: bool = True,
               layers_override: Optional[int] = None,
               scan_override: Optional[bool] = None,
               param_cast: Optional[str] = None,
               cfg_override: Optional[ModelConfig] = None) -> Cell:
    ok, why = cell_supported(arch, shape)
    if not ok:
        raise ValueError(why)
    cfg = cfg_override if cfg_override is not None else get_config(arch)
    if remat_policy is not None:
        cfg = dataclasses.replace(cfg, remat_policy=remat_policy)
    if layers_override is not None:
        cfg = dataclasses.replace(cfg, num_layers=layers_override)
    if scan_override is not None:
        cfg = dataclasses.replace(cfg, scan_layers=scan_override)
    if param_cast is not None:
        cfg = dataclasses.replace(cfg, param_cast=param_cast)
    sc = SHAPES[shape]
    env = AxisEnv.from_mesh(mesh)
    cfg = tp_pad_config(cfg, axis_size(mesh, env.tp))
    is_moe = cfg.ffn_kind == FFNKind.MOE.value
    if use_ep is None:
        use_ep = is_moe
    pspecs = transformer.param_specs(cfg, env)
    aparams = transformer.abstract_params(cfg)
    aparams = jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype,
                                          sharding=NamedSharding(mesh, s)),
        aparams, pspecs)
    ins = input_specs(arch, shape, mesh)
    b = sc.global_batch
    bs = _bspec(env, mesh, b)

    def sp_constraint(x):
        if x.ndim == 3 and x.shape[1] % max(axis_size(mesh, env.sp), 1) == 0 \
                and x.shape[1] > 1:
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(bs, "model", None)))
        return x

    def ns_tree(tree):
        return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                            is_leaf=lambda x: isinstance(x, P))

    if sc.kind == "train":
        tcfg = TrainConfig()
        train_step = steps.make_train_step(
            cfg, tcfg, use_ep=use_ep, mesh=mesh if use_ep else None,
            sp_constraint=sp_constraint)
        aopt = _abstract_opt(aparams)
        fn = jax.jit(train_step,
                     in_shardings=(ns_tree(pspecs), ns_tree(_opt_specs(pspecs)),
                                   None),
                     donate_argnums=(0, 1))
        batch = {"tokens": ins["tokens"], "labels": ins["labels"]}
        if "memory" in ins:
            batch["memory"] = ins["memory"]
        return Cell(arch, shape, fn, (aparams, aopt, batch), cfg)

    capacity = sc.seq_len
    if sc.kind == "prefill":
        prefill = steps.make_prefill_step(cfg, capacity, use_ep=use_ep,
                                          mesh=mesh if use_ep else None,
                                          sp_constraint=sp_constraint)
        if "memory" in ins:
            fn = jax.jit(lambda p, t, m: prefill(p, t, m))
            args = (aparams, ins["tokens"], ins["memory"])
        else:
            fn = jax.jit(lambda p, t: prefill(p, t))
            args = (aparams, ins["tokens"])
        return Cell(arch, shape, fn, args, cfg)

    # decode
    astate = transformer.abstract_state(cfg, b, capacity)
    sspecs = transformer.state_specs(cfg, env, b, capacity,
                                     batch_logical=batch_spec(env, mesh, b))
    astate = jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype,
                                          sharding=NamedSharding(mesh, s)),
        astate, sspecs)
    decode = steps.make_decode_step(cfg, use_ep=use_ep,
                                    mesh=mesh if use_ep else None)
    fn = jax.jit(decode, donate_argnums=(1,))
    return Cell(arch, shape, fn, (aparams, astate, ins["tokens"],
                                  ins["positions"]), cfg)
