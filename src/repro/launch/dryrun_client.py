"""Client-side runner for ``launch.multipod_dryrun`` subprocesses.

The dry-run entry point mutates ``XLA_FLAGS`` at module import (it must
precede jax initialization), so callers never import it — they spawn it
and parse the ``MULTIPOD_DRYRUN_JSON`` marker line.  This is the one
shared implementation of that protocol (benchmarks/engines.py and
tests/test_multipod.py both drive it); keep marker, env and exit-code
handling here so the contract cannot drift between consumers.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

MARKER = "MULTIPOD_DRYRUN_JSON "

REPO_ROOT = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "..", ".."))


def run_dryrun(mesh: str, *extra: str, timeout: int = 420,
               repo: str = REPO_ROOT) -> dict:
    """Run the multipod dry-run on ``mesh`` ("P,D,M"); returns the parsed
    report.  Raises AssertionError (with captured output) when the
    subprocess exits nonzero, reports a failed status, or emits no
    marker line."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.multipod_dryrun",
         "--mesh", mesh, *extra],
        capture_output=True, text=True, timeout=timeout, cwd=repo, env=env)
    rep = None
    for line in proc.stdout.splitlines():
        if line.startswith(MARKER):
            rep = json.loads(line[len(MARKER):])
    assert rep is not None, (
        f"no dry-run report (exit {proc.returncode})\n"
        f"stdout: {proc.stdout[-2000:]}\nstderr: {proc.stderr[-2000:]}")
    assert proc.returncode == 0 and rep.get("status") == "ok", (
        f"multipod dry-run failed (exit {proc.returncode}): "
        f"{json.dumps(rep, indent=1, default=str)[:4000]}\n"
        f"stderr: {proc.stderr[-2000:]}")
    return rep
