"""Multi-pod join dry-run: the sharded CNF engine on an emulated pod mesh.

Emulates a ``(pod, data, model)`` mesh with XLA host devices (the same
override contract as ``launch.dryrun``: the flag is set at module import,
before any jax initialization, and ONLY inside this entry point — library
code and tests see the real device count) and validates the multi-pod
engine end to end:

  * **parity**   — sharded-on-pod-mesh candidates ≡ the numpy oracle on a
    ragged corpus, with the double-buffered band loop required to report
    nonzero overlap on the multi-step sweep, plus the capacity-1 overflow
    fixture (every chunk overflows; the ≥4× per-shard retry must recover
    the full cross product without mutating the engine's configured
    capacity);
  * **stream**   — per-step chunks are disjoint and their union ≡ batch;
  * **serving**  — a ``JoinService`` over a mesh-attached
    ``FeaturePlaneStore``: the warm repeated sharded query must charge $0
    extraction, move 0 plane bytes H2D, **and report 0 plane reshard
    bytes** (the pre-sharded-residency invariant); the delta-append query
    must evaluate only L × ΔR;
  * **hlo**      — the compiled chunk-step program's collectives, split by
    pod locality (``distributed.hlo_analysis.pod_crossing_stats``): every
    pod-spanning collective must be candidate-count sized — cross-pod
    interconnect carries counts, never feature planes or masks.

Usage (defaults to the assignment's (2, 16, 16) dry-run mesh):

  PYTHONPATH=src python -m repro.launch.multipod_dryrun --mesh 2,16,16
  PYTHONPATH=src python -m repro.launch.multipod_dryrun --mesh 1,8,1 \
      --skip-serving

Prints one JSON report on stdout (marker line ``MULTIPOD_DRYRUN_JSON``);
exits nonzero on any failed check.  ``benchmarks/engines.py`` runs this as
a subprocess for the CI gate; ``tests/test_multipod.py`` drives the small
meshes in tier-1 and the full 512-device mesh under ``-m slow``.
"""

import os as _os
import sys as _sys


def _mesh_arg(argv) -> tuple:
    for i, a in enumerate(argv):
        if a == "--mesh" and i + 1 < len(argv):
            return tuple(int(x) for x in argv[i + 1].split(","))
        if a.startswith("--mesh="):
            return tuple(int(x) for x in a.split("=", 1)[1].split(","))
    return (2, 16, 16)


_SHAPE = _mesh_arg(_sys.argv)
if len(_SHAPE) != 3 or min(_SHAPE) < 1:
    raise SystemExit(f"--mesh must be P,D,M with P,D,M >= 1, got {_SHAPE}")
_os.environ["XLA_FLAGS"] = _os.environ.get("XLA_FLAGS", "") + \
    f" --xla_force_host_platform_device_count={_SHAPE[0] * _SHAPE[1] * _SHAPE[2]}"
# ^ MUST precede any other import (jax locks device count on first init).

import argparse
import json
import math
import time
import traceback


def _engine_opts(mesh, *, tl: int, tr: int, r_chunk: int, use_kernel: bool,
                 capacity=None) -> dict:
    opts = dict(mesh=mesh, tl=tl, tr=tr, r_chunk=r_chunk,
                use_kernel=use_kernel)
    if capacity is not None:
        opts["capacity"] = capacity
    return opts


def _check_parity(mesh, rep: dict, *, tl, tr, r_chunk, use_kernel,
                  contract=None) -> None:
    from repro.core.costs import CostLedger
    from repro.core.featurize import FeaturizationSpec, vectorize
    from repro.data.cnf_fixtures import representative_cnf
    from repro.data.simulated_llm import SimulatedExtractor
    from repro.data import synth
    from repro.engine import get_engine

    # corpus sized so the R sweep takes >= 2 stream steps on this mesh
    # (n_r = 2 * n_incidents with 2 reports/incident) — the per-pod band
    # rotation is only exercised when there is more than one band
    n_inc = max(37, r_chunk // 2 + 1)
    ds = synth.police_records(n_incidents=n_inc, reports_per_incident=2,
                              seed=5)
    specs, clauses, thetas = representative_cnf(ds)
    feats = SimulatedExtractor(ds).materialize(specs, CostLedger())
    oracle = get_engine("numpy", block=256).evaluate(feats, clauses, thetas)
    # prefetch_depth=4: the deep ring must change neither the candidate
    # set nor the counts-only pod-crossing profile (the hlo check compiles
    # the same per-step program the ring dispatches)
    eng = get_engine("sharded", prefetch_depth=4, **_engine_opts(
        mesh, tl=tl, tr=tr, r_chunk=r_chunk, use_kernel=use_kernel))
    res = eng.evaluate(feats, clauses, thetas)
    assert res.candidates == oracle.candidates, (
        f"pod-mesh candidates diverge from numpy: "
        f"{len(res.candidates)} vs {len(oracle.candidates)}")
    assert res.stats.n_candidates > 0, "degenerate parity corpus"
    s = res.stats
    rep["parity"] = {
        "n_l": s.n_l, "n_r": s.n_r, "candidates": s.n_candidates,
        "bytes_to_host": s.bytes_to_host, "bytes_h2d": s.bytes_h2d,
        "bytes_reshard": s.bytes_reshard, "plane_bytes": s.plane_bytes,
        "wall_s": round(s.wall_s, 3),
        "dispatch_wall_s": round(s.dispatch_wall_s, 4),
        "pull_wall_s": round(s.pull_wall_s, 4),
        "overlap_s": round(s.overlap_s, 4),
        "prefetch_depth": eng.effective_prefetch_depth,
        "conjunct_evals": s.conjunct_evals,
        "flops_per_candidate": round(s.flops_per_candidate, 2),
    }
    # the R sweep takes >= 2 steps here (corpus sized for it), so the
    # prefetch ring must have kept a successor step in flight during host
    # pulls: overlap_s == 0 means it degraded to serial
    assert s.overlap_s > 0, (
        "depth-4 prefetch ring reported zero overlap on a multi-step "
        "sweep — the pipeline degraded to the serial loop")
    assert s.conjunct_evals > 0, "conjunct-eval accounting missing"
    # host traffic must scale with candidates (8 B per pulled pair, plus
    # one count + one base + one conjunct-eval int32 per device per
    # step), never with the O(n_l*n_r) plane; the per-device-step ceiling
    # is contract policy from benchmarks/baseline/hlo_manifest.json
    n_dev = 1
    for v in mesh.shape.values():
        n_dev *= v
    n_steps = math.ceil(s.n_r / r_chunk)
    if contract is not None:
        allow = contract.host_pull_budget(s.n_candidates, n_dev, n_steps)
    else:
        allow = 8 * s.n_candidates + 12 * n_dev * n_steps + 1024
    assert s.bytes_to_host <= allow, (
        f"host traffic {s.bytes_to_host} not O(candidates) (allow {allow})")

    # stream: disjoint cover ≡ batch
    chunks = list(get_engine("sharded", **_engine_opts(
        mesh, tl=tl, tr=tr, r_chunk=r_chunk, use_kernel=use_kernel)
    ).evaluate_stream(feats, clauses, thetas))
    union = [p for ch in chunks for p in ch.candidates]
    assert len(union) == len(set(union)), "stream chunks overlap"
    assert sorted(union) == oracle.candidates, "stream union != batch"
    for ch in chunks:
        assert ch.candidates == sorted(ch.candidates), "chunk not sorted"
    rep["stream"] = {"chunks": len(chunks)}

    # capacity-1 fixture: every step overflows; retry must recover all
    n = 33
    spec = FeaturizationSpec("name", "", "word_overlap", "llm", "name")
    dense = [vectorize(spec, ["same text"] * n, ["same text"] * n)]
    eng1 = get_engine("sharded", **_engine_opts(
        mesh, tl=tl, tr=tr, r_chunk=r_chunk, use_kernel=use_kernel,
        capacity=1))
    res1 = eng1.evaluate(dense, [[0]], [0.5])
    want = [(i, j) for i in range(n) for j in range(n)]
    assert res1.candidates == want, "overflow retry truncated candidates"
    assert eng1.last_sweep_capacity >= 4, "capacity did not grow >=4x"
    assert eng1.capacity == 1, "overflow mutated the configured capacity"
    rep["overflow"] = {"candidates": len(res1.candidates),
                      "final_capacity": int(eng1.last_sweep_capacity)}


def _check_serving(mesh, rep: dict, *, tl, tr, r_chunk, use_kernel,
                   contract=None) -> None:
    from repro.core.join import FDJConfig
    from repro.data import synth
    from repro.serving.join_service import JoinService, hold_out_right
    from repro.serving.planes import FeaturePlaneStore

    # movies: embed-only planes keep the append on the incremental path
    ds = synth.movies_pages(n_movies=24, cast_size=4, filler_sentences=1,
                            seed=0)
    base, delta_rows = hold_out_right(ds, n_delta=ds.n_r // 5)
    cfg = FDJConfig(engine="sharded", seed=0, mc_trials=4000,
                    engine_opts=_engine_opts(mesh, tl=tl, tr=tr,
                                             r_chunk=r_chunk,
                                             use_kernel=use_kernel))
    svc = JoinService(base, cfg, store=FeaturePlaneStore(mesh=mesh))

    cold = svc.query()
    warm = svc.query()
    assert warm.pairs == cold.pairs, "warm pairs diverge from cold"
    assert warm.cost.inference == 0.0, (
        f"warm query charged ${warm.cost.inference} extraction")
    assert warm.cost.bytes_h2d == 0, (
        f"warm query moved {warm.cost.bytes_h2d} plane bytes H2D")
    assert warm.cost.bytes_reshard == 0, (
        f"warm query paid {warm.cost.bytes_reshard} reshard bytes — "
        f"resident planes were not pre-sharded onto the mesh")
    svc.append_right(delta_rows)
    dq = svc.query()
    assert dq.delta_rows == len(delta_rows.texts), (
        "delta query re-evaluated the full corpus")
    rep["serving"] = {
        "cold_reshard_bytes": cold.cost.bytes_reshard,
        "warm_reshard_bytes": warm.cost.bytes_reshard,
        "warm_h2d_bytes": warm.cost.bytes_h2d,
        "warm_extraction_cost": warm.cost.inference,
        "delta_rows": dq.delta_rows,
        "cold_wall_s": round(cold.wall_s, 3),
        "warm_wall_s": round(warm.wall_s, 3),
    }


def _lower_chunk_step(mesh, *, tl, tr, r_chunk, use_kernel) -> tuple:
    """Lower + compile the real chunk-step program; returns
    ``(hlo text, n_pods, pod_size, staged plane bytes)``."""
    import jax.numpy as jnp
    from repro.core.costs import CostLedger
    from repro.data.cnf_fixtures import representative_cnf
    from repro.data.simulated_llm import SimulatedExtractor
    from repro.data import synth
    from repro.engine.sharded import ShardedEngine, _mesh_geometry
    from repro.kernels.fused_cnf_join import ops as cnf_ops

    ds = synth.police_records(n_incidents=37, reports_per_incident=2, seed=5)
    specs, clauses, thetas = representative_cnf(ds)
    feats = SimulatedExtractor(ds).materialize(specs, CostLedger())
    eng = ShardedEngine(mesh, tl=tl, tr=tr, r_chunk=r_chunk,
                        use_kernel=use_kernel)
    l_axes, n_pods, n_data, n_model = _mesh_geometry(mesh)
    l_shards = n_pods * n_data
    staged = cnf_ops.stage_planes(feats, clauses, tl=l_shards * eng.tl,
                                  tr=r_chunk, mesh=mesh, l_axes=l_axes)
    rows_shard = staged.emb_l.shape[1] // l_shards
    n_chunks = staged.emb_r.shape[1] // r_chunk
    cap = 4096
    fn = eng._build(mesh, staged.kclauses,
                    tuple(float(t) for t in thetas), rows_shard, cap,
                    r_chunk, n_chunks)
    hlo = fn.lower(*staged.arrays, jnp.int32(0)).compile().as_text()
    plane_bytes = sum(int(a.nbytes) for a in staged.arrays)
    return hlo, n_pods, n_data * n_model, plane_bytes


def _check_hlo(mesh, rep: dict, *, tl, tr, r_chunk, use_kernel,
               contract=None) -> None:
    """Lower + compile one chunk-step program and gate its collectives
    against the committed manifest (benchmarks/baseline/hlo_manifest.json):
    cross-pod collectives exist (the count gather) but every one of them
    is counts-sized and of a reviewed kind — no plane or mask crosses a
    pod boundary, and no unreviewed collective lands green."""
    from repro.analysis.hlo_contracts import (DEFAULT_CONTRACTS,
                                              check_program)

    if contract is None:
        contract = DEFAULT_CONTRACTS["sharded_chunk_step"]
    hlo, n_pods, pod_size, plane_bytes = _lower_chunk_step(
        mesh, tl=tl, tr=tr, r_chunk=r_chunk, use_kernel=use_kernel)
    findings, rep["hlo"] = check_program(
        hlo, contract, n_pods=n_pods, pod_size=pod_size,
        plane_bytes=plane_bytes)
    assert not findings, "; ".join(str(f) for f in findings)


def main() -> None:
    # allow_abbrev=False: the XLA device-count override was derived from a
    # literal "--mesh" scan of sys.argv at import time, before jax — an
    # argparse prefix abbreviation ("--mes") would be honored here but
    # invisible to that scan, silently running the default mesh instead
    ap = argparse.ArgumentParser(allow_abbrev=False)
    ap.add_argument("--mesh", default="2,16,16",
                    help="P,D,M pod-mesh shape (emulated host devices)")
    ap.add_argument("--skip-serving", action="store_true",
                    help="skip the serving regime (parity + hlo only)")
    ap.add_argument("--kernel", action="store_true",
                    help="run the Pallas kernel (interpret mode) instead "
                         "of the jnp reference math — slow at high device "
                         "counts, exercised on small meshes in tier-1")
    ap.add_argument("--manifest", default=None,
                    help="HLO contract manifest path (default: "
                         "benchmarks/baseline/hlo_manifest.json)")
    ap.add_argument("--write-manifest", action="store_true",
                    help="regenerate the manifest's op-sets from the "
                         "freshly lowered program (budgets keep committed "
                         "policy) instead of checking — review the diff")
    args = ap.parse_args()
    if tuple(int(x) for x in args.mesh.split(",")) != _SHAPE:
        raise SystemExit(f"--mesh {args.mesh} disagrees with the "
                         f"import-time device override {_SHAPE}")
    n_pods, n_data, n_model = _SHAPE

    import jax
    from repro.analysis.hlo_contracts import (DEFAULT_CONTRACTS,
                                              default_manifest_path,
                                              dump_manifest, load_manifest,
                                              observed_contract)
    from repro.distributed.mesh import make_join_mesh
    t0 = time.time()
    rep = {"mesh": list(_SHAPE), "devices": len(jax.devices()),
           "use_kernel": bool(args.kernel), "status": "ok"}
    mesh = make_join_mesh(n_pods, n_data, n_model)
    # tiles sized so the smallest L shard and the per-model sub-band are
    # whole tiles on any requested mesh; tr is pinned at the 32-bit packed
    # word, r_chunk covers one tile per model-axis device
    tl, tr = 8, 32
    r_chunk = tr * n_model

    manifest_path = args.manifest or default_manifest_path()
    if args.write_manifest:
        hlo, _, pod_size, _ = _lower_chunk_step(
            mesh, tl=tl, tr=tr, r_chunk=r_chunk, use_kernel=args.kernel)
        base = (load_manifest(manifest_path)
                if _os.path.exists(manifest_path)
                else dict(DEFAULT_CONTRACTS))
        base["sharded_chunk_step"] = observed_contract(
            hlo, "sharded_chunk_step", pod_size=pod_size,
            base=base.get("sharded_chunk_step"))
        out = dump_manifest(base, manifest_path)
        print(json.dumps({"wrote_manifest": out}))
        raise SystemExit(0)
    try:
        contract = load_manifest(manifest_path)["sharded_chunk_step"]
        rep["manifest"] = manifest_path
    except (OSError, KeyError) as e:
        rep["manifest"] = (f"unavailable ({type(e).__name__}) — "
                           f"falling back to DEFAULT_CONTRACTS policy")
        contract = DEFAULT_CONTRACTS["sharded_chunk_step"]

    failed = []
    for name, check in (("parity", _check_parity),
                        ("serving", _check_serving),
                        ("hlo", _check_hlo)):
        if name == "serving" and args.skip_serving:
            continue
        try:
            check(mesh, rep, tl=tl, tr=tr, r_chunk=r_chunk,
                  use_kernel=args.kernel, contract=contract)
        except Exception as e:
            failed.append(name)
            rep[name] = {"error": f"{type(e).__name__}: {e}",
                         "traceback": traceback.format_exc()}
    rep["wall_s"] = round(time.time() - t0, 1)
    if failed:
        rep["status"] = "failed"
        rep["failed"] = failed
    print("MULTIPOD_DRYRUN_JSON " + json.dumps(rep, default=str))
    raise SystemExit(1 if failed else 0)


if __name__ == "__main__":
    main()
