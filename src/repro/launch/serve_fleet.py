"""Fleet-serving launcher: N tenants, one store, one mesh, K workers.

  PYTHONPATH=src python -m repro.launch.serve_fleet --engine sharded \
      --tenants 4 --queries 3 --shared --max-concurrent 2

Builds a ``JoinFleet``, registers ``--tenants`` tenants (``--shared``
gives every tenant the SAME corpus — the plane/plan dedup demo;
otherwise each tenant gets its own seed), then submits ``--queries``
queries per tenant concurrently through the admission loop.  Prints one
JSON event per completed query (which tenant, recall, extraction $,
dedup hits, wall) and a fleet summary: per-tenant ledgers, p50/p99 query
wall from the ``fleet.query_wall_s`` histogram, scheduler band-step /
interleave counts, and the shared store's counters.
"""

from __future__ import annotations

import argparse
import json

from repro.core.join import FDJConfig
from repro.launch._args import (add_common_flags, engine_opts_from,
                                make_dataset)
from repro.launch.serve_join import SERVE_SCALE
from repro.obs import Tracer, use_tracer, write_trace
from repro.serving.fleet import JoinFleet


def run_fleet(dataset: str = "police_records", engine: str = "sharded",
              stream: bool = False, size: float = 1.0, target: float = 0.9,
              delta: float = 0.1, seed: int = 0, n_tenants: int = 2,
              queries: int = 2, shared: bool = True,
              max_concurrent: int = 2, byte_budget=None, tenant_budget=None,
              engine_opts=None, prefetch_depth=None, oracle_latency=0.0,
              trace_out=None) -> dict:
    fleet = JoinFleet(byte_budget=byte_budget, max_concurrent=max_concurrent)
    for t in range(n_tenants):
        ds = make_dataset(dataset, size=size,
                          seed=seed if shared else seed + t,
                          scale=SERVE_SCALE)
        cfg = FDJConfig(recall_target=target, delta=delta, engine=engine,
                        stream_refinement=stream, seed=seed,
                        prefetch_depth=prefetch_depth,
                        engine_opts=engine_opts or {})
        fleet.add_tenant(f"t{t}", ds, cfg, byte_budget=tenant_budget,
                         oracle_factory=(
                             lambda d=ds: d.make_oracle(oracle_latency)))

    tracer = Tracer() if trace_out else None
    events = []
    with use_tracer(tracer):
        # interleaved submission (t0, t1, ..., t0, t1, ...): every tenant
        # has work queued from the start, so admission rotates and band
        # steps from different queries actually contend for the mesh
        futures = [(name, fleet.submit(name))
                   for _ in range(queries) for name in fleet.tenants]
        for name, fut in futures:
            r = fut.result()
            ev = {"tenant": name, "recall": round(r.join.recall, 4),
                  "precision": round(r.join.precision, 4),
                  "pairs": len(r.pairs), "plan_hit": r.plan_hit,
                  "extraction_$": round(r.cost.inference, 6),
                  "dedup_hits": r.cost.plane_dedup_hits,
                  "bytes_h2d": r.cost.bytes_h2d,
                  "wall_s": round(r.wall_s, 3)}
            events.append(ev)
            print(json.dumps(ev))
        summary = fleet.drain()
    if tracer is not None:
        write_trace(tracer, trace_out, metadata={
            "tenants": summary["tenants"], "engine": engine,
            "metrics": fleet.metrics.as_dict()})
    wall_hist = fleet.metrics.histogram("fleet.query_wall_s")
    summary.update(
        latency={k: round(v, 4) for k, v in wall_hist.summary().items()},
        p50_wall_s=round(wall_hist.quantile(0.5), 4),
        p99_wall_s=round(wall_hist.quantile(0.99), 4),
        tenant_ledgers={
            name: {k: round(v, 6) for k, v in
                   fleet.service(name).ledger.breakdown().items()}
            for name in fleet.tenants},
        tenant_bytes={name: fleet.store.tenant_bytes(name)
                      for name in fleet.tenants})
    fleet.close()
    print(json.dumps({"summary": summary}, indent=1))
    return {"events": events, "summary": summary}


def main():
    ap = add_common_flags(argparse.ArgumentParser(), engine_default="sharded")
    ap.add_argument("--tenants", type=int, default=2)
    ap.add_argument("--queries", type=int, default=2,
                    help="queries submitted per tenant")
    ap.add_argument("--shared", action="store_true",
                    help="all tenants join the SAME corpus (plane + plan "
                         "dedup demo); default gives each tenant its own "
                         "seed")
    ap.add_argument("--max-concurrent", type=int, default=2,
                    help="fleet worker threads (queries in flight at once)")
    ap.add_argument("--byte-budget", type=int, default=None,
                    help="shared plane-store device byte budget")
    ap.add_argument("--tenant-budget", type=int, default=None,
                    help="per-tenant charged-byte budget (fair eviction)")
    ap.add_argument("--oracle-latency", type=float, default=0.0,
                    help="simulated L_p round-trip seconds per labeled "
                         "pair (GIL-released; see SimulatedOracle)")
    args = ap.parse_args()
    run_fleet(args.dataset, args.engine, args.stream, args.size, args.target,
              args.delta, args.seed, args.tenants, args.queries, args.shared,
              args.max_concurrent, args.byte_budget, args.tenant_budget,
              engine_opts=engine_opts_from(args.r_chunk),
              prefetch_depth=args.prefetch_depth,
              oracle_latency=args.oracle_latency, trace_out=args.trace_out)


if __name__ == "__main__":
    main()
