"""Production mesh entry point (assignment-mandated signature).

``make_production_mesh`` is a function (not a module-level constant) so that
importing this module never touches jax device state — required because the
dry-run must set ``XLA_FLAGS`` before any jax initialization.
"""

from __future__ import annotations

from repro.distributed.mesh import (AxisEnv, axis_size, batch_spec,
                                    l_shard_axes, make_host_mesh,
                                    make_join_mesh, make_production_mesh)

__all__ = ["make_production_mesh", "make_host_mesh", "make_join_mesh",
           "l_shard_axes", "AxisEnv", "axis_size", "batch_spec"]
