"""Join-serving launcher: load a corpus, serve a scripted query stream.

  PYTHONPATH=src python -m repro.launch.serve_join --dataset police_records \
      --engine sharded --holdout 40 \
      --script "query,query,append=20,query,append,query@target=0.8"

Script ops (comma-separated, run in order against one JoinService):

  * ``query``            — FDJ query with the launcher's base config
  * ``query@target=0.8`` — override recall target (``@stream`` toggles the
    streaming refinement pump, ``@engine=pallas`` the backend)
  * ``append[=K]``       — append K held-out R rows (default: the rest)
  * ``replan``           — query with refresh_plan=True

Prints one JSON event per op: recall/precision, plan-cache hit, delta rows
joined incrementally, per-query extraction charges (zero on the warm
path), plane-store hit rate and bytes-to-device — the serving story of
DESIGN.md §4 as a watchable stream.
"""

from __future__ import annotations

import argparse
import json

from repro.core.join import FDJConfig, QueryOptions
from repro.launch._args import (add_common_flags, engine_opts_from,
                                make_dataset)
from repro.obs import Tracer, use_tracer, write_trace
from repro.serving.join_service import DeltaRows, JoinService, hold_out_right
from repro.serving.planes import FeaturePlaneStore

# serving launchers run half the one-shot launcher's corpus scale: many
# queries per run, same wall budget
SERVE_SCALE = 0.5


def _dataset(name: str, size: float, seed: int):
    return make_dataset(name, size=size, seed=seed, scale=SERVE_SCALE)


def _take_delta(pool: DeltaRows, k: int, base_n: int):
    """First k held-out rows (as a DeltaRows) + the remaining pool."""
    k = min(k, len(pool.texts))
    cut = base_n + k
    head = DeltaRows(pool.texts[:k],
                     {f: v[:k] for f, v in pool.fields.items()},
                     {(i, j) for (i, j) in pool.truth if j < cut})
    tail = DeltaRows(pool.texts[k:],
                     {f: v[k:] for f, v in pool.fields.items()},
                     {(i, j) for (i, j) in pool.truth if j >= cut})
    return head, tail


def _parse_op(op: str) -> tuple:
    """'query@target=0.8@stream' -> ('query', {...})."""
    parts = op.split("@")
    kw: dict = {}
    for p in parts[1:]:
        if p == "stream":
            kw["stream"] = True
        elif "=" in p:
            k, v = p.split("=", 1)
            k = {"target": "recall_target", "precision": "precision_target"}\
                .get(k, k)
            kw[k] = v if k == "engine" else float(v)
        else:
            raise ValueError(f"unknown query modifier {p!r}")
    return parts[0], kw


def run_serve(dataset: str = "police_records", engine: str = "numpy",
              stream: bool = False, size: float = 1.0, target: float = 0.9,
              delta: float = 0.1, holdout: int = 0,
              script: str = "query,query", seed: int = 0,
              byte_budget=None, engine_opts=None, prefetch_depth=None,
              trace_out=None) -> dict:
    ds = _dataset(dataset, size, seed)
    pool = None
    if holdout:
        ds, pool = hold_out_right(ds, holdout)
    cfg = FDJConfig(recall_target=target, delta=delta, engine=engine,
                    stream_refinement=stream, seed=seed,
                    prefetch_depth=prefetch_depth,
                    engine_opts=engine_opts or {})
    svc = JoinService(ds, cfg, store=FeaturePlaneStore(byte_budget))
    tracer = Tracer() if trace_out else None
    events = []
    with use_tracer(tracer):
        events = _run_script(svc, script, pool)
    if tracer is not None:
        write_trace(tracer, trace_out, metadata={
            "dataset": svc.dataset.name, "engine": engine, "script": script,
            "wall_summary": svc.ledger.wall_summary(),
            "metrics": svc.metrics.as_dict(),
        })
    summary = {
        "dataset": svc.dataset.name, "n_l": svc.dataset.n_l,
        "n_r": svc.dataset.n_r, "queries": svc.queries,
        "appends": svc.appends,
        "service_ledger": {k: round(v, 6)
                           for k, v in svc.ledger.breakdown().items()},
        "serving": svc.ledger.serving_summary(),
        "latency": {k: round(v, 4) for k, v in
                    svc.metrics.histogram("serve.query_wall_s")
                    .summary().items()},
        "store": svc.store.snapshot(),
    }
    print(json.dumps({"summary": summary}, indent=1))
    return {"events": events, "summary": summary}


def _run_script(svc: JoinService, script: str, pool) -> list:
    events = []
    for raw in [s for s in script.split(",") if s.strip()]:
        name, kw = _parse_op(raw.strip())
        if name.startswith("append"):
            k = int(name.split("=", 1)[1]) if "=" in name \
                else (len(pool.texts) if pool else 0)
            if not pool or not pool.texts:
                raise ValueError("append: no held-out rows (use --holdout)")
            head, pool = _take_delta(pool, k, svc.dataset.n_r)
            info = svc.append_right(head)
            ev = {"op": raw, "rows": info["rows"],
                  "extraction_$": round(info["ledger"].inference, 6),
                  "bytes_to_device": info["store"]["bytes_to_device"],
                  "n_r": svc.dataset.n_r}
        elif name in ("query", "replan"):
            # the typed request surface (DESIGN.md §8): script modifiers
            # become one QueryOptions, same shape JoinFleet.submit takes
            named = {k: kw.pop(k) for k in
                     ("engine", "stream", "recall_target",
                      "precision_target", "delta") if k in kw}
            r = svc.query(QueryOptions(
                refresh_plan=(name == "replan"), overrides=kw, **named))
            st = r.store
            looked = st["hits"] + st["misses"]
            ev = {"op": raw, "recall": round(r.join.recall, 4),
                  "precision": round(r.join.precision, 4),
                  "pairs": len(r.pairs), "plan_hit": r.plan_hit,
                  "delta_rows": r.delta_rows,
                  "extraction_$": round(r.cost.inference, 6),
                  "plane_hit_rate": round(st["hits"] / looked, 3) if looked else None,
                  "bytes_h2d": r.cost.bytes_h2d,
                  "wall_s": round(r.wall_s, 3)}
        else:
            raise ValueError(f"unknown script op {raw!r}")
        events.append(ev)
        print(json.dumps(ev))
    return events


def main():
    ap = add_common_flags(argparse.ArgumentParser())
    ap.add_argument("--holdout", type=int, default=0,
                    help="R rows held back for append ops")
    ap.add_argument("--script", default="query,query")
    ap.add_argument("--byte-budget", type=int, default=None,
                    help="plane-store device byte budget (LRU eviction)")
    args = ap.parse_args()
    run_serve(args.dataset, args.engine, args.stream, args.size, args.target,
              args.delta, args.holdout, args.script, args.seed,
              args.byte_budget, engine_opts=engine_opts_from(args.r_chunk),
              prefetch_depth=args.prefetch_depth, trace_out=args.trace_out)


if __name__ == "__main__":
    main()
