"""FDJ join launcher — the paper's end-to-end pipeline as a CLI.

  PYTHONPATH=src python -m repro.launch.join --dataset police_records \
      --target 0.9 --delta 0.1 [--engine numpy|pallas|sharded]

Also exposes the *distributed join step* (``build_join_cell``): the fused
CNF evaluation over an L x R block plane lowered on the production mesh —
L rows sharded over (pod, data), R rows over model — which is the
paper-technique dry-run/roofline cell referenced in EXPERIMENTS.md.
"""

from __future__ import annotations

import argparse
import json


from typing import Optional

from repro.core.costs import naive_join_cost
from repro.core.join import FDJConfig, fdj_join
from repro.data.simulated_llm import SimulatedExtractor, SimulatedProposer
from repro.launch._args import (add_common_flags, engine_opts_from,
                                make_dataset)
from repro.obs import Tracer, use_tracer, write_trace


def run_join(dataset: str = "police_records", target: float = 0.9,
             delta: float = 0.1, precision_target: float = 1.0,
             engine: str = "numpy", size: float = 1.0, seed: int = 0,
             stream: bool = False, pods: int = 1,
             prefetch_depth: Optional[int] = None,
             r_chunk: Optional[int] = None,
             trace_out: Optional[str] = None) -> dict:
    ds = make_dataset(dataset, size=size, seed=seed)
    oracle = ds.make_oracle()
    cfg = FDJConfig(recall_target=target, delta=delta, engine=engine,
                    precision_target=precision_target, seed=seed,
                    stream_refinement=stream, pods=pods,
                    prefetch_depth=prefetch_depth,
                    engine_opts=engine_opts_from(r_chunk))
    tracer = Tracer() if trace_out else None
    with use_tracer(tracer):
        res = fdj_join(ds, oracle, SimulatedProposer(ds),
                       SimulatedExtractor(ds, seed=seed), cfg)
    if tracer is not None:
        write_trace(tracer, trace_out, metadata={
            "dataset": ds.name, "engine": engine, "stream": stream,
            "prefetch_depth": prefetch_depth,
            "wall_summary": res.cost.wall_summary(),
            "breakdown": res.cost.breakdown(),
        })
    naive = naive_join_cost(ds.texts_l, ds.texts_r)
    return {
        "dataset": ds.name, "n_l": ds.n_l, "n_r": ds.n_r,
        "recall": round(res.recall, 4), "precision": round(res.precision, 4),
        "recall_target": target, "t_prime": round(res.t_prime, 4),
        "met_target": res.met_target,
        "clauses": res.scaffold.clauses,
        "featurizations": [s.key for s in res.specs],
        "candidates": res.candidate_count,
        "cost_ratio": round(res.cost.total / naive, 4),
        "breakdown": {k: round(v / naive, 4) for k, v in res.cost.breakdown().items()},
        "engine": (res.engine_stats.as_dict() if res.engine_stats else None),
        "stream_refinement": stream,
        "walls": {k: round(v, 4) for k, v in res.cost.wall_summary().items()},
    }


# ---------------------------------------------------------------------------
# distributed join step (dry-run cell for the paper's technique)
# ---------------------------------------------------------------------------

def build_join_cell(mesh, *, n_l: int = 262144, n_r: int = 262144,
                    f_vec: int = 4, d: int = 128, n_clauses: int = 3):
    """jitted CNF-join step + abstract inputs, sharded over the mesh."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.distributed.mesh import AxisEnv
    from repro.kernels.fused_cnf_join import ref as cref
    from repro.kernels.fused_cnf_join.kernel import VEC

    env = AxisEnv.from_mesh(mesh)
    rows_l = env.resolve(("batch",))[0]          # (pod, data)
    rows_r = "model"
    clauses = tuple(((VEC, i),) for i in range(n_clauses))
    thetas = tuple(0.4 for _ in range(n_clauses))

    def join_step(emb_l, emb_r):
        ok = cref.cnf_join_ref(emb_l, emb_r, None, None, clauses, thetas)
        return cref.pack_mask(ok)

    sds = jax.ShapeDtypeStruct
    a_l = sds((f_vec, n_l, d), jnp.float32,
              sharding=NamedSharding(mesh, P(None, rows_l, None)))
    a_r = sds((f_vec, n_r, d), jnp.float32,
              sharding=NamedSharding(mesh, P(None, rows_r, None)))
    out_sh = NamedSharding(mesh, P(rows_l, rows_r))
    fn = jax.jit(join_step, out_shardings=out_sh)
    return fn, (a_l, a_r)


def main():
    ap = add_common_flags(argparse.ArgumentParser())
    ap.add_argument("--precision-target", type=float, default=1.0)
    ap.add_argument("--pods", type=int, default=1,
                    help="pod-axis width for the sharded engine's 3-D "
                         "(pod, data, model) join mesh (FDJConfig.pods; "
                         "needs enough devices — see launch/multipod_dryrun "
                         "for the emulated (2, 16, 16) dry-run)")
    args = ap.parse_args()
    out = run_join(args.dataset, args.target, args.delta,
                   args.precision_target, args.engine, args.size, args.seed,
                   stream=args.stream, pods=args.pods,
                   prefetch_depth=args.prefetch_depth, r_chunk=args.r_chunk,
                   trace_out=args.trace_out)
    print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
