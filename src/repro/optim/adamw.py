"""AdamW with bf16-friendly mixed precision, gradient clipping, cosine
schedule, and optional gradient compression (for cross-pod reduction).

Pure-JAX (no optax): state = {"m", "v", "step"}; m/v in float32, params kept
in float32 master copies (param_dtype) while compute casts to bf16 inside the
model.  Gradient compression quantizes the *cross-pod* all-reduce payload —
the beyond-paper distributed-optimization lever for multi-pod training.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.common.config import TrainConfig


def cosine_schedule(cfg: TrainConfig):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = cfg.learning_rate * step / jnp.maximum(cfg.warmup_steps, 1)
        t = (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
        t = jnp.clip(t, 0.0, 1.0)
        cos = 0.1 * cfg.learning_rate + 0.9 * cfg.learning_rate * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < cfg.warmup_steps, warm, cos)
    return lr


def init_opt_state(params) -> dict:
    return {
        "m": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
        "v": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def compress_grads(grads, mode: str):
    """Quantize gradients for the cross-pod reduction. Returns (payload, deq).

    fp16: cast; int8: per-leaf absmax symmetric quantization. The dequantizer
    is applied after the all-reduce (mean).  'none' is identity.
    """
    if mode == "none":
        return grads, lambda x: x
    if mode == "fp16":
        return (jax.tree.map(lambda g: g.astype(jnp.float16), grads),
                lambda t: jax.tree.map(lambda g: g.astype(jnp.float32), t))
    if mode == "int8":
        scales = jax.tree.map(
            lambda g: jnp.maximum(jnp.max(jnp.abs(g.astype(jnp.float32))), 1e-12) / 127.0,
            grads)
        q = jax.tree.map(
            lambda g, s: jnp.clip(jnp.round(g.astype(jnp.float32) / s), -127, 127
                                  ).astype(jnp.int8), grads, scales)

        def deq(t):
            return jax.tree.map(lambda g, s: g.astype(jnp.float32) * s, t, scales)
        return q, deq
    raise ValueError(mode)


def adamw_update(params, grads, opt_state, cfg: TrainConfig, lr_fn=None):
    """One AdamW step. Returns (new_params, new_opt_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = opt_state["step"] + 1
    lr = (lr_fn or cosine_schedule(cfg))(step)
    b1, b2, eps, wd = cfg.b1, cfg.b2, cfg.eps, cfg.weight_decay
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * jnp.square(g)
        mh = m2 / bc1
        vh = v2 / bc2
        p32 = p.astype(jnp.float32)
        delta = mh / (jnp.sqrt(vh) + eps) + wd * p32
        return (p32 - lr * delta).astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {"grad_norm": gnorm, "lr": lr}
