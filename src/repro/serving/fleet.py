"""JoinFleet — multi-tenant FDJ serving on one shared plane store + mesh.

One fleet fronts N ``JoinService`` tenants (DESIGN.md §8a):

  * **Shared store.**  Every tenant's planes live in one mesh-attached
    ``FeaturePlaneStore``.  Planes are content-hash keyed, so two tenants
    joining the same corpus dedup to ONE resident copy: the second
    tenant's cold query finds every plane resident and charges $0
    extraction / 0 plane H2D — its ledger proves it (``plane_dedup_hits``
    counts the hits served off another tenant's planes).  ``provide``
    holds the store lock across the whole build, so even two tenants
    racing the same cold corpus serialize into one extraction.  Plans
    dedup the same way through the shared ``PlanLibrary`` (steps ①–⑥ are
    deterministic in (corpus, cfg, seed)), so the second tenant's cold
    query re-pays *neither* planning nor plane extraction.
  * **Fair eviction.**  ``add_tenant`` registers a per-tenant byte budget
    with the store; charged bytes split evenly across an entry's owners,
    and budget pressure releases the *most-over-budget* tenant's LRU
    entries — never another tenant's working set (planes.py).
  * **Band-step interleaving.**  All sharded-engine tenants share this
    fleet's ``BandScheduler``: each band-step enqueue passes through a
    FIFO ticket gate, so K concurrent queries take turns dispatching onto
    the one mesh instead of the first sweep monopolizing the device
    queue.  Only the *enqueue* is gated — pulls, padding filters and
    oracle refinement run ungated, overlapping other queries' device
    compute (JAX async dispatch).  ``fleet.interleaves`` counts grants
    that switched queries: > 0 is the benchmark's proof that steps
    actually interleaved.
  * **Admission.**  ``submit`` enqueues a request on its tenant's FIFO
    queue and returns a future; ``max_concurrent`` workers admit requests
    round-robin across tenants (one in flight per tenant — a
    ``JoinService`` is not reentrant), so a bursty tenant cannot starve
    the others.

Requests carry the same typed ``QueryOptions`` surface as
``JoinService.query`` — the fleet adds no third request shape.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
import time
from typing import Optional

from repro.core.join import FDJConfig, QueryOptions
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import current_tracer, use_tracer
from repro.serving.join_service import (DeltaRows, JoinService, PlanLibrary,
                                        ServeResult)
from repro.serving.planes import FeaturePlaneStore


class BandScheduler:
    """FIFO ticket gate over band-step dispatch enqueues.

    Engines call ``step()`` around each band-step enqueue; tickets are
    granted strictly in arrival order, so two queries dispatching
    concurrently alternate steps on the mesh (continuous batching) and a
    query that arrives mid-sweep starts interleaving immediately instead
    of waiting out the whole incumbent sweep.  Grants are counted —
    ``interleaves`` is the number of grants handed to a different query
    (thread) than the previous grant, the observable the fleet benchmark
    gates on.
    """

    def __init__(self):
        self._cond = threading.Condition()
        self._next_ticket = 0
        self._serving = 0
        self._last_owner: Optional[int] = None
        self.band_steps = 0
        self.interleaves = 0

    @contextlib.contextmanager
    def step(self):
        with self._cond:
            ticket = self._next_ticket
            self._next_ticket += 1
            while ticket != self._serving:
                self._cond.wait()
            owner = threading.get_ident()
            self.band_steps += 1
            if self._last_owner is not None and owner != self._last_owner:
                self.interleaves += 1
            self._last_owner = owner
        try:
            yield
        finally:
            with self._cond:
                self._serving += 1
                self._cond.notify_all()


@dataclasses.dataclass
class _Request:
    tenant: str
    kind: str                      # "query" | "append"
    payload: object                # QueryOptions | DeltaRows
    tracer: object                 # ambient tracer captured at submit
    done: threading.Event = dataclasses.field(
        default_factory=threading.Event)
    result: object = None
    error: Optional[BaseException] = None
    t_submit: float = 0.0


class FleetFuture:
    """Handle for one submitted request (``result()`` blocks; re-raises
    the worker-side exception, so a failed query fails its caller)."""

    def __init__(self, req: _Request):
        self._req = req

    def result(self, timeout: Optional[float] = None):
        if not self._req.done.wait(timeout):
            raise TimeoutError(
                f"fleet request for tenant {self._req.tenant!r} still "
                f"pending after {timeout}s")
        if self._req.error is not None:
            raise self._req.error
        return self._req.result

    def done(self) -> bool:
        return self._req.done.is_set()


class JoinFleet:
    """N ``JoinService`` tenants behind one store, one mesh, one scheduler.

    ``metrics`` (``fleet.*``) aggregates across tenants: submitted /
    admitted / completed / failed counters, ``fleet.queue_wait_s`` and
    ``fleet.query_wall_s`` histograms (p50/p99 come from the histogram
    quantiles), and the scheduler's ``fleet.band_steps`` /
    ``fleet.interleaves`` published on ``drain``.  Per-tenant ledgers
    stay on each tenant's ``JoinService`` — the fleet never merges them,
    so "who paid for what" remains answerable.
    """

    def __init__(self, *, byte_budget: Optional[int] = None, mesh=None,
                 store: Optional[FeaturePlaneStore] = None,
                 max_concurrent: int = 2):
        if max_concurrent < 1:
            raise ValueError(f"max_concurrent={max_concurrent} must be >= 1")
        self.store = store or FeaturePlaneStore(byte_budget, mesh=mesh)
        self.scheduler = BandScheduler()
        self.plan_library = PlanLibrary()
        self.metrics = MetricsRegistry()
        self.max_concurrent = int(max_concurrent)
        self._services: dict = {}          # tenant -> JoinService
        self._queues: dict = {}            # tenant -> list of _Request (FIFO)
        self._running: set = set()         # tenants with a request in flight
        self._rr: list = []                # admission round-robin order
        self._rr_next = 0
        self._cond = threading.Condition()
        self._mlock = threading.Lock()     # metrics writes (inc/observe race)
        self._closed = False
        self._workers = [
            threading.Thread(target=self._worker, name=f"fleet-worker-{i}",
                             daemon=True)
            for i in range(self.max_concurrent)]
        for w in self._workers:
            w.start()

    # -- tenants -------------------------------------------------------------

    def add_tenant(self, name: str, dataset, cfg: Optional[FDJConfig] = None,
                   *, byte_budget: Optional[int] = None,
                   **service_kwargs) -> JoinService:
        """Register a tenant: a ``JoinService`` over the shared store, its
        byte budget registered for fair eviction, and its sharded-engine
        dispatches routed through the fleet scheduler."""
        if self._closed:
            raise RuntimeError("fleet is closed")
        with self._cond:
            if name in self._services:
                raise ValueError(f"tenant {name!r} already registered")
            self.store.register_tenant(name, byte_budget)
            svc = JoinService(dataset, self._gated_cfg(cfg or FDJConfig()),
                              store=self.store, tenant=name,
                              plan_library=self.plan_library,
                              **service_kwargs)
            self._services[name] = svc
            self._queues[name] = []
            self._rr.append(name)
            return svc

    def service(self, name: str) -> JoinService:
        return self._services[name]

    @property
    def tenants(self) -> list:
        return list(self._rr)

    def _gated_cfg(self, cfg: FDJConfig) -> FDJConfig:
        """Route the config's sharded-engine dispatches through this
        fleet's scheduler.  Flat engine_opts are first keyed under the
        config's own engine so the scheduler entry never leaks into
        another backend's constructor."""
        from repro.engine import ENGINES
        opts = dict(cfg.engine_opts)
        if opts and not (set(opts) <= set(ENGINES)):
            opts = {cfg.engine: opts}
        sharded = dict(opts.get("sharded", {}))
        sharded["scheduler"] = self.scheduler
        opts["sharded"] = sharded
        return cfg.with_overrides(engine_opts=opts)

    # -- submission ----------------------------------------------------------

    def submit(self, tenant: str,
               options: Optional[QueryOptions] = None) -> FleetFuture:
        """Enqueue one query for ``tenant``; returns a future.  The same
        ``QueryOptions`` type ``JoinService.query`` takes — the fleet is
        a scheduler, not a third API."""
        return self._submit(tenant, "query", options or QueryOptions())

    def submit_append(self, tenant: str, rows: DeltaRows,
                      options: Optional[QueryOptions] = None) -> FleetFuture:
        """Enqueue an R-append for ``tenant`` (serialized with its queries
        by the per-tenant admission slot, so growth is ordered)."""
        return self._submit(tenant, "append", (rows, options))

    def _submit(self, tenant: str, kind: str, payload) -> FleetFuture:
        req = _Request(tenant, kind, payload, tracer=current_tracer() or None,
                       t_submit=time.perf_counter())
        with self._cond:
            if self._closed:
                raise RuntimeError("fleet is closed")
            if tenant not in self._services:
                raise KeyError(f"unknown tenant {tenant!r}")
            self._queues[tenant].append(req)
            self._cond.notify_all()
        with self._mlock:
            self.metrics.inc("fleet.submitted")
        return FleetFuture(req)

    def query(self, tenant: str,
              options: Optional[QueryOptions] = None) -> ServeResult:
        """Submit + wait — the synchronous convenience wrapper."""
        return self.submit(tenant, options).result()

    # -- admission loop ------------------------------------------------------

    def _next_request(self) -> Optional[_Request]:
        """Round-robin admission across tenants (caller holds the lock):
        scan from the cursor, skip tenants that are empty or already
        running, advance the cursor past the pick so service rotates."""
        n = len(self._rr)
        for i in range(n):
            idx = (self._rr_next + i) % n
            tenant = self._rr[idx]
            if tenant in self._running or not self._queues[tenant]:
                continue
            self._rr_next = (idx + 1) % n
            self._running.add(tenant)
            return self._queues[tenant].pop(0)
        return None

    def _worker(self) -> None:
        while True:
            with self._cond:
                req = self._next_request()
                while req is None:
                    if self._closed:
                        return
                    self._cond.wait()
                    req = self._next_request()
            self._run(req)

    def _run(self, req: _Request) -> None:
        svc = self._services[req.tenant]
        wait_s = time.perf_counter() - req.t_submit
        with self._mlock:
            self.metrics.inc("fleet.admitted")
            self.metrics.observe("fleet.queue_wait_s", wait_s)
        t0 = time.perf_counter()
        try:
            with use_tracer(req.tracer):
                with current_tracer().span(
                        f"fleet.{req.kind}", track=f"tenant:{req.tenant}",
                        tenant=req.tenant):
                    if req.kind == "query":
                        req.result = svc.query(req.payload)
                    else:
                        rows, options = req.payload
                        req.result = svc.append_right(rows, options)
            with self._mlock:
                self.metrics.inc("fleet.completed")
                self.metrics.observe("fleet.query_wall_s",
                                     time.perf_counter() - t0)
        except BaseException as e:      # delivered to the caller, not lost
            req.error = e
            with self._mlock:
                self.metrics.inc("fleet.failed")
        finally:
            with self._cond:
                self._running.discard(req.tenant)
                self._cond.notify_all()
            req.done.set()

    # -- lifecycle -----------------------------------------------------------

    def drain(self) -> dict:
        """Wait until every queue is empty and nothing is in flight, then
        publish scheduler totals into the metrics and return a summary."""
        with self._cond:
            while any(self._queues.values()) or self._running:
                self._cond.wait()
        with self._mlock:
            sched = self.scheduler
            for name, v in (("band_steps", sched.band_steps),
                            ("interleaves", sched.interleaves)):
                m = f"fleet.{name}"
                self.metrics.inc(m, v - self.metrics.value(m))
            return {
                "tenants": list(self._rr),
                "band_steps": sched.band_steps,
                "interleaves": sched.interleaves,
                "submitted": self.metrics.value("fleet.submitted"),
                "completed": self.metrics.value("fleet.completed"),
                "failed": self.metrics.value("fleet.failed"),
                "store": self.store.snapshot(),
            }

    def close(self) -> None:
        """Drain, then stop the workers (idempotent)."""
        self.drain()
        with self._cond:
            self._closed = True
            self._cond.notify_all()
        for w in self._workers:
            w.join(timeout=10)

    def __enter__(self) -> "JoinFleet":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
