"""JoinService — repeated FDJ queries over a device-resident plane store.

The serving regime (ROADMAP north star) answers many join queries against
the same tables: different recall/precision targets, different engines,
re-runs as rows arrive.  A one-shot ``fdj_join`` re-pays steps ①–⑦ every
time; the service splits the pipeline at its two durable artifacts:

  * **plans** (steps ①–⑥, ``core.join.plan_join``) — cached per query
    parameters.  A repeated query skips sampling, generation, scaffolding
    and thresholding entirely; because every stage is deterministic in
    (corpus, cfg, seed), replaying a cached plan is byte-identical to a
    cold run.
  * **planes** (step ⑦) — pinned in a ``FeaturePlaneStore``.  The warm
    path charges zero extraction dollars and moves zero plane bytes to the
    device; all three ``CnfEngine`` backends (and their streaming mode +
    ``RefinementPump``) are fed directly from the store via the
    ``plane_provider`` seam of ``execute_join``.

**Delta joins.**  ``append_right(rows)`` grows R in place: resident R
planes are extended by extracting *only the appended rows* (embed planes
are row-independent; scalar planes re-normalize from stored raw values
when the whole-corpus scale statistic shifts — see planes.py).  The next
query under a cached plan then evaluates only L × ΔR through the engine
and merges candidates/accepted pairs with the cached result, which is
exactly equivalent to evaluating the full concatenated corpus under the
same plan (CNF evaluation and precision-1 refinement are per-pair
independent; tests/test_join_service.py proves pair equality against a
cold materialization of the grown corpus).  The Appx-C precision path
(T_P < 1) needs whole-candidate-set quantiles, so those queries fall back
to full evaluation.

Plans are carried forward across appends (the delta-join contract,
DESIGN.md §4): the recall guarantee transfers under the usual sampling
assumption that appended rows are drawn from the same distribution the
plan was calibrated on.  ``query(refresh_plan=True)`` re-plans against
the current corpus when that assumption is in doubt.

**Online recalibration (DESIGN.md §4a).**  Carrying theta forward makes
the recall guarantee a *plan-time* statement; appends that shift the
plane distributions (new rows wordier, rescaled scalars) silently void
it.  The service therefore keeps a labeled *reservoir* per cached plan —
seeded for free from the plan's own threshold sample S′ — and, on the
first query after the corpus grew, tops it up with delta-region pairs
(labeled; the only new dollars), re-runs ``adj_target`` at the grown
pair count, and checks the cached theta against the refreshed target
T′.  Reservoir distances come free from the resident planes.  If the
cached theta still meets T′ the check is all that happens — the delta
path and its eval cache survive untouched, so stable distributions keep
the cheap incremental join.  If it fails, the device threshold sweep
re-solves Eq 4 on the reservoir, theta is hot-swapped in the cached
plan, and the (now-stale) cached evaluation is dropped.  Counters
(``recalibrations``, ``theta_swaps``, ``theta_drift``,
``reservoir_cost``) land in the ``CostLedger``; gate ``recalibrate``
off in ``FDJConfig`` for the historical carry-forward behavior.
"""

from __future__ import annotations

import contextlib
import copy
import dataclasses
import math
import threading
import time
import warnings
from collections import OrderedDict
from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.adj_target import adj_target
from repro.core.costs import CostLedger
from repro.core.featurize import distance_stack, vectorize
from repro.core.join import (FDJConfig, JoinPlan, JoinResult, QueryOptions,
                             _get_engine, apply_conjunct_order, execute_join,
                             make_label_fn, plan_join)
from repro.core.scaffold import min_fpr_thresholds, ordered_conjuncts
from repro.core.refine import RefinementPump
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import current_tracer
from repro.serving.planes import (FeaturePlaneStore,
                                  corpus_fingerprint)


@dataclasses.dataclass
class DeltaRows:
    """R rows to append: record texts, per-field values, and the ground
    truth pairs they add (global (i, j) indices, used for evaluation)."""
    texts: list
    fields: dict
    truth: set


def hold_out_right(ds, n_delta: int):
    """Split a dataset into a base view (R minus the last ``n_delta`` rows)
    plus the held-out ``DeltaRows`` — the benchmark/test fixture for the
    append path.  The base keeps the dataset name, so extraction
    determinism (keyed by (name, side, record index)) is preserved and
    ``base + delta`` is content-identical to the original."""
    cut = ds.n_r - n_delta
    if cut <= 0:
        raise ValueError(f"n_delta={n_delta} >= n_r={ds.n_r}")
    base = dataclasses.replace(
        ds,
        texts_r=list(ds.texts_r[:cut]),
        fields_r={k: list(v[:cut]) for k, v in ds.fields_r.items()},
        truth_set={(i, j) for (i, j) in ds.truth_set if j < cut},
        self_join=False)
    delta = DeltaRows(
        texts=list(ds.texts_r[cut:]),
        fields={k: list(v[cut:]) for k, v in ds.fields_r.items()},
        truth={(i, j) for (i, j) in ds.truth_set if j >= cut})
    return base, delta


def perturb_rows(rows: DeltaRows, *, n_tokens: int = 4,
                 seed: int = 0) -> DeltaRows:
    """Distribution-shifted copy of ``rows``: deterministic junk tokens are
    appended to every non-empty string field value (and record text).  The
    ground-truth pairs are untouched, but token-overlap and n-gram embed
    similarities between the appended rows and their true L matches drop,
    inflating clause distances — exactly the recall-threatening shift the
    delta-join contract assumes away.  The recalibration tests and the
    calibration benchmark replay this as their scripted append stream."""
    rng = np.random.default_rng(seed)

    def junk() -> str:
        return " ".join(
            "zq" + "".join(chr(97 + int(rng.integers(26))) for _ in range(6))
            for _ in range(n_tokens))

    fields = {}
    for k, vals in rows.fields.items():
        fields[k] = [v + " " + junk() if isinstance(v, str) and v else v
                     for v in vals]
    texts = [t + " " + junk() for t in rows.texts]
    return DeltaRows(texts=texts, fields=fields, truth=set(rows.truth))


@dataclasses.dataclass
class ServeResult:
    join: JoinResult               # pairs / recall / precision / ledger / stats
    plan_hit: bool                 # steps ①–⑥ served from the plan cache
    delta_rows: int                # R rows evaluated incrementally (0 = full)
    store: dict                    # this query's plane-store counter delta
    wall_s: float

    @property
    def pairs(self) -> set:
        return self.join.pairs

    @property
    def cost(self) -> CostLedger:
        return self.join.cost


@dataclasses.dataclass
class _EvalCache:
    n_r: int                       # R extent the cached evaluation covers
    candidates: list               # sorted step-② survivors at that extent
    pairs: set                     # accepted output pairs at that extent
    scales: tuple                  # per-used-spec scalar normalization at
                                   # eval time (None for embed kinds) — the
                                   # delta path is only exact while these
                                   # hold, so a shift forces re-evaluation


@dataclasses.dataclass
class _Reservoir:
    """Labeled calibration reservoir for one cached plan: a uniform pair
    sample over the L×R region it currently covers (``n_r`` marks the R
    extent), kept representative across appends by proportional top-up —
    and proportional down-sampling of the old region once ``reservoir_cap``
    binds.  Seeded for free from the plan's threshold sample S′; only the
    delta-region top-ups pay new oracle labels (``dollars``)."""
    pairs: list                    # global (i, j) pairs
    labels: np.ndarray             # (len(pairs),) bool oracle labels
    n_r: int                       # R extent the sample uniformly covers
    dollars: float = 0.0           # cumulative top-up labeling spend


def _plane_scales(planes) -> tuple:
    if planes is None:
        return ()
    return tuple(f.scale if f.kind == "scalar" else None for f in planes)


class PlanLibrary:
    """Cross-tenant plan dedup for the fleet (DESIGN.md §8a).

    ``plan_join`` is deterministic in (corpus, cfg, seed) — the basis of
    the per-service plan cache — so two tenants planning the same corpus
    under the same plan key would rebuild byte-identical plans, re-paying
    sampling, generation and threshold labeling.  The library memoizes
    plans by (fp_l, fp_r, plan key) across services sharing it: the
    second tenant's cold query charges $0 for steps ①–⑥, completing the
    shared-store story (planes dedup step ⑦; this dedups ①–⑥).

    Plans are mutable serving state (recalibration hot-swaps theta), so
    the library never shares an object: it stores a snapshot on ``put``
    and loans a deep copy on ``get`` — one tenant's theta swap can never
    bleed into another's guarantee.  LRU-bounded, lock-guarded.

    ``lease(key)`` serializes cold planning per key (the analogue of the
    store lock held through ``provide``): two tenants racing the same
    cold (corpus, plan key) plan once — the loser wakes to a library hit.
    """

    _MAX = 32

    def __init__(self):
        self._plans: OrderedDict = OrderedDict()
        self._lock = threading.Lock()
        self._leases: dict = {}        # key -> [per-key lock, holder count]
        self.hits = 0
        self.misses = 0

    @contextlib.contextmanager
    def lease(self, key: tuple):
        # refcounted so the entry dies with its last holder: a long-lived
        # fleet rotating plan keys must not accumulate one Lock per key
        # ever leased (keys cached in _plans used to pin theirs forever)
        with self._lock:
            entry = self._leases.get(key)
            if entry is None:
                lk = threading.Lock()
                entry = self._leases[key] = [lk, 0]
            else:
                lk = entry[0]
            entry[1] += 1
        try:
            with lk:
                yield
        finally:
            with self._lock:
                entry[1] -= 1
                if entry[1] == 0 and self._leases.get(key) is entry:
                    del self._leases[key]

    def get(self, key: tuple) -> Optional[JoinPlan]:
        with self._lock:
            plan = self._plans.get(key)
            if plan is None:
                self.misses += 1
                return None
            self.hits += 1
            self._plans.move_to_end(key)
            return copy.deepcopy(plan)

    def put(self, key: tuple, plan: JoinPlan) -> None:
        with self._lock:
            self._plans[key] = copy.deepcopy(plan)
            self._plans.move_to_end(key)
            while len(self._plans) > self._MAX:
                self._plans.popitem(last=False)


class JoinService:
    """Serve repeated ``fdj_join`` queries against one (growing) corpus.

    Each query gets a fresh oracle/extractor and its own ``CostLedger`` —
    the store and the plan cache are the *only* cross-query memory, so the
    per-query ledger honestly reports what serving saved (a fresh
    extractor would re-charge everything the store didn't absorb).
    Ledgers accumulate into ``self.ledger``.
    """

    _EVAL_CACHE_MAX = 8            # candidate lists retained for delta joins
    _PLAN_CACHE_MAX = 16           # cached plans (LRU, same discipline the
    #   sharded engine's _programs got in PR 7): a long-lived tenant with
    #   rotating configs must not leak plans + their reservoirs unboundedly

    def __init__(self, dataset, cfg: Optional[FDJConfig] = None, *,
                 store: Optional[FeaturePlaneStore] = None,
                 extractor_factory: Optional[Callable] = None,
                 proposer_factory: Optional[Callable] = None,
                 tenant: Optional[str] = None,
                 plan_library: Optional[PlanLibrary] = None,
                 oracle_factory: Optional[Callable] = None):
        from repro.data.simulated_llm import (SimulatedExtractor,
                                              SimulatedProposer)
        self.dataset = dataset
        self.cfg = cfg or FDJConfig()
        self.store = store or FeaturePlaneStore()
        self.tenant = tenant       # fleet identity: store-ownership and
        #   fair-eviction attribution for every plane this service touches
        self._plan_library = plan_library  # cross-tenant plan dedup (fleet)
        # late-bound: append_right swaps self.dataset for the grown corpus,
        # and the default oracle must follow it (a custom factory that
        # closes over a dataset owns that tracking itself)
        self._oracle_factory = oracle_factory or \
            (lambda: self.dataset.make_oracle())
        self._extractor_factory = extractor_factory or \
            (lambda ds: SimulatedExtractor(ds, seed=self.cfg.seed))
        self._proposer_factory = proposer_factory or \
            (lambda ds: SimulatedProposer(ds))
        self._fp_l = corpus_fingerprint(dataset.name, "l", dataset.texts_l,
                                        dataset.fields_l)
        self._fp_r = corpus_fingerprint(dataset.name, "r", dataset.texts_r,
                                        dataset.fields_r)
        self._plans: dict = {}     # plan key -> JoinPlan
        self._evals: dict = {}     # plan key -> _EvalCache
        self._reservoirs: dict = {}  # plan key -> _Reservoir (calibration)
        self.ledger = CostLedger() # service-lifetime accumulation
        # service-lifetime metrics (DESIGN.md §7).  Each per-query/append
        # ledger is bound to this registry, so every flow feeds it exactly
        # once as it happens; the lifetime ledger stays UNbound — its
        # ``absorb`` would re-feed the same flows.  Invariant:
        # ``ledger_from_metrics(self.metrics) == self.ledger`` at all times
        # (tests/test_obs.py pins it).
        self.metrics = MetricsRegistry()
        self.queries = 0
        self.appends = 0

    # -- internals ----------------------------------------------------------

    def _plan_key(self, cfg: FDJConfig) -> tuple:
        """Everything steps ①–⑥ depend on besides the corpus itself."""
        return (cfg.recall_target, cfg.precision_target, cfg.delta,
                cfg.gen_positives, cfg.thresh_positives, cfg.alpha, cfg.beta,
                cfg.gamma, cfg.max_iter, cfg.mc_trials, cfg.seed)

    def _provider(self, extractor) -> Callable:
        def provide(specs, ledger):
            return self.store.provide(specs, extractor, ledger,
                                      fp_l=self._fp_l, fp_r=self._fp_r,
                                      tenant=self.tenant)
        return provide

    @staticmethod
    def _coerce_options(options, legacy: dict) -> QueryOptions:
        """The one options path (DESIGN.md §8): a ``QueryOptions`` is used
        as-is; the historical kwarg surface (five special-cased kwargs +
        open-ended ``**cfg_overrides``) is a deprecation shim that routes
        through ``QueryOptions.from_legacy`` — parity-tested
        byte-identical in tests/test_query_options.py."""
        legacy = {k: v for k, v in legacy.items() if v is not None}
        if options is not None:
            if legacy:
                raise TypeError(
                    f"pass either options=QueryOptions(...) or legacy "
                    f"kwargs, not both (got {sorted(legacy)})")
            return options
        if legacy:
            warnings.warn(
                "JoinService per-query kwargs are deprecated; pass "
                "options=QueryOptions(...) instead", DeprecationWarning,
                stacklevel=3)
        return QueryOptions.from_legacy(**legacy)

    # -- queries ------------------------------------------------------------

    def query(self, options: Optional[QueryOptions] = None, *,
              refresh_plan: Optional[bool] = None,
              incremental: Optional[bool] = None,
              **legacy_overrides) -> ServeResult:
        """One FDJ query against the current corpus.

        ``options`` is the typed request surface shared with
        ``JoinFleet.submit``; the keyword form is the deprecated legacy
        shim (see ``_coerce_options``).

        Warm-path invariants (tests/test_join_service.py): a repeated
        query reports zero extraction charges, zero plane H2D bytes, and
        returns pairs byte-identical to a cold ``fdj_join`` with the same
        config, on every engine and in stream mode.
        """
        opts = self._coerce_options(
            options, dict(legacy_overrides, refresh_plan=refresh_plan,
                          incremental=incremental))
        tracer = current_tracer()
        with tracer.span("query", n=self.queries) as sp:
            out = self._query_impl(opts)
            if tracer:
                sp.set(engine=out.join.engine_stats.engine
                       if out.join.engine_stats else "none",
                       plan_hit=out.plan_hit, delta_rows=out.delta_rows,
                       candidates=out.join.candidate_count)
        self.metrics.inc("serve.plan_hits" if out.plan_hit
                         else "serve.plan_misses")
        self.metrics.observe("serve.query_wall_s", out.wall_s)
        return out

    def _query_impl(self, opts: QueryOptions) -> ServeResult:
        t0 = time.perf_counter()
        refresh_plan, incremental = opts.refresh_plan, opts.incremental
        cfg = opts.resolve(self.cfg)

        qledger = CostLedger()
        qledger.bind_metrics(self.metrics)   # flows feed once, as they happen
        oracle = self._oracle_factory()
        oracle.ledger = qledger
        label = make_label_fn(oracle, {})
        extractor = self._extractor_factory(self.dataset)
        snap0 = self.store.snapshot()

        key = self._plan_key(cfg)
        plan = self._plans.get(key)
        plan_hit = plan is not None and not refresh_plan
        if plan_hit:
            self._plans.pop(key)            # LRU: hit refreshes recency
            self._plans[key] = plan
        else:
            lib = self._plan_library
            lib_key = (self._fp_l, self._fp_r, key)

            def build():
                return plan_join(self.dataset, oracle,
                                 self._proposer_factory(self.dataset),
                                 extractor, cfg, ledger=qledger, label=label)

            if lib is not None and not refresh_plan:
                # cross-tenant dedup: a sibling service already planned
                # this exact (corpus, plan key) — determinism makes the
                # loaned copy byte-identical to planning it here.  The
                # lease serializes racing colds, so the loser wakes to a
                # hit instead of planning the same thing twice.
                with lib.lease(lib_key):
                    plan = lib.get(lib_key)
                    plan_hit = plan is not None
                    if plan is None:
                        plan = build()
                        lib.put(lib_key, plan)
            else:
                plan = build()
                if lib is not None:
                    lib.put(lib_key, plan)
            self._plans.pop(key, None)
            self._plans[key] = plan
            self._evals.pop(key, None)      # plan rebuilt: stale evaluation
            self._reservoirs.pop(key, None)
            if plan.calib_pairs is not None:
                # seed the calibration reservoir from the plan's own labeled
                # threshold sample — step ④ already paid for these labels
                self._reservoirs[key] = _Reservoir(
                    pairs=list(plan.calib_pairs),
                    labels=np.asarray(plan.calib_labels, bool).copy(),
                    n_r=self.dataset.n_r)
            while len(self._plans) > self._PLAN_CACHE_MAX:
                # bounded, like _programs (PR 7): a tenant rotating configs
                # must not pin plans + reservoirs + eval caches forever.
                # Evicting a plan drops its dependents — they are keyed by
                # it and unreachable once it is gone.
                old = next(iter(self._plans))
                self._plans.pop(old)
                self._evals.pop(old, None)
                self._reservoirs.pop(old, None)

        # capture the plane set execute/delta consumed: the eval cache must
        # remember the scalar normalizations its candidates were computed
        # under (the delta path is only exact while those hold)
        raw_provider = self._provider(extractor)
        captured: dict = {}

        def provider(specs, led):
            # one provide() per query: a delta-path fallback re-enters via
            # execute_join, which must reuse the already-provided planes
            # rather than re-counting store hits
            if "planes" not in captured:
                captured["planes"] = raw_provider(specs, led)
            return captured["planes"]

        # online guarantee recalibration (DESIGN.md §4a): before the plan is
        # replayed over a grown corpus, check its theta against a refreshed
        # reservoir + adjusted target, hot-swapping when the invariant broke.
        # Must run before the delta path — a swap invalidates the cached
        # evaluation (its candidates were produced under the old theta).
        res = self._reservoirs.get(key)
        if (cfg.recalibrate and plan_hit and not plan.degenerate
                and res is not None and res.n_r < self.dataset.n_r):
            with current_tracer().span("recalibrate",
                                       reservoir=len(res.pairs)):
                self._recalibrate(cfg, key, plan, res, label, provider,
                                  qledger)

        cached = self._evals.get(key)
        n_r = self.dataset.n_r
        delta_rows = 0
        jr = None
        if (incremental and cached is not None and cached.n_r < n_r
                and cfg.precision_target >= 1.0):
            jr = self._delta_execute(cfg, plan, cached, label, provider,
                                     qledger)
            if jr is not None:
                delta_rows = n_r - cached.n_r
        if jr is None:
            # degenerate plans skip candidate retention: the delta path
            # regenerates the cross product directly, so caching O(n_l·n_r)
            # tuples would pin memory for nothing
            jr = execute_join(self.dataset, oracle, extractor, cfg, plan,
                              plane_provider=provider, ledger=qledger,
                              label=label,
                              keep_candidates=not plan.degenerate)
        self._evals.pop(key, None)           # re-insert at MRU position
        self._evals[key] = _EvalCache(n_r, jr.candidates, set(jr.pairs),
                                      _plane_scales(captured.get("planes")))
        while len(self._evals) > self._EVAL_CACHE_MAX:
            # bounded: each cache pins a full candidate list; dropping one
            # only costs the next query under that plan a full evaluation
            self._evals.pop(next(iter(self._evals)))

        diff = FeaturePlaneStore.delta(snap0, self.store.snapshot())
        qledger.record_plane_traffic(
            hits=diff["hits"], misses=diff["misses"],
            dedup_hits=diff["dedup_hits"],
            evicted_bytes=diff["evicted_bytes"],
            resident_bytes=diff["resident_bytes"],
            bytes_h2d=diff["bytes_to_device"]
            + (jr.engine_stats.bytes_h2d if jr.engine_stats else 0),
            bytes_reshard=(jr.engine_stats.bytes_reshard
                           if jr.engine_stats else 0))
        self.ledger.absorb(qledger)
        self.queries += 1
        return ServeResult(join=jr, plan_hit=plan_hit, delta_rows=delta_rows,
                           store=diff, wall_s=time.perf_counter() - t0)

    def _recalibrate(self, cfg: FDJConfig, key: tuple, plan: JoinPlan,
                     res: _Reservoir, label, provider,
                     qledger: CostLedger) -> None:
        """Refresh the plan's calibration reservoir to the grown corpus and
        re-establish the recall invariant (DESIGN.md §4a).

        1. Top up the reservoir with uniform delta-region pairs, sized
           proportionally to the appended area so the sample stays uniform
           over the grown L×R (down-sampling the old region once
           ``reservoir_cap`` binds).  Labels are the only new dollars.
        2. Re-run ``adj_target`` at the grown pair count -> refreshed T′.
        3. Check the cached theta's recall on the reservoir (distances are
           free — they come from the already-resident planes, under the
           *current* normalization, so scalar rescales are seen too).
        4. Only if the invariant broke: re-solve Eq 4 via the device sweep,
           hot-swap theta/T′ in the cached plan, and drop the cached
           evaluation (its candidates predate the swap).
        """
        n_l, n_r = self.dataset.n_l, self.dataset.n_r
        off = res.n_r
        rng = np.random.default_rng([abs(cfg.seed), off, n_r])
        old_area = n_l * off
        delta_area = n_l * (n_r - off)
        # --- 1. proportional top-up ---------------------------------------
        n_new = int(math.ceil(len(res.pairs) * delta_area / max(old_area, 1)))
        cap = max(int(cfg.reservoir_cap), 1)
        if len(res.pairs) + n_new > cap:
            frac_old = old_area / max(old_area + delta_area, 1)
            n_keep = min(max(int(round(cap * frac_old)), 1), len(res.pairs))
            n_new = max(cap - n_keep, 0)
            keep = np.sort(rng.choice(len(res.pairs), size=n_keep,
                                      replace=False))
            res.pairs = [res.pairs[i] for i in keep]
            res.labels = res.labels[keep]
        n_new = min(n_new, delta_area)
        spent0 = qledger.labeling
        if n_new > 0:
            width = n_r - off
            flat = rng.choice(delta_area, size=n_new, replace=False)
            new_pairs = [(int(t // width), off + int(t % width))
                         for t in flat]
            new_labels = label(new_pairs, "labeling")
            res.pairs = res.pairs + new_pairs
            res.labels = np.concatenate([res.labels,
                                         np.asarray(new_labels, bool)])
        res.n_r = n_r
        dollars = qledger.labeling - spent0
        res.dollars += dollars

        # --- 2. refreshed adjusted target ---------------------------------
        k_plus = int(res.labels.sum())
        if k_plus == 0:
            # no positives to calibrate against: record the check and keep
            # the cached theta (nothing sounder is computable from here)
            qledger.record_recalibration(swapped=False, drift=0.0,
                                         dollars=dollars)
            return
        delta_recall = cfg.delta if cfg.precision_target >= 1.0 \
            else cfg.delta / 2.0
        adj = adj_target(k_plus, plan.sc_local.n_clauses, cfg.recall_target,
                         delta_recall, n_pairs=n_l * n_r,
                         k_sample=len(res.pairs), n_trials=cfg.mc_trials,
                         seed=cfg.seed)

        # --- 3. invariant check on free plane distances -------------------
        planes = provider(plan.used_specs, qledger)
        cd = plan.sc_local.clause_distances(
            distance_stack(list(planes), res.pairs))
        sel = np.all(cd <= plan.theta[None, :], axis=1)
        recall = float((sel & res.labels).sum()) / k_plus
        if recall >= adj.t_prime - 1e-12:
            plan.t_prime = adj.t_prime
            qledger.record_recalibration(swapped=False, drift=0.0,
                                         dollars=dollars)
            return

        # --- 4. re-sweep + hot-swap ---------------------------------------
        with current_tracer().span("theta_swap") as sp:
            thr = min_fpr_thresholds(cd, res.labels, adj.t_prime,
                                     method="auto")
            old_theta = np.asarray(plan.theta, float)
            drift = float(np.max(np.abs(thr.theta - old_theta))) \
                if thr.theta.shape == old_theta.shape else float("inf")
            plan.theta = thr.theta
            plan.t_prime = adj.t_prime
            plan.feasible = thr.feasible
            # new thresholds move per-conjunct pass rates: refresh the
            # cached plan's evaluation order from the same reservoir
            # distances (free — cd is already in hand; candidate set
            # invariant either way)
            plan.conjunct_order = ordered_conjuncts(cd, thr.theta,
                                                    plan.sc_local.clauses)
            self._evals.pop(key, None)      # candidates predate the swap
            sp.set(drift=drift, t_prime=adj.t_prime)
        qledger.record_recalibration(swapped=True, drift=drift,
                                     dollars=dollars)

    def _delta_execute(self, cfg: FDJConfig, plan: JoinPlan,
                       cached: _EvalCache, label, provider,
                       qledger: CostLedger) -> Optional[JoinResult]:
        """Evaluate only L × ΔR under the cached plan and merge.

        Exactness: the CNF decides each pair independently and precision-1
        refinement is a per-pair oracle call, so (cached result on R[:off])
        ∪ (this evaluation on R[off:]) equals a full evaluation of the
        grown corpus under the same plan, pair for pair — PROVIDED the
        plane normalizations the cached candidates were computed under
        still hold.  A scalar plane whose whole-corpus scale shifted (a
        rescaling append, or a plane that was evicted and re-extracted on
        the grown corpus) changes distances for the *old* rows too, so
        this returns None and the caller re-evaluates in full.
        """
        off = cached.n_r
        n_l, n_r = self.dataset.n_l, self.dataset.n_r
        engine_stats = None
        if plan.degenerate:
            # refine-everything over L × ΔR, labeled in bounded row blocks
            # (the same chunking policy as core.join's barrier fallback —
            # never one O(n_l·Δn_r) host list)
            from repro.engine.base import iter_cross_product_chunks
            t0 = time.perf_counter()
            accepted = set()
            for block in iter_cross_product_chunks(n_l, n_r - off):
                block = [(i, j + off) for (i, j) in block]
                labs = label(block, "refinement")
                accepted |= {p for p, l in zip(block, labs) if l}
            qledger.record_walls(0.0, time.perf_counter() - t0, 0.0)
        else:
            planes = provider(plan.used_specs, qledger)
            if _plane_scales(planes) != cached.scales:
                return None          # normalization shifted: delta inexact
            sub = planes.slice_r(off)
            eng = _get_engine(cfg)
            # the delta join evaluates under the cached plan's measured
            # conjunct order — same permutation the full evaluation used,
            # so the merge stays bit-exact (order never changes the set)
            d_clauses, d_theta = apply_conjunct_order(
                plan.sc_local.clauses, plan.theta,
                plan.conjunct_order if cfg.order_conjuncts else None)
            if cfg.stream_refinement:
                def shifted(chunks):
                    for ch in chunks:
                        ch.candidates = [(i, j + off)
                                         for (i, j) in ch.candidates]
                        yield ch

                def refine_chunk(batch):
                    labs = label(batch, "refinement")
                    return {p for p, l in zip(batch, labs) if l}

                pump = RefinementPump(refine_chunk,
                                      batch_pairs=cfg.refine_batch_pairs,
                                      max_queue_chunks=cfg.pump_queue_chunks)
                pr = pump.run(shifted(eng.evaluate_stream(
                    sub, d_clauses, d_theta)), ledger=qledger)
                delta_cands = pr.candidates
                accepted = pr.pairs
                engine_stats = pr.engine_stats
            else:
                res = eng.evaluate(sub, d_clauses, d_theta)
                delta_cands = [(i, j + off) for (i, j) in res.candidates]
                engine_stats = res.stats
                t0 = time.perf_counter()
                labs = label(delta_cands, "refinement")
                accepted = {p for p, l in zip(delta_cands, labs) if l}
                qledger.record_walls(res.stats.wall_s,
                                     time.perf_counter() - t0, 0.0)
                qledger.record_engine_stats(engine_stats)

        out_pairs = set(cached.pairs) | accepted
        if plan.degenerate:
            # candidates are definitionally the full cross product: count
            # without retaining O(n_l·n_r) tuples in the cache
            candidates, n_cands = None, n_l * n_r
        else:
            candidates = sorted(cached.candidates + list(delta_cands))
            n_cands = len(candidates)
        truth = self.dataset.truth_set
        tp = len(out_pairs & truth)
        recall = tp / max(len(truth), 1)
        precision = tp / max(len(out_pairs), 1) if out_pairs else 1.0
        return JoinResult(
            pairs=out_pairs, recall=recall, precision=precision,
            cost=qledger, scaffold=plan.scaffold, specs=plan.specs,
            theta=plan.theta, t_prime=plan.t_prime,
            candidate_count=n_cands,
            met_target=(recall >= cfg.recall_target - 1e-12
                        and precision >= cfg.precision_target - 1e-12),
            engine_stats=engine_stats, candidates=candidates)

    # -- appends ------------------------------------------------------------

    def append_right(self, rows: DeltaRows,
                     options: Optional[QueryOptions] = None) -> dict:
        """Append R rows, extending resident R planes by the delta only.

        ``options`` is accepted for call-shape parity with ``query`` and
        ``JoinFleet.submit`` (scripted drivers carry one request type); an
        append itself is config-independent, so the options are validated
        against the base config and otherwise unused.

        Returns the append's ledger + store counter delta.  Cached plans
        and cached evaluations survive — the next query under a cached
        plan joins only L × ΔR (see ``_delta_execute``).

        "Delta only" is a statement about the expensive resources —
        extraction charges and bytes to device scale with ΔR.  Host-side
        bookkeeping (re-fingerprinting the grown side, list copies, and
        the simulated extractor's per-side value pass) is still O(n_r)
        per append; chaining the fingerprint incrementally and slicing the
        extraction simulation are follow-ups if appends ever dominate.
        """
        if options is not None:
            options.resolve(self.cfg)   # reject unknown override fields
        ds = self.dataset
        off = ds.n_r
        new_texts = list(ds.texts_r) + list(rows.texts)
        new_fields = {k: list(v) + list(rows.fields[k])
                      for k, v in ds.fields_r.items()}
        new_truth = set(ds.truth_set) | set(rows.truth)
        self.dataset = dataclasses.replace(
            ds, texts_r=new_texts, fields_r=new_fields, truth_set=new_truth,
            self_join=False)
        old_fp = self._fp_r
        self._fp_r = corpus_fingerprint(ds.name, "r", new_texts, new_fields)

        aledger = CostLedger()
        aledger.bind_metrics(self.metrics)   # same once-per-flow feed as query
        extractor = self._extractor_factory(self.dataset)
        embedder = getattr(extractor, "_embedder", None)
        snap0 = self.store.snapshot()
        n_new = len(new_texts)
        for entry in self.store.entries_for("r", old_fp):
            spec = entry.spec
            delta_vals = extractor.extract_values(
                spec, "r", aledger, idx=np.arange(off, n_new))
            vals = list(entry.values) + list(delta_vals)
            # retire the old-fingerprint entry *before* pinning the grown
            # one: no transient double residency to trip byte-budget
            # eviction of live planes
            self.store.drop(spec, "r", old_fp, superseded=True)
            if entry.kind == "embed":
                dfd = vectorize(spec, [], delta_vals, embedder)
                host = np.concatenate([entry.host, dfd.data_r], axis=0)
                dev = jnp.concatenate(
                    [entry.device, jnp.asarray(dfd.data_r)], axis=0)
                self.store.charge_upload(dfd.data_r.nbytes)
                self.store.put(spec, "r", self._fp_r, vals, host,
                               "embed", entry.scale, device=dev,
                               tenant=self.tenant)
            else:
                # scalar planes: the p95–p5 scale is a whole-corpus
                # statistic — recompute from raw values so the result is
                # byte-identical to a cold materialization of the grown
                # corpus.  Unchanged scale ⇒ append-only upload; shifted
                # scale ⇒ both (4-byte/row) sides re-pinned.
                l_entry = self.store.peek(spec, "l", self._fp_l)
                vals_l = l_entry.values if l_entry is not None else \
                    extractor.extract_values(spec, "l", aledger)
                fd = vectorize(spec, vals_l, vals, embedder)
                if l_entry is not None and fd.scale == l_entry.scale:
                    delta_host = fd.data_r[off:]
                    host = np.concatenate([entry.host, delta_host])
                    dev = jnp.concatenate(
                        [entry.device, jnp.asarray(delta_host)])
                    self.store.charge_upload(delta_host.nbytes)
                    self.store.put(spec, "r", self._fp_r, vals, host,
                                   "scalar", fd.scale, device=dev,
                                   tenant=self.tenant)
                else:
                    self.store.put(spec, "r", self._fp_r, vals, fd.data_r,
                                   "scalar", fd.scale, tenant=self.tenant)
                    self.store.put(spec, "l", self._fp_l, vals_l, fd.data_l,
                                   "scalar", fd.scale, tenant=self.tenant)

        diff = FeaturePlaneStore.delta(snap0, self.store.snapshot())
        aledger.record_plane_traffic(
            hits=diff["hits"], misses=diff["misses"],
            dedup_hits=diff["dedup_hits"],
            evicted_bytes=diff["evicted_bytes"],
            resident_bytes=diff["resident_bytes"],
            bytes_h2d=diff["bytes_to_device"])
        self.ledger.absorb(aledger)
        self.appends += 1
        return {"rows": len(rows.texts), "ledger": aledger, "store": diff}
