"""FeaturePlaneStore — device-resident featurization planes (DESIGN.md §4).

FDJ's dominant recurring machine costs are step ⑦ (full-corpus feature
extraction) and moving the resulting planes host→device for step ⑧.  Both
are pure functions of (featurization spec version, corpus content), so in
the serving regime — the same tables joined repeatedly under different
predicates, thresholds, or freshly appended rows — they are pointless to
re-pay.  The store pins materialized planes on device, keyed by content
hash, and serves them back with zero extraction charges and zero
host→device plane bytes.

Keying.  One entry per (spec key+version, extraction identity, side,
corpus fingerprint).  The fingerprint (``corpus_fingerprint``) hashes the
side's record content, so appended rows produce a *new* fingerprint —
stale planes can never alias a grown corpus; delta extension
(join_service.JoinService.append_right) re-keys entries explicitly.

Each entry carries three representations of the same plane:

  * ``values`` — the raw extracted field values (host).  Kept because
    scalar re-normalization after a delta append (the p95–p5 scale is a
    whole-corpus statistic) must recompute from raw values to stay
    byte-identical with a cold materialization of the grown corpus;
  * ``host``   — the vectorized array (``core.featurize`` layout), used by
    the numpy engine and by refinement-time pair distances;
  * ``device`` — the same array as a jnp buffer pinned on device, consumed
    by the pallas/sharded engines via ``ops.stage_planes`` (device-side
    assembly, no H2D).

Eviction.  ``byte_budget`` bounds the device-resident total; inserts past
the budget evict least-recently-used entries (``get``/``put`` refresh
recency).  Hit/miss/eviction/H2D counters are surfaced per query through
``CostLedger.record_plane_traffic`` (core/costs.py serving fields).

Tenancy (DESIGN.md §8a).  The fleet fronts ONE store with N concurrent
tenants.  Content-hash keying makes cross-tenant dedup free — two tenants
joining byte-identical corpora share one resident entry — so the tenancy
layer only has to *attribute* and *arbitrate*:

  * every ``get``/``put``/``provide`` optionally names a ``tenant``; the
    entry records its owners (who can see it) and its *producer* (who
    paid the extraction + upload).  A hit whose producer is a different
    tenant counts as a ``dedup_hit`` — the per-tenant ledger line that
    proves the second tenant's cold query over a shared corpus paid $0;
  * ``register_tenant(name, byte_budget)`` declares a per-tenant byte
    budget.  A tenant's *charged* bytes split shared entries evenly
    across owners (an entry two tenants share charges each half), so
    dedup is rewarded in the accounting, not just in residency;
  * eviction is fair, budget-proportional, layered on the same LRU: when
    the global budget binds, the most-over-budget tenant (largest
    charged/budget ratio) releases its least-recently-used entry first —
    a shared entry merely drops that owner (the others keep it resident);
    a solely-owned one is actually evicted.  A tenant over its OWN budget
    releases its LRU entries the same way even when the global budget is
    fine, so one churning tenant can never squeeze the others out.

All public methods take one reentrant lock — the store is the fleet's
single shared mutable structure, hit concurrently by every worker thread
(tests/test_fleet.py pins serial≡concurrent byte-identity and counter
consistency).
"""

from __future__ import annotations

import dataclasses
import hashlib
import threading
from collections import OrderedDict
from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.featurize import FeatureData, FeaturizationSpec, vectorize


def corpus_fingerprint(name: str, side: str, texts: Sequence,
                       fields: dict) -> str:
    """Content hash of one side of a join corpus.

    Covers the dataset name (extraction determinism is keyed by it), the
    record texts, and every schema field's values — anything that can
    change an extracted plane changes the fingerprint.
    """
    h = hashlib.blake2b(digest_size=16)
    h.update(f"{name}|{side}|{len(texts)}".encode())
    for t in texts:
        h.update(str(t).encode())
        h.update(b"\x00")
    for fname in sorted(fields):
        h.update(fname.encode())
        for v in fields[fname]:
            h.update(str(v).encode())
            h.update(b"\x00")
    return h.hexdigest()


def plane_key(spec: FeaturizationSpec, side: str, fingerprint: str) -> tuple:
    """Store key: spec version + extraction identity + side + corpus."""
    return (spec.key, spec.field, spec.distance_kind, side, fingerprint)


@dataclasses.dataclass
class PlaneEntry:
    key: tuple
    spec: FeaturizationSpec
    side: str
    values: list                  # raw extracted values (host)
    host: np.ndarray              # vectorized plane (featurize layout)
    device: object                # same plane as a device-resident jnp array
    kind: str                     # embed | scalar
    scale: float
    producer: Optional[str] = None  # tenant that paid extraction + upload
    owners: set = dataclasses.field(default_factory=set)
    #   tenants sharing this entry (charged nbytes/len(owners) each)

    @property
    def nbytes(self) -> int:
        return int(self.host.nbytes)


class DevicePlaneSet(Sequence):
    """Materialized planes for one query: a drop-in for the FeatureData
    list the engines take, plus per-feature device-resident arrays.

    ``ops.stage_planes`` duck-types on ``device_l``/``device_r`` to
    assemble the kernel layout on device (zero H2D); the numpy engine and
    ``corpus_shape`` use the Sequence-of-FeatureData protocol unchanged.
    ``pack_cache`` memoizes assembled kernel layouts per padded geometry so
    repeated warm queries skip even the on-device reshuffle.

    ``mesh`` (inherited from the store) is the sharded engine's default
    execution mesh for queries over this plane set: the engine lays the
    assembled planes out over the mesh's L axes once (a device-to-device
    reshard, memoized in ``pack_cache``), so repeated warm sharded queries
    — including multi-pod (pod, data, model) meshes — report zero plane
    reshard bytes (DESIGN.md §4).
    """

    def __init__(self, feats: list, dev_l: list, dev_r: list, *, mesh=None):
        self.feats = list(feats)
        self._dev_l = list(dev_l)
        self._dev_r = list(dev_r)
        self.mesh = mesh
        self.pack_cache: dict = {}

    def __len__(self) -> int:
        return len(self.feats)

    def __getitem__(self, i):
        return self.feats[i]

    def device_l(self, i: int):
        return self._dev_l[i]

    def device_r(self, i: int):
        return self._dev_r[i]

    def slice_r(self, start: int) -> "DevicePlaneSet":
        """View of this plane set restricted to R rows [start, n_r) — the
        delta-join working set.  Host views are numpy slices; device views
        are on-device slices (no transfer)."""
        feats = [FeatureData(f.spec, f.kind, f.data_l, f.data_r[start:],
                             scale=f.scale) for f in self.feats]
        return DevicePlaneSet(feats, self._dev_l,
                              [d[start:] for d in self._dev_r],
                              mesh=self.mesh)


class FeaturePlaneStore:
    """Byte-budget LRU cache of device-resident featurization planes.

    ``mesh`` (optional) attaches an execution mesh — e.g. the 3-D
    (pod, data, model) join mesh from ``distributed.mesh.make_join_mesh``
    — to every served ``DevicePlaneSet``: the sharded engine picks it up
    as its default mesh and memoizes the mesh-sharded kernel assembly on
    the set, so warm sharded queries skip the D2D reshard entirely.
    """

    _PROVIDED_CACHE_MAX = 4

    def __init__(self, byte_budget: Optional[int] = None, *, mesh=None):
        self.byte_budget = byte_budget
        self.mesh = mesh
        self._lock = threading.RLock()
        self._entries: OrderedDict = OrderedDict()
        self._provided: OrderedDict = OrderedDict()
        #   (spec identities, fp_l, fp_r) -> (store version, DevicePlaneSet):
        #   repeated warm queries get the *same* plane-set object back, so
        #   its pack_cache (assembled kernel layouts) survives across
        #   queries; invalidated by any store mutation via the version tag
        self._tenant_budgets: OrderedDict = OrderedDict()
        #   tenant -> byte budget (None = registered but unconstrained)
        self.version = 0              # bumped on any mutation (memo guard)
        self.hits = 0
        self.misses = 0
        self.puts = 0
        self.evictions = 0
        self.evicted_bytes = 0
        self.superseded = 0           # entries re-keyed/replaced (delta, rescale)
        self.bytes_to_device = 0      # H2D actually paid by the store
        self.dedup_hits = 0           # hits on a plane another tenant produced
        self.releases = 0             # ownership drops on still-shared entries

    # -- tenancy ------------------------------------------------------------

    def register_tenant(self, tenant: str,
                        byte_budget: Optional[int] = None) -> None:
        """Declare a tenant and its byte budget.  Budgets bound the
        tenant's *charged* bytes (shared entries split evenly across
        owners); exceeding one releases that tenant's own LRU entries —
        never another tenant's."""
        with self._lock:
            self._tenant_budgets[tenant] = byte_budget

    def tenant_bytes(self, tenant: str) -> float:
        """Bytes charged to ``tenant``: each owned entry contributes
        nbytes/len(owners) — dedup across tenants halves both bills."""
        with self._lock:
            return sum(e.nbytes / len(e.owners)
                       for e in self._entries.values()
                       if tenant in e.owners)

    def _note_hit(self, e: PlaneEntry, tenant: Optional[str]) -> None:
        if tenant is None:
            return
        if e.producer is not None and e.producer != tenant:
            self.dedup_hits += 1
        e.owners.add(tenant)

    # -- primitives ---------------------------------------------------------

    def _bump(self) -> None:
        """Any mutation invalidates memoized plane sets; purge them eagerly
        so stale sets (and the pack assemblies they pin) free promptly."""
        self.version += 1
        self._provided.clear()

    @property
    def resident_bytes(self) -> int:
        """Device bytes held by raw plane entries.  Derived artifacts —
        pack assemblies memoized on served DevicePlaneSets — are bounded
        by ``_PROVIDED_CACHE_MAX`` live sets but are NOT counted against
        ``byte_budget``; size the budget with that padding headroom in
        mind."""
        with self._lock:
            return sum(e.nbytes for e in self._entries.values())

    def get(self, spec: FeaturizationSpec, side: str, fingerprint: str,
            *, tenant: Optional[str] = None) -> Optional[PlaneEntry]:
        """Counted lookup: refreshes LRU recency on hit.  ``tenant`` joins
        the entry's owners; a hit on a plane a *different* tenant produced
        counts as a dedup hit (the fleet's shared-corpus proof)."""
        with self._lock:
            key = plane_key(spec, side, fingerprint)
            e = self._entries.get(key)
            if e is None:
                self.misses += 1
                return None
            self.hits += 1
            self._note_hit(e, tenant)
            self._entries.move_to_end(key)
            return e

    def peek(self, spec: FeaturizationSpec, side: str,
             fingerprint: str) -> Optional[PlaneEntry]:
        """Uncounted lookup (no recency refresh) — internal bookkeeping."""
        with self._lock:
            return self._entries.get(plane_key(spec, side, fingerprint))

    def put(self, spec: FeaturizationSpec, side: str, fingerprint: str,
            values: list, host: np.ndarray, kind: str, scale: float,
            *, device=None, tenant: Optional[str] = None) -> PlaneEntry:
        """Pin a plane.  Uploads ``host`` unless a ``device`` buffer is
        handed in (delta path: the caller already concatenated on device
        and paid only the delta's H2D via ``charge_upload``).  ``tenant``
        becomes the entry's producer (it paid) and joins the owners a
        superseded entry accumulated."""
        with self._lock:
            key = plane_key(spec, side, fingerprint)
            old = self._entries.pop(key, None)
            if old is not None:
                self.superseded += 1
            if device is None:
                device = jnp.asarray(host)
                self.bytes_to_device += int(host.nbytes)
            owners = set(old.owners) if old is not None else set()
            if tenant is not None:
                owners.add(tenant)
            entry = PlaneEntry(key, spec, side, values, host, device, kind,
                               scale, producer=tenant, owners=owners)
            self._entries[key] = entry
            self.puts += 1
            self._bump()
            self._evict_to_budget(keep=key)
            return entry

    def drop(self, spec: FeaturizationSpec, side: str, fingerprint: str,
             *, superseded: bool = False) -> None:
        with self._lock:
            e = self._entries.pop(plane_key(spec, side, fingerprint), None)
            if e is not None:
                self._bump()
                if superseded:
                    self.superseded += 1
                else:
                    self.evictions += 1
                    self.evicted_bytes += e.nbytes

    def entries_for(self, side: str, fingerprint: str) -> list:
        """All resident entries of one corpus side (delta-append sweep)."""
        with self._lock:
            return [e for e in list(self._entries.values())
                    if e.side == side and e.key[4] == fingerprint]

    def charge_upload(self, nbytes: int) -> None:
        """Record H2D paid outside ``put`` (delta-row uploads)."""
        with self._lock:
            self.bytes_to_device += int(nbytes)

    # -- eviction -----------------------------------------------------------

    def _evict_entry(self, key: tuple) -> None:
        e = self._entries.pop(key)
        self.evictions += 1
        self.evicted_bytes += e.nbytes
        self._bump()

    def _release_lru(self, tenant: str, keep: tuple) -> bool:
        """Release ``tenant``'s least-recently-used entry: a shared entry
        merely drops this owner (it stays resident for the rest — dedup
        must never let one tenant evict another's working set); a solely
        owned one is evicted.  Returns False when the tenant owns nothing
        releasable (everything left is ``keep``)."""
        for key, e in list(self._entries.items()):    # LRU first
            if key == keep or tenant not in e.owners:
                continue
            e.owners.discard(tenant)
            if e.owners:
                self.releases += 1
            else:
                self._evict_entry(key)
            return True
        return False

    def _evict_lru_step(self, keep: tuple) -> bool:
        """Legacy global-LRU eviction step (no tenancy in play)."""
        if len(self._entries) <= 1:
            return False
        key = next(iter(self._entries))
        if key == keep:                # never evict the entry just pinned
            self._entries.move_to_end(key)
            key = next(iter(self._entries))
        self._evict_entry(key)
        return True

    def _fair_step(self, keep: tuple) -> bool:
        """One budget-proportional eviction step: unowned entries go first
        (nobody is charged for them), then the most-over-budget tenant —
        largest charged/budget ratio, charged bytes as the tie-break (a
        None budget ranks as unconstrained) — releases its LRU entry."""
        for key, e in self._entries.items():          # LRU first
            if key != keep and not e.owners:
                self._evict_entry(key)
                return True
        ranked = sorted(
            self._tenant_budgets,
            key=lambda t: (-(self.tenant_bytes(t) / self._tenant_budgets[t])
                           if self._tenant_budgets[t] else 0.0,
                           -self.tenant_bytes(t)))
        for t in ranked:
            if self._release_lru(t, keep):
                return True
        return False

    def _evict_to_budget(self, keep: tuple) -> None:
        # per-tenant budgets bind independently of the global one: a
        # tenant over ITS budget releases its own LRU entries even while
        # the store as a whole has room
        for t, b in list(self._tenant_budgets.items()):
            if b is None:
                continue
            while self.tenant_bytes(t) > b:
                if not self._release_lru(t, keep):
                    break
        if self.byte_budget is None:
            return
        while self.resident_bytes > self.byte_budget and len(self._entries) > 1:
            done = (self._fair_step(keep) if self._tenant_budgets
                    else self._evict_lru_step(keep))
            if not done:
                break

    # -- query-facing -------------------------------------------------------

    def provide(self, specs: Sequence[FeaturizationSpec], extractor,
                ledger, *, fp_l: str, fp_r: str,
                embedder=None, tenant: Optional[str] = None) -> DevicePlaneSet:
        """Materialize ``specs`` as a DevicePlaneSet, serving resident
        planes for free and extracting only the misses.

        ``extractor`` must expose ``extract_values(spec, side, ledger)``
        (full-corpus raw values, charging the ledger for records actually
        extracted — see data/simulated_llm.py).  A resident plane charges
        nothing and moves nothing to the device.

        Holds the store lock for the whole build: two tenants racing the
        same cold corpus serialize here, so the loser finds every plane
        resident and pays $0 extraction / 0 H2D (the fleet's dedup proof).
        """
        with self._lock:
            return self._provide(specs, extractor, ledger, fp_l=fp_l,
                                 fp_r=fp_r, embedder=embedder, tenant=tenant)

    def _provide(self, specs, extractor, ledger, *, fp_l, fp_r,
                 embedder, tenant) -> DevicePlaneSet:
        embedder = embedder or getattr(extractor, "_embedder", None)
        pkey = (tuple((s.key, s.field, s.distance_kind) for s in specs),
                fp_l, fp_r)
        memo = self._provided.get(pkey)
        if memo is not None and memo[0] == self.version:
            # same counters the per-entry path reports (all entries are
            # still resident — any eviction/put bumped the version)
            for spec in specs:
                self.get(spec, "l", fp_l, tenant=tenant)
                self.get(spec, "r", fp_r, tenant=tenant)
            return memo[1]
        feats, dev_l, dev_r = [], [], []
        for spec in specs:
            el = self.get(spec, "l", fp_l, tenant=tenant)
            er = self.get(spec, "r", fp_r, tenant=tenant)
            scale_ok = (el is None or er is None or el.kind == "embed"
                        or el.scale == er.scale)
            if el is not None and er is not None and scale_ok:
                feats.append(FeatureData(spec, el.kind, el.host, er.host,
                                         scale=el.scale))
                dev_l.append(el.device)
                dev_r.append(er.device)
                continue
            vals_l = el.values if el is not None else \
                extractor.extract_values(spec, "l", ledger)
            vals_r = er.values if er is not None else \
                extractor.extract_values(spec, "r", ledger)
            fd = vectorize(spec, vals_l, vals_r, embedder)
            # a side whose resident plane is still valid (embed kinds are
            # row-independent; scalar only if the joint scale held) keeps
            # its device buffer; anything else is (re)pinned.
            if el is not None and (fd.kind == "embed" or el.scale == fd.scale):
                dev_l.append(el.device)
            else:
                el = self.put(spec, "l", fp_l, vals_l, fd.data_l, fd.kind,
                              fd.scale, tenant=tenant)
                dev_l.append(el.device)
            if er is not None and (fd.kind == "embed" or er.scale == fd.scale):
                dev_r.append(er.device)
            else:
                er = self.put(spec, "r", fp_r, vals_r, fd.data_r, fd.kind,
                              fd.scale, tenant=tenant)
                dev_r.append(er.device)
            feats.append(FeatureData(spec, fd.kind, el.host, er.host,
                                     scale=fd.scale))
        planes = DevicePlaneSet(feats, dev_l, dev_r, mesh=self.mesh)
        # memoize only if the whole working set survived the build: a
        # byte_budget smaller than one query can evict this query's own
        # entries mid-build, and a memo would then serve evicted arrays
        # (budget bypassed) while the counting replay misreports misses
        if all(plane_key(s, "l", fp_l) in self._entries
               and plane_key(s, "r", fp_r) in self._entries for s in specs):
            while len(self._provided) >= self._PROVIDED_CACHE_MAX:
                self._provided.popitem(last=False)
            self._provided[pkey] = (self.version, planes)
        return planes

    # -- observability ------------------------------------------------------

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "hits": self.hits, "misses": self.misses, "puts": self.puts,
                "evictions": self.evictions,
                "evicted_bytes": self.evicted_bytes,
                "superseded": self.superseded,
                "bytes_to_device": self.bytes_to_device,
                "dedup_hits": self.dedup_hits,
                "releases": self.releases,
                "resident_bytes": self.resident_bytes,
                "entries": len(self._entries),
            }

    @staticmethod
    def delta(before: dict, after: dict) -> dict:
        """Per-query counter delta (levels — resident_bytes/entries — pass
        through as the 'after' value)."""
        out = {}
        for k, v in after.items():
            out[k] = v if k in ("resident_bytes", "entries") else v - before[k]
        return out
