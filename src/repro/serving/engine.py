"""Batched serving engine: wave-scheduled continuous batching.

Requests are bucketed into *waves* of up to ``batch_slots``; each wave is
left-padded to its longest prompt (pad positions are masked end-to-end via
``valid_from`` — attention masks them, SSM recurrences treat them as
identity), prefilled in one batched call, then decoded in lockstep with
greedy sampling.  A slot whose request finishes keeps decoding garbage until
the wave drains (its output is truncated) — the fixed-shape trade-off that
keeps every step a single compiled program.

This engine backs the serve-mode examples and ``ServingOracle`` — the
real-LLM backend for FDJ's join/extraction calls.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import ModelConfig
from repro.models import steps


@dataclasses.dataclass
class Request:
    prompt: np.ndarray               # (len,) int32
    max_new_tokens: int = 16
    eos_id: int = -1                 # -1: never
    out_tokens: list = dataclasses.field(default_factory=list)


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, batch_slots: int = 8,
                 capacity: int = 512):
        self.cfg = cfg
        self.params = params
        self.b = batch_slots
        self.capacity = capacity
        self._prefill = jax.jit(steps.make_prefill_step(cfg, capacity))
        self._decode = jax.jit(steps.make_decode_step(cfg))
        self.steps_executed = 0

    def _run_wave(self, wave: List[Request]) -> None:
        b = self.b
        lens = [len(r.prompt) for r in wave]
        pmax = max(lens)
        tokens = np.zeros((b, pmax), np.int32)
        valid_from = np.full(b, pmax, np.int32)      # empty slots: all pad
        for s, r in enumerate(wave):
            # a Request resubmitted to run() (retry, or reuse across
            # engines) must not carry the previous run's decode state: the
            # eos / max_new_tokens checks below read out_tokens, so stale
            # tokens would silently truncate or suppress this run
            r.out_tokens = []
            tokens[s, pmax - lens[s]:] = r.prompt
            valid_from[s] = pmax - lens[s]
        # logical (RoPE) positions start at 0 for each request's first real
        # token; cache masking stays on physical positions via valid_from.
        logical = np.maximum(np.arange(pmax)[None, :] - valid_from[:, None], 0)
        state, last_logits = self._prefill(
            self.params, jnp.asarray(tokens), None, jnp.asarray(valid_from),
            jnp.asarray(logical, np.int32))
        last = np.asarray(steps.greedy_sample(last_logits))
        for s, r in enumerate(wave):
            r.out_tokens.append(int(last[s]))
        pos = pmax
        budget = max(r.max_new_tokens for r in wave) - 1
        vf = jnp.asarray(valid_from)
        for _ in range(max(budget, 0)):
            if pos >= self.capacity:
                break
            tok = jnp.asarray(last, jnp.int32)[:, None]
            posv = jnp.asarray(pos - valid_from, jnp.int32)[:, None]   # logical
            state, logits = self._decode(self.params, state, tok, posv, vf)
            last = np.asarray(steps.greedy_sample(logits))
            self.steps_executed += 1
            alive = False
            for s, r in enumerate(wave):
                if len(r.out_tokens) >= r.max_new_tokens:
                    continue
                if r.out_tokens and r.out_tokens[-1] == r.eos_id:
                    continue
                r.out_tokens.append(int(last[s]))
                alive = True
            pos += 1
            if not alive:
                break

    def run(self, requests: Sequence[Request]) -> List[Request]:
        """Processes all requests; returns them with ``out_tokens`` filled."""
        reqs = sorted(requests, key=lambda r: len(r.prompt))  # length bucketing
        for w0 in range(0, len(reqs), self.b):
            wave = list(reqs[w0 : w0 + self.b])
            self._run_wave(wave)
        return list(requests)
