"""Pure-jnp oracle for the fused CNF-join kernel (identical math, unfused).

This is also the *baseline* lowering used in the kernel benchmark: every
feature's full (n_l, n_r) distance plane is materialized, then min-reduced
and compared — what a straightforward XLA program would do.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.fused_cnf_join.kernel import VEC


def cnf_join_ref(emb_l, emb_r, scal_l, scal_r, clauses, thetas) -> jnp.ndarray:
    """Returns the boolean match matrix (n_l, n_r)."""
    ok = None
    for ci, members in enumerate(clauses):
        dmin = None
        for kind, fi in members:
            if kind == VEC:
                dot = jnp.einsum("ld,rd->lr", emb_l[fi], emb_r[fi])
                d = jnp.clip(0.5 - 0.5 * dot, 0.0, 1.0)
            else:
                d = jnp.clip(jnp.abs(scal_l[fi][:, None] - scal_r[fi][None, :]),
                             0.0, 1.0)
            dmin = d if dmin is None else jnp.minimum(dmin, d)
        pas = dmin <= thetas[ci]
        ok = pas if ok is None else ok & pas
    return ok


def pack_mask(ok: jnp.ndarray) -> jnp.ndarray:
    """Pack a boolean (n_l, n_r) matrix to uint32 words along R."""
    n_l, n_r = ok.shape
    if n_r % 32 != 0:
        raise ValueError(
            f"n_r={n_r} must be a multiple of 32 to pack into uint32 words; "
            f"a ragged tail would be silently truncated")
    okw = ok.reshape(n_l, n_r // 32, 32).astype(jnp.uint32)
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
    return jnp.sum(okw * weights[None, None, :], axis=-1, dtype=jnp.uint32)


def unpack_mask(packed, n_r: int):
    """uint32 (n_l, n_r//32) -> bool (n_l, n_r)."""
    import numpy as np
    p = np.asarray(packed)
    bits = ((p[:, :, None] >> np.arange(32, dtype=np.uint32)) & 1).astype(bool)
    return bits.reshape(p.shape[0], -1)[:, :n_r]
