"""Pure-jnp oracle for the fused CNF-join kernel (identical math, unfused).

This is also the *baseline* lowering used in the kernel benchmark: every
feature's full (n_l, n_r) distance plane is materialized, then min-reduced
and compared — what a straightforward XLA program would do.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.fused_cnf_join.kernel import VEC


def _clause_pass(emb_l, emb_r, scal_l, scal_r, members, theta) -> jnp.ndarray:
    dmin = None
    for kind, fi in members:
        if kind == VEC:
            dot = jnp.einsum("ld,rd->lr", emb_l[fi], emb_r[fi])
            d = jnp.clip(0.5 - 0.5 * dot, 0.0, 1.0)
        else:
            d = jnp.clip(jnp.abs(scal_l[fi][:, None] - scal_r[fi][None, :]),
                         0.0, 1.0)
        dmin = d if dmin is None else jnp.minimum(dmin, d)
    return dmin <= theta


def cnf_join_ref(emb_l, emb_r, scal_l, scal_r, clauses, thetas) -> jnp.ndarray:
    """Returns the boolean match matrix (n_l, n_r)."""
    ok = None
    for ci, members in enumerate(clauses):
        pas = _clause_pass(emb_l, emb_r, scal_l, scal_r, members, thetas[ci])
        ok = pas if ok is None else ok & pas
    return ok


def cnf_join_ref_counted(emb_l, emb_r, scal_l, scal_r, clauses, thetas, *,
                         early_reject: bool = True):
    """``cnf_join_ref`` with the band-level short-circuit and an honest
    conjunct-eval count.

    Returns ``(ok, evals_units)`` where ``evals_units`` is an int32 scalar:
    the number of clauses whose distance plane was actually computed for
    this band.  With ``early_reject`` and >= 2 clauses the remaining
    clauses run under a ``lax.cond`` on the first clause passing anywhere —
    a dead band returns an all-false mask at cost 1 clause.  The candidate
    set is identical either way (skipped planes could only AND against an
    all-false mask).
    """
    n_c = len(clauses)
    ok0 = _clause_pass(emb_l, emb_r, scal_l, scal_r, clauses[0], thetas[0])

    def rest(ok0):
        ok = ok0
        for ci in range(1, n_c):
            ok = ok & _clause_pass(emb_l, emb_r, scal_l, scal_r,
                                   clauses[ci], thetas[ci])
        return ok, jnp.int32(n_c)

    if not early_reject or n_c < 2:
        return rest(ok0)

    def skip(ok0):
        return jnp.zeros_like(ok0), jnp.int32(1)

    return jax.lax.cond(jnp.any(ok0), rest, skip, ok0)


def pack_mask(ok: jnp.ndarray) -> jnp.ndarray:
    """Pack a boolean (n_l, n_r) matrix to uint32 words along R."""
    n_l, n_r = ok.shape
    if n_r % 32 != 0:
        raise ValueError(
            f"n_r={n_r} must be a multiple of 32 to pack into uint32 words; "
            f"a ragged tail would be silently truncated")
    okw = ok.reshape(n_l, n_r // 32, 32).astype(jnp.uint32)
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
    return jnp.sum(okw * weights[None, None, :], axis=-1, dtype=jnp.uint32)


def unpack_mask(packed, n_r: int):
    """uint32 (n_l, n_r//32) -> bool (n_l, n_r)."""
    import numpy as np
    p = np.asarray(packed)
    bits = ((p[:, :, None] >> np.arange(32, dtype=np.uint32)) & 1).astype(bool)
    return bits.reshape(p.shape[0], -1)[:, :n_r]
