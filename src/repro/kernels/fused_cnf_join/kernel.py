"""Fused CNF-join Pallas TPU kernel.

Evaluates a featurized decomposition (CNF with per-clause tied thresholds,
Lemma D.1 form) over an (L_TILE x R_TILE) block of the cross product in one
pass:

  * vector features (semantic / word-overlap):  dist = 0.5 - 0.5 * (A @ B^T)
    — an MXU matmul over embeddings staged in VMEM (augmented [e, m, 1] /
    [e, 1, m] rows encode missing values, see repro.core.featurize);
  * scalar features (arithmetic / date):        dist = |x - y|  (VPU);
  * per clause: min over member features, compare against the clause
    threshold; AND across clauses;
  * output: uint32 bitmask packed along the R dimension (32 pairs/word) —
    n^2/8 bytes of HBM traffic instead of F * n^2 * 4 for the unfused
    XLA lowering that materializes every feature's distance plane.

The clause structure and thresholds are *compile-time constants* (closed
over), so the kernel body unrolls into a static sequence of matmuls +
vector ops — the only data-dependent control flow is the optional
``early_reject`` tile skip below.

``early_reject=True`` short-circuits the conjunction: the first clause is
evaluated unconditionally, and the remaining clauses run under a
``pl.when`` predicated on the first clause passing *somewhere* in the
tile.  A tile (and hence a whole band, when every tile of the band is
dead) whose first-conjunct popcount is zero writes a zero mask without
touching the later clauses' planes.  The candidate set is identical
either way — skipped work can only be ANDed against an all-false mask.

``with_evals=True`` adds a second (grid_l, grid_r) int32 output counting
the clauses actually evaluated per tile (1 when the tile was rejected
early, len(clauses) otherwise), so hosts can charge conjunct FLOPs
honestly instead of assuming the short-circuit saved anything.

VMEM budget per grid step (TL=256, TR=512, D=128, F=6):
  emb_l  F*TL*D*4  = 768 KiB     emb_r  F*TR*D*4 = 1.5 MiB
  planes 2*TL*TR*4 = 1   MiB     out    TL*TR/8  = 16 KiB      < 4 MiB total.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# feature kind tags used in the static clause structure
VEC, SCAL = 0, 1


def _clause_min_dist(emb_l_ref, emb_r_ref, scal_l_ref, scal_r_ref, members):
    """min over a clause's member features of the (TL, TR) distance plane."""
    dmin = None
    for kind, fi in members:
        if kind == VEC:
            a = emb_l_ref[fi, :, :]                       # (TL, D)
            b = emb_r_ref[fi, :, :]                       # (TR, D)
            dot = jax.lax.dot_general(
                a, b, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)       # (TL, TR) MXU
            d = jnp.clip(0.5 - 0.5 * dot, 0.0, 1.0)
        else:
            x = scal_l_ref[fi, :]                         # (TL,)
            y = scal_r_ref[fi, :]                         # (TR,)
            d = jnp.clip(jnp.abs(x[:, None] - y[None, :]), 0.0, 1.0)
        dmin = d if dmin is None else jnp.minimum(dmin, d)
    return dmin


def _pack_tile(ok, tl, tr):
    """Pack a boolean (TL, TR) tile to uint32 words (32 R-neighbours each)."""
    okw = ok.reshape(tl, tr // 32, 32).astype(jnp.uint32)
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
    return jnp.sum(okw * weights[None, None, :], axis=-1, dtype=jnp.uint32)


def _cnf_body(emb_l_ref, emb_r_ref, scal_l_ref, scal_r_ref, out_ref,
              evals_ref, *, clauses, thetas, tl, tr, early_reject):
    n_c = len(clauses)

    def pass_matrix(ci):
        dmin = _clause_min_dist(emb_l_ref, emb_r_ref, scal_l_ref, scal_r_ref,
                                clauses[ci])
        return dmin <= thetas[ci]

    def full(ok0=None):
        ok = ok0
        for ci in range(0 if ok0 is None else 1, n_c):
            pas = pass_matrix(ci)
            ok = pas if ok is None else jnp.logical_and(ok, pas)
        out_ref[:, :] = _pack_tile(ok, tl, tr)
        if evals_ref is not None:
            evals_ref[0, 0] = jnp.int32(n_c)

    if not early_reject or n_c < 2:
        full()
        return

    ok0 = pass_matrix(0)
    live = jnp.any(ok0)

    @pl.when(live)
    def _():
        full(ok0)

    @pl.when(jnp.logical_not(live))
    def _():
        out_ref[:, :] = jnp.zeros((tl, tr // 32), jnp.uint32)
        if evals_ref is not None:
            evals_ref[0, 0] = jnp.int32(1)


def _cnf_kernel(emb_l_ref, emb_r_ref, scal_l_ref, scal_r_ref, out_ref, *,
                clauses, thetas, tl, tr, early_reject=False):
    """clauses: tuple of clauses, each a tuple of (kind, idx); thetas: floats."""
    _cnf_body(emb_l_ref, emb_r_ref, scal_l_ref, scal_r_ref, out_ref, None,
              clauses=clauses, thetas=thetas, tl=tl, tr=tr,
              early_reject=early_reject)


def _cnf_kernel_evals(emb_l_ref, emb_r_ref, scal_l_ref, scal_r_ref, out_ref,
                      evals_ref, *, clauses, thetas, tl, tr,
                      early_reject=False):
    _cnf_body(emb_l_ref, emb_r_ref, scal_l_ref, scal_r_ref, out_ref,
              evals_ref, clauses=clauses, thetas=thetas, tl=tl, tr=tr,
              early_reject=early_reject)


def cnf_join_block(emb_l, emb_r, scal_l, scal_r, clauses, thetas, *,
                   tl: int = 256, tr: int = 512, interpret: bool = False,
                   early_reject: bool = False, with_evals: bool = False):
    """Launch the fused kernel over the full (n_l x n_r) plane.

    emb_l: (F_v, n_l, D) f32   emb_r: (F_v, n_r, D) f32
    scal_l: (F_s, n_l) f32     scal_r: (F_s, n_r) f32
    clauses: static structure (tuple of tuples of (kind, idx))
    thetas: tuple of python floats (compile-time constants)
    early_reject: predicate later clauses on the first clause passing
        somewhere in the tile (candidate set unchanged; see module doc)
    with_evals: also return a (n_l//tl, n_r//tr) int32 grid of clauses
        evaluated per tile

    Returns packed uint32 mask (n_l, n_r // 32); with ``with_evals`` a
    ``(mask, evals_grid)`` pair.
    """
    fv, n_l, d = emb_l.shape
    n_r = emb_r.shape[1]
    if tr % 32 != 0:
        raise ValueError(
            f"tr={tr} must be a multiple of 32: the output bitmask packs "
            f"32 R-neighbours per uint32 word and a ragged tile would be "
            f"silently truncated")
    if n_l % tl != 0 or n_r % tr != 0:
        raise ValueError(
            f"(n_l={n_l}, n_r={n_r}) must be multiples of tiles "
            f"(tl={tl}, tr={tr}); pad via ops.pack_features")
    grid = (n_l // tl, n_r // tr)
    in_specs = [
        pl.BlockSpec((fv, tl, d), lambda i, j: (0, i, 0)),
        pl.BlockSpec((fv, tr, d), lambda i, j: (0, j, 0)),
        pl.BlockSpec((max(scal_l.shape[0], 1), tl), lambda i, j: (0, i)),
        pl.BlockSpec((max(scal_r.shape[0], 1), tr), lambda i, j: (0, j)),
    ]
    if with_evals:
        kernel = functools.partial(
            _cnf_kernel_evals, clauses=tuple(clauses),
            thetas=tuple(float(t) for t in thetas), tl=tl, tr=tr,
            early_reject=early_reject)
        return pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=in_specs,
            out_specs=[
                pl.BlockSpec((tl, tr // 32), lambda i, j: (i, j)),
                pl.BlockSpec((1, 1), lambda i, j: (i, j)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((n_l, n_r // 32), jnp.uint32),
                jax.ShapeDtypeStruct(grid, jnp.int32),
            ],
            interpret=interpret,
        )(emb_l, emb_r, scal_l, scal_r)
    kernel = functools.partial(_cnf_kernel, clauses=tuple(clauses),
                               thetas=tuple(float(t) for t in thetas),
                               tl=tl, tr=tr, early_reject=early_reject)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((tl, tr // 32), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_l, n_r // 32), jnp.uint32),
        interpret=interpret,
    )(emb_l, emb_r, scal_l, scal_r)
