"""jit'd wrapper + corpus driver for the fused CNF-join kernel.

``pack_features`` converts a list of core ``FeatureData`` (+ scaffold clause
structure) into the kernel's array layout, padding record counts to tile
multiples and embedding dims to a lane multiple (128).  ``evaluate_corpus``
is the engine behind ``FDJConfig(engine="pallas")``: it runs the kernel
block-wise (interpret mode on CPU, compiled on TPU) and returns candidate
pair indices.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.kernels.fused_cnf_join import ref
from repro.kernels.fused_cnf_join.kernel import SCAL, VEC, cnf_join_block


def _pad_to(x: np.ndarray, n: int, axis: int, value: float) -> np.ndarray:
    pad = n - x.shape[axis]
    if pad <= 0:
        return x
    width = [(0, 0)] * x.ndim
    width[axis] = (0, pad)
    return np.pad(x, width, constant_values=value)


def pack_features(feats: Sequence, clauses: Sequence, *, tl: int, tr: int,
                  lane: int = 128):
    """Returns (emb_l, emb_r, scal_l, scal_r, kclauses, n_l, n_r).

    Padded L rows are marked missing (distance 1 to everything) so they can
    never produce spurious matches; padded R likewise.
    """
    used = sorted({f for c in clauses for f in c})
    vec_ids = [f for f in used if feats[f].kind == "embed"]
    scal_ids = [f for f in used if feats[f].kind == "scalar"]
    vmap = {f: i for i, f in enumerate(vec_ids)}
    smap = {f: i for i, f in enumerate(scal_ids)}
    kclauses = tuple(
        tuple((VEC, vmap[f]) if feats[f].kind == "embed" else (SCAL, smap[f])
              for f in c)
        for c in clauses)

    n_l = feats[used[0]].data_l.shape[0]
    n_r = feats[used[0]].data_r.shape[0]
    pl_n = -(-n_l // tl) * tl
    pr_n = -(-n_r // tr) * tr
    d_max = max([feats[f].data_l.shape[1] for f in vec_ids], default=lane)
    d_pad = -(-d_max // lane) * lane

    if vec_ids:
        emb_l = np.zeros((len(vec_ids), pl_n, d_pad), np.float32)
        emb_r = np.zeros((len(vec_ids), pr_n, d_pad), np.float32)
        for f in vec_ids:
            dl, dr = feats[f].data_l, feats[f].data_r
            emb_l[vmap[f], : n_l, : dl.shape[1]] = dl
            emb_r[vmap[f], : n_r, : dr.shape[1]] = dr
            # padded rows: missing markers [.., m=-2, 1] / [.., 1, m=-2]
            emb_l[vmap[f], n_l:, dl.shape[1] - 2] = -2.0
            emb_l[vmap[f], n_l:, dl.shape[1] - 1] = 1.0
            emb_r[vmap[f], n_r:, dr.shape[1] - 2] = 1.0
            emb_r[vmap[f], n_r:, dr.shape[1] - 1] = -2.0
    else:
        emb_l = np.zeros((1, pl_n, d_pad), np.float32)
        emb_r = np.zeros((1, pr_n, d_pad), np.float32)

    if scal_ids:
        scal_l = np.stack([_pad_to(feats[f].data_l.astype(np.float32), pl_n, 0, 1e9)
                           for f in scal_ids])
        scal_r = np.stack([_pad_to(feats[f].data_r.astype(np.float32), pr_n, 0, -1e9)
                           for f in scal_ids])
    else:
        scal_l = np.full((1, pl_n), 1e9, np.float32)
        scal_r = np.full((1, pr_n), -1e9, np.float32)
    return emb_l, emb_r, scal_l, scal_r, kclauses, n_l, n_r


def evaluate_corpus(feats: Sequence, clauses: Sequence, thetas,
                    *, tl: int = 256, tr: int = 512, interpret=None,
                    return_mask_bytes: bool = False):
    """Full-corpus CNF evaluation through the kernel; returns [(i, j), ...].

    With ``return_mask_bytes=True`` also returns the device->host transfer
    size of the packed mask (the quantity the sharded engine eliminates).
    """
    pairs: list = []
    mask_bytes = 0
    for block_pairs, nbytes in evaluate_corpus_stream(
            feats, clauses, thetas, tl=tl, tr=tr, l_block=None,
            interpret=interpret):
        pairs.extend(block_pairs)
        mask_bytes += nbytes
    if return_mask_bytes:
        return pairs, mask_bytes
    return pairs


def evaluate_corpus_stream(feats: Sequence, clauses: Sequence, thetas,
                           *, tl: int = 256, tr: int = 512,
                           l_block=None, interpret=None):
    """Streaming corpus driver: yields (pairs, mask_bytes) per L-row block.

    Features are packed once; the kernel then grids one ``l_block``-row
    strip at a time (``l_block`` a multiple of ``tl``, default one whole
    pass — i.e. batch semantics).  Each strip's packed mask is pulled and
    unpacked immediately, so candidates for early rows reach the consumer
    while later strips are still on the device.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    emb_l, emb_r, scal_l, scal_r, kclauses, n_l, n_r = pack_features(
        feats, clauses, tl=tl, tr=tr)
    pl_n, pr_n = emb_l.shape[1], emb_r.shape[1]
    if l_block is None:
        l_block = pl_n
    if l_block % tl != 0:
        raise ValueError(f"l_block={l_block} must be a multiple of tl={tl}")
    thetas = tuple(float(t) for t in thetas)
    demb_l, demb_r = jnp.asarray(emb_l), jnp.asarray(emb_r)
    dscal_l, dscal_r = jnp.asarray(scal_l), jnp.asarray(scal_r)
    for i0 in range(0, pl_n, l_block):
        rows = min(l_block, pl_n - i0)
        packed = cnf_join_block(
            lax.slice_in_dim(demb_l, i0, i0 + rows, axis=1), demb_r,
            lax.slice_in_dim(dscal_l, i0, i0 + rows, axis=1), dscal_r,
            kclauses, thetas, tl=tl, tr=tr, interpret=interpret)
        host_mask = np.asarray(packed)              # O(rows * n_r / 8) pull
        ok = ref.unpack_mask(host_mask, pr_n)[: max(n_l - i0, 0), :n_r]
        ii, jj = np.nonzero(ok)
        yield list(zip((ii + i0).tolist(), jj.tolist())), host_mask.nbytes
