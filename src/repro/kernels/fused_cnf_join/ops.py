"""jit'd wrapper + corpus driver for the fused CNF-join kernel.

``pack_features`` converts a list of core ``FeatureData`` (+ scaffold clause
structure) into the kernel's array layout, padding record counts to tile
multiples and embedding dims to a lane multiple (128).  ``evaluate_corpus``
is the engine behind ``FDJConfig(engine="pallas")``: it runs the kernel
block-wise (interpret mode on CPU, compiled on TPU) and returns candidate
pair indices.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.kernels.fused_cnf_join import ref
from repro.kernels.fused_cnf_join.kernel import SCAL, VEC, cnf_join_block


def _pad_to(x: np.ndarray, n: int, axis: int, value: float) -> np.ndarray:
    pad = n - x.shape[axis]
    if pad <= 0:
        return x
    width = [(0, 0)] * x.ndim
    width[axis] = (0, pad)
    return np.pad(x, width, constant_values=value)


def pack_features(feats: Sequence, clauses: Sequence, *, tl: int, tr: int,
                  lane: int = 128):
    """Returns (emb_l, emb_r, scal_l, scal_r, kclauses, n_l, n_r).

    Padded L rows are marked missing (distance 1 to everything) so they can
    never produce spurious matches; padded R likewise.
    """
    kclauses, vec_ids, scal_ids = _clause_layout(feats, clauses)
    used = sorted({f for c in clauses for f in c})
    vmap = {f: i for i, f in enumerate(vec_ids)}

    n_l = feats[used[0]].data_l.shape[0]
    n_r = feats[used[0]].data_r.shape[0]
    pl_n = -(-n_l // tl) * tl
    pr_n = -(-n_r // tr) * tr
    d_max = max([feats[f].data_l.shape[1] for f in vec_ids], default=lane)
    d_pad = -(-d_max // lane) * lane

    if vec_ids:
        emb_l = np.zeros((len(vec_ids), pl_n, d_pad), np.float32)
        emb_r = np.zeros((len(vec_ids), pr_n, d_pad), np.float32)
        for f in vec_ids:
            dl, dr = feats[f].data_l, feats[f].data_r
            emb_l[vmap[f], : n_l, : dl.shape[1]] = dl
            emb_r[vmap[f], : n_r, : dr.shape[1]] = dr
            # padded rows: missing markers [.., m=-2, 1] / [.., 1, m=-2]
            emb_l[vmap[f], n_l:, dl.shape[1] - 2] = -2.0
            emb_l[vmap[f], n_l:, dl.shape[1] - 1] = 1.0
            emb_r[vmap[f], n_r:, dr.shape[1] - 2] = 1.0
            emb_r[vmap[f], n_r:, dr.shape[1] - 1] = -2.0
    else:
        emb_l = np.zeros((1, pl_n, d_pad), np.float32)
        emb_r = np.zeros((1, pr_n, d_pad), np.float32)

    if scal_ids:
        scal_l = np.stack([_pad_to(feats[f].data_l.astype(np.float32), pl_n, 0, 1e9)
                           for f in scal_ids])
        scal_r = np.stack([_pad_to(feats[f].data_r.astype(np.float32), pr_n, 0, -1e9)
                           for f in scal_ids])
    else:
        scal_l = np.full((1, pl_n), 1e9, np.float32)
        scal_r = np.full((1, pr_n), -1e9, np.float32)
    return emb_l, emb_r, scal_l, scal_r, kclauses, n_l, n_r


def _clause_layout(feats: Sequence, clauses: Sequence):
    """Kernel-facing clause structure shared by host and device packing:
    (kclauses, vec_ids, scal_ids) with featurization indices remapped into
    the packed embed/scalar stacks."""
    used = sorted({f for c in clauses for f in c})
    vec_ids = [f for f in used if feats[f].kind == "embed"]
    scal_ids = [f for f in used if feats[f].kind == "scalar"]
    vmap = {f: i for i, f in enumerate(vec_ids)}
    smap = {f: i for i, f in enumerate(scal_ids)}
    kclauses = tuple(
        tuple((VEC, vmap[f]) if feats[f].kind == "embed" else (SCAL, smap[f])
              for f in c)
        for c in clauses)
    return kclauses, vec_ids, scal_ids


def _pad_embed_device(x, pl_n: int, d_pad: int, side: str):
    """Device-side equivalent of pack_features' embed row/col padding: pad
    rows carry the missing markers [m=-2, 1] (L) / [1, m=-2] (R) in the
    last two *pre-padding* columns, so they can never match below theta=1."""
    n, d = x.shape
    if pl_n > n:
        pad = jnp.zeros((pl_n - n, d), x.dtype)
        m, one = (-2.0, 1.0)
        pad = (pad.at[:, d - 2].set(m if side == "l" else one)
                  .at[:, d - 1].set(one if side == "l" else m))
        x = jnp.concatenate([x, pad], axis=0)
    if d_pad > d:
        x = jnp.pad(x, ((0, 0), (0, d_pad - d)))
    return x


def _pad_scalar_device(x, pl_n: int, fill: float):
    n = x.shape[0]
    if pl_n > n:
        x = jnp.concatenate(
            [x, jnp.full((pl_n - n,), fill, x.dtype)], axis=0)
    return x


def pack_features_device(planes, clauses: Sequence, *, tl: int, tr: int,
                         lane: int = 128):
    """``pack_features`` assembled on device from resident per-feature
    arrays (serving.planes.DevicePlaneSet) — zero host->device plane bytes.

    Writes the identical values as the host path (padding is constant
    writes, no arithmetic), so kernel outputs are bit-identical whichever
    path staged the planes.  Assemblies are memoized on the plane set
    (keyed by used features + padded geometry) so repeated warm queries
    skip the reshuffle entirely.
    """
    kclauses, vec_ids, scal_ids = _clause_layout(planes, clauses)
    used = sorted({f for c in clauses for f in c})
    n_l = planes[used[0]].data_l.shape[0]
    n_r = planes[used[0]].data_r.shape[0]
    pl_n = -(-n_l // tl) * tl
    pr_n = -(-n_r // tr) * tr
    d_max = max([planes[f].data_l.shape[1] for f in vec_ids], default=lane)
    d_pad = -(-d_max // lane) * lane

    cache = getattr(planes, "pack_cache", None)
    key = (tuple(used), pl_n, pr_n, d_pad)
    if cache is not None and key in cache:
        emb_l, emb_r, scal_l, scal_r = cache[key]
        return emb_l, emb_r, scal_l, scal_r, kclauses, n_l, n_r

    if vec_ids:
        emb_l = jnp.stack([_pad_embed_device(planes.device_l(f), pl_n, d_pad, "l")
                           for f in vec_ids])
        emb_r = jnp.stack([_pad_embed_device(planes.device_r(f), pr_n, d_pad, "r")
                           for f in vec_ids])
    else:
        emb_l = jnp.zeros((1, pl_n, d_pad), jnp.float32)
        emb_r = jnp.zeros((1, pr_n, d_pad), jnp.float32)
    if scal_ids:
        scal_l = jnp.stack([_pad_scalar_device(planes.device_l(f), pl_n, 1e9)
                            for f in scal_ids])
        scal_r = jnp.stack([_pad_scalar_device(planes.device_r(f), pr_n, -1e9)
                            for f in scal_ids])
    else:
        scal_l = jnp.full((1, pl_n), 1e9, jnp.float32)
        scal_r = jnp.full((1, pr_n), -1e9, jnp.float32)
    if cache is not None:
        cache[key] = (emb_l, emb_r, scal_l, scal_r)
    return emb_l, emb_r, scal_l, scal_r, kclauses, n_l, n_r


@dataclasses.dataclass(eq=False)
class StagedPlanes:
    """Device-staged kernel inputs plus the transfer accounting for how
    they got there (``bytes_h2d``: host link; ``bytes_reshard``: device-to-
    device moves to lay planes out on a mesh — the quantity warm sharded
    serving queries must report as zero, DESIGN.md §4)."""
    emb_l: object
    emb_r: object
    scal_l: object
    scal_r: object
    kclauses: tuple
    n_l: int
    n_r: int
    bytes_h2d: int = 0
    bytes_reshard: int = 0

    @property
    def arrays(self) -> tuple:
        return (self.emb_l, self.emb_r, self.scal_l, self.scal_r)


def _mesh_shardings(mesh, l_axes: tuple):
    """NamedShardings for the four plane stacks under the engine's layout:
    L rows sharded over ``l_axes`` (("pod", "data") on a pod mesh), R and
    scalars-R replicated (the within-pod broadcast)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    row = l_axes[0] if len(l_axes) == 1 else tuple(l_axes)
    return (NamedSharding(mesh, P(None, row, None)),   # emb_l
            NamedSharding(mesh, P()),                  # emb_r (replicated)
            NamedSharding(mesh, P(None, row)),         # scal_l
            NamedSharding(mesh, P()))                  # scal_r (replicated)


def _place_on_mesh(arrays, mesh, l_axes: tuple):
    """device_put the staged arrays onto the mesh layout, counting only the
    bytes that actually move (an array already laid out equivalently —
    e.g. any placement on a 1-device mesh — costs nothing)."""
    out, moved = [], 0
    for a, sh in zip(arrays, _mesh_shardings(mesh, l_axes)):
        cur = getattr(a, "sharding", None)
        if cur is not None and cur.is_equivalent_to(sh, a.ndim):
            out.append(a)
            continue
        moved += int(a.nbytes)
        out.append(jax.device_put(a, sh))
    return tuple(out), moved


def stage_planes(feats: Sequence, clauses: Sequence, *, tl: int, tr: int,
                 lane: int = 128, mesh=None,
                 l_axes: tuple = ("data",)) -> StagedPlanes:
    """Stage feature planes for the kernel, preferring device residency.

    Returns a ``StagedPlanes`` with the four arrays on device.  A plain
    ``FeatureData`` list is packed on the host and uploaded (bytes_h2d =
    packed bytes); a plane set exposing ``device_l``/``device_r``
    (serving.planes.DevicePlaneSet) is assembled on device from the
    resident arrays (bytes_h2d = 0).

    With ``mesh`` the staged arrays are additionally laid out for the
    sharded engine (L rows over ``l_axes``, R replicated).  The host path
    device_puts straight to that layout; the resident path pays a one-time
    device-to-device reshard (``bytes_reshard``) whose result is memoized
    on the plane set's ``pack_cache`` keyed by (geometry, mesh, axes) —
    repeated warm queries reuse the pre-sharded assembly and report
    ``bytes_reshard == 0``.
    """
    if hasattr(feats, "device_l") and hasattr(feats, "device_r"):
        emb_l, emb_r, scal_l, scal_r, kclauses, n_l, n_r = \
            pack_features_device(feats, clauses, tl=tl, tr=tr, lane=lane)
        staged = StagedPlanes(emb_l, emb_r, scal_l, scal_r, kclauses,
                              n_l, n_r)
        if mesh is not None:
            cache = getattr(feats, "pack_cache", None)
            used = tuple(sorted({f for c in clauses for f in c}))
            mkey = ("mesh", used, emb_l.shape, emb_r.shape, mesh,
                    tuple(l_axes))
            if cache is not None and mkey in cache:
                staged = dataclasses.replace(
                    staged, **dict(zip(
                        ("emb_l", "emb_r", "scal_l", "scal_r"),
                        cache[mkey])))
            else:
                arrays, moved = _place_on_mesh(staged.arrays, mesh,
                                               tuple(l_axes))
                staged = dataclasses.replace(
                    staged, emb_l=arrays[0], emb_r=arrays[1],
                    scal_l=arrays[2], scal_r=arrays[3], bytes_reshard=moved)
                if cache is not None:
                    cache[mkey] = arrays
        return staged
    emb_l, emb_r, scal_l, scal_r, kclauses, n_l, n_r = pack_features(
        feats, clauses, tl=tl, tr=tr, lane=lane)
    h2d = emb_l.nbytes + emb_r.nbytes + scal_l.nbytes + scal_r.nbytes
    if mesh is not None:
        shardings = _mesh_shardings(mesh, tuple(l_axes))
        arrays = tuple(jax.device_put(a, sh) for a, sh in
                       zip((emb_l, emb_r, scal_l, scal_r), shardings))
    else:
        arrays = tuple(jnp.asarray(a)
                       for a in (emb_l, emb_r, scal_l, scal_r))
    return StagedPlanes(arrays[0], arrays[1], arrays[2], arrays[3],
                        kclauses, n_l, n_r, bytes_h2d=h2d)


def evaluate_corpus(feats: Sequence, clauses: Sequence, thetas,
                    *, tl: int = 256, tr: int = 512, interpret=None,
                    return_mask_bytes: bool = False):
    """Full-corpus CNF evaluation through the kernel; returns [(i, j), ...].

    With ``return_mask_bytes=True`` also returns the device->host transfer
    size of the packed mask (the quantity the sharded engine eliminates).
    """
    pairs: list = []
    mask_bytes = 0
    for delta in evaluate_corpus_stream(
            feats, clauses, thetas, tl=tl, tr=tr, l_block=None,
            interpret=interpret):
        pairs.extend(delta.pairs)
        mask_bytes += delta.bytes_to_host
    if return_mask_bytes:
        return pairs, mask_bytes
    return pairs


def evaluate_corpus_stream(feats: Sequence, clauses: Sequence, thetas,
                           *, tl: int = 256, tr: int = 512,
                           l_block=None, interpret=None,
                           early_reject: bool = True):
    """Streaming corpus driver: yields an ``engine.base.ChunkDelta`` per
    L-row block.

    Features are staged once (host pack + upload, or assembled from
    device-resident planes with zero H2D — see ``stage_planes``); the
    kernel then grids one ``l_block``-row strip at a time (``l_block`` a
    multiple of ``tl``, default one whole pass — i.e. batch semantics).
    Each strip's packed mask is pulled and unpacked immediately, so
    candidates for early rows reach the consumer while later strips are
    still on the device.  The one-time plane upload is attributed to the
    first emitted block.  ``early_reject`` enables the kernel's tile-level
    conjunct short-circuit; either way the per-tile eval counts are
    pulled with the mask and charged to the chunk (``conjunct_evals``,
    in pair-clause units over padded tiles — honest device work).
    """
    from repro.engine.base import ChunkDelta
    from repro.obs.trace import current_tracer
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    tracer = current_tracer()
    staged = stage_planes(feats, clauses, tl=tl, tr=tr)
    demb_l, demb_r, dscal_l, dscal_r = staged.arrays
    kclauses, n_l, n_r, h2d = (staged.kclauses, staged.n_l, staged.n_r,
                               staged.bytes_h2d)
    pl_n, pr_n = demb_l.shape[1], demb_r.shape[1]
    if l_block is None:
        l_block = pl_n
    if l_block % tl != 0:
        raise ValueError(f"l_block={l_block} must be a multiple of tl={tl}")
    thetas = tuple(float(t) for t in thetas)
    for i0 in range(0, pl_n, l_block):
        rows = min(l_block, pl_n - i0)
        t0 = time.perf_counter()
        packed, evals_grid = cnf_join_block(
            lax.slice_in_dim(demb_l, i0, i0 + rows, axis=1), demb_r,
            lax.slice_in_dim(dscal_l, i0, i0 + rows, axis=1), dscal_r,
            kclauses, thetas, tl=tl, tr=tr, interpret=interpret,
            early_reject=early_reject, with_evals=True)
        t1 = time.perf_counter()
        host_mask = np.asarray(packed)              # O(rows * n_r / 8) pull
        evals_host = np.asarray(evals_grid)         # one int32 per tile
        t2 = time.perf_counter()
        ok = ref.unpack_mask(host_mask, pr_n)[: max(n_l - i0, 0), :n_r]
        ii, jj = np.nonzero(ok)
        # trace sub-slices only (DESIGN.md §7): the kernel call vs the
        # blocking mask pull.  Deliberately NOT named dispatch/pull — this
        # backend's EngineStats carries no dispatch/pull walls, and the
        # reconciliation in launch/trace_report sums by those names.
        trace = None
        if tracer:
            trace = [
                {"name": "kernel", "t0": t0, "t1": t1,
                 "attrs": {"rows": rows}},
                {"name": "mask_pull", "t0": t1, "t1": t2,
                 "attrs": {"bytes": host_mask.nbytes + evals_host.nbytes}},
            ]
        yield ChunkDelta(
            list(zip((ii + i0).tolist(), jj.tolist())),
            bytes_to_host=host_mask.nbytes + evals_host.nbytes,
            bytes_h2d=h2d if i0 == 0 else 0,
            conjunct_evals=int(evals_host.sum()) * tl * tr,
            trace=trace)
