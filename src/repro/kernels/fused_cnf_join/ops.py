"""jit'd wrapper + corpus driver for the fused CNF-join kernel.

``pack_features`` converts a list of core ``FeatureData`` (+ scaffold clause
structure) into the kernel's array layout, padding record counts to tile
multiples and embedding dims to a lane multiple (128).  ``evaluate_corpus``
is the engine behind ``FDJConfig(engine="pallas")``: it runs the kernel
block-wise (interpret mode on CPU, compiled on TPU) and returns candidate
pair indices.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.fused_cnf_join import ref
from repro.kernels.fused_cnf_join.kernel import SCAL, VEC, cnf_join_block


def _pad_to(x: np.ndarray, n: int, axis: int, value: float) -> np.ndarray:
    pad = n - x.shape[axis]
    if pad <= 0:
        return x
    width = [(0, 0)] * x.ndim
    width[axis] = (0, pad)
    return np.pad(x, width, constant_values=value)


def pack_features(feats: Sequence, clauses: Sequence, *, tl: int, tr: int,
                  lane: int = 128):
    """Returns (emb_l, emb_r, scal_l, scal_r, kclauses, n_l, n_r).

    Padded L rows are marked missing (distance 1 to everything) so they can
    never produce spurious matches; padded R likewise.
    """
    used = sorted({f for c in clauses for f in c})
    vec_ids = [f for f in used if feats[f].kind == "embed"]
    scal_ids = [f for f in used if feats[f].kind == "scalar"]
    vmap = {f: i for i, f in enumerate(vec_ids)}
    smap = {f: i for i, f in enumerate(scal_ids)}
    kclauses = tuple(
        tuple((VEC, vmap[f]) if feats[f].kind == "embed" else (SCAL, smap[f])
              for f in c)
        for c in clauses)

    n_l = feats[used[0]].data_l.shape[0]
    n_r = feats[used[0]].data_r.shape[0]
    pl_n = -(-n_l // tl) * tl
    pr_n = -(-n_r // tr) * tr
    d_max = max([feats[f].data_l.shape[1] for f in vec_ids], default=lane)
    d_pad = -(-d_max // lane) * lane

    if vec_ids:
        emb_l = np.zeros((len(vec_ids), pl_n, d_pad), np.float32)
        emb_r = np.zeros((len(vec_ids), pr_n, d_pad), np.float32)
        for f in vec_ids:
            dl, dr = feats[f].data_l, feats[f].data_r
            emb_l[vmap[f], : n_l, : dl.shape[1]] = dl
            emb_r[vmap[f], : n_r, : dr.shape[1]] = dr
            # padded rows: missing markers [.., m=-2, 1] / [.., 1, m=-2]
            emb_l[vmap[f], n_l:, dl.shape[1] - 2] = -2.0
            emb_l[vmap[f], n_l:, dl.shape[1] - 1] = 1.0
            emb_r[vmap[f], n_r:, dr.shape[1] - 2] = 1.0
            emb_r[vmap[f], n_r:, dr.shape[1] - 1] = -2.0
    else:
        emb_l = np.zeros((1, pl_n, d_pad), np.float32)
        emb_r = np.zeros((1, pr_n, d_pad), np.float32)

    if scal_ids:
        scal_l = np.stack([_pad_to(feats[f].data_l.astype(np.float32), pl_n, 0, 1e9)
                           for f in scal_ids])
        scal_r = np.stack([_pad_to(feats[f].data_r.astype(np.float32), pr_n, 0, -1e9)
                           for f in scal_ids])
    else:
        scal_l = np.full((1, pl_n), 1e9, np.float32)
        scal_r = np.full((1, pr_n), -1e9, np.float32)
    return emb_l, emb_r, scal_l, scal_r, kclauses, n_l, n_r


def evaluate_corpus(feats: Sequence, clauses: Sequence, thetas,
                    *, tl: int = 256, tr: int = 512, interpret=None,
                    return_mask_bytes: bool = False):
    """Full-corpus CNF evaluation through the kernel; returns [(i, j), ...].

    With ``return_mask_bytes=True`` also returns the device->host transfer
    size of the packed mask (the quantity the sharded engine eliminates).
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    emb_l, emb_r, scal_l, scal_r, kclauses, n_l, n_r = pack_features(
        feats, clauses, tl=tl, tr=tr)
    packed = cnf_join_block(
        jnp.asarray(emb_l), jnp.asarray(emb_r), jnp.asarray(scal_l),
        jnp.asarray(scal_r), kclauses, tuple(float(t) for t in thetas),
        tl=tl, tr=tr, interpret=interpret)
    host_mask = np.asarray(packed)                  # O(n_l * n_r / 8) pull
    ok = ref.unpack_mask(host_mask, emb_r.shape[1])[:n_l, :n_r]
    ii, jj = np.nonzero(ok)
    pairs = list(zip(ii.tolist(), jj.tolist()))
    if return_mask_bytes:
        return pairs, host_mask.nbytes
    return pairs
