"""jit'd wrapper for the threshold-sweep kernel + grid helpers."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.threshold_sweep.kernel import threshold_sweep


def _pad_rows(x, n, value):
    pad = n - x.shape[0]
    if pad <= 0:
        return x
    width = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
    return np.pad(x, width, constant_values=value)


def sweep(cd: np.ndarray, labels: np.ndarray, thetas: np.ndarray, *,
          tg: int = 256, tk: int = 512, interpret=None):
    """Padded, jit'd sweep. Returns (pos_counts, sel_counts) as (G,) arrays."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    k, c = cd.shape
    g = thetas.shape[0]
    kp = -(-k // tk) * tk
    gp = -(-g // tg) * tg
    cd_p = _pad_rows(cd.astype(np.float32), kp, np.inf)
    lab_p = _pad_rows(labels.astype(np.float32), kp, 0.0)
    th_p = _pad_rows(thetas.astype(np.float32), gp, -np.inf)
    out = threshold_sweep(jnp.asarray(cd_p), jnp.asarray(lab_p),
                          jnp.asarray(th_p), tg=tg, tk=tk, interpret=interpret)
    out = np.asarray(out)[:g]
    return out[:, 0], out[:, 1]


def candidate_grid(cd_pos: np.ndarray, max_per_dim: int = 24) -> np.ndarray:
    """Cartesian grid of per-clause positive-distance quantiles."""
    c = cd_pos.shape[1]
    axes = []
    for j in range(c):
        vals = np.unique(cd_pos[:, j])
        if len(vals) > max_per_dim:
            qs = np.linspace(0, 1, max_per_dim)
            vals = np.unique(np.quantile(vals, qs, method="nearest"))
        axes.append(vals)
    mesh = np.meshgrid(*axes, indexing="ij")
    return np.stack([m.ravel() for m in mesh], axis=1).astype(np.float32)
