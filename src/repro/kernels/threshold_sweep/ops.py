"""jit'd wrapper for the threshold-sweep kernel + grid helpers."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.threshold_sweep.kernel import threshold_sweep
from repro.kernels.threshold_sweep.ref import threshold_sweep_ref_jit


def _pad_rows(x, n, value):
    pad = n - x.shape[0]
    if pad <= 0:
        return x
    width = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
    return np.pad(x, width, constant_values=value)


def sweep(cd: np.ndarray, labels: np.ndarray, thetas: np.ndarray, *,
          tg: int = 256, tk: int = 512, interpret=None):
    """Padded, jit'd sweep. Returns (pos_counts, sel_counts) as (G,) arrays.

    Pad rows are excluded by an explicit validity mask (labels and valid
    padded with 0), NOT by sentinel distances: the historical +inf cd pad
    leaked into ``sel`` whenever a threshold column was +inf (``inf <= inf``
    is true) — which ``min_fpr_thresholds`` emits for positive-free samples
    and all-missing features induce.  The cd pad value is immaterial now
    (0 keeps the compare well-defined for -inf thresholds too).
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    k, c = cd.shape
    g = thetas.shape[0]
    kp = -(-k // tk) * tk
    gp = -(-g // tg) * tg
    cd_p = _pad_rows(cd.astype(np.float32), kp, 0.0)
    lab_p = _pad_rows(labels.astype(np.float32), kp, 0.0)
    valid_p = _pad_rows(np.ones(k, np.float32), kp, 0.0)
    th_p = _pad_rows(thetas.astype(np.float32), gp, -np.inf)
    out = threshold_sweep(jnp.asarray(cd_p), jnp.asarray(lab_p),
                          jnp.asarray(valid_p), jnp.asarray(th_p),
                          tg=tg, tk=tk, interpret=interpret)
    out = np.asarray(out)[:g]
    return out[:, 0], out[:, 1]


def sweep_counts(cd: np.ndarray, labels: np.ndarray,
                 thetas: np.ndarray) -> tuple:
    """(pos_counts, sel_counts) for the guarantee path (Eq 4 / serving
    recalibration): the pallas kernel on an accelerator backend, the jitted
    jnp oracle on CPU — identical math (tests/test_kernels.py holds them
    bit-for-bit equal), but interpret-mode pallas is ~20x slower than XLA
    on host, and threshold selection sits on the serving critical path.
    """
    if thetas.shape[0] == 0:
        return np.zeros(0, np.float32), np.zeros(0, np.float32)
    if jax.default_backend() == "cpu":
        out = np.asarray(threshold_sweep_ref_jit(
            jnp.asarray(cd, jnp.float32),
            jnp.asarray(labels, jnp.float32),
            jnp.asarray(thetas, jnp.float32)))
        return out[:, 0], out[:, 1]
    return sweep(cd, labels, thetas)


def candidate_grid(cd_pos: np.ndarray, max_per_dim: int = 24,
                   max_grid: int = 4096) -> np.ndarray:
    """Cartesian grid of per-clause positive-distance quantiles.

    ``max_grid`` caps the total grid size: the naive cartesian product is
    ``max_per_dim ** C`` — an unguarded 24^C blowup for wide scaffolds —
    so per-dim quantile counts are shrunk (largest dim first) until the
    product fits.  Every dim always keeps its max positive distance (the
    recall-1 corner), so the grid is never infeasible when the sample has
    positives; at least 2 values per dim are kept whenever available.
    """
    c = cd_pos.shape[1]
    if c == 0:
        return np.zeros((1, 0), np.float32)
    uniq = [np.unique(cd_pos[:, j]) for j in range(c)]
    counts = [min(len(u), max_per_dim) for u in uniq]
    # shrink the largest dim until the cartesian product fits the cap
    while int(np.prod(counts)) > max_grid and max(counts) > 2:
        counts[int(np.argmax(counts))] -= 1
    axes = []
    for j, u in enumerate(uniq):
        if len(u) > counts[j]:
            qs = np.linspace(0, 1, counts[j])
            vals = np.unique(np.quantile(u, qs, method="nearest"))
        else:
            vals = u
        if len(vals) == 0 or vals[-1] != u[-1]:
            vals = np.append(vals, u[-1])   # keep the recall-1 corner
        axes.append(vals)
    mesh = np.meshgrid(*axes, indexing="ij")
    return np.stack([m.ravel() for m in mesh], axis=1).astype(np.float32)
