"""Pure-jnp oracle for the threshold-sweep kernel."""

from __future__ import annotations

import jax.numpy as jnp


def threshold_sweep_ref(cd, labels, thetas):
    """cd: (k, C); labels: (k,); thetas: (G, C) -> (G, 2) [pos, sel]."""
    ok = jnp.all(cd[None, :, :] <= thetas[:, None, :], axis=-1)  # (G, k)
    pos = ok.astype(jnp.float32) @ labels.astype(jnp.float32)
    sel = jnp.sum(ok, axis=1).astype(jnp.float32)
    return jnp.stack([pos, sel], axis=1)
