"""Pure-jnp oracle for the threshold-sweep kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def threshold_sweep_ref(cd, labels, thetas, valid=None):
    """cd: (k, C); labels: (k,); thetas: (G, C) -> (G, 2) [pos, sel].

    ``valid`` (k,) optionally masks rows out of both counts — the explicit
    pad-row mask the kernel uses.  Padded rows must be excluded by mask,
    never by sentinel distances: ``inf <= inf`` is true, so a +inf pad row
    still passes a non-finite threshold column.
    """
    ok = jnp.all(cd[None, :, :] <= thetas[:, None, :], axis=-1)  # (G, k)
    okf = ok.astype(jnp.float32)
    if valid is not None:
        okf = okf * jnp.asarray(valid, jnp.float32)[None, :]
    pos = okf @ labels.astype(jnp.float32)
    sel = jnp.sum(okf, axis=1)
    return jnp.stack([pos, sel], axis=1)


# jit once: the serving-time calibration path calls this on every CPU-backend
# recalibration (ops.sweep_counts dispatches here when no accelerator is
# attached — interpret-mode pallas would be ~20x slower for identical math)
threshold_sweep_ref_jit = jax.jit(threshold_sweep_ref)
