"""Threshold-sweep Pallas kernel.

Evaluates G candidate threshold vectors against k labeled sample rows of
clause distances in one pass — the inner loop of Eq 1 / Eq 4 (scaffold cost
estimation and final threshold selection).  For each grid row g:

    pos[g] = sum_i valid_i * labels_i * AND_c (cd[i,c] <= theta[g,c])
    sel[g] = sum_i valid_i *            AND_c (cd[i,c] <= theta[g,c])

The (TG x TK) pass/fail plane is built on the VPU from C unrolled broadcast
compares; the label reduction is a (TG,TK)@(TK,) matvec on the MXU.  Output
accumulates across the k grid dimension (out block revisited; initialized at
program_id(1)==0).

``valid`` masks padded sample rows *explicitly*.  The historical scheme
padded cd rows with +inf and relied on ``inf <= theta`` being false — but
``inf <= inf`` is true, so any non-finite threshold column (which
``min_fpr_thresholds`` emits when a sample has no positives) or +inf
distance row inflated ``sel`` by the pad count.  Pad rows now carry
valid = 0 and count nothing under *any* threshold, finite or not.

Output layout: (G, 128) f32, col 0 = positive count, col 1 = selected count
(lane-padded for TPU tiling).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sweep_kernel(cd_ref, lab_ref, valid_ref, th_ref, out_ref, *,
                  n_clauses, tg, tk):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[:, :] = jnp.zeros_like(out_ref)

    ok = None
    for c in range(n_clauses):                       # static unroll
        d = cd_ref[:, c]                             # (TK,)
        t = th_ref[:, c]                             # (TG,)
        pas = d[None, :] <= t[:, None]               # (TG, TK)
        ok = pas if ok is None else jnp.logical_and(ok, pas)
    # explicit pad-row mask: a padded sample row contributes to neither
    # count, regardless of the threshold values (inf <= inf is true!)
    okf = ok.astype(jnp.float32) * valid_ref[:][None, :]
    lab = lab_ref[:]                                 # (TK,)
    pos = jax.lax.dot_general(okf, lab[:, None], (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)[:, 0]
    sel = jnp.sum(okf, axis=1)
    acc = out_ref[:, :]
    acc = acc.at[:, 0].add(pos)
    acc = acc.at[:, 1].add(sel)
    out_ref[:, :] = acc


def threshold_sweep(cd, labels, valid, thetas, *, tg: int = 256, tk: int = 512,
                    interpret: bool = False):
    """cd: (k, C) f32; labels: (k,) f32 in {0,1}; valid: (k,) f32 in {0,1}
    (0 marks padded rows); thetas: (G, C) f32.

    k and G must be tile multiples (pad labels/valid with 0; cd pad values
    are arbitrary — the valid mask, not the compare, excludes them; pad
    thetas rows with -inf so padded grid rows select nothing real).
    Returns (G, 128) f32; [:, 0] = positives, [:, 1] = selected.
    """
    k, c = cd.shape
    g = thetas.shape[0]
    assert k % tk == 0 and g % tg == 0
    kernel = functools.partial(_sweep_kernel, n_clauses=c, tg=tg, tk=tk)
    return pl.pallas_call(
        kernel,
        grid=(g // tg, k // tk),
        in_specs=[
            pl.BlockSpec((tk, c), lambda i, j: (j, 0)),
            pl.BlockSpec((tk,), lambda i, j: (j,)),
            pl.BlockSpec((tk,), lambda i, j: (j,)),
            pl.BlockSpec((tg, c), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tg, 128), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((g, 128), jnp.float32),
        interpret=interpret,
    )(cd, labels, valid, thetas)
