"""Zamba2-1.2B — hybrid Mamba2 backbone with periodic (shared) attention.

[arXiv:2411.15242; hf] 38L d_model=2048 32H (kv=32) d_ff=8192 ssm_state=64.
Pattern: 5 Mamba2 blocks then 1 attention(+FFN) block, repeating; FFN only on
attention layers (Mamba blocks carry their own mixer MLP capacity).
"""
from repro.common.config import ModelConfig, SSMConfig


CONFIG = ModelConfig(
    name="zamba2-1.2b",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    head_dim=64,
    block_pattern=("mamba2", "mamba2", "mamba2", "mamba2", "mamba2", "attn"),
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_dim=4, chunk=256),
    rope_theta=10000.0,
    max_seq_len=1048576,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke",
        num_layers=8, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
        vocab_size=256, head_dim=16,
        block_pattern=("mamba2", "mamba2", "attn"),
        ssm=SSMConfig(state_dim=16, head_dim=16, expand=2, conv_dim=4, chunk=32),
        max_seq_len=2048, remat=False)
