"""Minitron-8B — width-pruned Nemotron-4, dense GQA.

[arXiv:2407.14679; hf] 32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000.
"""
from repro.common.config import ModelConfig


CONFIG = ModelConfig(
    name="minitron-8b",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=256000,
    head_dim=128,
    block_pattern=("attn",),
    rope_theta=10000.0,
    max_seq_len=4096,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="minitron-smoke",
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=2, d_ff=192,
        vocab_size=512, head_dim=16, block_pattern=("attn",),
        max_seq_len=512, remat=False)
