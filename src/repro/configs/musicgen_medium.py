"""MusicGen-medium — decoder-only transformer over EnCodec tokens.

[arXiv:2306.05284; hf] 48L d_model=1536 24H (MHA kv=24) d_ff=6144 vocab=2048.
Modality frontend (EnCodec) is a stub: inputs are precomputed token frames.
"""
from repro.common.config import ModelConfig


CONFIG = ModelConfig(
    name="musicgen-medium",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    head_dim=64,
    block_pattern=("attn",),
    ffn_kind="dense",
    rope_theta=10000.0,
    max_seq_len=32768,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-smoke",
        num_layers=3, d_model=48, num_heads=4, num_kv_heads=4, d_ff=96,
        vocab_size=64, head_dim=12, block_pattern=("attn",),
        max_seq_len=256, remat=False)
