"""FDJ — the paper's own configuration (§8.1 defaults).

T_R=0.9, T_P=1.0, delta=0.1; 50 positives for featurization generation +
scaffold, 200 for threshold selection; gamma=0.05; alpha/beta per §5.
The distributed join-step cell (for dry-run/roofline of the paper technique)
is built by ``repro.launch.join.build_join_cell``.
"""
from repro.core.join import FDJConfig

CONFIG = FDJConfig(
    recall_target=0.9,
    precision_target=1.0,
    delta=0.1,
    gen_positives=50,
    thresh_positives=200,
    alpha=3,
    beta=20,
    gamma=0.05,
    max_iter=8,
    mc_trials=20000,
    block=4096,
    engine="numpy",
)


def smoke_config() -> FDJConfig:
    import dataclasses
    return dataclasses.replace(CONFIG, gen_positives=20, thresh_positives=80,
                               mc_trials=2000, block=512)
