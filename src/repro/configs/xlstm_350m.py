"""xLSTM-350M — alternating mLSTM / sLSTM blocks, no FFN.

[arXiv:2405.04517; unverified] 24L d_model=1024 4H vocab=50304 d_ff=0.
"""
from repro.common.config import ModelConfig, SSMConfig


CONFIG = ModelConfig(
    name="xlstm-350m",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    block_pattern=("mlstm", "slstm"),
    ssm=SSMConfig(expand=2, chunk=256),
    max_seq_len=1048576,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-smoke",
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=4, d_ff=0,
        vocab_size=256, block_pattern=("mlstm", "slstm"),
        ssm=SSMConfig(expand=2, chunk=32), max_seq_len=2048, remat=False)
