"""Phi-4-mini 3.8B — dense GQA, RoPE, SwiGLU, tied embeddings.

[arXiv:2412.08905; hf] 32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064.
"""
from repro.common.config import ModelConfig


CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=200064,
    head_dim=128,
    block_pattern=("attn",),
    tie_embeddings=True,
    rope_theta=10000.0,
    max_seq_len=131072,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="phi4-mini-smoke",
        num_layers=3, d_model=48, num_heads=4, num_kv_heads=2, d_ff=96,
        vocab_size=128, head_dim=12, block_pattern=("attn",),
        tie_embeddings=True, max_seq_len=512, remat=False)
