"""Mistral-NeMo 12B — dense GQA, 128k context.

[hf:mistralai/Mistral-Nemo-Base-2407; hf] 40L d_model=5120 32H (GQA kv=8)
d_ff=14336 vocab=131072, head_dim=128.
"""
from repro.common.config import ModelConfig


CONFIG = ModelConfig(
    name="mistral-nemo-12b",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=131072,
    head_dim=128,
    block_pattern=("attn",),
    rope_theta=1000000.0,
    max_seq_len=131072,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mistral-nemo-smoke",
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=2, d_ff=160,
        vocab_size=256, head_dim=16, block_pattern=("attn",),
        max_seq_len=512, remat=False)
