"""Llama-3.2-Vision 90B — text decoder with gated cross-attn image layers.

[hf:meta-llama/Llama-3.2-90B-Vision; unverified] 100L d_model=8192 64H
(GQA kv=8) d_ff=28672 vocab=128256; cross-attn every 5th layer; vision
frontend is a stub providing precomputed patch embeddings (dim 1280).
"""
from repro.common.config import ModelConfig


CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    num_layers=100,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    head_dim=128,
    block_pattern=("attn",),
    cross_attn_every=5,
    cross_attn_memory_len=1600,
    frontend_embed_dim=1280,
    rope_theta=500000.0,
    max_seq_len=131072,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama-vision-smoke",
        num_layers=5, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
        vocab_size=256, head_dim=16, block_pattern=("attn",),
        cross_attn_every=5, cross_attn_memory_len=16, frontend_embed_dim=24,
        max_seq_len=512, remat=False)
