"""DeepSeek-V2 236B — MLA + 160-expert MoE (2 shared, top-6).

[arXiv:2405.04434; hf] 60L d_model=5120 128H d_ff(expert)=1536 vocab=102400,
MLA kv_lora=512, q_lora=1536; layer 0 dense FFN (d_ff 12288), rest MoE.
"""
from repro.common.config import MLAConfig, ModelConfig, MoEConfig


CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,
    d_ff=12288,                      # dense FFN for first_dense_layers
    vocab_size=102400,
    head_dim=192,                    # qk_nope(128) + qk_rope(64)
    block_pattern=("mla",),
    ffn_kind="moe",
    moe=MoEConfig(
        num_experts=160, num_shared_experts=2, top_k=6,
        expert_d_ff=1536, shared_d_ff=1536, first_dense_layers=1),
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    rope_theta=10000.0,
    max_seq_len=131072,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-smoke",
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
        vocab_size=256, head_dim=48, block_pattern=("mla",), ffn_kind="moe",
        moe=MoEConfig(num_experts=8, num_shared_experts=2, top_k=2,
                      expert_d_ff=32, shared_d_ff=32, first_dense_layers=1),
        mla=MLAConfig(kv_lora_rank=32, q_lora_rank=48,
                      qk_nope_head_dim=32, qk_rope_head_dim=16, v_head_dim=32),
        rope_theta=10000.0, max_seq_len=512, remat=False)
