"""Architecture config registry.

Each assigned architecture is a module ``<id>.py`` exporting ``CONFIG`` (the
exact published configuration) and ``smoke_config()`` (a reduced same-family
variant for CPU smoke tests).  ``get_config(name)`` / ``get_smoke(name)``
resolve by id; ids use underscores in module names, dashes accepted.
"""

from __future__ import annotations

import importlib

ARCH_IDS = [
    "deepseek-v2-236b",
    "llama4-maverick-400b-a17b",
    "musicgen-medium",
    "mistral-nemo-12b",
    "phi4-mini-3.8b",
    "minitron-8b",
    "starcoder2-3b",
    "llama-3.2-vision-90b",
    "zamba2-1.2b",
    "xlstm-350m",
]

# archs allowed to run the long_500k cell (sub-quadratic sequence mixing);
# pure full-attention archs skip it per the assignment.
LONG_CONTEXT_ARCHS = ["zamba2-1.2b", "xlstm-350m"]


def _module(name: str):
    mod = name.replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{mod}")


def get_config(name: str):
    return _module(name).CONFIG


def get_smoke(name: str):
    return _module(name).smoke_config()
