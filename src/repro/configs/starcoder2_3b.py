"""StarCoder2-3B — dense GQA (kv=2), RoPE.

[arXiv:2402.19173; hf] 30L d_model=3072 24H (GQA kv=2) d_ff=12288 vocab=49152.
"""
from repro.common.config import ModelConfig


CONFIG = ModelConfig(
    name="starcoder2-3b",
    num_layers=30,
    d_model=3072,
    num_heads=24,
    num_kv_heads=2,
    d_ff=12288,
    vocab_size=49152,
    head_dim=128,
    block_pattern=("attn",),
    rope_theta=999999.0,
    max_seq_len=16384,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-smoke",
        num_layers=3, d_model=48, num_heads=4, num_kv_heads=1, d_ff=96,
        vocab_size=128, head_dim=12, block_pattern=("attn",),
        max_seq_len=512, remat=False)
