"""Llama-4 Maverick 400B (17B active) — interleaved MoE, 128e top-1 + shared.

[hf:meta-llama/Llama-4; unverified] 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048; MoE every other layer; early fusion (text backbone
here, frontend stubbed).
"""
from repro.common.config import ModelConfig, MoEConfig


CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    head_dim=128,
    block_pattern=("attn",),
    ffn_kind="moe",
    moe=MoEConfig(num_experts=128, num_shared_experts=1, top_k=1,
                  expert_d_ff=8192, shared_d_ff=8192, moe_layer_step=2),
    rope_theta=500000.0,
    max_seq_len=1048576,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-smoke",
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
        vocab_size=256, head_dim=16, block_pattern=("attn",), ffn_kind="moe",
        moe=MoEConfig(num_experts=8, num_shared_experts=1, top_k=1,
                      expert_d_ff=64, shared_d_ff=64, moe_layer_step=2),
        max_seq_len=512, remat=False)
