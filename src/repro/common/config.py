"""Configuration dataclasses for the repro framework.

A single ``ModelConfig`` describes every assigned architecture: dense GQA
transformers, MLA (DeepSeek-V2), MoE variants, Mamba2/xLSTM SSM blocks, hybrid
stacks, and cross-attention VLM layers.  The block layout is expressed as a
repeating ``block_pattern`` so heterogeneous stacks (zamba2, xlstm) lower to a
small number of scanned segments instead of 40+ unrolled layers.
"""

from __future__ import annotations

import dataclasses
import enum
import math
from typing import Optional, Sequence


class BlockKind(str, enum.Enum):
    ATTN = "attn"              # softmax attention (MHA / GQA)
    MLA = "mla"                # multi-head latent attention (DeepSeek-V2)
    CROSS_ATTN = "cross_attn"  # cross attention to vision/audio memory
    MAMBA2 = "mamba2"          # Mamba-2 SSD block
    SLSTM = "slstm"            # xLSTM sLSTM block
    MLSTM = "mlstm"            # xLSTM mLSTM block


class FFNKind(str, enum.Enum):
    DENSE = "dense"            # SwiGLU dense MLP
    MOE = "moe"                # routed mixture of experts
    NONE = "none"              # block has fused/no FFN (SSM blocks)


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 1
    expert_d_ff: int = 0            # per-expert hidden dim
    shared_d_ff: int = 0            # shared-expert hidden dim
    capacity_factor: float = 1.25
    router_aux_loss_coef: float = 0.001
    router_z_loss_coef: float = 0.0001
    # first N layers use dense FFN (e.g. deepseek layer 0 is dense)
    first_dense_layers: int = 0
    # layer i is MoE iff (i % moe_layer_step == moe_layer_step - 1)
    # (llama4-maverick interleaves MoE every other layer)
    moe_layer_step: int = 1


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 0            # 0 => full-rank q projection
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 64             # N: SSM state size per head
    num_heads: int = 0              # mamba2 heads (0 => derived)
    head_dim: int = 64
    expand: int = 2                 # d_inner = expand * d_model
    conv_dim: int = 4               # depthwise conv width
    chunk: int = 256                # SSD chunk length


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                     # 0 => d_model // num_heads
    block_pattern: Sequence[str] = ("attn",)   # repeats to cover num_layers
    ffn_kind: str = "dense"
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # positions / norm
    rope_theta: float = 500000.0
    max_seq_len: int = 131072
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # modality frontend stub: if >0, inputs may be precomputed embeddings with
    # this dimension (audio frames / image patches) instead of token ids.
    frontend_embed_dim: int = 0
    cross_attn_every: int = 0             # VLM: 1 cross-attn layer every N
    cross_attn_memory_len: int = 0        # image/audio memory tokens
    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    # "per_use": cast weights to compute dtype at each use (baseline; FSDP
    # gathers move f32).  "once": cast the whole tree before the layer stack
    # so gathers move bf16 (§Perf hillclimb; ~2x weight-traffic saving).
    param_cast: str = "per_use"
    # dtype in which S^2 attention scores/probs are materialized (f32
    # baseline; bf16 halves the dominant HBM term on long-seq cells; §Perf)
    attn_scores_dtype: str = "float32"
    remat: bool = True
    remat_policy: str = "nothing_saveable"   # or "dots_saveable"
    scan_layers: bool = True
    logits_softcap: float = 0.0

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def pattern(self) -> tuple:
        """Full per-layer block kinds of length num_layers."""
        p = list(self.block_pattern)
        reps = math.ceil(self.num_layers / len(p))
        full = (p * reps)[: self.num_layers]
        if self.cross_attn_every > 0:
            for i in range(self.num_layers):
                if (i + 1) % self.cross_attn_every == 0:
                    full[i] = BlockKind.CROSS_ATTN.value
        return tuple(full)

    def _layer_ffn(self, kind: str) -> str:
        if kind in (BlockKind.MAMBA2.value, BlockKind.SLSTM.value, BlockKind.MLSTM.value):
            return FFNKind.NONE.value
        return self.ffn_kind


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str                 # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                 # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    microbatch: int = 0              # 0 => no gradient accumulation
    # dtype of the backward pass / cross-shard gradient reductions:
    # float32 (baseline) or bfloat16 (halves grad-reduce traffic; §Perf)
    grads_dtype: str = "float32"
    seed: int = 0
    checkpoint_every: int = 100
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    grad_compression: str = "none"   # none | fp16 | int8
