"""Checkpointing with atomic writes, rotation, and elastic resharding.

Layout:  <dir>/step_<N>/ {manifest.json, arrays.npz}; a checkpoint becomes
visible only when its directory is atomically renamed from a ``.tmp``
staging name, so a crash mid-write can never yield a readable-but-corrupt
checkpoint.  ``restore`` device_puts every leaf with the *current* mesh's
sharding — loading a checkpoint written on a different mesh (elastic
scale-up/down, pod loss) is the same code path.
"""

from __future__ import annotations

import json
import os
import shutil
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(directory: str, step: int, tree: Any, *, keep: int = 3,
         extra: Optional[dict] = None) -> str:
    """Atomic checkpoint write; returns the final path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, treedef = _flatten(tree)
    arrays = {f"a{i}": np.asarray(l) for i, l in enumerate(leaves)}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "n_leaves": len(leaves),
        "written_at": time.time(),
        "treedef": str(treedef),
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                       # atomic visibility
    _rotate(directory, keep)
    return final


def _rotate(directory: str, keep: int):
    ckpts = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in ckpts[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(directory: str, template: Any, *, step: Optional[int] = None,
            mesh=None, specs: Any = None) -> tuple:
    """Restore into the structure of ``template``; reshard onto ``mesh`` with
    ``specs`` (same tree structure) when given.  Returns (tree, step)."""
    if step is None:
        step = latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with np.load(os.path.join(path, "arrays.npz")) as data:
        leaves, treedef = _flatten(template)
        if len(leaves) != len(data.files):
            raise ValueError(
                f"checkpoint has {len(data.files)} leaves, template {len(leaves)}"
                " — incompatible architecture")
        loaded = [data[f"a{i}"] for i in range(len(leaves))]
    tree = jax.tree.unflatten(treedef, loaded)
    if mesh is not None and specs is not None:
        from jax.sharding import NamedSharding
        tree = jax.tree.map(
            lambda x, s: jax.device_put(jnp.asarray(x), NamedSharding(mesh, s)),
            tree, specs)
    return tree, step
