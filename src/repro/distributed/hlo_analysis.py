"""HLO-text analysis: collective-traffic accounting for the roofline.

``collective_bytes(hlo_text)`` sums the result-shape bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
op, multiplying ops inside while-loop bodies by the loop trip count
(recovered from the loop-condition constant — scan-over-layers shows up as
one while loop of n_periods iterations).

``pod_crossing_stats(hlo_text, pod_size)`` additionally classifies each
collective by whether any of its replica groups spans devices from more
than one pod (device ``d`` belongs to pod ``d // pod_size`` under the
mesh's pod-major flattening).  This is the multi-pod dry-run gate: the
sharded join engine must show cross-pod collectives that move *only
candidate counts* — never feature planes or masks (DESIGN.md §3,
``launch/multipod_dryrun.py``).

This is a structural estimate (result bytes ~ payload moved once); link-hop
multipliers for multi-hop ICI rings are applied by the roofline layer, not
here.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?\s*->", re.M)


def _shape_bytes(shape_str: str) -> int:
    """Sum bytes over (possibly tuple) shapes in a result type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    total_bytes: float
    by_kind: dict
    n_ops: int


_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")


def _split_computations(text: str) -> dict:
    """name -> list of op lines."""
    comps: dict = {}
    cur = None
    for line in text.splitlines():
        s = line.strip()
        m = _HDR_RE.match(s)
        if m:
            cur = m.group(1)
            comps.setdefault(cur, [])
        elif cur is not None and s and not s.startswith("}"):
            comps[cur].append(s)
    return comps


def _while_trip_counts(text: str, comps: dict) -> dict:
    """body computation name -> trip count (best-effort)."""
    out: dict = {}
    for m in re.finditer(
            r"while\([^)]*\)[^\n]*condition=%?([\w\.\-]+)[^\n]*body=%?([\w\.\-]+)",
            text):
        cond, body = m.group(1), m.group(2)
        trip = 1
        for line in comps.get(cond, []):
            for c in re.findall(r"constant\((\d+)\)", line):
                trip = max(trip, int(c))
        out[body] = max(out.get(body, 1), trip)
    return out


_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\)|[a-z0-9]+\[[\d,]*\](?:\{[\d,]*\})?))\s+"
    r"([a-z0-9\-]+)\(")


_GROUPS_EXPLICIT = re.compile(r"replica_groups=\{(\{[^}]*\}(?:,\{[^}]*\})*)\}")
_GROUPS_IOTA = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")


def parse_replica_groups(line: str) -> Optional[list]:
    """Replica groups of one HLO op line as a list of device-id lists.

    Handles both textual forms:
      * explicit  — ``replica_groups={{0,1},{2,3}}``
      * iota (v2) — ``replica_groups=[2,4]<=[8]`` with an optional
        reshape+transpose, e.g. ``[4,2]<=[2,4]T(1,0)``: the id sequence is
        iota over the source dims, transposed by the permutation, then
        reshaped to (num_groups, group_size).
    Returns None when the line carries no replica_groups annotation.
    """
    m = _GROUPS_EXPLICIT.search(line)
    if m:
        return [[int(x) for x in g.split(",") if x]
                for g in re.findall(r"\{([^}]*)\}", m.group(1))]
    m = _GROUPS_IOTA.search(line)
    if m:
        n_groups, group_size = int(m.group(1)), int(m.group(2))
        src = [int(x) for x in m.group(3).split(",") if x]
        total = 1
        for d in src:
            total *= d
        if total != n_groups * group_size:   # malformed annotation
            return None
        ids = _iota_transpose(src, m.group(4))
        return [ids[g * group_size: (g + 1) * group_size]
                for g in range(n_groups)]
    return None


def _iota_transpose(src_dims: list, perm_str: Optional[str]) -> list:
    total = 1
    for d in src_dims:
        total *= d
    if not perm_str:
        return list(range(total))
    import numpy as np
    perm = [int(x) for x in perm_str.split(",") if x]
    return (np.arange(total).reshape(src_dims).transpose(perm)
            .ravel().tolist())


@dataclasses.dataclass
class PodCrossingStats:
    """Collective traffic split by pod locality (bytes are per-device
    result bytes, while-loop trip counts applied)."""
    cross_pod_bytes: float         # total bytes of pod-spanning collectives
    intra_pod_bytes: float         # total bytes of pod-local collectives
    cross_pod_ops: int
    intra_pod_ops: int
    max_cross_op_bytes: float      # largest single pod-spanning op
    cross_kinds: dict              # opcode -> bytes for pod-spanning ops


def _iter_collectives(hlo_text: str):
    """Yield (kind, nbytes, op_line) for every collective op — the one
    walk both accountants share: computation split, while-loop trip
    multipliers, opcode matching (counted once, at -start for async
    pairs), result-shape byte sizing."""
    comps = _split_computations(hlo_text)
    trips = _while_trip_counts(hlo_text, comps)
    for name, lines in comps.items():
        mult = trips.get(name, 1)
        for line in lines:
            m = _OP_RE.search(line)
            if not m:
                continue
            shape_str, opcode = m.group(1), m.group(2)
            kind = next((k for k in COLLECTIVE_OPS
                         if opcode in (k, k + "-start")), None)
            if kind is None:
                continue
            yield kind, _shape_bytes(shape_str) * mult, line


def pod_crossing_stats(hlo_text: str, pod_size: int) -> PodCrossingStats:
    """Classify every collective by whether its replica groups cross a pod
    boundary (``pod = device_id // pod_size``, pod-major mesh flattening).

    Ops without a parseable replica_groups annotation are conservatively
    counted as cross-pod (a missing annotation usually means "all
    devices", which spans pods whenever there is more than one).
    """
    out = PodCrossingStats(0.0, 0.0, 0, 0, 0.0, {})
    for kind, nbytes, line in _iter_collectives(hlo_text):
        groups = parse_replica_groups(line)
        crossing = True
        if groups is not None:
            crossing = any(
                len({d // pod_size for d in g}) > 1 for g in groups)
        if crossing:
            out.cross_pod_bytes += nbytes
            out.cross_pod_ops += 1
            out.max_cross_op_bytes = max(out.max_cross_op_bytes, nbytes)
            out.cross_kinds[kind] = out.cross_kinds.get(kind, 0.0) + nbytes
        else:
            out.intra_pod_bytes += nbytes
            out.intra_pod_ops += 1
    return out


def collective_bytes(hlo_text: str) -> CollectiveStats:
    by_kind: dict = {k: 0.0 for k in COLLECTIVE_OPS}
    n_ops = 0
    for kind, nbytes, _ in _iter_collectives(hlo_text):
        by_kind[kind] += nbytes
        n_ops += 1
    total = float(sum(by_kind.values()))
    return CollectiveStats(total_bytes=total, by_kind=by_kind, n_ops=n_ops)
