"""HLO-text analysis: collective-traffic accounting for the roofline.

``collective_bytes(hlo_text)`` sums the result-shape bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
op, multiplying ops inside while-loop bodies by the loop trip count
(recovered from the loop-condition constant — scan-over-layers shows up as
one while loop of n_periods iterations).

This is a structural estimate (result bytes ~ payload moved once); link-hop
multipliers for multi-hop ICI rings are applied by the roofline layer, not
here.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?\s*->", re.M)


def _shape_bytes(shape_str: str) -> int:
    """Sum bytes over (possibly tuple) shapes in a result type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    total_bytes: float
    by_kind: dict
    n_ops: int


_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")


def _split_computations(text: str) -> dict:
    """name -> list of op lines."""
    comps: dict = {}
    cur = None
    for line in text.splitlines():
        s = line.strip()
        m = _HDR_RE.match(s)
        if m:
            cur = m.group(1)
            comps.setdefault(cur, [])
        elif cur is not None and s and not s.startswith("}"):
            comps[cur].append(s)
    return comps


def _while_trip_counts(text: str, comps: dict) -> dict:
    """body computation name -> trip count (best-effort)."""
    out: dict = {}
    for m in re.finditer(
            r"while\([^)]*\)[^\n]*condition=%?([\w\.\-]+)[^\n]*body=%?([\w\.\-]+)",
            text):
        cond, body = m.group(1), m.group(2)
        trip = 1
        for line in comps.get(cond, []):
            for c in re.findall(r"constant\((\d+)\)", line):
                trip = max(trip, int(c))
        out[body] = max(out.get(body, 1), trip)
    return out


_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\)|[a-z0-9]+\[[\d,]*\](?:\{[\d,]*\})?))\s+"
    r"([a-z0-9\-]+)\(")


def collective_bytes(hlo_text: str) -> CollectiveStats:
    comps = _split_computations(hlo_text)
    trips = _while_trip_counts(hlo_text, comps)
    by_kind: dict = {k: 0.0 for k in COLLECTIVE_OPS}
    n_ops = 0
    for name, lines in comps.items():
        mult = trips.get(name, 1)
        for line in lines:
            m = _OP_RE.search(line)
            if not m:
                continue
            shape_str, opcode = m.group(1), m.group(2)
            for kind in COLLECTIVE_OPS:
                # count the op once (at -start for async pairs)
                if opcode == kind or opcode == kind + "-start":
                    by_kind[kind] += _shape_bytes(shape_str) * mult
                    n_ops += 1
                    break
    total = float(sum(by_kind.values()))
    return CollectiveStats(total_bytes=total, by_kind=by_kind, n_ops=n_ops)
