"""Mesh construction and logical sharding rules.

The production mesh is (16, 16) = 256 chips per pod with axes ("data",
"model"), or (2, 16, 16) with axes ("pod", "data", "model") for the
multi-pod dry-run.  Parameters and activations are annotated with *logical*
dimension names which are resolved to mesh axes here, so the same model code
lowers on 1-device CPU (all rules resolve to None), a single pod, or the
multi-pod mesh.

Logical names:
  batch   — activation batch dim           -> ("pod", "data")
  fsdp    — weight dim sharded ZeRO-3 style -> ("data", "pod")
  tp      — tensor-parallel weight/act dim -> ("model",)
  sp      — sequence dim of saved activations (sequence parallelism) -> ("model",)
  expert  — MoE expert dim (expert parallelism) -> ("model",)
  (None)  — replicated
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """The assigned production mesh: 16x16 single pod, 2x16x16 multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh() -> Mesh:
    """Whatever devices exist locally, as a 1-D 'data' mesh (smoke tests)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def make_join_mesh(n_pods: int = 1, n_data: Optional[int] = None,
                   n_model: int = 1) -> Mesh:
    """A 3-D ``(pod, data, model)`` mesh for the sharded join engine.

    Always carries all three axes — a degenerate ``(1, N, 1)`` request still
    produces a pod axis of size 1, so the pod code path (hierarchical count
    prefix-sum, per-pod R-band rotation) is exercised on any device count.
    ``n_data`` defaults to whatever divides the available devices evenly
    after pod/model are fixed.  The (2, 16, 16) dry-run mesh is
    ``make_join_mesh(2, 16, 16)`` under a 512-device host override
    (``launch/multipod_dryrun.py``).
    """
    n = len(jax.devices())
    if n_data is None:
        n_data = n // (n_pods * n_model)
    if n_pods * n_data * n_model > n or n_data < 1:
        raise ValueError(
            f"join mesh ({n_pods}, {n_data}, {n_model}) needs "
            f"{n_pods * max(n_data, 1) * n_model} devices, have {n}")
    return jax.make_mesh((n_pods, n_data, n_model), ("pod", "data", "model"))


def l_shard_axes(mesh: Mesh) -> tuple:
    """Mesh axes the join engine shards L rows over: ("pod", "data") on a
    pod mesh, ("data",) otherwise (DESIGN.md §3)."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


@dataclasses.dataclass(frozen=True)
class AxisEnv:
    """Resolved mesh-axis assignments for the logical names."""
    batch: tuple
    fsdp: tuple
    tp: tuple
    sp: tuple
    expert: tuple

    @staticmethod
    def from_mesh(mesh: Mesh) -> "AxisEnv":
        names = set(mesh.axis_names)
        has_pod = "pod" in names
        data = ("data",) if "data" in names else ()
        pod = ("pod",) if has_pod else ()
        model = ("model",) if "model" in names else ()
        return AxisEnv(
            batch=pod + data,
            fsdp=data + pod,
            tp=model,
            sp=model,
            expert=model,
        )

    def resolve(self, logical: Sequence[Optional[str]]) -> P:
        """Map a tuple of logical dim names to a PartitionSpec."""
        out = []
        for name in logical:
            if name is None:
                out.append(None)
                continue
            axes = getattr(self, name)
            if len(axes) == 0:
                out.append(None)
            elif len(axes) == 1:
                out.append(axes[0])
            else:
                out.append(tuple(axes))
        return P(*out)


def axis_size(mesh: Mesh, names: tuple) -> int:
    n = 1
    for name in names:
        n *= mesh.shape.get(name, 1)
    return n


def batch_spec(env: AxisEnv, mesh: Mesh, global_batch: int) -> Optional[str]:
    """'batch' if the batch dim divides the batch mesh axes, else None.

    long_500k has global_batch=1: replicate rather than pad 1 -> 32.
    """
    ways = axis_size(mesh, env.batch)
    return "batch" if global_batch % ways == 0 and global_batch >= ways else None


def shard_leaf(mesh: Mesh, x, spec: P):
    return jax.device_put(x, NamedSharding(mesh, spec))
