"""Observability spine: structured tracing + metrics (DESIGN.md §7).

Zero-dependency. Three pieces:

  * ``trace``   — ``Tracer`` span trees (context-manager and retroactive
    recording, cross-thread parents, per-track lanes) with a falsy
    allocation-free ``NULL_TRACER`` for the disabled path, plumbed
    ambiently via ``use_tracer`` / ``current_tracer``;
  * ``metrics`` — ``MetricsRegistry`` counters / gauges / log-bucket
    quantile histograms; ``CostLedger`` binds one so ledger and metrics
    can never disagree (core.costs.ledger_from_metrics);
  * ``export``  — Chrome/Perfetto trace-event JSON (``write_trace``) and
    its schema check (``validate_trace``), rendered/verified by
    ``launch/trace_report.py``.
"""

from repro.obs.export import to_trace_events, validate_trace, write_trace
from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.trace import (NULL_SPAN, NULL_TRACER, NullTracer, Span,
                             SpanEvent, Tracer, current_tracer, use_tracer)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "NULL_SPAN", "NULL_TRACER", "NullTracer", "Span", "SpanEvent", "Tracer",
    "current_tracer", "use_tracer",
    "to_trace_events", "validate_trace", "write_trace",
]
