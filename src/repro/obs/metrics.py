"""MetricsRegistry — counters, gauges, and log-bucket histograms.

Zero-dependency serving metrics (DESIGN.md §7).  Three instrument kinds:

  * ``Counter`` — monotonically accumulating flow (dollars, bytes, evals);
  * ``Gauge``   — a level, last-write-wins (resident bytes, queue depth);
  * ``Histogram`` — streaming distribution over fixed *log* buckets:
    bucket ``i`` covers ``[BASE**i, BASE**(i+1))`` with ``BASE = 2**0.25``
    (≈19% wide, 16 buckets per decade), so any quantile estimate is within
    one bucket — a bounded ~±10% relative error at O(1) memory, which is
    the right trade for p50/p99 serving-latency gates (an exact quantile
    would need the full sample; a fixed-range linear histogram would need
    the range known up front).  Values ≤ 0 land in a dedicated underflow
    bucket that reports 0.0.

``CostLedger`` (core.costs) optionally *binds* a registry: every charge
and counter mutation then feeds the equivalent metric as it happens, and
``core.costs.ledger_from_metrics`` reconstructs a ledger from a registry —
the invariant (tested) that keeps the two views from ever disagreeing.
"""

from __future__ import annotations

import math
import threading
from typing import Optional

BASE = 2.0 ** 0.25                     # log-bucket width (16 per decade)
_LOG_BASE = math.log(BASE)
_UNDERFLOW = -(10 ** 9)                # bucket index for values <= 0


class Counter:
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        self.value += v


class Gauge:
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    __slots__ = ("name", "buckets", "count", "sum", "min", "max")

    def __init__(self, name: str):
        self.name = name
        self.buckets: dict = {}        # bucket index -> count
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        idx = (_UNDERFLOW if v <= 0.0
               else int(math.floor(math.log(v) / _LOG_BASE)))
        self.buckets[idx] = self.buckets.get(idx, 0) + 1
        self.count += 1
        self.sum += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)

    def quantile(self, q: float) -> float:
        """Estimate the q-quantile (0 <= q <= 1): geometric midpoint of the
        bucket holding the q-th observation, clamped to the observed
        [min, max] so tiny samples don't report beyond their extremes."""
        if self.count == 0:
            return 0.0
        rank = q * self.count
        seen = 0
        for idx in sorted(self.buckets):
            seen += self.buckets[idx]
            if seen >= rank:
                if idx == _UNDERFLOW:
                    return 0.0
                mid = BASE ** (idx + 0.5)
                return min(max(mid, self.min), self.max)
        return self.max

    def summary(self) -> dict:
        return {
            "count": self.count,
            "sum": self.sum,
            "min": self.min if self.count else 0.0,
            "max": self.max if self.count else 0.0,
            "p50": self.quantile(0.50),
            "p90": self.quantile(0.90),
            "p99": self.quantile(0.99),
        }


# Serving-layer metric names that do NOT derive from the CostLedger
# (those live in core.costs.FIELD_METRICS / GAUGE_METRICS and round-trip
# through ledger_from_metrics).  The analysis pass (repro.analysis
# checkers, rule "metric-name") requires every literal name passed to
# inc/observe/set_gauge to appear either here or in the ledger maps, so
# a typo'd or undeclared metric fails CI instead of silently creating a
# dangling instrument nothing reads.
DECLARED_METRICS = {
    "serve.plan_hits": "JoinService plan-cache hits",
    "serve.plan_misses": "JoinService plan-cache misses",
    "serve.query_wall_s": "per-query wall seconds (service-side)",
    "fleet.submitted": "queries accepted by JoinFleet.submit",
    "fleet.admitted": "queries admitted by the round-robin worker",
    "fleet.completed": "queries finished without error",
    "fleet.failed": "queries that raised",
    "fleet.queue_wait_s": "submit-to-admission wait seconds",
    "fleet.query_wall_s": "per-query wall seconds (fleet-side)",
    "fleet.band_steps": "band-step dispatches through BandScheduler",
    "fleet.interleaves": "band steps that switched the running query",
    "refine.batches": "oracle refinement batches pulled off the queue",
    "refine.pairs": "candidate pairs refined",
    "refine.queue_depth": "RefinementPump queue depth (gauge)",
}


class MetricsRegistry:
    """Get-or-create instrument registry.  One lock guards instrument
    creation; mutation of an instrument is a float add under the GIL, so
    the hot path (counter feeds from the band loop) takes no lock."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict = {}
        self._gauges: dict = {}
        self._histograms: dict = {}

    def _get(self, table: dict, name: str, cls):
        inst = table.get(name)
        if inst is None:
            with self._lock:
                inst = table.setdefault(name, cls(name))
        return inst

    def counter(self, name: str) -> Counter:
        return self._get(self._counters, name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(self._gauges, name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(self._histograms, name, Histogram)

    # convenience mutators (the CostLedger binding uses these)
    def inc(self, name: str, v: float = 1.0) -> None:
        self.counter(name).inc(v)

    def set_gauge(self, name: str, v: float) -> None:
        self.gauge(name).set(v)

    def observe(self, name: str, v: float) -> None:
        self.histogram(name).observe(v)

    def value(self, name: str, default: float = 0.0) -> float:
        """Current value of a counter or gauge (0.0 when never touched)."""
        c = self._counters.get(name)
        if c is not None:
            return c.value
        g = self._gauges.get(name)
        if g is not None:
            return g.value
        return default

    def has(self, name: str) -> bool:
        return (name in self._counters or name in self._gauges
                or name in self._histograms)

    def quantile(self, name: str, q: float) -> Optional[float]:
        h = self._histograms.get(name)
        return h.quantile(q) if h is not None else None

    def as_dict(self) -> dict:
        """Flat ``{metric_name: value}`` dict — counters and gauges by
        value, histograms expanded to ``name.count/.sum/.p50/.p90/.p99``.
        This is the block merged into benchmark rows and trace metadata."""
        out = {}
        for name, c in sorted(self._counters.items()):
            out[name] = c.value
        for name, g in sorted(self._gauges.items()):
            out[name] = g.value
        for name, h in sorted(self._histograms.items()):
            for k, v in h.summary().items():
                out[f"{name}.{k}"] = v
        return out
