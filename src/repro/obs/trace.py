"""Tracer — per-query span trees with monotonic timestamps (DESIGN.md §7).

A ``Span`` is a named ``[t0, t1)`` interval (``time.perf_counter`` seconds)
with structured attributes, point-in-time events, and a parent link — the
span *tree* of one query is what the Perfetto exporter (obs.export) and
the text reporter (launch.trace_report) render.  Spans are created two
ways:

  * ``tracer.span(name, ...)`` — a context manager; nesting follows a
    per-thread stack, so synchronous code gets its tree for free;
  * ``tracer.record_span(name, t0, t1, ...)`` — retroactive: hot loops
    (the sharded band ring, the refinement pump) measure their own
    timestamps anyway, so they record finished intervals instead of
    holding spans open across generator yields, where context-manager
    stack discipline would misattribute consumer work to the producer.

Cross-thread trees are explicit: a worker thread passes ``parent=`` (the
span captured on the spawning thread) rather than inheriting a stack it
does not share.  ``track`` names a rendering lane — slices on one track
must nest, so concurrent band steps go on per-ring-slot tracks and the
pump's batches on the worker-thread track (obs.export maps tracks to
Perfetto tids).

The disabled path is ``NULL_TRACER``: falsy (hot loops guard with a plain
``if tracer:`` — one truthiness check, zero allocations) and inert (every
method returns a shared singleton), so untraced runs pay nothing and
traced/untraced candidate sets are trivially identical.  The ambient
tracer travels by contextvar (``use_tracer`` / ``current_tracer``), not by
threading it through every engine signature; threads started inside a
traced region must capture it (and a parent span) explicitly —
``contextvars`` do not cross ``threading.Thread``.
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import itertools
import threading
import time
from typing import Optional


@dataclasses.dataclass
class SpanEvent:
    """A point-in-time marker on a span (overflow, retry, theta_swap...)."""
    name: str
    ts: float                          # perf_counter seconds
    attrs: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class Span:
    name: str
    span_id: int
    parent_id: Optional[int]
    t0: float                          # perf_counter seconds
    t1: Optional[float] = None         # None while still open
    track: Optional[str] = None        # rendering lane (export tid)
    thread: str = ""                   # thread name it was recorded on
    attrs: dict = dataclasses.field(default_factory=dict)
    events: list = dataclasses.field(default_factory=list)

    @property
    def duration_s(self) -> float:
        return (self.t1 - self.t0) if self.t1 is not None else 0.0

    def set(self, **attrs) -> "Span":
        self.attrs.update(attrs)
        return self

    def event(self, name: str, ts: Optional[float] = None, **attrs) -> None:
        self.events.append(SpanEvent(name, time.perf_counter()
                                     if ts is None else ts, attrs))


class Tracer:
    """Collects one trace: a flat span list linked into trees by parent id.

    Thread-safe for concurrent recording (one lock around the span list;
    the per-thread open-span stacks are thread-local by construction)."""

    def __init__(self):
        self.epoch = time.perf_counter()       # export time zero
        self.wall_epoch = time.time()  # wallclock-ok: metadata, not span math
        self._spans: list = []
        self._lock = threading.Lock()
        self._ids = itertools.count(1)
        self._tls = threading.local()

    def __bool__(self) -> bool:
        return True

    # -- recording ----------------------------------------------------------

    def _stack(self) -> list:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def current_span(self) -> Optional[Span]:
        st = self._stack()
        return st[-1] if st else None

    def _new_span(self, name, t0, t1, parent, track, attrs) -> Span:
        pid = parent.span_id if isinstance(parent, Span) else parent
        if pid is None:
            cur = self.current_span()
            pid = cur.span_id if cur is not None else None
        sp = Span(name=name, span_id=next(self._ids), parent_id=pid,
                  t0=t0, t1=t1, track=track,
                  thread=threading.current_thread().name,
                  attrs=dict(attrs) if attrs else {})
        with self._lock:
            self._spans.append(sp)
        return sp

    @contextlib.contextmanager
    def span(self, name: str, *, parent=None, track: Optional[str] = None,
             **attrs):
        """Open a span for the duration of the ``with`` block.  Nests under
        this thread's innermost open span unless ``parent`` is given."""
        sp = self._new_span(name, time.perf_counter(), None, parent, track,
                            attrs)
        st = self._stack()
        st.append(sp)
        try:
            yield sp
        finally:
            sp.t1 = time.perf_counter()
            # tolerate out-of-order exits rather than corrupting the stack
            if st and st[-1] is sp:
                st.pop()
            elif sp in st:
                st.remove(sp)

    def record_span(self, name: str, t0: float, t1: float, *, parent=None,
                    track: Optional[str] = None, attrs: Optional[dict] = None,
                    events: Optional[list] = None) -> Span:
        """Record an already-finished ``[t0, t1)`` interval.  ``parent``
        may be a Span or a span id; defaults to this thread's innermost
        open span.  ``events`` is a list of ``SpanEvent`` or ``(name, ts,
        attrs)`` tuples."""
        sp = self._new_span(name, t0, t1, parent, track, attrs)
        for ev in events or ():
            if isinstance(ev, SpanEvent):
                sp.events.append(ev)
            else:
                nm, ts, at = ev
                sp.events.append(SpanEvent(nm, ts, dict(at) if at else {}))
        return sp

    def event(self, name: str, ts: Optional[float] = None, **attrs) -> None:
        """Mark an instant on this thread's innermost open span (dropped
        when no span is open — events always belong to a span)."""
        cur = self.current_span()
        if cur is not None:
            cur.event(name, ts=ts, **attrs)

    # -- reading ------------------------------------------------------------

    def spans(self) -> list:
        with self._lock:
            return list(self._spans)

    def close_open_spans(self) -> None:
        """Clamp any still-open span to now (export of an abandoned or
        mid-stream trace must not emit None durations)."""
        now = time.perf_counter()
        with self._lock:
            for sp in self._spans:
                if sp.t1 is None:
                    sp.t1 = now


class _NullSpan:
    """Inert singleton standing in for Span on the disabled path."""
    __slots__ = ()
    span_id = 0
    parent_id = None
    name = ""
    t0 = 0.0
    t1 = 0.0
    track = None
    attrs: dict = {}
    events: list = []
    duration_s = 0.0

    def set(self, **attrs):
        return self

    def event(self, name, ts=None, **attrs):
        return None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NULL_SPAN = _NullSpan()


class NullTracer:
    """The guaranteed-cheap disabled tracer: falsy, allocation-free.

    ``bool(NULL_TRACER)`` is False so hot loops skip instrumentation with
    one branch; every method returns the shared ``NULL_SPAN`` (which is
    its own context manager), so accidental unguarded calls still cost no
    allocations (tests/test_obs.py pins this with tracemalloc)."""
    __slots__ = ()
    epoch = 0.0
    wall_epoch = 0.0

    def __bool__(self) -> bool:
        return False

    def span(self, name, *, parent=None, track=None, **attrs):
        return NULL_SPAN

    def record_span(self, name, t0, t1, *, parent=None, track=None,
                    attrs=None, events=None):
        return NULL_SPAN

    def event(self, name, ts=None, **attrs):
        return None

    def current_span(self):
        return None

    def spans(self) -> list:
        return []

    def close_open_spans(self) -> None:
        return None


NULL_TRACER = NullTracer()

# ambient tracer: set once at the query/CLI root, read at instrumentation
# sites (contextvars don't cross threads — workers get explicit handles)
_CURRENT: contextvars.ContextVar = contextvars.ContextVar(
    "fdj_tracer", default=None)


def current_tracer():
    """The ambient tracer (NULL_TRACER when tracing is off)."""
    return _CURRENT.get() or NULL_TRACER


@contextlib.contextmanager
def use_tracer(tracer):
    """Install ``tracer`` as the ambient tracer for the block (None ⇒
    leave tracing off — callers can pass their optional tracer through)."""
    token = _CURRENT.set(tracer)
    try:
        yield tracer if tracer is not None else NULL_TRACER
    finally:
        _CURRENT.reset(token)
