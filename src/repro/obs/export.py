"""Perfetto / Chrome trace-event export (DESIGN.md §7).

Serializes a ``Tracer``'s span tree to the trace-event JSON object format
(``{"traceEvents": [...]}``) that Perfetto, ``chrome://tracing`` and
``ui.perfetto.dev`` load directly:

  * every Span becomes one complete slice (``"ph": "X"``) with
    microsecond ``ts``/``dur`` relative to the tracer epoch and its
    attributes (plus ``span_id``/``parent_id``, so the tree survives the
    flat format) under ``args``;
  * every SpanEvent becomes a thread-scoped instant (``"ph": "i"``);
  * tracks map to synthetic tids: a span renders on its own ``track``
    when set, else its nearest ancestor's, else its recording thread.
    Slices sharing a tid must nest — that is why concurrent band steps
    carry per-ring-slot tracks (engine/sharded.py) — and overlap across
    tids is exactly what makes prefetch-ring concurrency *visible*
    instead of a summed ``overlap_s`` scalar.

Extra context (CostLedger wall summary, metrics snapshot) rides in a
top-level ``"fdj"`` block — ignored by viewers, consumed by
``launch/trace_report.py`` to reconcile span sums against the ledger.
``validate_trace`` is the schema check behind ``trace_report --check``.
"""

from __future__ import annotations

import json
from typing import Optional

PROCESS_NAME = "fdj"
_PID = 1


def _resolve_tracks(spans) -> dict:
    """span_id -> track name (own track, else nearest ancestor's, else the
    recording thread)."""
    by_id = {sp.span_id: sp for sp in spans}
    out: dict = {}

    def resolve(sp) -> str:
        cached = out.get(sp.span_id)
        if cached is not None:
            return cached
        if sp.track:
            track = sp.track
        elif sp.parent_id is not None and sp.parent_id in by_id:
            track = resolve(by_id[sp.parent_id])
        else:
            track = f"thread:{sp.thread or 'main'}"
        out[sp.span_id] = track
        return track

    for sp in spans:
        resolve(sp)
    return out


def to_trace_events(tracer, metadata: Optional[dict] = None) -> dict:
    """Render ``tracer`` as a trace-event JSON object (see module doc)."""
    tracer.close_open_spans()
    spans = tracer.spans()
    tracks = _resolve_tracks(spans)
    # stable tid order: first appearance in span order
    tids: dict = {}
    for sp in spans:
        tids.setdefault(tracks[sp.span_id], len(tids) + 1)

    def us(t: float) -> float:
        return round((t - tracer.epoch) * 1e6, 3)

    events = [{"ph": "M", "pid": _PID, "tid": 0, "name": "process_name",
               "args": {"name": PROCESS_NAME}}]
    for track, tid in tids.items():
        events.append({"ph": "M", "pid": _PID, "tid": tid,
                       "name": "thread_name", "args": {"name": track}})
    for sp in spans:
        tid = tids[tracks[sp.span_id]]
        args = dict(sp.attrs)
        args["span_id"] = sp.span_id
        if sp.parent_id is not None:
            args["parent_id"] = sp.parent_id
        # dur from the *rounded* endpoints, so slices that share a raw
        # boundary stay exactly adjacent after rounding (validate_trace
        # checks ts+dur nesting)
        ts0, ts1 = us(sp.t0), us(sp.t1)
        events.append({
            "ph": "X", "pid": _PID, "tid": tid, "name": sp.name,
            "cat": sp.name.split("[", 1)[0],
            "ts": ts0, "dur": round(max(ts1 - ts0, 0.0), 3),
            "args": args,
        })
        for ev in sp.events:
            events.append({
                "ph": "i", "pid": _PID, "tid": tid, "name": ev.name,
                "s": "t", "ts": us(ev.ts),
                "args": dict(ev.attrs, span_id=sp.span_id),
            })
    out = {"traceEvents": events, "displayTimeUnit": "ms"}
    if metadata:
        out["fdj"] = metadata
    return out


def write_trace(tracer, path: str, metadata: Optional[dict] = None) -> dict:
    obj = to_trace_events(tracer, metadata=metadata)
    with open(path, "w") as f:
        json.dump(obj, f, indent=1)
    return obj


_REQUIRED = {"ph", "pid", "tid", "name"}


def validate_trace(obj) -> list:
    """Schema check for an exported trace object; returns a list of error
    strings (empty = valid).  Checks exactly what a viewer and
    trace_report rely on: the traceEvents envelope, required keys per
    phase, numeric non-negative ts/dur on slices, and same-track slice
    nesting (overlapping non-nested slices on one tid render garbage)."""
    errs = []
    if not isinstance(obj, dict):
        return [f"top level must be an object, got {type(obj).__name__}"]
    events = obj.get("traceEvents")
    if not isinstance(events, list):
        return ["missing traceEvents list"]
    slices_by_tid: dict = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            errs.append(f"event[{i}]: not an object")
            continue
        missing = _REQUIRED - set(ev)
        if missing:
            errs.append(f"event[{i}]: missing keys {sorted(missing)}")
            continue
        ph = ev["ph"]
        if ph not in ("X", "i", "M", "B", "E", "C"):
            errs.append(f"event[{i}]: unknown phase {ph!r}")
            continue
        if not isinstance(ev["name"], str) or not ev["name"]:
            errs.append(f"event[{i}]: name must be a nonempty string")
        if ph in ("X", "i"):
            ts = ev.get("ts")
            if not isinstance(ts, (int, float)) or ts < 0:
                errs.append(f"event[{i}] {ev['name']!r}: bad ts {ts!r}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errs.append(f"event[{i}] {ev['name']!r}: bad dur {dur!r}")
            else:
                slices_by_tid.setdefault(ev["tid"], []).append(
                    (ev["ts"], ev["ts"] + dur, ev["name"]))
    for tid, slices in slices_by_tid.items():
        # enclosing slice first when starts tie, so a parent sharing its
        # child's t0 is on the stack before the child is checked
        slices.sort(key=lambda s: (s[0], -s[1]))
        open_stack: list = []
        for t0, t1, name in slices:
            while open_stack and open_stack[-1][0] <= t0 + 1e-6:
                open_stack.pop()
            if open_stack and t1 > open_stack[-1][0] + 1e-6:
                errs.append(
                    f"tid {tid}: slice {name!r} [{t0}, {t1}] overlaps "
                    f"{open_stack[-1][1]!r} without nesting")
                continue
            open_stack.append((t1, name))
    return errs
