"""Training data pipeline: byte-level tokenizer, document packing, sharded
batching, prefetch with straggler mitigation.

Design for the production mesh (see DESIGN.md):
  * deterministic shard assignment — host h of H owns documents
    ``i % H == h``; any host can recompute any other host's batch stream
    (pure function of (seed, step)), which is what makes both *elastic
    rescale* (recompute assignment for a new H) and *straggler backup*
    (a fast host can serve a slow host's batch) correct by construction;
  * bounded prefetch queue on a background thread; if the producer misses
    the deadline the consumer synthesizes the batch itself (self-backup) —
    the CPU-container stand-in for cross-host work stealing.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional, Sequence

import numpy as np


class ByteTokenizer:
    """Reversible byte-level tokenizer with a small special-token space."""
    PAD, BOS, EOS = 0, 1, 2
    OFFSET = 3

    def __init__(self, vocab_size: int = 259):
        self.vocab_size = max(vocab_size, 256 + self.OFFSET)

    def encode(self, text: str) -> np.ndarray:
        b = text.encode("utf-8", errors="replace")
        return np.frombuffer(b, np.uint8).astype(np.int32) + self.OFFSET

    def decode(self, ids: Sequence[int]) -> str:
        bs = bytes(int(i) - self.OFFSET for i in ids
                   if int(i) >= self.OFFSET and int(i) - self.OFFSET < 256)
        return bs.decode("utf-8", errors="replace")


@dataclasses.dataclass
class PackedLMConfig:
    seq_len: int = 256
    batch_size: int = 8
    seed: int = 0
    host_index: int = 0
    host_count: int = 1


class PackedLMDataset:
    """Packs a corpus of documents into fixed (batch, seq) LM examples.

    Batch generation is a pure function of (seed, step, host_index) so any
    host can reproduce any stream — see module docstring.
    """

    def __init__(self, texts: Sequence[str], cfg: PackedLMConfig,
                 tokenizer: Optional[ByteTokenizer] = None,
                 vocab_size: int = 259):
        self.cfg = cfg
        self.tok = tokenizer or ByteTokenizer(vocab_size)
        owned = [t for i, t in enumerate(texts)
                 if i % cfg.host_count == cfg.host_index]
        ids = [np.concatenate([[self.tok.BOS], self.tok.encode(t), [self.tok.EOS]])
               for t in owned] or [np.asarray([self.tok.BOS, self.tok.EOS])]
        self.stream = np.concatenate(ids).astype(np.int32)
        self.stream = np.clip(self.stream, 0, vocab_size - 1)

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 65_537 + cfg.host_index)
        n = len(self.stream)
        need = cfg.seq_len + 1
        starts = rng.integers(0, max(n - need, 1), size=cfg.batch_size)
        rows = np.stack([self.stream[s : s + need] if s + need <= n
                         else np.pad(self.stream[s:], (0, s + need - n))
                         for s in starts])
        return {"tokens": rows[:, :-1], "labels": rows[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class PrefetchLoader:
    """Background-thread prefetch with self-backup on producer stall."""

    def __init__(self, dataset: PackedLMDataset, depth: int = 4,
                 timeout_s: float = 5.0):
        self.ds = dataset
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self.timeout_s = timeout_s
        self.step = 0
        self.backup_batches = 0                 # straggler-mitigation counter
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._produce, daemon=True)
        self._thread.start()

    def _produce(self):
        step = 0
        while not self._stop.is_set():
            batch = self.ds.batch_at(step)
            while not self._stop.is_set():
                try:
                    self.q.put((step, batch), timeout=0.25)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self) -> dict:
        try:
            step, batch = self.q.get(timeout=self.timeout_s)
        except queue.Empty:
            # producer is a straggler: synthesize deterministically
            batch = self.ds.batch_at(self.step)
            self.backup_batches += 1
        self.step += 1
        return batch

    def close(self):
        self._stop.set()
