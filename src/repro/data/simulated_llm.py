"""Simulated Alg-2 LLM backends: proposer + extractor.

``SimulatedProposer`` models get-featurization-from-examples (Alg 2): it
proposes featurizations drawn from the dataset schema in relevance order with
realistic pathologies — missed features (found only in later iterations when
feedback examples surface them), redundant duplicates, occasional wrong
distance-function choices — and *fixes* extraction errors (version bump) when
the Alg-1 feedback loop returns failing examples.

``SimulatedExtractor`` models the extraction functions X_L / X_R: it returns
the true field value with deterministic per-(spec, side, record) corruption
whose rate decays with the spec version (the LLM's fixes), charging the
ledger with token-accurate extraction + embedding costs on first touch of
each record (generation phase touches only sampled records; the join-time
``materialize`` pass touches the full corpus).  Charging is vectorized per
spec — one batched ledger charge over the newly touched records — and
``extract_values`` exposes per-side raw extraction for the serving plane
store (serving/planes.py).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.costs import CostLedger, n_tokens
from repro.core.featurize import (FeatureData, FeaturizationSpec, distance_stack,
                                  vectorize)
from repro.core.llm import HashedNgramEmbedder, _stable_hash
from repro.data.synth import Field, JoinDataset


def _unit(seed_key: str) -> float:
    return (_stable_hash(seed_key, seed=13) % (2**32)) / 2.0**32


def _garble(value: str, key: str) -> str:
    if not value:
        return value
    h = _stable_hash(value + key, seed=29)
    s = list(str(value))
    k = max(1, len(s) // 3)
    start = h % max(len(s) - k, 1)
    repl = "".join(chr(97 + ((h >> (i % 48)) % 26)) for i in range(k))
    return "".join(s[:start]) + repl + "".join(s[start + k:])


@dataclasses.dataclass
class SimulatedProposer:
    dataset: JoinDataset
    miss_prob: float = 0.2          # chance a relevant field is missed this call
    redundant_prob: float = 0.25    # chance of proposing a redundant variant
    wrong_distance_prob: float = 0.1
    fix_prob: float = 0.6           # chance to fix a noisy extractor on feedback
    max_new_per_call: int = 2
    calls: int = 0

    def propose(self, example_pairs, example_labels, existing, join_prompt,
                ledger: CostLedger) -> list:
        self.calls += 1
        out: list = []
        by_field = {}
        for s in existing:
            by_field.setdefault(s.field, []).append(s)
        # --- fix pass: bump versions of noisy extractors; the fix also
        # corrects a wrong distance-function choice (the LLM sees the
        # extraction outputs alongside the failing examples)
        schema = {f.name: f for f in self.dataset.schema}
        for s in existing:
            fld = schema.get(s.field)
            if fld is None:
                continue
            wrong_kind = s.distance_kind != fld.distance_kind
            if (fld.base_noise <= 0 or s.version >= 2) and not wrong_kind:
                continue
            if _unit(f"fix|{self.dataset.name}|{s.key}|{self.calls}") < self.fix_prob:
                out.append(dataclasses.replace(
                    s, version=s.version + 1,
                    distance_kind=fld.distance_kind if wrong_kind else s.distance_kind))
        # --- new featurizations ----------------------------------------------
        fields = sorted(self.dataset.schema, key=lambda f: -f.relevance)
        n_new = 0
        for fld in fields:
            if fld.name in by_field or n_new >= self.max_new_per_call:
                continue
            if _unit(f"miss|{self.dataset.name}|{fld.name}|{self.calls}") < self.miss_prob:
                continue
            kind = fld.distance_kind
            if _unit(f"dk|{self.dataset.name}|{fld.name}|{self.calls}") < self.wrong_distance_prob:
                kind = "semantic" if fld.distance_kind != "semantic" else "word_overlap"
            out.append(FeaturizationSpec(
                name=fld.name, description=f"extract {fld.name}",
                distance_kind=kind,
                extractor_kind="llm" if fld.llm_needed else "code",
                field=fld.name))
            n_new += 1
        # --- redundant variant -------------------------------------------------
        if existing and _unit(f"red|{self.dataset.name}|{self.calls}") < self.redundant_prob:
            base = existing[self.calls % len(existing)]
            alt = "semantic" if base.distance_kind != "semantic" else "word_overlap"
            out.append(dataclasses.replace(
                base, name=base.name + "_alt", distance_kind=alt, version=0))
        # --- cost: Alg-2 is a multi-call pipeline ------------------------------
        texts = []
        for (i, j) in example_pairs:
            texts.append(self.dataset.texts_l[i])
            texts.append(self.dataset.texts_r[j])
        prompt_tok = sum(n_tokens(t) for t in texts) + 400
        ledger.charge_generation(prompt_tok * 4, 150 * max(len(out), 1) * 3)
        return out


@dataclasses.dataclass
class SimulatedExtractor:
    dataset: JoinDataset
    seed: int = 0

    def __post_init__(self):
        self._values: dict = {}        # (key, side) -> list of values
        self._features: dict = {}      # key -> FeatureData
        self._charged: dict = {}       # (key, side) -> bool ndarray
        self._embedder = HashedNgramEmbedder(dim=128)
        self._text_tok: dict = {}      # side -> per-record token counts
        self._val_tok: dict = {}       # (key, side) -> per-record token counts

    # -- extraction simulation ------------------------------------------------
    def _noise_rate(self, fld: Field, version: int) -> float:
        return fld.base_noise * (0.25 ** version)

    def _extract_side(self, spec: FeaturizationSpec, side: str) -> list:
        key = (spec.key, side)
        if key in self._values:
            return self._values[key]
        fields = self.dataset.fields_l if side == "l" else self.dataset.fields_r
        schema = {f.name: f for f in self.dataset.schema}
        fld = schema[spec.field]
        true_vals = fields[spec.field]
        vals = []
        for i, v in enumerate(true_vals):
            u_m = _unit(f"miss|{spec.field}|{side}|{i}|{self.dataset.name}")
            u_c = _unit(f"corr|{spec.field}|{side}|{i}|{self.dataset.name}")
            if v is None or u_m < fld.missing:
                vals.append(None)
            elif u_c < self._noise_rate(fld, spec.version):
                if spec.distance_kind in ("arithmetic", "date"):
                    jitter = 5.0 + 45.0 * _unit(f"j|{spec.field}|{side}|{i}")
                    vals.append(float(v) + jitter)
                else:
                    vals.append(_garble(str(v), f"{side}|{i}"))
            else:
                vals.append(v)
        self._values[key] = vals
        return vals

    def _feature(self, spec: FeaturizationSpec) -> FeatureData:
        if spec.key not in self._features:
            vl = self._extract_side(spec, "l")
            vr = self._extract_side(spec, "r")
            self._features[spec.key] = vectorize(spec, vl, vr, self._embedder)
        return self._features[spec.key]

    # -- cost charging ----------------------------------------------------------
    # Charging is a vectorized per-spec pass: token counts are precomputed
    # once per (spec, side) as arrays, and a materialize/extract call issues
    # ONE batched ledger charge over the newly touched records instead of a
    # per-record host loop.  Totals match the per-record loop exactly (the
    # per-record prices are linear in token counts; see
    # tests/test_simulated_llm.py for the parity check).

    def _text_tok_counts(self, side: str) -> np.ndarray:
        if side not in self._text_tok:
            texts = self.dataset.texts_l if side == "l" else self.dataset.texts_r
            self._text_tok[side] = np.asarray(
                [n_tokens(t) for t in texts], np.int64)
        return self._text_tok[side]

    def _val_tok_counts(self, spec: FeaturizationSpec, side: str) -> np.ndarray:
        key = (spec.key, side)
        if key not in self._val_tok:
            vals = self._extract_side(spec, side)
            self._val_tok[key] = np.asarray(
                [n_tokens(str(v or "")) for v in vals], np.int64)
        return self._val_tok[key]

    def _charge(self, spec: FeaturizationSpec, side: str, idx: np.ndarray,
                ledger: CostLedger):
        key = (spec.key, side)
        texts = self.dataset.texts_l if side == "l" else self.dataset.texts_r
        if key not in self._charged:
            self._charged[key] = np.zeros(len(texts), bool)
        mask = self._charged[key]
        new = np.unique(idx[~mask[idx]]) if len(idx) else np.zeros(0, int)
        if new.size == 0:
            return
        val_tok = self._val_tok_counts(spec, side)
        if spec.extractor_kind == "llm":
            ledger.charge_extraction(
                int(self._text_tok_counts(side)[new].sum() + 30 * new.size),
                int(val_tok[new].sum() + 2 * new.size))
        if spec.distance_kind == "semantic":
            ledger.charge_embedding(int(val_tok[new].sum() + new.size))
        mask[new] = True

    # -- FeatureExtractor protocol ------------------------------------------------
    def pair_distances(self, specs: Sequence[FeaturizationSpec], pairs,
                       ledger: CostLedger) -> np.ndarray:
        il = np.asarray([p[0] for p in pairs], int)
        ir = np.asarray([p[1] for p in pairs], int)
        feats = []
        for s in specs:
            f = self._feature(s)
            self._charge(s, "l", il, ledger)
            self._charge(s, "r", ir, ledger)
            feats.append(f)
        return distance_stack(feats, pairs)

    def materialize(self, specs: Sequence[FeaturizationSpec],
                    ledger: CostLedger) -> list:
        feats = []
        for s in specs:
            f = self._feature(s)
            self._charge(s, "l", np.arange(self.dataset.n_l), ledger)
            self._charge(s, "r", np.arange(self.dataset.n_r), ledger)
            feats.append(f)
        return feats

    def extract_values(self, spec: FeaturizationSpec, side: str,
                       ledger: CostLedger, idx=None) -> list:
        """Raw extracted values for ``side`` at ``idx`` (full corpus when
        None), charging the ledger for first-touch records only — the
        extraction seam the serving plane store builds on (a resident
        plane never reaches this call)."""
        n = self.dataset.n_l if side == "l" else self.dataset.n_r
        idx = np.arange(n) if idx is None else np.asarray(idx, int)
        vals = self._extract_side(spec, side)
        self._charge(spec, side, idx, ledger)
        return [vals[i] for i in idx]
