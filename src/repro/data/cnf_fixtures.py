"""Representative CNF scaffolds for the synth datasets.

Shared by the engine parity tests (tests/test_engines.py) and the
engine-comparison benchmark (benchmarks/engines.py) so both exercise the
*same* decomposition — a drift between them would silently decouple what
is tested from what is measured.
"""

from __future__ import annotations

from repro.core.featurize import FeaturizationSpec


def representative_cnf(ds):
    """(specs, clauses, thetas) for a dataset by its field schema.

    police_records gets the paper's running example (date conjunct,
    officer/location disjunct); anything else gets one word-overlap clause
    per leading field.
    """
    fields = list(ds.fields_l.keys())
    if "incident_date" in fields:
        specs = [
            FeaturizationSpec("incident_date", "", "arithmetic", "llm", "incident_date"),
            FeaturizationSpec("officer_names", "", "word_overlap", "llm", "officer_names"),
            FeaturizationSpec("location", "", "semantic", "llm", "location"),
        ]
        return specs, [[0], [1, 2]], [0.02, 0.35]
    specs, clauses, thetas = [], [], []
    for i, f in enumerate(fields[:2]):
        specs.append(FeaturizationSpec(f, "", "word_overlap", "llm", f))
        clauses.append([i])
        thetas.append(0.4)
    return specs, clauses, thetas
