"""Synthetic join datasets + the simulated Alg-2 LLM backends.

Generators mirror the paper's six real datasets structurally (§8.2's three
categories) and the §8.4 templated-sentence generator (entity-count and
text-length sweeps).  Ground truth is known by construction; each dataset
carries a *schema* of latent fields with per-field extraction difficulty so
the simulated proposer/extractor reproduce the paper's LLM behaviors:
redundant or erroneous featurizations first, fixed when the Alg-1 feedback
loop surfaces failing examples.

Determinism: every record's corruption is keyed by (spec, side, index) via a
stable hash — repeated extraction of the same record yields the same value.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.llm import SimulatedOracle, _stable_hash


# ---------------------------------------------------------------------------
# schema
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Field:
    name: str
    distance_kind: str            # semantic | word_overlap | arithmetic | date
    llm_needed: bool = True       # should-use-llm verdict
    relevance: float = 1.0        # proposer ordering signal
    base_noise: float = 0.0       # extraction corruption prob at version 0
    missing: float = 0.0          # extraction missing prob


@dataclasses.dataclass
class JoinDataset:
    name: str
    texts_l: list
    texts_r: list
    fields_l: dict                # field name -> list of true values (or None)
    fields_r: dict
    schema: list                  # list[Field]
    truth_set: set                # {(i, j)}
    join_prompt: str
    self_join: bool = False

    @property
    def n_l(self) -> int:
        return len(self.texts_l)

    @property
    def n_r(self) -> int:
        return len(self.texts_r)

    @property
    def n_positive(self) -> int:
        return len(self.truth_set)

    def truth(self, i: int, j: int) -> bool:
        return (i, j) in self.truth_set

    def make_oracle(self, latency_s: float = 0.0) -> SimulatedOracle:
        return SimulatedOracle(self.texts_l, self.texts_r, self.truth,
                               join_prompt=self.join_prompt + " {l} ||| {r}",
                               latency_s=latency_s)


# ---------------------------------------------------------------------------
# word pools (deterministic)
# ---------------------------------------------------------------------------

_SYL = ["ba", "ra", "mi", "ko", "ta", "li", "su", "ne", "vo", "da", "ше"[:0] or "ze",
        "fa", "lo", "ki", "ru", "ma", "te", "no", "vi", "sa"]
_ADJ = ["silent", "crimson", "lost", "golden", "broken", "hidden", "last",
        "burning", "frozen", "electric", "paper", "midnight", "hollow",
        "savage", "gentle", "distant"]
_NOUN = ["river", "empire", "garden", "horizon", "letter", "shadow", "engine",
         "harbor", "mirror", "station", "voyage", "canyon", "signal", "orchid",
         "archive", "monsoon"]
_STREET = ["Bay", "Adam", "Oak", "Hill", "Lake", "Main", "Pine", "Cedar",
           "River", "Sunset", "Market", "Union", "Grove", "Walnut"]
_CITY = ["Fairview", "Riverton", "Lakewood", "Brookside", "Hillcrest",
         "Maplewood", "Westfield", "Northgate"]
_BRAND = ["Voltron", "Acme", "Zenith", "Polarix", "Nimbus", "Vertex", "Orion",
          "Quasar"]
_COLOR = ["black", "white", "silver", "blue", "red", "green", "graphite"]
_CATEGORY = ["kitchen appliances", "outdoor gear", "office electronics",
             "garden tools", "pet supplies", "sports equipment",
             "home lighting", "audio devices", "car accessories",
             "baby products", "craft materials", "computer parts"]
_REACTION = ["nausea", "dizziness", "skin rash", "headache", "insomnia",
             "joint pain", "fatigue", "blurred vision", "dry mouth", "anxiety",
             "tremor", "fever", "palpitations", "loss of appetite"]
_FILLER = [
    "The committee will reconvene after the scheduled maintenance window",
    "Additional documentation is archived in the central records office",
    "Routine procedures were followed according to the published manual",
    "No further remarks were entered into the register at this time",
    "Subsequent amendments may be filed through the standard channels",
    "The undersigned affirms the accuracy of the foregoing statements",
    "Weather conditions on the day were unremarkable and mild",
    "Members of the public may request copies subject to applicable fees",
]


def _rng(seed, *key) -> np.random.Generator:
    h = _stable_hash("|".join(str(k) for k in key), seed=seed)
    return np.random.default_rng(h % (2**63))


def _person_name(rng) -> str:
    def w(n):
        return "".join(rng.choice(_SYL) for _ in range(n)).capitalize()
    return f"{w(2)} {w(3)}"


def _movie_name(rng) -> str:
    return f"The {rng.choice(_ADJ).capitalize()} {rng.choice(_NOUN).capitalize()}"


def _filler(rng, n_sentences: int) -> str:
    if n_sentences <= 0:
        return ""
    return " ".join(str(rng.choice(_FILLER)) + "." for _ in range(n_sentences))


# ---------------------------------------------------------------------------
# §8.4 generator — movie-likes sentences (Fig 10 sweeps) and Movies analogue
# ---------------------------------------------------------------------------

def movie_likes(n: int = 400, persons_per_sentence: int = 1,
                filler_sentences: int = 0, seed: int = 0) -> JoinDataset:
    """Self-join: do two records mention a movie liked by the same person?

    D construction per §8.4: n persons, n movies, each person -> 2 movies,
    each movie -> 2 persons => 2n rows.
    """
    rng = _rng(seed, "movie_likes", n)
    persons = [_person_name(rng) for _ in range(n)]
    movies = [_movie_name(rng) for _ in range(n)]
    rows = []
    for i in range(n):
        rows.append((i, i))                       # person i likes movie i
        rows.append((i, (i + 1) % n))             # person i likes movie i+1
    texts, f_person, f_movie = [], [], []
    for ridx, (p, m) in enumerate(rows):
        extra = [persons[(p + 7 * (j + 1)) % n]
                 for j in range(persons_per_sentence - 1)]
        names = [persons[p]] + extra
        if len(names) > 1:
            namestr = ", ".join(names[:-1]) + " and " + names[-1]
        else:
            namestr = names[0]
        rr = _rng(seed, "filler", ridx)
        t1, t2 = _filler(rr, filler_sentences), _filler(rr, filler_sentences)
        verb = "likes" if len(names) == 1 else "like"
        sent = (f"{t1} For example, {namestr} {verb} "
                f"the movie {movies[m]}. {t2}").strip()
        texts.append(sent)
        f_person.append(" ".join(names))
        f_movie.append(movies[m])
    truth = set()
    by_person: dict = {}
    for ridx, (p, m) in enumerate(rows):
        extra = [(p + 7 * (j + 1)) % n for j in range(persons_per_sentence - 1)]
        for pp in [p] + extra:
            by_person.setdefault(pp, []).append(ridx)
    for pp, rids in by_person.items():
        for a in rids:
            for b in rids:
                if a != b:
                    truth.add((a, b))
    schema = [
        Field("person_names", "word_overlap", llm_needed=True, relevance=1.0,
              base_noise=0.02),
        Field("movie_name", "word_overlap", llm_needed=True, relevance=0.3,
              base_noise=0.02),
    ]
    return JoinDataset(
        name=f"movie_likes_p{persons_per_sentence}_f{filler_sentences}",
        texts_l=texts, texts_r=texts,
        fields_l={"person_names": f_person, "movie_name": f_movie},
        fields_r={"person_names": f_person, "movie_name": f_movie},
        schema=schema, truth_set=truth, self_join=True,
        join_prompt="Do the two records mention a movie liked by the same person?")


def movies_pages(n_movies: int = 150, cast_size: int = 6, filler_sentences: int = 4,
                 seed: int = 0) -> JoinDataset:
    """Movies analogue (category 1): movie pages x actor pages, join = acts-in."""
    rng = _rng(seed, "movies_pages", n_movies)
    movies = [_movie_name(rng) for _ in range(n_movies)]
    n_actors = n_movies * 2
    actors = [_person_name(rng) for _ in range(n_actors)]
    cast = {m: sorted(rng.choice(n_actors, size=cast_size, replace=False).tolist())
            for m in range(n_movies)}
    texts_l, f_title, f_cast = [], [], []
    for m in range(n_movies):
        rr = _rng(seed, "mfill", m)
        names = ", ".join(actors[a] for a in cast[m])
        texts_l.append(
            f"{_filler(rr, filler_sentences)} {movies[m]} is a feature film. "
            f"The cast includes {names}. {_filler(rr, filler_sentences)}")
        f_title.append(movies[m])
        f_cast.append(" ".join(actors[a] for a in cast[m]))
    texts_r, f_actor, f_filmo = [], [], []
    films_of: dict = {a: [] for a in range(n_actors)}
    for m, cs in cast.items():
        for a in cs:
            films_of[a].append(m)
    for a in range(n_actors):
        rr = _rng(seed, "afill", a)
        filmo = ", ".join(movies[m] for m in films_of[a]) or "various stage plays"
        texts_r.append(
            f"{_filler(rr, filler_sentences)} {actors[a]} is an actor known "
            f"for {filmo}. {_filler(rr, filler_sentences)}")
        f_actor.append(actors[a])
        f_filmo.append(" ".join(movies[m] for m in films_of[a]))
    truth = {(m, a) for m, cs in cast.items() for a in cs}
    schema = [
        Field("cast_or_actor", "word_overlap", relevance=1.0, base_noise=0.03),
        Field("title_or_films", "word_overlap", relevance=0.9, base_noise=0.03),
    ]
    return JoinDataset(
        name="movies_pages", texts_l=texts_l, texts_r=texts_r,
        fields_l={"cast_or_actor": f_cast, "title_or_films": f_title},
        fields_r={"cast_or_actor": f_actor, "title_or_films": f_filmo},
        schema=schema, truth_set=truth,
        join_prompt="Is the person a cast or crew member of the movie?")


def citations(n_docs: int = 300, filler_sentences: int = 3, seed: int = 0) -> JoinDataset:
    """Citations analogue (category 1): one dominant feature (case number)."""
    rng = _rng(seed, "citations", n_docs)
    n_cases = max(n_docs // 3, 1)
    case_ids = [f"{rng.integers(1,5)}-CR-{rng.integers(1000, 9999)}" for _ in range(n_cases)]
    texts, f_case = [], []
    for i in range(n_docs):
        c = int(rng.integers(0, n_cases))
        rr = _rng(seed, "cfill", i)
        texts.append(
            f"{_filler(rr, filler_sentences)} The court relies on the holding "
            f"in case {case_ids[c]} as controlling precedent. "
            f"{_filler(rr, filler_sentences)}")
        f_case.append(case_ids[c])
    truth = {(i, j) for i in range(n_docs) for j in range(n_docs)
             if i != j and f_case[i] == f_case[j]}
    schema = [
        Field("case_number", "word_overlap", llm_needed=False, relevance=1.0,
              base_noise=0.01),
        Field("legal_topic", "semantic", relevance=0.2, base_noise=0.05),
    ]
    topics = [t.split()[0] for t in f_case]
    return JoinDataset(
        name="citations", texts_l=texts, texts_r=texts,
        fields_l={"case_number": f_case, "legal_topic": topics},
        fields_r={"case_number": f_case, "legal_topic": topics},
        schema=schema, truth_set=truth, self_join=True,
        join_prompt="Do the two legal arguments cite the same case?")


def police_records(n_incidents: int = 120, reports_per_incident: int = 2,
                   filler_sentences: int = 8, seed: int = 0) -> JoinDataset:
    """Police-records analogue (category 2, the running example): multiple
    weak features — date (±1 day jitter), location paraphrase, officer names."""
    rng = _rng(seed, "police", n_incidents)
    texts, f_date, f_loc, f_off, inc_of = [], [], [], [], []
    for inc in range(n_incidents):
        day0 = int(rng.integers(0, 3650))
        street = rng.choice(_STREET)
        cross = rng.choice([s for s in _STREET if s != street])
        city = rng.choice(_CITY)
        officers = [_person_name(rng) for _ in range(3)]
        for rep in range(reports_per_incident):
            rr = _rng(seed, "pfill", inc, rep)
            day = day0 + int(rr.integers(0, 2))          # ±1 day jitter
            loc_variants = [
                f"the intersection of {street} and {cross} St in {city}",
                f"{street} St at {cross}, {city}",
                f"near {cross} and {street} Streets, {city}",
            ]
            loc = loc_variants[int(rr.integers(0, len(loc_variants)))]
            offs = [officers[k] for k in rr.permutation(3)[: int(rr.integers(1, 4))]]
            texts.append(
                f"{_filler(rr, filler_sentences)} On day {day}, officers "
                f"{', '.join(offs)} responded to an incident at {loc}. "
                f"{_filler(rr, filler_sentences)}")
            f_date.append(float(day))
            f_loc.append(loc)
            f_off.append(" ".join(offs))
            inc_of.append(inc)
    n = len(texts)
    truth = {(i, j) for i in range(n) for j in range(n)
             if i != j and inc_of[i] == inc_of[j]}
    schema = [
        Field("incident_date", "arithmetic", llm_needed=True, relevance=1.0,
              base_noise=0.05, missing=0.02),
        Field("location", "semantic", llm_needed=True, relevance=0.9,
              base_noise=0.05, missing=0.02),
        Field("officer_names", "word_overlap", llm_needed=True, relevance=0.8,
              base_noise=0.05, missing=0.02),
    ]
    return JoinDataset(
        name="police_records", texts_l=texts, texts_r=texts,
        fields_l={"incident_date": f_date, "location": f_loc, "officer_names": f_off},
        fields_r={"incident_date": f_date, "location": f_loc, "officer_names": f_off},
        schema=schema, truth_set=truth, self_join=True,
        join_prompt="Do the two police reports refer to the same incident?")


def products(n_products: int = 200, seed: int = 0) -> JoinDataset:
    """Products analogue (category 2): model numbers missing/truncated."""
    rng = _rng(seed, "products", n_products)
    texts_l, texts_r, fl, fr = [], [], {"model": [], "brand": [], "color": []}, \
        {"model": [], "brand": [], "color": []}
    truth = set()
    for p in range(n_products):
        brand = str(rng.choice(_BRAND))
        color = str(rng.choice(_COLOR))
        model = f"{brand[:2].upper()}{rng.integers(100, 999)}-{rng.integers(10, 99)}"
        for side, (txts, ff) in enumerate([(texts_l, fl), (texts_r, fr)]):
            rr = _rng(seed, "prod", p, side)
            m = model
            if rr.random() < 0.25:
                m = model.split("-")[0]                  # truncated digits
            if rr.random() < 0.2:
                m = None                                 # not listed
            desc = (f"{brand} {color} unit"
                    + (f" model {m}" if m else "")
                    + f". {_filler(rr, 2)}")
            txts.append(desc)
            ff["model"].append(m)
            ff["brand"].append(brand)
            ff["color"].append(color)
        truth.add((p, p))
    schema = [
        Field("model", "word_overlap", llm_needed=False, relevance=1.0,
              base_noise=0.02, missing=0.0),
        Field("brand", "word_overlap", llm_needed=True, relevance=0.7,
              base_noise=0.03),
        Field("color", "word_overlap", llm_needed=True, relevance=0.4,
              base_noise=0.03),
    ]
    return JoinDataset(
        name="products", texts_l=texts_l, texts_r=texts_r,
        fields_l=fl, fields_r=fr, schema=schema, truth_set=truth,
        join_prompt="Do the two listings describe the same product?")


def _category_pool(n: int) -> list:
    """Expand the base category list into n distinct labels (the real
    Categorize/BioDEX label spaces have 10^2-10^4 entries)."""
    out = []
    i = 0
    while len(out) < n:
        base = _CATEGORY[i % len(_CATEGORY)]
        adj = _ADJ[(i // len(_CATEGORY)) % len(_ADJ)]
        out.append(f"{adj} {base}" if i >= len(_CATEGORY) else base)
        i += 1
    return out


def categorize(n_items: int = 400, n_categories: int = 120, seed: int = 0) -> JoinDataset:
    """Categorize analogue (category 3): multi-label classification-as-join."""
    rng = _rng(seed, "categorize", n_items)
    cats = _category_pool(n_categories)
    texts_l, f_kw = [], []
    truth = set()
    for i in range(n_items):
        rr = _rng(seed, "cat", i)
        labels = [int(rr.integers(0, len(cats)))]
        if rr.random() < 0.1:                             # multi-label
            labels.append(int(rr.integers(0, len(cats))))
        hints = []
        for c in labels:
            truth.add((i, c))
            hints.append(" ".join(cats[c].split()[-2:]) if rr.random() < 0.93
                         else str(rng.choice(_NOUN)))
        texts_l.append(
            f"A {rng.choice(_COLOR)} {rng.choice(_ADJ)} item related to "
            f"{' and '.join(hints)} for daily use. {_filler(rr, 3)}")
        f_kw.append("; ".join(hints))
    schema = [
        Field("product_keywords", "semantic", relevance=1.0, base_noise=0.05),
        Field("category_name", "semantic", relevance=0.8, base_noise=0.0),
    ]
    return JoinDataset(
        name="categorize", texts_l=texts_l, texts_r=list(cats),
        fields_l={"product_keywords": f_kw, "category_name": f_kw},
        fields_r={"product_keywords": cats, "category_name": cats},
        schema=schema, truth_set=truth,
        join_prompt="Can the product be classified with the category?")


_BODY = ["arm", "knee", "chest", "back", "neck", "shoulder", "hip", "wrist",
         "ankle", "jaw"]
_SYMPTOM_SYNONYM = {
    "nausea": "felt queasy", "dizziness": "light-headedness",
    "skin rash": "red patches", "headache": "pressure in the head",
    "insomnia": "trouble sleeping", "joint pain": "aching joints",
    "fatigue": "persistent exhaustion", "blurred vision": "vision trouble",
    "dry mouth": "parched mouth", "anxiety": "feeling on edge",
    "tremor": "shaking hands", "fever": "elevated temperature",
    "palpitations": "racing heart", "loss of appetite": "no desire to eat",
}


def _reaction_pool(n: int) -> list:
    out = list(_REACTION)
    i = 0
    while len(out) < n:
        out.append(f"{_REACTION[i % len(_REACTION)]} of the "
                   f"{_BODY[(i // len(_REACTION)) % len(_BODY)]}")
        i += 1
    return out[:n]


def biodex(n_notes: int = 300, n_terms: int = 140, seed: int = 0) -> JoinDataset:
    """BioDEX analogue (category 3): weakly decomposable classification."""
    terms = _reaction_pool(n_terms)
    texts_l, f_sym = [], []
    truth = set()
    for i in range(n_notes):
        rr = _rng(seed, "bio", i)
        k = int(rr.integers(1, 3))
        cs = rr.choice(len(terms), size=k, replace=False)
        mentions = []
        for c in cs:
            truth.add((i, int(c)))
            base = terms[c].split(" of the ")[0]
            loc = terms[c][len(base):]
            m = _SYMPTOM_SYNONYM.get(base, base) if rr.random() < 0.55 else base
            mentions.append(m + loc)
        texts_l.append(
            f"Patient reports {', and '.join(mentions)} after starting the "
            f"medication. {_filler(rr, 4)}")
        f_sym.append("; ".join(mentions))
    schema = [
        Field("symptoms", "semantic", relevance=1.0, base_noise=0.06,
              missing=0.05),
        Field("term", "semantic", relevance=0.8, base_noise=0.0),
    ]
    return JoinDataset(
        name="biodex", texts_l=texts_l, texts_r=list(terms),
        fields_l={"symptoms": f_sym, "term": f_sym},
        fields_r={"symptoms": list(terms), "term": list(terms)},
        schema=schema, truth_set=truth,
        join_prompt="Does the medical reaction term apply to the patient?")


DATASETS: dict = {
    "citations": citations,
    "police_records": police_records,
    "categorize": categorize,
    "biodex": biodex,
    "movies": movies_pages,
    "products": products,
}
