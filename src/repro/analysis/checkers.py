"""Repo-specific AST lint rules ruff cannot express (DESIGN.md §9).

Four rules, each guarding an invariant a past PR introduced and nothing
else enforces:

``tracer-guard``
    Every ``tracer.record_span / .event / .current_span`` call on a
    *possibly-null* tracer must sit under an ``if tracer:`` truthiness
    guard (or ternary).  The no-op path's guarantee (PR 8: tracing off
    costs one falsy check) dies the day someone calls ``record_span``
    unguarded — NullTracer would need real methods and the hot loop a
    real call.  ``with tracer.span(...)`` is exempt: ``span`` exists on
    NullTracer precisely so with-statements stay unconditional.  A
    parameter annotated non-Optional ``Tracer`` is treated as guarded:
    the annotation states the caller's contract (guard before calling).

``legacy-kwargs``
    ``QueryOptions.from_legacy`` and legacy query kwargs
    (``engine=``/``recall_target=``/... on ``.query``/``.append_right``)
    are a deprecation shim for *external* callers (PR 9).  Internal call
    sites must construct ``QueryOptions`` directly — otherwise the shim
    can never be deleted and every internal call pays a
    DeprecationWarning.  Only the shim's own module may reference it.

``metric-name``
    Every literal metric name passed to ``inc/observe/set_gauge`` must
    be declared: ledger-derived names in ``core.costs.FIELD_METRICS`` /
    ``GAUGE_METRICS`` (the ledger↔metrics round-trip invariant),
    serving-layer names in ``obs.metrics.DECLARED_METRICS``.  A typo'd
    name otherwise creates a dangling instrument that dashboards and
    ``ledger_from_metrics`` silently never see.

``wallclock``
    ``time.time()`` is banned in span-path packages (obs, core, engine,
    serving, kernels, distributed): span math must use
    ``time.perf_counter()`` — wall clock steps under NTP and breaks
    duration/overlap accounting.  Deliberate wall-clock metadata reads
    carry a ``# wallclock-ok:`` comment on the same line.
"""

from __future__ import annotations

import ast
from typing import Optional

from repro.analysis.findings import Finding, iter_py_sources

# --------------------------------------------------------------------------
# tracer-guard
# --------------------------------------------------------------------------

# methods that only exist on a real Tracer (NullTracer has span/__bool__)
_TRACER_ONLY = ("record_span", "event", "current_span")
# files allowed to touch tracer internals unguarded
_TRACER_EXEMPT = ("src/repro/obs/trace.py",)


def _looks_like_tracer(e) -> Optional[str]:
    """Variable name if ``e`` plausibly evaluates to a maybe-null tracer."""
    if isinstance(e, ast.Name) and "tracer" in e.id.lower():
        return e.id
    if isinstance(e, ast.Attribute) and "tracer" in e.attr.lower():
        return ast.unparse(e)
    return None


class _TracerGuardVisitor(ast.NodeVisitor):
    def __init__(self, path: str):
        self.path = path
        self.findings: list = []
        self._guarded: list = []       # stack of guarded tracer exprs

    @staticmethod
    def _truthy_names(test) -> list:
        """Tracer-ish expressions asserted truthy by an ``if`` test."""
        out = []
        t = _looks_like_tracer(test)
        if t:
            out.append(t)
        if isinstance(test, ast.BoolOp) and isinstance(test.op, ast.And):
            for v in test.values:
                out.extend(_TracerGuardVisitor._truthy_names(v))
        if isinstance(test, ast.Compare) and len(test.ops) == 1 \
                and isinstance(test.ops[0], ast.IsNot):
            out.extend(filter(None, [_looks_like_tracer(test.left)]))
        return out

    def _visit_func(self, node):
        # a param annotated `Tracer` (not Optional[Tracer]) is non-null
        # by signature: the caller guards, per the annotation contract
        names = []
        for a in (list(node.args.posonlyargs) + list(node.args.args)
                  + list(node.args.kwonlyargs)):
            ann = a.annotation
            if isinstance(ann, ast.Name) and ann.id == "Tracer":
                names.append(a.arg)
            elif isinstance(ann, ast.Attribute) and ann.attr == "Tracer":
                names.append(a.arg)
        self._guarded.extend(names)
        self.generic_visit(node)
        del self._guarded[len(self._guarded) - len(names):]

    visit_FunctionDef = _visit_func
    visit_AsyncFunctionDef = _visit_func

    def visit_If(self, node):
        names = self._truthy_names(node.test)
        self._guarded.extend(names)
        for st in node.body:
            self.visit(st)
        del self._guarded[len(self._guarded) - len(names):]
        for st in node.orelse:
            self.visit(st)

    def visit_IfExp(self, node):
        names = self._truthy_names(node.test)
        self._guarded.extend(names)
        self.visit(node.body)
        del self._guarded[len(self._guarded) - len(names):]
        self.visit(node.test)
        self.visit(node.orelse)

    def visit_BoolOp(self, node):
        # ``tracer and tracer.event(...)`` guards the right-hand side
        if isinstance(node.op, ast.And) and len(node.values) >= 2:
            names = []
            for v in node.values[:-1]:
                names.extend(self._truthy_names(v))
                self.visit(v)
            self._guarded.extend(names)
            self.visit(node.values[-1])
            del self._guarded[len(self._guarded) - len(names):]
            return
        self.generic_visit(node)

    def visit_Call(self, node):
        f = node.func
        if isinstance(f, ast.Attribute) and f.attr in _TRACER_ONLY:
            recv = _looks_like_tracer(f.value)
            if recv is not None and recv not in self._guarded:
                self.findings.append(Finding(
                    "tracer-guard", self.path, node.lineno,
                    f"unguarded tracer call {recv}.{f.attr}(...): wrap "
                    f"in `if {recv}:` so the no-op path stays one falsy "
                    f"check (NullTracer has no {f.attr})"))
        self.generic_visit(node)


def check_tracer_guards(sources: list) -> list:
    out = []
    for path, src in sources:
        if path in _TRACER_EXEMPT:
            continue
        v = _TracerGuardVisitor(path)
        v.visit(ast.parse(src, filename=path))
        out.extend(v.findings)
    return out


# --------------------------------------------------------------------------
# legacy-kwargs
# --------------------------------------------------------------------------

# the one module allowed to mention the shim: where it is defined/used
# to coerce *external* kwargs
_LEGACY_EXEMPT = ("src/repro/core/join.py",
                  "src/repro/serving/join_service.py")
# legacy kwarg names on .query/.append_right that the shim absorbs
_LEGACY_KWARGS = frozenset({
    "engine", "stream", "recall_target", "precision_target", "delta",
})
_LEGACY_METHODS = ("query", "append_right")


def check_legacy_kwargs(sources: list) -> list:
    out = []
    for path, src in sources:
        if path in _LEGACY_EXEMPT:
            continue
        tree = ast.parse(src, filename=path)
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            if isinstance(f, ast.Attribute) and f.attr == "from_legacy":
                out.append(Finding(
                    "legacy-kwargs", path, node.lineno,
                    "internal call to QueryOptions.from_legacy: construct "
                    "QueryOptions(...) directly — the shim exists only to "
                    "absorb external legacy kwargs and must stay deletable"))
            elif isinstance(f, ast.Attribute) \
                    and f.attr in _LEGACY_METHODS:
                bad = sorted(kw.arg for kw in node.keywords
                             if kw.arg in _LEGACY_KWARGS)
                if bad:
                    out.append(Finding(
                        "legacy-kwargs", path, node.lineno,
                        f".{f.attr}({', '.join(bad)}=...) uses deprecated "
                        f"legacy kwargs: pass "
                        f"options=QueryOptions(...) instead"))
    return out


# --------------------------------------------------------------------------
# metric-name
# --------------------------------------------------------------------------

_METRIC_METHODS = ("inc", "observe", "set_gauge")
# registry internals + the ledger binding construct names dynamically
_METRIC_EXEMPT = ("src/repro/obs/metrics.py", "src/repro/core/costs.py")


def _declared_metric_names() -> set:
    from repro.core.costs import FIELD_METRICS, GAUGE_METRICS
    from repro.obs.metrics import DECLARED_METRICS
    return (set(FIELD_METRICS.values()) | set(GAUGE_METRICS.values())
            | set(DECLARED_METRICS))


def check_metric_names(sources: list,
                       declared: Optional[set] = None) -> list:
    if declared is None:
        declared = _declared_metric_names()
    out = []
    for path, src in sources:
        if path in _METRIC_EXEMPT:
            continue
        tree = ast.parse(src, filename=path)
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            if not (isinstance(f, ast.Attribute)
                    and f.attr in _METRIC_METHODS and node.args):
                continue
            recv = f.value
            recv_txt = ast.unparse(recv)
            if "metric" not in recv_txt.lower():
                continue               # counter.inc(), histogram.observe()
            arg = node.args[0]
            if not (isinstance(arg, ast.Constant)
                    and isinstance(arg.value, str)):
                continue               # dynamic names audited at runtime
            if arg.value not in declared:
                out.append(Finding(
                    "metric-name", path, node.lineno,
                    f"metric {arg.value!r} is not declared: add it to "
                    f"obs.metrics.DECLARED_METRICS (serving-layer) or "
                    f"derive it from the ledger maps in core.costs"))
    return out


# --------------------------------------------------------------------------
# wallclock
# --------------------------------------------------------------------------

# packages on the span path: durations here must be monotonic
_SPAN_PATH_PREFIXES = (
    "src/repro/obs/", "src/repro/core/", "src/repro/engine/",
    "src/repro/serving/", "src/repro/kernels/", "src/repro/distributed/",
)
_WALLCLOCK_OK = "# wallclock-ok:"


def check_wallclock(sources: list) -> list:
    out = []
    for path, src in sources:
        if not path.startswith(_SPAN_PATH_PREFIXES):
            continue
        lines = src.splitlines()
        tree = ast.parse(src, filename=path)
        for node in ast.walk(tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "time"
                    and isinstance(node.func.value, ast.Name)
                    and node.func.value.id == "time"):
                continue
            line_txt = lines[node.lineno - 1] \
                if node.lineno <= len(lines) else ""
            if _WALLCLOCK_OK in line_txt:
                continue
            out.append(Finding(
                "wallclock", path, node.lineno,
                "time.time() on the span path: use time.perf_counter() "
                "for durations (wall clock steps under NTP); if this is "
                "deliberate wall-clock metadata, annotate the line with "
                "`# wallclock-ok: <reason>`"))
    return out


# --------------------------------------------------------------------------
# entry point
# --------------------------------------------------------------------------

def run_checkers(sources: Optional[list] = None) -> list:
    """All four rules over ``(path, source)`` pairs (default: src/repro,
    benchmarks, and examples for the legacy-kwargs rule)."""
    if sources is None:
        sources = iter_py_sources("src/repro")
        extra = iter_py_sources("benchmarks", "examples")
    else:
        extra = []
    findings = []
    findings += check_tracer_guards(sources)
    findings += check_legacy_kwargs(sources + extra)
    findings += check_metric_names(sources)
    findings += check_wallclock(sources)
    findings.sort(key=lambda f: (f.file, f.line, f.rule))
    return findings
