"""CLI for the analysis pass: ``python -m repro.analysis --check``.

Exit 0 when every analyzer is clean, 1 with one finding per line on
stderr otherwise.  ``--dot PATH`` additionally renders the lock-order
graph as Graphviz DOT (CI uploads it as a workflow artifact next to the
Perfetto trace).  The HLO *manifest structure* is validated here; the
expensive lower-and-compare against a real program runs in the multipod
dry-run (``repro.launch.multipod_dryrun``), which CI also executes.
"""

from __future__ import annotations

import argparse
import os
import sys

from repro.analysis.checkers import run_checkers
from repro.analysis.findings import Finding
from repro.analysis.hlo_contracts import default_manifest_path, load_manifest
from repro.analysis.lockgraph import build_lock_graph, render_text, to_dot


def _check_manifest() -> list:
    """The committed manifest must exist and parse into contracts — the
    dry-run falls back to defaults without it, which would silently
    un-gate the collective budgets."""
    path = default_manifest_path()
    rel = os.path.relpath(path, os.getcwd())
    try:
        contracts = load_manifest(path)
    except FileNotFoundError:
        return [Finding("hlo-manifest", rel, 0,
                        "missing: regenerate with `python -m "
                        "repro.launch.multipod_dryrun --write-manifest` "
                        "and commit it")]
    except (KeyError, ValueError) as e:
        return [Finding("hlo-manifest", rel, 0,
                        f"unparseable ({type(e).__name__}: {e})")]
    if "sharded_chunk_step" not in contracts:
        return [Finding("hlo-manifest", rel, 0,
                        "no 'sharded_chunk_step' program entry — the "
                        "dry-run's chunk-step gate has no contract")]
    return []


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    ap.add_argument("--check", action="store_true",
                    help="run all analyzers; exit nonzero on any finding")
    ap.add_argument("--dot", metavar="PATH", default=None,
                    help="write the lock-order graph as Graphviz DOT")
    args = ap.parse_args(argv)
    if not args.check and not args.dot:
        ap.error("nothing to do: pass --check and/or --dot PATH")

    graph = build_lock_graph()
    if args.dot:
        os.makedirs(os.path.dirname(os.path.abspath(args.dot)),
                    exist_ok=True)
        with open(args.dot, "w", encoding="utf-8") as f:
            f.write(to_dot(graph))
        print(f"lock graph DOT -> {args.dot}")
    if not args.check:
        return 0

    findings = list(graph.findings)
    findings += run_checkers()
    findings += _check_manifest()

    print(render_text(graph), end="")
    if findings:
        print(f"\n{len(findings)} finding(s):", file=sys.stderr)
        for f in findings:
            print(f"  {f}", file=sys.stderr)
        return 1
    print("analysis: clean (lock graph, lint rules, HLO manifest)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
