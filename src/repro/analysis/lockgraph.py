"""Static lock-order analysis over the repo's threaded stack.

PR 9 made four modules take locks (``serving/fleet.py``,
``serving/planes.py``, ``serving/join_service.py``, ``engine/sharded.py``)
with worker threads crossing the pump and fleet boundaries.  Nothing but
reviewer discipline stopped a new lock-order cycle or a blocking pull
inside a critical section from landing green; this module is the machine
check (DESIGN.md §9).

What it does, per ``build_lock_graph(sources)``:

  1. **Lock discovery** — every ``threading.Lock/RLock/Condition()``
     construction becomes a named node: ``self._lock = threading.Lock()``
     in class ``C`` of module ``m`` is ``m.C._lock``; class-level and
     module-level locks name accordingly; a function-local construction
     (the per-key lease lock) names ``m.C.func.<var>``.  The node records
     its construction line — the key the runtime witness (witness.py)
     uses to map real locks back onto static nodes.
  2. **Acquisition extraction** — ``with`` items, ``.acquire()`` calls,
     and ``@contextmanager`` functions that hold locks *at their yield*
     (``PlanLibrary.lease`` holds the per-key lock at yield, so the
     caller's with-body runs under it; ``BandScheduler.step`` releases
     before yield, so its body runs unlocked — both are modeled).
  3. **Interprocedural edges** — per-function summaries of "locks this
     may acquire (transitively)" are propagated to a fixpoint over a
     resolved call graph (receiver types inferred from constructor
     assignments and annotations; untyped receivers fall back to
     unique-name matching behind a stdlib-method denylist so dict
     ``.get``/``.put`` can never fabricate an edge).  Holding ``h`` while
     calling anything that may acquire ``a`` adds edge ``h -> a``.
  4. **Checks** — a cycle among distinct nodes (potential deadlock), a
     same-thread re-acquisition path on a non-reentrant ``Lock``, and a
     *blocking call under any held lock* (``jax.device_get``, oracle
     ``label_pairs``, ``Future.result``, ``queue.put/get``,
     ``time.sleep``, ``Thread.join``; ``Condition.wait`` exempts its own
     lock) are each CI-failing findings.  Deliberate holds are waived
     explicitly in ``BLOCKING_WAIVERS`` with a reason — waivers are
     reported, never silent.

The graph renders as text (CLI) and DOT (CI artifact, next to the
Perfetto trace).  ``tests/test_analysis.py`` pins both the clean verdict
on this tree and a seeded violation per check.
"""

from __future__ import annotations

import ast
import dataclasses
import fnmatch
from typing import Optional

from repro.analysis.findings import Finding, iter_py_sources, module_name

# threading constructors that create a lock node (Condition's underlying
# lock is an RLock, so re-entry on one Condition is not a self-deadlock)
_LOCK_CTORS = ("Lock", "RLock", "Condition")

# method names that NEVER resolve by bare name against repo classes: the
# stdlib container/sync surface.  An untyped ``d.get(...)`` must not
# resolve to ``FeaturePlaneStore.get`` and fabricate a lock edge.
_NAME_DENYLIST = frozenset({
    "get", "put", "pop", "popitem", "setdefault", "keys", "values",
    "items", "update", "append", "appendleft", "extend", "insert",
    "remove", "sort", "reverse", "clear", "copy", "add", "discard",
    "union", "intersection", "move_to_end", "count", "index",
    "join", "split", "rsplit", "strip", "lstrip", "rstrip", "format",
    "startswith", "endswith", "encode", "decode", "lower", "upper",
    "replace", "find", "rfind", "title", "ljust", "rjust", "zfill",
    "read", "write", "close", "flush", "seek", "tell", "readline",
    "readlines", "open",
    "acquire", "release", "locked", "wait", "wait_for", "notify",
    "notify_all", "set", "is_set", "start", "run", "is_alive",
    "result", "exception", "done", "cancel", "submit", "shutdown",
    "get_nowait", "put_nowait", "qsize", "empty", "full", "task_done",
    "match", "search", "findall", "finditer", "sub", "group", "groups",
    "end", "span",
    "astype", "tolist", "item", "reshape", "ravel", "flatten",
    "squeeze", "sum", "min", "max", "mean", "std", "any", "all",
    "nonzero", "cumsum", "argsort", "take", "dot", "view", "fill",
})

# resolution fan-out cap for name-based fallback: more candidates than
# this means the name is too generic to trust
_MAX_FANOUT = 6

# (lock-node glob, blocking-kind glob, reason).  Waived findings are
# reported in the text output — the escape hatch is visible, not silent.
BLOCKING_WAIVERS = (
    ("serving.join_service.PlanLibrary.lease.*", "*",
     "per-key planning lease is *designed* to be held across plan_join "
     "(oracle labeling + engine pulls): racing cold plans serialize so "
     "the loser wakes to a library hit — DESIGN.md §8a"),
)

# blocking-call surface (ISSUE 10): call name -> kind, with receiver
# constraints applied in _blocking_kind below
_QUEUE_TYPES = ("Queue", "SimpleQueue", "LifoQueue", "PriorityQueue")


@dataclasses.dataclass(frozen=True)
class LockNode:
    name: str                      # e.g. "serving.planes.FeaturePlaneStore._lock"
    kind: str                      # Lock | RLock | Condition
    file: str                      # repo-relative path
    line: int                      # construction line (witness map key)


class _CMRef:
    """A context-manager call held on the with-stack: resolved to the
    callee's locks-held-at-yield during edge generation."""
    __slots__ = ("call",)

    def __init__(self, call):
        self.call = call


@dataclasses.dataclass
class _Call:
    line: int
    held: tuple                    # entries: node name (str) | _CMRef
    name: str                      # method or function name
    is_method: bool
    recv_kind: str                 # self | name | attr | class | call | none
    recv_type: Optional[str]       # inferred class name, if any
    recv_name: str                 # textual receiver, for heuristics
    recv_lock: Optional[str]       # lock node of the receiver (cond.wait)


@dataclasses.dataclass
class _Acq:
    node: str
    line: int
    held: tuple


@dataclasses.dataclass
class _Func:
    qual: str                      # "mod.Class.method" | "mod.func[.nested]"
    mod: str
    cls: Optional[str]
    name: str
    file: str
    line: int
    is_cm: bool = False
    nested: bool = False           # defined inside another function
    acqs: list = dataclasses.field(default_factory=list)
    calls: list = dataclasses.field(default_factory=list)
    yield_helds: list = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class _ClassRec:
    mod: str
    bases: list
    methods: dict                  # name -> _Func
    lock_attrs: dict               # attr -> node name
    attr_types: dict               # attr -> class-name string


@dataclasses.dataclass
class LockGraph:
    nodes: dict                    # name -> LockNode
    edges: dict                    # (held, acquired) -> [site strings]
    findings: list                 # [Finding]
    waived: list                   # [str] — waived blocking reports

    def edge_set(self) -> set:
        return set(self.edges)


def _lock_ctor_kind(e) -> Optional[str]:
    """'Lock'|'RLock'|'Condition' if ``e`` is ``threading.X()``."""
    if not isinstance(e, ast.Call):
        return None
    f = e.func
    if (isinstance(f, ast.Attribute) and f.attr in _LOCK_CTORS
            and isinstance(f.value, ast.Name) and f.value.id == "threading"):
        return f.attr
    return None


def _ann_type(a) -> Optional[str]:
    """Best-effort class name out of an annotation node: unwraps
    Optional[X], Union[X, None], X | None, "X" string forms."""
    if a is None:
        return None
    if isinstance(a, ast.Constant) and isinstance(a.value, str):
        try:
            a = ast.parse(a.value, mode="eval").body
        except SyntaxError:
            return None
    if isinstance(a, ast.Name):
        return a.id
    if isinstance(a, ast.Attribute):
        return a.attr
    if isinstance(a, ast.Subscript):           # Optional[X] / Union[...]
        inner = a.slice
        if isinstance(inner, ast.Tuple):
            for el in inner.elts:
                t = _ann_type(el)
                if t and t != "None":
                    return t
            return None
        return _ann_type(inner)
    if isinstance(a, ast.BinOp) and isinstance(a.op, ast.BitOr):
        return _ann_type(a.left) or _ann_type(a.right)
    return None


def _ctor_type(e) -> Optional[str]:
    """Class name if ``e`` constructs one: ``C(...)``, ``mod.C(...)``,
    ``x or C(...)`` (class names are CapWords by repo convention)."""
    if isinstance(e, ast.Call):
        f = e.func
        name = f.id if isinstance(f, ast.Name) else (
            f.attr if isinstance(f, ast.Attribute) else None)
        if name and name[:1].isupper():
            return name
        return None
    if isinstance(e, ast.BoolOp) and isinstance(e.op, ast.Or):
        for v in e.values:
            t = _ctor_type(v)
            if t:
                return t
    return None


class _ModuleScan:
    """Per-module AST pass: lock nodes, class/type tables, and a
    held-stack walk of every function body."""

    def __init__(self, path: str, source: str):
        self.path = path
        self.mod = module_name(path)
        self.tree = ast.parse(source, filename=path)
        self.nodes: dict = {}              # node name -> LockNode
        self.classes: dict = {}            # class name -> _ClassRec
        self.module_locks: dict = {}       # bare name -> node name
        self.funcs: list = []              # [_Func]
        self._scan_toplevel()
        self._scan_attr_tables()
        self._scan_functions()

    # -- discovery -----------------------------------------------------------

    def _add_node(self, name: str, kind: str, line: int) -> str:
        if name not in self.nodes:
            self.nodes[name] = LockNode(name, kind, self.path, line)
        return name

    def _scan_toplevel(self) -> None:
        for st in self.tree.body:
            if isinstance(st, ast.Assign) and len(st.targets) == 1 \
                    and isinstance(st.targets[0], ast.Name):
                kind = _lock_ctor_kind(st.value)
                if kind:
                    n = st.targets[0].id
                    self.module_locks[n] = self._add_node(
                        f"{self.mod}.{n}", kind, st.lineno)
            elif isinstance(st, ast.ClassDef):
                bases = [b.id if isinstance(b, ast.Name) else
                         (b.attr if isinstance(b, ast.Attribute) else None)
                         for b in st.bases]
                rec = _ClassRec(self.mod, [b for b in bases if b], {}, {}, {})
                self.classes[st.name] = rec
                for s in st.body:              # class-level locks
                    if isinstance(s, ast.Assign) and len(s.targets) == 1 \
                            and isinstance(s.targets[0], ast.Name):
                        kind = _lock_ctor_kind(s.value)
                        if kind:
                            attr = s.targets[0].id
                            rec.lock_attrs[attr] = self._add_node(
                                f"{self.mod}.{st.name}.{attr}", kind,
                                s.lineno)

    def _scan_attr_tables(self) -> None:
        """``self.X = ...`` across every method: lock attrs + attr types."""
        for cname, rec in self.classes.items():
            cdef = next(st for st in self.tree.body
                        if isinstance(st, ast.ClassDef) and st.name == cname)
            for m in cdef.body:
                if not isinstance(m, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    continue
                params = self._param_types(m)
                for node in ast.walk(m):
                    tgt = val = None
                    if isinstance(node, ast.Assign) \
                            and len(node.targets) == 1:
                        tgt, val = node.targets[0], node.value
                    elif isinstance(node, ast.AnnAssign):
                        tgt, val = node.target, node.value
                    if not (isinstance(tgt, ast.Attribute)
                            and isinstance(tgt.value, ast.Name)
                            and tgt.value.id == "self"):
                        continue
                    attr = tgt.attr
                    kind = _lock_ctor_kind(val)
                    if kind:
                        rec.lock_attrs.setdefault(attr, self._add_node(
                            f"{self.mod}.{cname}.{attr}", kind, node.lineno))
                        continue
                    t = _ctor_type(val) if val is not None else None
                    if t is None and isinstance(node, ast.AnnAssign):
                        t = _ann_type(node.annotation)
                    if t is None and isinstance(val, ast.Name):
                        t = params.get(val.id)
                    if t:
                        rec.attr_types.setdefault(attr, t)

    @staticmethod
    def _param_types(fn) -> dict:
        out = {}
        args = list(fn.args.posonlyargs) + list(fn.args.args) \
            + list(fn.args.kwonlyargs)
        for a in args:
            t = _ann_type(a.annotation)
            if t:
                out[a.arg] = t
        return out

    # -- function walk -------------------------------------------------------

    def _scan_functions(self) -> None:
        for st in self.tree.body:
            if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._scan_one(st, f"{self.mod}.{st.name}", None, {})
            elif isinstance(st, ast.ClassDef):
                for m in st.body:
                    if isinstance(m, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)):
                        self._scan_one(m, f"{self.mod}.{st.name}.{m.name}",
                                       st.name, {})

    def _scan_one(self, fn, qual: str, cls: Optional[str],
                  outer_types: dict, nested: bool = False) -> None:
        f = _Func(qual=qual, mod=self.mod, cls=cls, name=fn.name,
                  file=self.path, line=fn.lineno,
                  is_cm=any(self._is_cm_decorator(d)
                            for d in fn.decorator_list),
                  nested=nested)
        walker = _FuncWalker(self, f, cls, dict(outer_types))
        walker.types.update(self._param_types(fn))
        walker.walk_body(fn.body)
        self.funcs.append(f)
        for inner in walker.nested:
            self._scan_one(inner, f"{qual}.{inner.name}", cls,
                           walker.types, nested=True)

    @staticmethod
    def _is_cm_decorator(d) -> bool:
        name = d.attr if isinstance(d, ast.Attribute) else (
            d.id if isinstance(d, ast.Name) else None)
        return name == "contextmanager"


class _FuncWalker:
    """Held-stack interpretation of one function body."""

    def __init__(self, mod: _ModuleScan, func: _Func, cls: Optional[str],
                 types: dict):
        self.mod = mod
        self.func = func
        self.cls = cls
        self.types = types             # local/param name -> class name
        self.local_locks: dict = {}    # local name -> node name
        self.held: list = []           # node names / _CMRef, innermost last
        self.nested: list = []         # nested FunctionDefs, scanned after
        self.call_by_ast: dict = {}    # id(ast.Call) -> _Call

    # -- lock expression resolution -----------------------------------------

    def _lock_node_of(self, e) -> Optional[str]:
        if isinstance(e, ast.Attribute) and isinstance(e.value, ast.Name):
            base, attr = e.value.id, e.attr
            if base == "self" and self.cls:
                rec = self.mod.classes.get(self.cls)
                if rec and attr in rec.lock_attrs:
                    return rec.lock_attrs[attr]
            rec = self.mod.classes.get(base)
            if rec and attr in rec.lock_attrs:  # ClassName._class_lock
                return rec.lock_attrs[attr]
        elif isinstance(e, ast.Name):
            return self.local_locks.get(e.id) \
                or self.mod.module_locks.get(e.id)
        return None

    # -- statement walk ------------------------------------------------------

    def walk_body(self, stmts) -> None:
        for st in stmts:
            self.walk_stmt(st)

    def walk_stmt(self, st) -> None:
        if isinstance(st, (ast.With, ast.AsyncWith)):
            self._handle_with(st)
        elif isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
            self.nested.append(st)
        elif isinstance(st, ast.ClassDef):
            pass
        elif isinstance(st, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            self._handle_assign(st)
        else:
            for ch in ast.iter_child_nodes(st):
                if isinstance(ch, ast.stmt):
                    self.walk_stmt(ch)
                elif isinstance(ch, ast.expr):
                    self.walk_expr(ch)

    def _handle_with(self, w) -> None:
        pushed = 0
        for item in w.items:
            ce = item.context_expr
            node = self._lock_node_of(ce)
            if node is not None:
                self.func.acqs.append(
                    _Acq(node, ce.lineno, tuple(self.held)))
                self.held.append(node)
                pushed += 1
                continue
            self.walk_expr(ce)
            for cn in self._context_calls(ce):
                rec = self.call_by_ast.get(id(cn))
                if rec is not None:
                    self.held.append(_CMRef(rec))
                    pushed += 1
        self.walk_body(w.body)
        del self.held[len(self.held) - pushed:]

    @staticmethod
    def _context_calls(e) -> list:
        """Top-level Call nodes of a with-item context expression
        (through IfExp branches / BoolOp alternatives)."""
        if isinstance(e, ast.Call):
            return [e]
        if isinstance(e, ast.IfExp):
            return (_FuncWalker._context_calls(e.body)
                    + _FuncWalker._context_calls(e.orelse))
        if isinstance(e, ast.BoolOp):
            out = []
            for v in e.values:
                out.extend(_FuncWalker._context_calls(v))
            return out
        return []

    def _handle_assign(self, st) -> None:
        tgt = None
        if isinstance(st, ast.Assign) and len(st.targets) == 1:
            tgt = st.targets[0]
        elif isinstance(st, (ast.AnnAssign, ast.AugAssign)):
            tgt = st.target
        val = st.value
        if val is None:
            return
        kind = _lock_ctor_kind(val)
        if kind and isinstance(tgt, ast.Name):
            # lk = threading.Lock() — a function-local lock (lease locks)
            node = self.mod._add_node(
                f"{self.func.qual}.{tgt.id}", kind, val.lineno)
            self.local_locks[tgt.id] = node
            return
        if kind and isinstance(tgt, ast.Attribute):
            return                         # self.X: pre-scanned attr table
        # a lock constructed *inside* the value (setdefault, containers)
        embedded = next((n for n in ast.walk(val)
                         if _lock_ctor_kind(n)), None)
        if embedded is not None:
            node = self.mod._add_node(
                f"{self.func.qual}.@{embedded.lineno}",
                _lock_ctor_kind(embedded), embedded.lineno)
            if isinstance(tgt, ast.Name) and isinstance(val, ast.Call):
                # lk = d.setdefault(k, threading.Lock()): result IS the lock
                self.local_locks[tgt.id] = node
        self.walk_expr(val)
        if isinstance(tgt, ast.Name):
            t = _ctor_type(val)
            if t is None and isinstance(st, ast.AnnAssign):
                t = _ann_type(st.annotation)
            if t is None and isinstance(val, ast.Attribute) \
                    and isinstance(val.value, ast.Name) \
                    and val.value.id == "self" and self.cls:
                rec = self.mod.classes.get(self.cls)
                t = rec.attr_types.get(val.attr) if rec else None
            if t is None and isinstance(val, ast.Name):
                t = self.types.get(val.id)
            if t:
                self.types[tgt.id] = t

    # -- expression walk -----------------------------------------------------

    def walk_expr(self, e) -> None:
        if e is None:
            return
        if isinstance(e, ast.Lambda):
            return                         # body runs later, elsewhere
        if isinstance(e, (ast.Yield, ast.YieldFrom)):
            self.func.yield_helds.append(tuple(self.held))
            if getattr(e, "value", None) is not None:
                self.walk_expr(e.value)
            return
        if isinstance(e, ast.Call):
            self._record_call(e)
            for ch in ast.iter_child_nodes(e):
                if isinstance(ch, ast.expr) and ch is not e.func:
                    self.walk_expr(ch)
                elif isinstance(ch, ast.keyword):
                    self.walk_expr(ch.value)
            if isinstance(e.func, ast.Attribute):
                self.walk_expr(e.func.value)
            return
        for ch in ast.iter_child_nodes(e):
            if isinstance(ch, ast.expr):
                self.walk_expr(ch)
            elif isinstance(ch, ast.keyword):
                self.walk_expr(ch.value)
            elif isinstance(ch, ast.comprehension):
                self.walk_expr(ch.iter)
                for cond in ch.ifs:
                    self.walk_expr(cond)

    def _record_call(self, e: ast.Call) -> None:
        f = e.func
        rec = None
        if isinstance(f, ast.Attribute):
            name = f.attr
            r = f.value
            recv_kind, recv_type, recv_name = "attr", None, ""
            if isinstance(r, ast.Name):
                recv_name = r.id
                if r.id == "self":
                    recv_kind = "self"
                elif r.id in self.mod.classes:
                    recv_kind, recv_type = "class", r.id
                else:
                    recv_kind = "name"
                    recv_type = self.types.get(r.id)
            elif isinstance(r, ast.Attribute) \
                    and isinstance(r.value, ast.Name) \
                    and r.value.id == "self":
                recv_name = f"self.{r.attr}"
                crec = self.mod.classes.get(self.cls) if self.cls else None
                recv_type = crec.attr_types.get(r.attr) if crec else None
            elif isinstance(r, ast.Call):
                recv_kind = "call"
                cf = r.func
                cname = cf.id if isinstance(cf, ast.Name) else (
                    cf.attr if isinstance(cf, ast.Attribute) else "")
                recv_name = f"{cname}()"
                if cname == "current_tracer":
                    recv_type = "Tracer"
                elif cname[:1].isupper():
                    recv_type = cname
            rec = _Call(e.lineno, tuple(self.held), name, True,
                        recv_kind, recv_type, recv_name,
                        self._lock_node_of(r))
            if name == "acquire":
                node = self._lock_node_of(r)
                if node is not None:
                    self.func.acqs.append(
                        _Acq(node, e.lineno, tuple(self.held)))
                    self.held.append(node)  # held to end of function scope
            elif name == "release":
                node = self._lock_node_of(r)
                if node is not None and node in self.held:
                    # drop the innermost occurrence
                    for i in range(len(self.held) - 1, -1, -1):
                        if self.held[i] == node:
                            del self.held[i]
                            break
        elif isinstance(f, ast.Name):
            rec = _Call(e.lineno, tuple(self.held), f.id, False,
                        "none", None, "", None)
        if rec is not None:
            self.func.calls.append(rec)
            self.call_by_ast[id(e)] = rec


# ---------------------------------------------------------------------------
# Graph assembly: call resolution, fixpoints, edges, checks
# ---------------------------------------------------------------------------

class _GraphBuilder:
    def __init__(self, scans: list):
        self.scans = scans
        self.nodes: dict = {}
        self.classes: dict = {}            # simple class name -> _ClassRec
        self.class_mod: dict = {}
        self.funcs: dict = {}              # qual -> _Func
        self.methods_by_name: dict = {}
        self.functions_by_name: dict = {}
        self.node_kind: dict = {}
        for s in scans:
            self.nodes.update(s.nodes)
            for cname, rec in s.classes.items():
                self.classes.setdefault(cname, rec)
            for f in s.funcs:
                self.funcs[f.qual] = f
                if f.cls and not f.nested:
                    rec = s.classes[f.cls]
                    rec.methods.setdefault(f.name, f)
                    self.methods_by_name.setdefault(f.name, []).append(f)
                else:
                    # nested closures resolve like plain functions: a
                    # method-local ``def build()`` is called by bare name
                    self.functions_by_name.setdefault(
                        f.name, []).append(f)
        self.node_kind = {n: ln.kind for n, ln in self.nodes.items()}
        self._resolve_cache: dict = {}

    # -- call resolution -----------------------------------------------------

    def resolve(self, c: _Call, ctx_cls: Optional[str]) -> list:
        key = (id(c), ctx_cls)
        hit = self._resolve_cache.get(key)
        if hit is not None:
            return hit
        out = self._resolve_uncached(c, ctx_cls)
        self._resolve_cache[key] = out
        return out

    def _method_on(self, cls: Optional[str], name: str,
                   depth: int = 0) -> Optional[_Func]:
        if cls is None or depth > 4:
            return None
        rec = self.classes.get(cls)
        if rec is None:
            return None
        m = rec.methods.get(name)
        if m is not None:
            return m
        for b in rec.bases:
            m = self._method_on(b, name, depth + 1)
            if m is not None:
                return m
        return None

    def _resolve_uncached(self, c: _Call, ctx_cls: Optional[str]) -> list:
        if c.is_method:
            if c.recv_kind == "self":
                m = self._method_on(ctx_cls, c.name)
                if m is not None:
                    return [m]
            elif c.recv_type is not None:
                m = self._method_on(c.recv_type, c.name)
                if m is not None:
                    return [m]
                if c.recv_type not in self.classes:
                    # typed with a non-repo class (Queue, ndarray...):
                    # never fall through to name matching
                    return []
            if c.name in _NAME_DENYLIST:
                return []
            cands = (self.methods_by_name.get(c.name, [])
                     + self.functions_by_name.get(c.name, []))
            return cands if 0 < len(cands) <= _MAX_FANOUT else []
        # plain-name call: constructor or function
        rec = self.classes.get(c.name)
        if rec is not None:
            init = self._method_on(c.name, "__init__")
            return [init] if init is not None else []
        cands = self.functions_by_name.get(c.name, [])
        return cands if 0 < len(cands) <= _MAX_FANOUT else []

    # -- fixpoints -----------------------------------------------------------

    def may_acquire(self) -> dict:
        may = {q: {a.node for a in f.acqs} for q, f in self.funcs.items()}
        changed = True
        while changed:
            changed = False
            for q, f in self.funcs.items():
                s = may[q]
                before = len(s)
                for c in f.calls:
                    for g in self.resolve(c, f.cls):
                        s |= may[g.qual]
                if len(s) != before:
                    changed = True
        return may

    def may_block(self) -> dict:
        """qual -> {kind: (example line, call-chain tuple)} — transitively
        reachable blocking calls (Condition.wait stays direct-site only:
        waiting under its own condition is the normal pattern)."""
        mayb: dict = {}
        for q, f in self.funcs.items():
            d = {}
            for c in f.calls:
                kind = _blocking_kind(c)
                if kind and kind != "Condition.wait":
                    d.setdefault(kind, (c.line, (q,)))
            mayb[q] = d
        changed = True
        while changed:
            changed = False
            for q, f in self.funcs.items():
                d = mayb[q]
                for c in f.calls:
                    for g in self.resolve(c, f.cls):
                        for kind, (_, chain) in mayb[g.qual].items():
                            if kind not in d and len(chain) < 8:
                                d[kind] = (c.line, (q,) + chain)
                                changed = True
        return mayb

    # -- held-stack expansion ------------------------------------------------

    def expand_held(self, held: tuple, ctx_cls: Optional[str],
                    _depth: int = 0) -> list:
        out: list = []
        for h in held:
            if isinstance(h, str):
                if h not in out:
                    out.append(h)
            elif isinstance(h, _CMRef) and _depth < 4:
                for g in self.resolve(h.call, ctx_cls):
                    if not g.is_cm:
                        continue
                    for yh in g.yield_helds:
                        for n in self.expand_held(yh, g.cls, _depth + 1):
                            if n not in out:
                                out.append(n)
        return out


def _blocking_kind(c: _Call) -> Optional[str]:
    if c.name == "device_get":
        return "jax.device_get"
    if c.name == "label_pairs":
        return "oracle.label_pairs"
    if not c.is_method:
        return None
    if c.name == "result":
        return "Future.result"
    if c.name == "sleep" and c.recv_name == "time":
        return "time.sleep"
    if c.name in ("put", "get") and (
            c.recv_type in _QUEUE_TYPES
            or c.recv_name in ("q", "queue")
            or c.recv_name.endswith((".q", "._q", ".queue"))):
        return f"queue.{c.name}"
    if c.name == "wait":
        return "Condition.wait"
    if c.name == "join" and (
            c.recv_type == "Thread"
            or "thread" in c.recv_name.lower()
            or "worker" in c.recv_name.lower()):
        return "Thread.join"
    return None


def _waiver_for(lock: str, kind: str) -> Optional[str]:
    for pat_lock, pat_kind, reason in BLOCKING_WAIVERS:
        if fnmatch.fnmatch(lock, pat_lock) and fnmatch.fnmatch(kind,
                                                               pat_kind):
            return reason
    return None


def build_lock_graph(sources: Optional[list] = None) -> LockGraph:
    """Analyze ``(path, source)`` pairs (default: the src/repro tree)."""
    if sources is None:
        sources = iter_py_sources("src/repro")
    scans = [_ModuleScan(p, s) for p, s in sources]
    b = _GraphBuilder(scans)
    may = b.may_acquire()
    mayb = b.may_block()

    edges: dict = {}
    findings: list = []
    waived: list = []

    def edge(h: str, a: str, site: str) -> None:
        sites = edges.setdefault((h, a), [])
        if len(sites) < 4 and site not in sites:
            sites.append(site)

    for f in b.funcs.values():
        for acq in f.acqs:
            for h in b.expand_held(acq.held, f.cls):
                edge(h, acq.node, f"{f.file}:{acq.line}")
        for c in f.calls:
            held = b.expand_held(c.held, f.cls)
            if not held:
                continue
            callees = b.resolve(c, f.cls)
            for g in callees:
                for a in may[g.qual]:
                    for h in held:
                        edge(h, a, f"{f.file}:{c.line} via {g.qual}")
            # blocking under a held lock: direct + transitive
            kinds: dict = {}
            direct = _blocking_kind(c)
            if direct:
                kinds[direct] = (c.line, (f.qual,))
            for g in callees:
                for kind, (_, chain) in mayb[g.qual].items():
                    kinds.setdefault(kind, (c.line, (f.qual,) + chain))
            for kind, (line, chain) in kinds.items():
                blockers = held
                if kind == "Condition.wait" and c.recv_lock is not None:
                    blockers = [h for h in held if h != c.recv_lock]
                for h in blockers:
                    reason = _waiver_for(h, kind)
                    via = " -> ".join(chain)
                    if reason is not None:
                        waived.append(
                            f"{f.file}:{line}: {kind} under {h} "
                            f"(via {via}) — waived: {reason}")
                    else:
                        findings.append(Finding(
                            "lock-blocking", f.file, line,
                            f"blocking call {kind} reached while holding "
                            f"{h} (path: {via})"))

    # self-acquisition on a non-reentrant Lock is a guaranteed deadlock
    for (h, a), sites in sorted(edges.items()):
        if h == a and b.node_kind.get(h) == "Lock":
            findings.append(Finding(
                "lock-self-deadlock", b.nodes[h].file, b.nodes[h].line,
                f"non-reentrant Lock {h} may be re-acquired while held "
                f"(sites: {', '.join(sites)})"))

    for cyc in _cycles(edges, b.node_kind):
        ring = " -> ".join(cyc + [cyc[0]])
        first = b.nodes.get(cyc[0])
        findings.append(Finding(
            "lock-cycle", first.file if first else "?",
            first.line if first else 0,
            f"lock-order cycle (potential deadlock): {ring}"))

    return LockGraph(nodes=b.nodes, edges=edges, findings=findings,
                     waived=waived)


def _cycles(edges: dict, kinds: dict) -> list:
    """Cycles among *distinct* nodes (Tarjan SCCs of size > 1; reentrant
    self-loops are legal and handled separately)."""
    adj: dict = {}
    for (h, a) in edges:
        if h != a:
            adj.setdefault(h, set()).add(a)
            adj.setdefault(a, set())
    index: dict = {}
    low: dict = {}
    on: set = set()
    stack: list = []
    out: list = []
    counter = [0]

    def strong(v):
        index[v] = low[v] = counter[0]
        counter[0] += 1
        stack.append(v)
        on.add(v)
        for w in sorted(adj.get(v, ())):
            if w not in index:
                strong(w)
                low[v] = min(low[v], low[w])
            elif w in on:
                low[v] = min(low[v], index[w])
        if low[v] == index[v]:
            comp = []
            while True:
                w = stack.pop()
                on.discard(w)
                comp.append(w)
                if w == v:
                    break
            if len(comp) > 1:
                out.append(sorted(comp))

    for v in sorted(adj):
        if v not in index:
            strong(v)
    return out


# ---------------------------------------------------------------------------
# Rendering
# ---------------------------------------------------------------------------

def to_dot(g: LockGraph) -> str:
    """Graphviz DOT of the lock-order graph (CI artifact)."""
    lines = ["digraph lock_order {",
             '  rankdir=LR; node [shape=box, fontsize=10];']
    used = {n for e in g.edges for n in e}
    for name in sorted(g.nodes):
        ln = g.nodes[name]
        if name not in used:
            continue
        style = {"RLock": "rounded", "Condition": "diagonals"}.get(
            ln.kind, "solid")
        lines.append(
            f'  "{name}" [label="{name}\\n{ln.kind} {ln.file}:{ln.line}",'
            f' style="{style}"];')
    for (h, a), sites in sorted(g.edges.items()):
        attr = ' [style=dashed]' if h == a else ''
        lines.append(f'  "{h}" -> "{a}"'
                     f' [tooltip="{sites[0]}"]{attr};' if h != a else
                     f'  "{h}" -> "{a}"{attr};')
    lines.append("}")
    return "\n".join(lines) + "\n"


def render_text(g: LockGraph) -> str:
    out = [f"lock nodes: {len(g.nodes)}   order edges: {len(g.edges)}"]
    for (h, a), sites in sorted(g.edges.items()):
        loop = "   (reentrant self-loop)" if h == a else ""
        out.append(f"  {h} -> {a}{loop}")
        out.append(f"      e.g. {sites[0]}")
    if g.waived:
        out.append("waived blocking holds (explicit, see "
                   "lockgraph.BLOCKING_WAIVERS):")
        for w in g.waived:
            out.append(f"  {w}")
    if g.findings:
        out.append("FINDINGS:")
        for f in g.findings:
            out.append(f"  {f}")
    else:
        out.append("no lock-order or blocking violations")
    return "\n".join(out) + "\n"
