"""Declarative compiled-program contracts over HLO (DESIGN.md §9).

PR 4's pod-locality invariant — *cross-pod interconnect carries
candidate counts, never planes or masks* — was asserted by ad-hoc
budgets inside ``launch/multipod_dryrun.py``.  This module turns it into
a committed, reviewable artifact: ``benchmarks/baseline/hlo_manifest.json``
names, per compiled program, the **allowed collective op-set**, the
**allowed cross-pod op-set**, the **per-op cross-pod byte budget** (an
affine form in the pod count, since the count gather moves one int32 per
pod), the **plane ratio** (total cross-pod traffic must stay orders
below the staged planes), and the **host-pull ceiling** (bytes per
device per stream step beyond the 8 B/candidate pulls).

The multipod dry-run lowers the real chunk-step program and calls
``check_program`` against the manifest: an unreviewed collective — a new
kind, a pod-crossing kind that used to stay inside pods, an op over
budget — fails CI with a named diff pointing at the manifest entry to
update *in review*.  Regenerate intentionally with
``python -m repro.launch.multipod_dryrun --write-manifest`` and commit
the diff.

Byte parsing and replica-group pod analysis come from
``distributed.hlo_analysis`` (while-trip multipliers, iota + explicit
group forms); this module adds only the policy layer.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Optional

from repro.analysis.findings import Finding, repo_root
from repro.distributed.hlo_analysis import (_iter_collectives,
                                            collective_bytes,
                                            pod_crossing_stats)

MANIFEST_RELPATH = os.path.join("benchmarks", "baseline",
                                "hlo_manifest.json")


@dataclasses.dataclass(frozen=True)
class ProgramContract:
    """Budget envelope for one compiled program's collectives."""
    name: str
    collectives: tuple                  # allowed kinds, any locality
    cross_pod_collectives: tuple        # allowed pod-crossing kinds
    cross_pod_op_bytes_per_pod: int     # per-op budget = per_pod * n_pods
    cross_pod_op_bytes_base: int        #                + base
    plane_ratio: int                    # total cross < plane_bytes / ratio
    host_pull_bytes_per_device_step: int
    require_cross_pod: bool             # count gather must exist (pods > 1)

    def cross_op_budget(self, n_pods: int) -> int:
        return self.cross_pod_op_bytes_per_pod * n_pods \
            + self.cross_pod_op_bytes_base

    def host_pull_budget(self, n_candidates: int, n_devices: int,
                         n_steps: int) -> int:
        # 8 B per pulled (i, j) pair + the per-device per-step scalars
        # (count, base offset, conjunct evals) + slack for padding
        return (8 * n_candidates
                + self.host_pull_bytes_per_device_step * n_devices * n_steps
                + 1024)


def default_manifest_path() -> str:
    return os.path.join(repo_root(), MANIFEST_RELPATH)


def load_manifest(path: Optional[str] = None) -> dict:
    """``{program name: ProgramContract}`` from the committed manifest."""
    path = path or default_manifest_path()
    with open(path, encoding="utf-8") as f:
        raw = json.load(f)
    out = {}
    for name, e in raw["programs"].items():
        out[name] = ProgramContract(
            name=name,
            collectives=tuple(e["collectives"]),
            cross_pod_collectives=tuple(e["cross_pod_collectives"]),
            cross_pod_op_bytes_per_pod=int(e["cross_pod_op_bytes_per_pod"]),
            cross_pod_op_bytes_base=int(e["cross_pod_op_bytes_base"]),
            plane_ratio=int(e["plane_ratio"]),
            host_pull_bytes_per_device_step=int(
                e["host_pull_bytes_per_device_step"]),
            require_cross_pod=bool(e["require_cross_pod"]),
        )
    return out


def dump_manifest(contracts: dict, path: Optional[str] = None) -> str:
    path = path or default_manifest_path()
    raw = {"_comment": (
        "Compiled-HLO contract manifest (DESIGN.md §9). Checked by "
        "repro.analysis.hlo_contracts against freshly lowered HLO in the "
        "multipod dry-run; regenerate intentionally with "
        "`python -m repro.launch.multipod_dryrun --write-manifest` and "
        "review the diff — a new collective kind or budget is a "
        "cost-model change, not a formality."),
        "programs": {}}
    for name in sorted(contracts):
        c = contracts[name]
        raw["programs"][name] = {
            "collectives": sorted(c.collectives),
            "cross_pod_collectives": sorted(c.cross_pod_collectives),
            "cross_pod_op_bytes_per_pod": c.cross_pod_op_bytes_per_pod,
            "cross_pod_op_bytes_base": c.cross_pod_op_bytes_base,
            "plane_ratio": c.plane_ratio,
            "host_pull_bytes_per_device_step":
                c.host_pull_bytes_per_device_step,
            "require_cross_pod": c.require_cross_pod,
        }
    text = json.dumps(raw, indent=1, sort_keys=False) + "\n"
    with open(path, "w", encoding="utf-8") as f:
        f.write(text)
    return path


def _present_kinds(hlo_text: str) -> set:
    """Collective kinds with at least one op in the program (by_kind is
    zero-seeded with every kind, so it can't be used for presence)."""
    return {kind for kind, _, _ in _iter_collectives(hlo_text)}


def check_program(hlo_text: str, contract: ProgramContract, *,
                  n_pods: int, pod_size: int,
                  plane_bytes: int) -> tuple:
    """Gate one lowered program.  Returns ``(findings, report)``: empty
    findings = within contract; the report is the dry-run's ``hlo``
    block (observed kinds, bytes, budgets) either way."""
    where = f"hlo_manifest.json:{contract.name}"
    coll = collective_bytes(hlo_text)
    kinds = _present_kinds(hlo_text)
    cross = pod_crossing_stats(hlo_text, pod_size)
    budget = contract.cross_op_budget(n_pods)
    report = {
        "program": contract.name,
        "collective_bytes_total": coll.total_bytes,
        "collective_ops": coll.n_ops,
        "collective_kinds": sorted(kinds),
        "cross_pod_bytes": cross.cross_pod_bytes,
        "cross_pod_ops": cross.cross_pod_ops,
        "intra_pod_bytes": cross.intra_pod_bytes,
        "max_cross_op_bytes": cross.max_cross_op_bytes,
        "cross_kinds": cross.cross_kinds,
        "staged_plane_bytes": plane_bytes,
        "cross_op_budget_bytes": budget,
    }
    fs = []

    def bad(msg):
        fs.append(Finding("hlo-contract", where, 0, msg))

    for kind in sorted(kinds - set(contract.collectives)):
        bad(f"collective {kind!r} not in the reviewed op-set "
            f"{sorted(contract.collectives)} — a new collective is a "
            f"cost-model change; add it to the manifest in review")
    for kind in sorted(set(cross.cross_kinds)
                       - set(contract.cross_pod_collectives)):
        bad(f"{kind!r} crosses a pod boundary but only "
            f"{sorted(contract.cross_pod_collectives)} may — pod "
            f"interconnect carries counts, never planes or masks")
    if n_pods > 1:
        if contract.require_cross_pod and cross.cross_pod_ops < 1:
            bad("expected the cross-pod candidate-count gather, found no "
                "pod-crossing collective — the hierarchical prefix-sum "
                "was compiled away or replica groups changed shape")
        if cross.max_cross_op_bytes > budget:
            bad(f"a cross-pod collective moves {cross.max_cross_op_bytes} "
                f"B > count budget {budget} B "
                f"(= {contract.cross_pod_op_bytes_per_pod}*{n_pods} + "
                f"{contract.cross_pod_op_bytes_base}): planes/masks are "
                f"crossing pods")
        if plane_bytes > 0 and cross.cross_pod_bytes >= \
                plane_bytes / contract.plane_ratio:
            bad(f"total cross-pod traffic {cross.cross_pod_bytes} B is "
                f"not {contract.plane_ratio}x below the staged planes "
                f"({plane_bytes} B)")
    elif cross.cross_pod_ops != 0:
        bad(f"single-pod mesh has {cross.cross_pod_ops} pod-crossing "
            f"collective(s) — replica-group pod math regressed")
    return fs, report


def observed_contract(hlo_text: str, name: str, *, pod_size: int,
                      base: Optional[ProgramContract] = None
                      ) -> ProgramContract:
    """Contract matching the *observed* op-sets of ``hlo_text`` while
    keeping the committed budget policy (``--write-manifest``): op-sets
    are evidence, budgets are review decisions."""
    if base is None:
        base = DEFAULT_CONTRACTS[name]
    cross = pod_crossing_stats(hlo_text, pod_size)
    return dataclasses.replace(
        base, name=name,
        collectives=tuple(sorted(_present_kinds(hlo_text))),
        cross_pod_collectives=tuple(sorted(cross.cross_kinds)))


# Budget policy seeds for --write-manifest on a fresh tree.  The count
# gather's result is s32[n_pods] per device: 4*32 B per pod of slack
# covers fused/rewritten forms while staying orders below any plane.
DEFAULT_CONTRACTS = {
    "sharded_chunk_step": ProgramContract(
        name="sharded_chunk_step",
        collectives=("all-gather",),
        cross_pod_collectives=("all-gather",),
        cross_pod_op_bytes_per_pod=128,
        cross_pod_op_bytes_base=256,
        plane_ratio=100,
        host_pull_bytes_per_device_step=12,
        require_cross_pod=True,
    ),
}
