"""Shared finding type + source iteration for the analysis pass.

Every analyzer in ``repro.analysis`` (lock graph, AST lint rules, HLO
contract checks) reports through one ``Finding`` shape so the CLI can
render and gate them uniformly.  Analyzers take ``(path, source)`` pairs
rather than reading the tree themselves — that is what lets the
seeded-violation tests feed synthetic modules through the exact code CI
runs (tests/test_analysis.py).
"""

from __future__ import annotations

import dataclasses
import os


@dataclasses.dataclass(frozen=True)
class Finding:
    """One analyzer verdict, pointing at a source location."""
    rule: str                      # e.g. "lock-cycle", "tracer-guard"
    file: str                      # repo-relative path
    line: int
    msg: str

    def __str__(self) -> str:
        return f"{self.file}:{self.line}: [{self.rule}] {self.msg}"


def repo_root(start: str = __file__) -> str:
    """The repo root, resolved from this file (src/repro/analysis/..)."""
    return os.path.abspath(
        os.path.join(os.path.dirname(os.path.abspath(start)),
                     "..", "..", ".."))


def iter_py_sources(*dirs: str, root: str = "") -> list:
    """``(repo-relative path, source text)`` for every .py under ``dirs``.

    Paths are sorted for deterministic analyzer output; ``root`` defaults
    to the repo root so callers can pass "src/repro", "benchmarks", ...
    """
    root = root or repo_root()
    out = []
    for d in dirs:
        base = os.path.join(root, d)
        for dirpath, dirnames, files in os.walk(base):
            dirnames[:] = [x for x in dirnames if x != "__pycache__"]
            for fn in sorted(files):
                if not fn.endswith(".py"):
                    continue
                p = os.path.join(dirpath, fn)
                with open(p, encoding="utf-8") as f:
                    out.append((os.path.relpath(p, root), f.read()))
    out.sort()
    return out


def module_name(path: str) -> str:
    """Dotted module name for a repo-relative path — the prefix used in
    lock-node names (``serving.fleet.JoinFleet._cond``)."""
    p = path.replace(os.sep, "/")
    for prefix in ("src/repro/", "src/"):
        if p.startswith(prefix):
            p = p[len(prefix):]
            break
    if p.endswith(".py"):
        p = p[:-3]
    if p.endswith("/__init__"):
        p = p[:-len("/__init__")]
    return p.replace("/", ".")
