"""Runtime lock witness: lockdep for the threaded tier-1 tests.

The static graph (lockgraph.py) proves what *can* happen; this records
what *does*.  ``lock_witness()`` patches ``threading.Lock``/``RLock`` so
every lock constructed under the repo's source tree while the witness is
active becomes an instrumented wrapper that tracks, per thread, the
stack of held locks keyed by *creation site* (file:line of the
constructing frame).  From that it derives:

  * **observed order edges** — (held site -> acquired site), the runtime
    analogue of the static graph's edges;
  * **order violations** — a cycle among observed edges (A taken under B
    in one thread, B under A in another: a real deadlock candidate even
    if neither run deadlocked);
  * **self-deadlock** — same-thread re-acquisition of a non-reentrant
    ``Lock`` raises immediately instead of hanging the test;
  * **blocking-under-lock** — with ``guard_blocking=True``, a patched
    ``jax.device_get`` asserts no instrumented lock is held at pull
    time (the "no device pull inside a critical section" invariant).

``check_against(static_graph)`` maps creation sites onto static
``LockNode``s by (file, line) — node construction lines are recorded for
exactly this — and validates that observed ∪ static stays acyclic, so a
runtime order the AST pass could not see (e.g. through a callback) still
fails the test.

Locks created *outside* the include paths (stdlib ``queue.Queue``
internals, test scaffolding) get raw locks: the witness never changes
stdlib behavior behind its back.
"""

from __future__ import annotations

import dataclasses
import os
import threading
from typing import Optional

from repro.analysis.findings import repo_root
from repro.analysis.lockgraph import LockGraph, _cycles

_LOCAL = threading.local()


def _held_stack() -> list:
    st = getattr(_LOCAL, "held", None)
    if st is None:
        st = _LOCAL.held = []
    return st


@dataclasses.dataclass(frozen=True)
class Site:
    """Creation site of an instrumented lock: the witness's node id."""
    file: str                      # repo-relative
    line: int
    kind: str                      # Lock | RLock

    def __str__(self) -> str:
        return f"{self.file}:{self.line}({self.kind})"


class WitnessedLock:
    """threading.Lock wrapper: order recording + self-deadlock trap."""

    def __init__(self, rec: "LockWitness", site: Site):
        self._rec = rec
        self._site = site
        self._inner = rec._raw_lock()

    def acquire(self, blocking: bool = True, timeout: float = -1):
        held = _held_stack()
        if blocking:
            # a trylock cannot deadlock (Condition._is_owned probes plain
            # locks with acquire(False)) — only blocking acquisition gets
            # the trap and contributes order edges
            if any(w is self for w in held):
                raise RuntimeError(
                    f"self-deadlock: non-reentrant Lock {self._site} "
                    f"re-acquired by the thread already holding it")
            self._rec._record(self._site, held)
        got = self._inner.acquire(blocking, timeout)
        if got:
            held.append(self)
        return got

    def release(self):
        held = _held_stack()
        for i in range(len(held) - 1, -1, -1):
            if held[i] is self:
                del held[i]
                break
        self._inner.release()

    def locked(self):
        return self._inner.locked()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False


class WitnessedRLock:
    """threading.RLock wrapper.  Exposes the private hooks Condition
    needs (``_is_owned``/``_release_save``/``_acquire_restore``) so a
    ``threading.Condition`` built on an instrumented RLock works."""

    def __init__(self, rec: "LockWitness", site: Site):
        self._rec = rec
        self._site = site
        self._inner = rec._raw_rlock()

    def acquire(self, blocking: bool = True, timeout: float = -1):
        held = _held_stack()
        if blocking and not any(w is self for w in held):
            self._rec._record(self._site, held)  # reentry adds no edge
        got = self._inner.acquire(blocking, timeout)
        if got:
            held.append(self)
        return got

    def release(self):
        held = _held_stack()
        for i in range(len(held) - 1, -1, -1):
            if held[i] is self:
                del held[i]
                break
        self._inner.release()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False

    # Condition plumbing ----------------------------------------------------
    def _is_owned(self):
        return self._inner._is_owned()

    def _release_save(self):
        held = _held_stack()
        n = sum(1 for w in held if w is self)
        _LOCAL.held = [w for w in held if w is not self]
        return (self._inner._release_save(), n)

    def _acquire_restore(self, state):
        inner_state, n = state
        self._inner._acquire_restore(inner_state)
        _held_stack().extend([self] * n)


class LockWitness:
    """Install with ``lock_witness()``; inspect after the workload."""

    def __init__(self, include_paths: tuple, guard_blocking: bool):
        self.include_paths = tuple(os.path.abspath(p)
                                   for p in include_paths)
        self.guard_blocking = guard_blocking
        self.root = repo_root()
        self._raw_lock = None      # originals, captured on install
        self._raw_rlock = None
        self.edges: dict = {}      # (held Site, acquired Site) -> count
        self.sites: set = set()
        self.blocking_violations: list = []
        self._elock = None         # raw lock guarding the edge dict
        self._saved_device_get = None
        self._jax = None

    # -- recording ----------------------------------------------------------

    def _site_of_caller(self, kind: str) -> Optional[Site]:
        import sys
        f = sys._getframe(2)       # caller of the patched factory
        while f is not None:
            fn = os.path.abspath(f.f_code.co_filename)
            if any(fn.startswith(p + os.sep) or fn == p
                   for p in self.include_paths):
                rel = os.path.relpath(fn, self.root)
                return Site(rel.replace(os.sep, "/"), f.f_lineno, kind)
            f = f.f_back
        return None

    def _record(self, site: Site, held: list) -> None:
        with self._elock:
            self.sites.add(site)
            for w in held:
                k = (w._site, site)
                self.edges[k] = self.edges.get(k, 0) + 1

    def assert_no_held(self, what: str) -> None:
        held = _held_stack()
        if held:
            names = ", ".join(str(w._site) for w in held)
            msg = (f"blocking call {what} while holding "
                   f"instrumented lock(s): {names}")
            with self._elock:
                self.blocking_violations.append(msg)
            raise AssertionError(msg)

    # -- install / uninstall ------------------------------------------------

    def _install(self) -> None:
        self._raw_lock = threading.Lock
        self._raw_rlock = threading.RLock
        self._elock = self._raw_lock()
        wit = self

        def make_lock():
            site = wit._site_of_caller("Lock")
            return WitnessedLock(wit, site) if site else wit._raw_lock()

        def make_rlock():
            site = wit._site_of_caller("RLock")
            return WitnessedRLock(wit, site) if site else wit._raw_rlock()

        threading.Lock = make_lock
        threading.RLock = make_rlock
        if self.guard_blocking:
            try:
                import jax
            except ImportError:
                jax = None
            if jax is not None:
                self._jax = jax
                self._saved_device_get = jax.device_get

                def guarded_device_get(*a, **kw):
                    wit.assert_no_held("jax.device_get")
                    return wit._saved_device_get(*a, **kw)

                jax.device_get = guarded_device_get

    def _uninstall(self) -> None:
        threading.Lock = self._raw_lock
        threading.RLock = self._raw_rlock
        if self._jax is not None:
            self._jax.device_get = self._saved_device_get
            self._jax = None

    # -- verdicts -----------------------------------------------------------

    def order_cycles(self) -> list:
        e = {(str(h), str(a)): 1 for (h, a) in self.edges}
        return _cycles(e, {})

    def check_against(self, graph: LockGraph) -> list:
        """Merge observed edges into the static graph (mapping creation
        sites to static nodes by construction file:line) and return any
        cycles in the union.  Empty list = runtime agrees with the
        static model."""
        by_site = {(n.file, n.line): n.name for n in graph.nodes.values()}
        merged = {(h, a): 1 for (h, a) in graph.edges if h != a}
        for (h, a) in self.edges:
            hn = by_site.get((h.file, h.line), str(h))
            an = by_site.get((a.file, a.line), str(a))
            if hn != an:
                merged[(hn, an)] = 1
        return _cycles(merged, {})


class _WitnessCM:
    def __init__(self, include_paths, guard_blocking):
        self.w = LockWitness(include_paths, guard_blocking)

    def __enter__(self) -> LockWitness:
        self.w._install()
        return self.w

    def __exit__(self, *exc):
        self.w._uninstall()
        return False


def lock_witness(include_paths: Optional[tuple] = None,
                 guard_blocking: bool = False) -> _WitnessCM:
    """Context manager installing the witness.  Locks created while
    active by code under ``include_paths`` (default: ``src/repro``) are
    instrumented; everything else gets raw locks."""
    if include_paths is None:
        include_paths = (os.path.join(repo_root(), "src", "repro"),)
    return _WitnessCM(include_paths, guard_blocking)
