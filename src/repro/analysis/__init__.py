"""Repo-invariant static analysis (DESIGN.md §9).

``python -m repro.analysis --check`` runs, in one CI-gated pass:

  * ``lockgraph``      — static lock-order graph over the threaded stack
    (cycle = potential deadlock = failure) + blocking-under-lock checks,
    with an opt-in runtime witness (``witness.lock_witness``) that
    cross-validates real acquisition orders during threaded tests;
  * ``checkers``       — AST lint rules ruff cannot express: tracer
    guards, legacy-kwarg bans, metric-name declarations, monotonic-clock
    enforcement on span paths;
  * ``hlo_contracts``  — declarative collective budgets for compiled
    programs, checked by the multipod dry-run against the committed
    ``benchmarks/baseline/hlo_manifest.json``.
"""

from repro.analysis.findings import Finding
from repro.analysis.lockgraph import build_lock_graph
from repro.analysis.checkers import run_checkers
from repro.analysis.hlo_contracts import (ProgramContract, check_program,
                                          load_manifest)
from repro.analysis.witness import lock_witness

__all__ = [
    "Finding", "build_lock_graph", "run_checkers", "ProgramContract",
    "check_program", "load_manifest", "lock_witness",
]
