"""BARGAIN-style cascade baselines [65] and SUPG-style [28] (for LOTUS [46]).

BARGAIN applied to joins (paper §8.1): the join is a filter over L×R with
proxy score = embedding similarity.  With β=0 every kept pair is verified by
the LLM (precision 1); the threshold must keep >= T recall w.h.p.  That is a
1-D instance of FDJ's threshold problem, so we reuse ``adj_target`` with r=1
— giving BARGAIN the *same* statistical guarantee the paper grants it.

``supg_threshold`` is the CLT/limit-style selection (observed recall >= T on
the sample, no finite-sample adjustment) — the variant shown in Table 2 to
miss targets; included to reproduce that failure mode.

``bargain_precision_subset`` is the precision-target primitive used by the
Appx-C extension: largest score-prefix whose precision >= T_P w.h.p., via a
Hoeffding ladder over candidate thresholds.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional

import numpy as np

from repro.core.adj_target import adj_target


@dataclasses.dataclass
class CascadeResult:
    tau: float                 # keep pairs with distance <= tau (score-based)
    t_prime: float
    observed_recall: float


def recall_guarded_threshold(sample_dists: np.ndarray, sample_labels: np.ndarray,
                             target: float, delta: float, *, n_pairs: int,
                             n_trials: int = 20000) -> CascadeResult:
    """Smallest-keep-set distance threshold with observed recall >= T'.

    sample_dists: proxy distances (smaller = more likely match) for a uniform
    sample; labels from the oracle.
    """
    labels = sample_labels.astype(bool)
    k_plus = int(labels.sum())
    k = len(labels)
    res = adj_target(max(k_plus, 1), 1, target, delta, n_pairs=n_pairs,
                     k_sample=k, n_trials=n_trials)
    t_prime = res.t_prime
    pos = np.sort(sample_dists[labels])
    if k_plus == 0:
        return CascadeResult(float("inf"), t_prime, 1.0)
    need = int(math.ceil(t_prime * k_plus - 1e-9))
    tau = pos[min(need, k_plus) - 1]
    obs = float((sample_dists[labels] <= tau).sum()) / k_plus
    return CascadeResult(float(tau), t_prime, obs)


def supg_threshold(sample_dists: np.ndarray, sample_labels: np.ndarray,
                   target: float) -> float:
    """SUPG/LOTUS-style: observed recall >= T on the sample, no adjustment."""
    labels = sample_labels.astype(bool)
    k_plus = int(labels.sum())
    if k_plus == 0:
        return float("inf")
    pos = np.sort(sample_dists[labels])
    need = int(math.ceil(target * k_plus - 1e-9))
    return float(pos[min(need, k_plus) - 1])


def optimal_cascade_threshold(all_dists: np.ndarray, all_labels: np.ndarray,
                              target: float) -> float:
    """Oracle threshold: smallest keep-set with TRUE recall >= T (uses all
    ground truth; infeasible in practice — lower bound for cascades)."""
    labels = all_labels.astype(bool)
    pos = np.sort(all_dists[labels])
    if pos.size == 0:
        return float("inf")
    need = int(math.ceil(target * pos.size - 1e-9))
    return float(pos[need - 1])


def bargain_precision_subset(
    dists: np.ndarray,
    label_fn: Callable[[np.ndarray], np.ndarray],
    t_p: float,
    delta: float,
    *,
    sample_per_level: int = 40,
    n_levels: int = 12,
    rng: Optional[np.random.Generator] = None,
) -> np.ndarray:
    """Largest prefix (by ascending distance) with precision >= T_P w.h.p.

    label_fn(indices) -> bool labels (charges the oracle's ledger).
    Returns a boolean accept-mask over ``dists``.  Hoeffding ladder: level j
    tests the prefix up to quantile q_j with failure budget delta/n_levels.
    """
    rng = rng or np.random.default_rng(0)
    n = len(dists)
    if n == 0:
        return np.zeros(0, bool)
    order = np.argsort(dists, kind="stable")
    accept = np.zeros(n, bool)
    d_level = delta / n_levels
    eps = math.sqrt(math.log(1.0 / d_level) / (2.0 * sample_per_level))
    best = 0
    for j in range(1, n_levels + 1):
        m = int(n * j / n_levels)
        if m <= best:
            continue
        idx = order[:m]
        take = rng.choice(idx, size=min(sample_per_level, m), replace=False)
        labs = label_fn(take)
        p_hat = float(np.mean(labs))
        if p_hat - eps >= t_p:
            best = m
        else:
            break
    accept[order[:best]] = True
    return accept
