"""Logical scaffolds (§6.1–6.2) and threshold search (Eq 1 / Eq 4, Appx G).

A scaffold is a CNF over featurization indices: ``clauses = [[f, ...], ...]``
(outer conjunction, inner disjunction).  Per Appx D / Lemma D.1 thresholds
are tied within a clause, so a clause's effective distance is the *min* over
its featurizations' distances — the CNF then reduces to a pure conjunction
over per-clause distances with one threshold each.

``min_fpr_thresholds`` solves  min FPR  s.t. observed recall >= target:
exhaustive for 1 clause (Appx G pruning makes this O(k log k)); for more
clauses, ``method`` selects between two routes:

  * ``"greedy"`` — the Alg-8 greedy coordinate descent from +inf, with
    swap-repair local search (the numpy fallback, and the cheap route
    Alg-4 scaffold *cost estimation* stays on: it only needs relative
    ordering across candidate scaffolds, not the tightest theta);
  * ``"device"`` — the ``kernels/threshold_sweep`` path: a capped
    cartesian grid of per-clause positive-distance quantiles
    (``candidate_grid``) is swept in one ``pallas_call`` (all (pos, sel)
    counts at once), the argmin-FPR grid point subject to recall >= target
    seeds a greedy coordinate refinement, and the result is A/B'd against
    the plain greedy descent — the device route never returns a worse
    feasible FPR than the greedy baseline, by construction;
  * ``"auto"`` — ``"device"`` when the sweep kernel's stack imports,
    else ``"greedy"`` (the guarantee path — Eq-4 selection in plan_join
    and serving-time recalibration — passes this).

Candidate thresholds are exactly the positive pairs' distances — pushing a
threshold below the largest retained positive only drops negatives, so
optima sit on positive distances.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np


@dataclasses.dataclass
class Scaffold:
    clauses: list                      # list[list[int]] featurization indices

    @property
    def n_clauses(self) -> int:
        return len(self.clauses)

    def used_featurizations(self) -> list:
        return sorted({f for c in self.clauses for f in c})

    def clause_distances(self, dstack: np.ndarray) -> np.ndarray:
        """dstack: (k, F) per-featurization distances -> (k, C) clause-min."""
        if not self.clauses:
            return np.zeros((dstack.shape[0], 0), dstack.dtype)
        return np.stack([dstack[:, c].min(axis=1) for c in self.clauses], axis=1)


@dataclasses.dataclass
class ThresholdResult:
    theta: np.ndarray                  # (C,)
    fpr: float
    recall: float
    feasible: bool


def _eval(cd: np.ndarray, labels: np.ndarray, theta: np.ndarray):
    """FPR = admitted negatives / all negatives — proportional to refinement
    cost (the paper's cost proxy); recall over positives."""
    sel = np.all(cd <= theta[None, :], axis=1)
    n_pos = max(int(labels.sum()), 1)
    n_neg = max(int((~labels).sum()), 1)
    recall = float((sel & labels).sum()) / n_pos
    fpr = float((sel & ~labels).sum()) / n_neg
    return recall, fpr, sel


def min_fpr_thresholds(cd: np.ndarray, labels: np.ndarray, target: float,
                       method: str = "greedy") -> ThresholdResult:
    """cd: (k, C) clause distances; labels: (k,) bool. Solves Eq 1 / Eq 4.

    ``method``: "greedy" | "device" | "auto" (see module docstring).  The
    device sweep is strictly-no-worse: its result is the best feasible of
    (refined sweep winner, greedy baseline).
    """
    if method not in ("greedy", "device", "auto"):
        raise ValueError(f"unknown threshold method {method!r}")
    k, c = cd.shape
    labels = labels.astype(bool)
    n_pos = int(labels.sum())
    if c == 0:
        recall, fpr, _ = _eval(cd, labels, np.zeros(0))
        return ThresholdResult(np.zeros(0), fpr, 1.0, True)
    if n_pos == 0:
        return ThresholdResult(np.full(c, np.inf), 0.0, 1.0, False)

    pos = cd[labels]                                # (k+, C)
    need = int(math.ceil(target * n_pos - 1e-9))    # min retained positives

    if c == 1 and method != "device":
        return _sweep_1d(cd[:, 0], labels, need, n_pos)

    # --- greedy coordinate descent from +inf (Alg 8 style) -----------------
    if c == 1:
        best = _sweep_1d(cd[:, 0], labels, need, n_pos)
    else:
        theta = pos.max(axis=0).astype(np.float64)  # recall = 1
        best = _greedy(cd, labels, theta, need, n_pos)
        # swap-repair passes: raise one dim to its max, re-descend
        for j in range(c):
            t2 = best.theta.copy()
            t2[j] = pos[:, j].max()
            cand = _greedy(cd, labels, t2, need, n_pos)
            if cand.feasible and cand.fpr < best.fpr - 1e-12:
                best = cand
    if method == "greedy":
        return best
    dev = _device_sweep(cd, labels, pos, need, n_pos,
                        required=(method == "device"))
    if dev is None:                                 # auto: kernel unavailable
        return best
    if dev.feasible and (not best.feasible or dev.fpr < best.fpr - 1e-12):
        return dev
    return best


def _device_sweep(cd: np.ndarray, labels: np.ndarray, pos: np.ndarray,
                  need: int, n_pos: int, *, required: bool):
    """Grid sweep on device (kernels/threshold_sweep) + coordinate
    refinement around the argmin-FPR feasible grid point.

    Returns None when the sweep stack cannot import and the caller asked
    for "auto" (the numpy greedy remains the fallback); ``required=True``
    re-raises instead — "device" was requested explicitly.
    """
    try:
        from repro.kernels.threshold_sweep.ops import (candidate_grid,
                                                       sweep_counts)
    except Exception:
        if required:
            raise
        return None
    grid = candidate_grid(pos)
    pos_counts, sel_counts = sweep_counts(cd, labels, grid)
    k = cd.shape[0]
    n_neg = max(k - n_pos, 1)
    feas = pos_counts >= need - 0.5                 # counts are exact f32 ints
    if not feas.any():
        # grid always contains the per-dim positive max (recall-1 corner),
        # so this only happens when even recall 1 cannot reach ``need``
        v = pos.max(axis=0).astype(np.float64)
        recall, fpr, _ = _eval(cd, labels, v)
        return ThresholdResult(v, fpr, recall, False)
    fprs = np.where(feas, (sel_counts - pos_counts) / n_neg, np.inf)
    theta0 = grid[int(np.argmin(fprs))].astype(np.float64)
    # coordinate refinement: the winner seeds the same descent the greedy
    # route uses, landing on exact positive-distance optima the quantile
    # grid straddles
    if cd.shape[1] == 1:
        refined = _sweep_1d(cd[:, 0], labels, need, n_pos)
    else:
        refined = _greedy(cd, labels, theta0, need, n_pos)
    return refined


def _sweep_1d(d: np.ndarray, labels: np.ndarray, need: int, n_pos: int) -> ThresholdResult:
    pos_vals = np.sort(np.unique(d[labels]))
    n_neg = max(len(d) - n_pos, 1)
    order = np.argsort(d, kind="stable")
    ds = d[order]
    ls = labels[order]
    cum_pos = np.cumsum(ls)
    cum_all = np.arange(1, len(d) + 1)
    # for each candidate v: retained = count(d <= v)
    idx = np.searchsorted(ds, pos_vals, side="right") - 1
    npos_at = cum_pos[idx]
    nsel_at = cum_all[idx]
    feas = npos_at >= need
    if not feas.any():
        v = pos_vals[-1]
        recall, fpr, _ = _eval(d[:, None], labels, np.array([v]))
        return ThresholdResult(np.array([v]), fpr, recall, False)
    fprs = np.where(feas, (nsel_at - npos_at) / n_neg, np.inf)
    i = int(np.argmin(fprs))
    v = pos_vals[i]
    recall = npos_at[i] / n_pos
    return ThresholdResult(np.array([v]), float(fprs[i]), float(recall), True)


def _greedy(cd: np.ndarray, labels: np.ndarray, theta0: np.ndarray,
            need: int, n_pos: int) -> ThresholdResult:
    k, c = cd.shape
    theta = theta0.astype(np.float64).copy()
    pos = cd[labels]
    cands = [np.sort(np.unique(pos[:, j]))[::-1] for j in range(c)]  # desc
    recall, fpr, sel = _eval(cd, labels, theta)
    if int((sel & labels).sum()) < need:
        return ThresholdResult(theta, fpr, recall, False)
    improved = True
    while improved:
        improved = False
        best_move = None
        best_fpr = fpr
        # under the current other-dims selection, try lowering each dim
        for j in range(c):
            others = np.all(np.delete(cd, j, axis=1) <=
                            np.delete(theta, j)[None, :], axis=1) if c > 1 else \
                np.ones(k, bool)
            dj = cd[:, j]
            vals = cands[j]
            vals = vals[vals < theta[j]]
            if vals.size == 0:
                continue
            # vectorized: counts for each candidate
            alive = others
            d_alive = dj[alive]
            l_alive = labels[alive]
            o = np.argsort(d_alive, kind="stable")
            ds, ls = d_alive[o], l_alive[o]
            cpos = np.cumsum(ls)
            idx = np.searchsorted(ds, vals, side="right") - 1
            valid = idx >= 0
            npos_at = np.where(valid, cpos[np.maximum(idx, 0)], 0)
            nsel_at = np.where(valid, idx + 1, 0)
            feas = npos_at >= need
            n_neg = max(k - int(labels.sum()), 1)
            f = np.where(feas, (nsel_at - npos_at) / n_neg, np.inf)
            if f.size and f.min() < best_fpr - 1e-12:
                i = int(np.argmin(f))
                best_fpr = float(f[i])
                best_move = (j, float(vals[i]))
        if best_move is not None:
            j, v = best_move
            theta[j] = v
            recall, fpr, sel = _eval(cd, labels, theta)
            improved = True
    return ThresholdResult(theta, fpr, recall, True)


def ordered_conjuncts(cd: np.ndarray, theta: np.ndarray,
                      clauses: list) -> list:
    """Cheapest-and-most-selective-first conjunct order for short-circuit
    CNF evaluation (the classic selectivity ordering for AND chains).

    cd: (k, C) clause distances on the threshold sample (step ⑤'s S′ —
    already computed for threshold selection, so measurement is free);
    theta: (C,) selected thresholds; clauses: the scaffold's clause list
    (cost proxy = clause width, the number of distance planes it min-
    reduces).

    Rank = cost / (1 - pass_rate): the expected planes evaluated per
    rejection if this conjunct goes first.  Pass-everything conjuncts
    (pass_rate ~ 1) reject nothing and sort last.  Ties break by
    (pass_rate, cost, original index) so the order is deterministic.
    Returns a permutation of range(C) — a pure *evaluation* order: the
    conjunction commutes, so the candidate set is invariant under it
    (tests/test_conjunct_order.py proves it per backend).
    """
    c = cd.shape[1]
    if c != len(clauses) or theta.shape[0] != c:
        raise ValueError(
            f"clause-distance width {c} disagrees with {len(clauses)} "
            f"clauses / {theta.shape[0]} thresholds")
    if cd.shape[0] == 0:
        return list(range(c))
    rates = (cd <= theta[None, :]).mean(axis=0)
    def rank(ci):
        cost = max(len(clauses[ci]), 1)
        reject = 1.0 - float(rates[ci])
        key = cost / reject if reject > 1e-12 else math.inf
        return (key, float(rates[ci]), cost, ci)
    return sorted(range(c), key=rank)


# ---------------------------------------------------------------------------
# Alg 4 — greedy scaffold construction
# ---------------------------------------------------------------------------

def scaffold_cost(dstack: np.ndarray, labels: np.ndarray, sc: Scaffold,
                  target: float) -> float:
    """Ĉ_S(Π̊): optimistic min-FPR over thresholds (Eq 1)."""
    cd = sc.clause_distances(dstack)
    res = min_fpr_thresholds(cd, labels, target)
    return res.fpr if res.feasible else np.inf


def get_logical_scaffold(dstack: np.ndarray, labels: np.ndarray, target: float,
                         gamma: float = 0.05,
                         max_clauses: Optional[int] = None) -> Scaffold:
    """Alg 4: greedy conjunction growth, then disjunction growth.

    dstack: (k, F) distances for the labeled sample; labels: (k,) bool.
    max_clauses enforces Thm 6.1's r <= 1/(1-T).
    """
    k, f = dstack.shape
    if max_clauses is None:
        max_clauses = max(int(math.floor(1.0 / max(1.0 - target, 1e-9))), 1)
    sc = Scaffold(clauses=[])
    # cost of the empty scaffold: every negative admitted (FPR = 1)
    cur_cost = 1.0
    remaining = list(range(f))

    # conjunctions (Lines 3-12)
    while remaining and sc.n_clauses < max_clauses:
        costs = []
        for phi in remaining:
            cand = Scaffold(clauses=sc.clauses + [[phi]])
            costs.append(scaffold_cost(dstack, labels, cand, target))
        i = int(np.argmin(costs))
        if costs[i] < cur_cost - gamma:
            sc = Scaffold(clauses=sc.clauses + [[remaining[i]]])
            cur_cost = costs[i]
            remaining.pop(i)
        else:
            break

    # disjunctions (Lines 13-18): each (featurization, clause) pair once
    for phi in list(remaining):
        for ci in range(sc.n_clauses):
            cand_clauses = [list(c) for c in sc.clauses]
            cand_clauses[ci] = cand_clauses[ci] + [phi]
            cand = Scaffold(clauses=cand_clauses)
            cost = scaffold_cost(dstack, labels, cand, target)
            if cost < cur_cost - gamma:
                sc = cand
                cur_cost = cost
                break
    return sc
