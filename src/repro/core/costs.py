"""Monetary cost accounting (paper §8.1 methodology).

Every oracle/extraction/embedding invocation is *simulated* against ground
truth, but its cost is charged as if the real prompt had been sent: tokens
are counted from the prompt string that would have been constructed, priced
with the per-model $/Mtok constants below (GPT-4.1-class join/extraction LLM,
o3-class featurization-generation LLM, text-embedding-3-large-class E).
"""

from __future__ import annotations

import dataclasses


# $ per 1M tokens (input, output) — OpenAI list prices (2025)
PRICE_JOIN_LLM_IN = 2.00       # GPT-4.1 input
PRICE_JOIN_LLM_OUT = 8.00      # GPT-4.1 output
PRICE_GEN_LLM_IN = 2.00        # o3 input
PRICE_GEN_LLM_OUT = 8.00       # o3 output
PRICE_EMBED = 0.13             # text-embedding-3-large

CHARS_PER_TOKEN = 4.0          # standard approximation


def n_tokens(text: str) -> int:
    return max(1, int(len(text) / CHARS_PER_TOKEN))


@dataclasses.dataclass
class CostLedger:
    """Accumulates costs by category (paper Fig 9 breakdown)."""
    labeling: float = 0.0        # LLM labels for sampled pairs
    construction: float = 0.0    # featurization-generation LLM calls
    inference: float = 0.0       # feature extraction + embeddings
    refinement: float = 0.0      # LLM on predicted-positive pairs
    # wall-clock accounting for the step-②/⑨ pipeline (DESIGN.md §3a):
    # seconds, not dollars — reported via wall_summary(), kept out of the
    # monetary breakdown().  overlap_wall > 0 only when stream_refinement
    # actually ran refinement concurrently with candidate production.
    step2_wall: float = 0.0      # candidate production (engine stream)
    refine_wall: float = 0.0     # oracle refinement
    overlap_wall: float = 0.0    # portion of the two that ran concurrently
    # engine-internal pipeline split (sharded double buffering, DESIGN.md
    # §3): host time enqueueing device steps vs blocked pulling/filtering,
    # and the host work that ran with a successor step in flight.
    # step2_overlap_wall == 0 on the sharded engine means the band loop
    # degraded to serial — the regression the benchmark gate watches.
    step2_dispatch_wall: float = 0.0
    step2_pull_wall: float = 0.0
    step2_overlap_wall: float = 0.0
    # (pair, clause) evaluations step ② actually computed (the conjunct
    # short-circuit's honest FLOPs proxy — EngineStats.conjunct_evals,
    # including padding and overflow-retry re-work).  A count, not
    # dollars; reported via wall_summary(), kept out of breakdown().
    step2_conjunct_evals: int = 0
    # serving counters (DESIGN.md §4): plane-store traffic for this query.
    # Counts, not dollars — the whole point of the store is that a plane
    # hit costs $0; reported via serving_summary(), kept out of total.
    plane_hits: int = 0          # (spec, side) planes served device-resident
    plane_misses: int = 0        # planes that had to be extracted + uploaded
    plane_evicted_bytes: int = 0 # device bytes freed by LRU eviction
    plane_resident_bytes: int = 0  # device bytes pinned after the query
    bytes_h2d: int = 0           # host->device plane bytes actually moved
    bytes_reshard: int = 0       # device->device bytes laying planes out on
                                 # the sharded engine's mesh (warm: 0)
    # online guarantee calibration (DESIGN.md §4a): serving-time reservoir
    # recalibration of cached plans.  ``reservoir_cost`` dollars are ALSO
    # counted inside ``labeling`` (they are oracle labels) — this field
    # exists so the serving benchmark can report what keeping the guarantee
    # live costs, separately from plan-time sampling.
    recalibrations: int = 0      # reservoir-refresh + invariant checks run
    theta_swaps: int = 0         # recalibrations that hot-swapped theta
    theta_drift: float = 0.0     # summed L-inf theta movement across swaps
    reservoir_cost: float = 0.0  # labeling dollars spent refreshing reservoirs

    def charge_label(self, prompt_tokens: int, output_tokens: int = 1):
        self.labeling += (prompt_tokens * PRICE_JOIN_LLM_IN
                          + output_tokens * PRICE_JOIN_LLM_OUT) / 1e6

    def charge_refine(self, prompt_tokens: int, output_tokens: int = 1):
        self.refinement += (prompt_tokens * PRICE_JOIN_LLM_IN
                            + output_tokens * PRICE_JOIN_LLM_OUT) / 1e6

    def charge_generation(self, prompt_tokens: int, output_tokens: int):
        self.construction += (prompt_tokens * PRICE_GEN_LLM_IN
                              + output_tokens * PRICE_GEN_LLM_OUT) / 1e6

    def charge_extraction(self, prompt_tokens: int, output_tokens: int):
        self.inference += (prompt_tokens * PRICE_JOIN_LLM_IN
                           + output_tokens * PRICE_JOIN_LLM_OUT) / 1e6

    def charge_embedding(self, tokens: int):
        self.inference += tokens * PRICE_EMBED / 1e6

    def record_walls(self, step2: float, refine: float, overlap: float):
        self.step2_wall += step2
        self.refine_wall += refine
        self.overlap_wall += overlap

    def record_engine_walls(self, dispatch: float, pull: float,
                            overlap: float):
        """Accumulate the engine-internal dispatch/pull/overlap split
        (``EngineStats.dispatch_wall_s`` etc. of one evaluation)."""
        self.step2_dispatch_wall += dispatch
        self.step2_pull_wall += pull
        self.step2_overlap_wall += overlap

    def record_engine_stats(self, stats) -> None:
        """Convenience: record an ``EngineStats``'s pipeline walls (no-op
        for None, e.g. the degenerate-plan path)."""
        if stats is not None:
            self.record_engine_walls(stats.dispatch_wall_s,
                                     stats.pull_wall_s, stats.overlap_s)
            self.step2_conjunct_evals += int(stats.conjunct_evals)

    def record_plane_traffic(self, *, hits: int = 0, misses: int = 0,
                             evicted_bytes: int = 0, resident_bytes: int = 0,
                             bytes_h2d: int = 0, bytes_reshard: int = 0):
        """Accumulate plane-store counters (resident_bytes is a level, not a
        flow: callers pass the store's current value and it overwrites)."""
        self.plane_hits += int(hits)
        self.plane_misses += int(misses)
        self.plane_evicted_bytes += int(evicted_bytes)
        self.plane_resident_bytes = int(resident_bytes)
        self.bytes_h2d += int(bytes_h2d)
        self.bytes_reshard += int(bytes_reshard)

    def record_recalibration(self, *, swapped: bool, drift: float,
                             dollars: float) -> None:
        """One serving-time guarantee recalibration: an invariant check on
        the refreshed reservoir, plus (when the cached theta failed it) a
        device re-sweep that hot-swapped the plan's thresholds."""
        self.recalibrations += 1
        self.theta_swaps += int(swapped)
        self.theta_drift += float(drift)
        self.reservoir_cost += float(dollars)

    def absorb(self, other: "CostLedger") -> None:
        """Merge another ledger's charges in (serving: per-query ledgers
        accumulate into the service-lifetime ledger)."""
        self.labeling += other.labeling
        self.construction += other.construction
        self.inference += other.inference
        self.refinement += other.refinement
        self.record_walls(other.step2_wall, other.refine_wall,
                          other.overlap_wall)
        self.record_engine_walls(other.step2_dispatch_wall,
                                 other.step2_pull_wall,
                                 other.step2_overlap_wall)
        self.step2_conjunct_evals += other.step2_conjunct_evals
        self.record_plane_traffic(
            hits=other.plane_hits, misses=other.plane_misses,
            evicted_bytes=other.plane_evicted_bytes,
            resident_bytes=other.plane_resident_bytes,
            bytes_h2d=other.bytes_h2d, bytes_reshard=other.bytes_reshard)
        self.recalibrations += other.recalibrations
        self.theta_swaps += other.theta_swaps
        self.theta_drift += other.theta_drift
        self.reservoir_cost += other.reservoir_cost

    def serving_summary(self) -> dict:
        """Plane-store counters for the Fig-9 breakdown / serving benchmark."""
        return {
            "plane_hits": self.plane_hits,
            "plane_misses": self.plane_misses,
            "plane_evicted_bytes": self.plane_evicted_bytes,
            "plane_resident_bytes": self.plane_resident_bytes,
            "bytes_h2d": self.bytes_h2d,
            "bytes_reshard": self.bytes_reshard,
            "recalibrations": self.recalibrations,
            "theta_swaps": self.theta_swaps,
            "theta_drift": self.theta_drift,
            "reservoir_cost": self.reservoir_cost,
        }

    def wall_summary(self) -> dict:
        """Pipeline wall seconds; pipelined_wall is the effective critical
        path (step2 + refine - overlap) the streaming pump achieves."""
        return {
            "step2_wall": self.step2_wall,
            "refine_wall": self.refine_wall,
            "overlap_wall": self.overlap_wall,
            "pipelined_wall": self.step2_wall + self.refine_wall
            - self.overlap_wall,
            "step2_dispatch_wall": self.step2_dispatch_wall,
            "step2_pull_wall": self.step2_pull_wall,
            "step2_overlap_wall": self.step2_overlap_wall,
            "step2_conjunct_evals": self.step2_conjunct_evals,
        }

    @property
    def total(self) -> float:
        return self.labeling + self.construction + self.inference + self.refinement

    def breakdown(self) -> dict:
        return {
            "labeling": self.labeling,
            "construction": self.construction,
            "inference": self.inference,
            "refinement": self.refinement,
            "total": self.total,
        }


def naive_join_cost(texts_l, texts_r, join_prompt_overhead_tokens: int = 40) -> float:
    """Cost of the naive all-pairs LLM join (cost-ratio denominator)."""
    tl = [n_tokens(t) for t in texts_l]
    tr = [n_tokens(t) for t in texts_r]
    total_in = sum(tl) * len(tr) + sum(tr) * len(tl) \
        + join_prompt_overhead_tokens * len(tl) * len(tr)
    total_out = len(tl) * len(tr)
    return (total_in * PRICE_JOIN_LLM_IN + total_out * PRICE_JOIN_LLM_OUT) / 1e6
