"""Monetary cost accounting (paper §8.1 methodology).

Every oracle/extraction/embedding invocation is *simulated* against ground
truth, but its cost is charged as if the real prompt had been sent: tokens
are counted from the prompt string that would have been constructed, priced
with the per-model $/Mtok constants below (GPT-4.1-class join/extraction LLM,
o3-class featurization-generation LLM, text-embedding-3-large-class E).
"""

from __future__ import annotations

import dataclasses
from typing import Optional


# $ per 1M tokens (input, output) — OpenAI list prices (2025)
PRICE_JOIN_LLM_IN = 2.00       # GPT-4.1 input
PRICE_JOIN_LLM_OUT = 8.00      # GPT-4.1 output
PRICE_GEN_LLM_IN = 2.00        # o3 input
PRICE_GEN_LLM_OUT = 8.00       # o3 output
PRICE_EMBED = 0.13             # text-embedding-3-large

CHARS_PER_TOKEN = 4.0          # standard approximation


def n_tokens(text: str) -> int:
    return max(1, int(len(text) / CHARS_PER_TOKEN))


# CostLedger field -> canonical metric name (DESIGN.md §7).  Every *flow*
# field (charges, walls, counts) accumulates through ``_flow`` so a bound
# MetricsRegistry sees each delta as it happens; ``ledger_from_metrics``
# inverts the mapping, and tests pin the round trip — the ledger and the
# registry are two views of one record, never two records.
FIELD_METRICS = {
    "labeling": "cost.labeling_usd",
    "construction": "cost.construction_usd",
    "inference": "cost.inference_usd",
    "refinement": "cost.refinement_usd",
    "step2_wall": "wall.step2_s",
    "refine_wall": "wall.refine_s",
    "overlap_wall": "wall.overlap_s",
    "step2_dispatch_wall": "wall.step2_dispatch_s",
    "step2_pull_wall": "wall.step2_pull_s",
    "step2_overlap_wall": "wall.step2_overlap_s",
    "step2_conjunct_evals": "engine.conjunct_evals",
    "plane_hits": "planes.hits",
    "plane_misses": "planes.misses",
    "plane_dedup_hits": "planes.dedup_hits",
    "plane_evicted_bytes": "planes.evicted_bytes",
    "bytes_h2d": "planes.bytes_h2d",
    "bytes_reshard": "planes.bytes_reshard",
    "recalibrations": "calib.recalibrations",
    "theta_swaps": "calib.theta_swaps",
    "theta_drift": "calib.theta_drift",
    "reservoir_cost": "calib.reservoir_usd",
}
# plane_resident_bytes is a *level*, not a flow: it maps to a gauge
GAUGE_METRICS = {"plane_resident_bytes": "planes.resident_bytes"}


@dataclasses.dataclass
class CostLedger:
    """Accumulates costs by category (paper Fig 9 breakdown)."""
    labeling: float = 0.0        # LLM labels for sampled pairs
    construction: float = 0.0    # featurization-generation LLM calls
    inference: float = 0.0       # feature extraction + embeddings
    refinement: float = 0.0      # LLM on predicted-positive pairs
    # wall-clock accounting for the step-②/⑨ pipeline (DESIGN.md §3a):
    # seconds, not dollars — reported via wall_summary(), kept out of the
    # monetary breakdown().  overlap_wall > 0 only when stream_refinement
    # actually ran refinement concurrently with candidate production.
    step2_wall: float = 0.0      # candidate production (engine stream)
    refine_wall: float = 0.0     # oracle refinement
    overlap_wall: float = 0.0    # portion of the two that ran concurrently
    # engine-internal pipeline split (sharded double buffering, DESIGN.md
    # §3): host time enqueueing device steps vs blocked pulling/filtering,
    # and the host work that ran with a successor step in flight.
    # step2_overlap_wall == 0 on the sharded engine means the band loop
    # degraded to serial — the regression the benchmark gate watches.
    step2_dispatch_wall: float = 0.0
    step2_pull_wall: float = 0.0
    step2_overlap_wall: float = 0.0
    # (pair, clause) evaluations step ② actually computed (the conjunct
    # short-circuit's honest FLOPs proxy — EngineStats.conjunct_evals,
    # including padding and overflow-retry re-work).  A count, not
    # dollars; reported via wall_summary(), kept out of breakdown().
    step2_conjunct_evals: int = 0
    # serving counters (DESIGN.md §4): plane-store traffic for this query.
    # Counts, not dollars — the whole point of the store is that a plane
    # hit costs $0; reported via serving_summary(), kept out of total.
    plane_hits: int = 0          # (spec, side) planes served device-resident
    plane_misses: int = 0        # planes that had to be extracted + uploaded
    plane_dedup_hits: int = 0    # hits on planes another tenant materialized
                                 # (shared-store fleet: the $0 dedup proof)
    plane_evicted_bytes: int = 0 # device bytes freed by LRU eviction
    plane_resident_bytes: int = 0  # device bytes pinned after the query
    bytes_h2d: int = 0           # host->device plane bytes actually moved
    bytes_reshard: int = 0       # device->device bytes laying planes out on
                                 # the sharded engine's mesh (warm: 0)
    # online guarantee calibration (DESIGN.md §4a): serving-time reservoir
    # recalibration of cached plans.  ``reservoir_cost`` dollars are ALSO
    # counted inside ``labeling`` (they are oracle labels) — this field
    # exists so the serving benchmark can report what keeping the guarantee
    # live costs, separately from plan-time sampling.
    recalibrations: int = 0      # reservoir-refresh + invariant checks run
    theta_swaps: int = 0         # recalibrations that hot-swapped theta
    theta_drift: float = 0.0     # summed L-inf theta movement across swaps
    reservoir_cost: float = 0.0  # labeling dollars spent refreshing reservoirs
    # observability binding (DESIGN.md §7): when set, every flow mutation
    # also feeds the equivalent metric (FIELD_METRICS) as it happens, so
    # the registry is always reconcilable with the ledger.  Bookkeeping,
    # not a charge: excluded from equality/repr.
    metrics: Optional[object] = dataclasses.field(
        default=None, compare=False, repr=False)
    # True once record_plane_traffic actually ran on this ledger: the
    # resident-bytes *level* is only meaningful then, and ``absorb`` must
    # not let a ledger that never touched the plane store clobber it
    plane_level_set: bool = dataclasses.field(
        default=False, compare=False, repr=False)

    def _flow(self, field: str, v) -> None:
        """Accumulate a flow field, feeding the bound metric if any."""
        if not v:
            return
        setattr(self, field, getattr(self, field) + v)
        if self.metrics is not None:
            self.metrics.inc(FIELD_METRICS[field], v)

    def _set_resident(self, resident_bytes: int) -> None:
        self.plane_resident_bytes = int(resident_bytes)
        self.plane_level_set = True
        if self.metrics is not None:
            self.metrics.set_gauge(GAUGE_METRICS["plane_resident_bytes"],
                                   self.plane_resident_bytes)

    def bind_metrics(self, registry) -> None:
        """Attach a MetricsRegistry: future mutations stream into it, and
        the ledger's current state is published up front so a mid-life
        binding starts reconciled."""
        self.metrics = registry
        for field, metric in FIELD_METRICS.items():
            v = getattr(self, field)
            if v:
                registry.inc(metric, v)
        if self.plane_level_set:
            registry.set_gauge(GAUGE_METRICS["plane_resident_bytes"],
                               self.plane_resident_bytes)

    def charge_label(self, prompt_tokens: int, output_tokens: int = 1):
        self._flow("labeling", (prompt_tokens * PRICE_JOIN_LLM_IN
                                + output_tokens * PRICE_JOIN_LLM_OUT) / 1e6)

    def charge_refine(self, prompt_tokens: int, output_tokens: int = 1):
        self._flow("refinement", (prompt_tokens * PRICE_JOIN_LLM_IN
                                  + output_tokens * PRICE_JOIN_LLM_OUT) / 1e6)

    def charge_generation(self, prompt_tokens: int, output_tokens: int):
        self._flow("construction", (prompt_tokens * PRICE_GEN_LLM_IN
                                    + output_tokens * PRICE_GEN_LLM_OUT) / 1e6)

    def charge_extraction(self, prompt_tokens: int, output_tokens: int):
        self._flow("inference", (prompt_tokens * PRICE_JOIN_LLM_IN
                                 + output_tokens * PRICE_JOIN_LLM_OUT) / 1e6)

    def charge_embedding(self, tokens: int):
        self._flow("inference", tokens * PRICE_EMBED / 1e6)

    def record_walls(self, step2: float, refine: float, overlap: float):
        self._flow("step2_wall", step2)
        self._flow("refine_wall", refine)
        self._flow("overlap_wall", overlap)

    def record_engine_walls(self, dispatch: float, pull: float,
                            overlap: float):
        """Accumulate the engine-internal dispatch/pull/overlap split
        (``EngineStats.dispatch_wall_s`` etc. of one evaluation)."""
        self._flow("step2_dispatch_wall", dispatch)
        self._flow("step2_pull_wall", pull)
        self._flow("step2_overlap_wall", overlap)

    def record_engine_stats(self, stats) -> None:
        """Convenience: record an ``EngineStats``'s pipeline walls (no-op
        for None, e.g. the degenerate-plan path)."""
        if stats is not None:
            self.record_engine_walls(stats.dispatch_wall_s,
                                     stats.pull_wall_s, stats.overlap_s)
            self._flow("step2_conjunct_evals", int(stats.conjunct_evals))

    def record_plane_traffic(self, *, hits: int = 0, misses: int = 0,
                             evicted_bytes: int = 0, resident_bytes: int = 0,
                             bytes_h2d: int = 0, bytes_reshard: int = 0,
                             dedup_hits: int = 0):
        """Accumulate plane-store counters (resident_bytes is a level, not a
        flow: callers pass the store's current value and it overwrites)."""
        self._flow("plane_hits", int(hits))
        self._flow("plane_misses", int(misses))
        self._flow("plane_dedup_hits", int(dedup_hits))
        self._flow("plane_evicted_bytes", int(evicted_bytes))
        self._set_resident(resident_bytes)
        self._flow("bytes_h2d", int(bytes_h2d))
        self._flow("bytes_reshard", int(bytes_reshard))

    def record_recalibration(self, *, swapped: bool, drift: float,
                             dollars: float) -> None:
        """One serving-time guarantee recalibration: an invariant check on
        the refreshed reservoir, plus (when the cached theta failed it) a
        device re-sweep that hot-swapped the plan's thresholds."""
        self._flow("recalibrations", 1)
        self._flow("theta_swaps", int(swapped))
        self._flow("theta_drift", float(drift))
        self._flow("reservoir_cost", float(dollars))

    def absorb(self, other: "CostLedger") -> None:
        """Merge another ledger's charges in (serving: per-query ledgers
        accumulate into the service-lifetime ledger).  Flows add; the
        resident-bytes *level* only transfers when the absorbed ledger
        actually recorded plane traffic — a query that never touched the
        store (degenerate plan, storeless execute) must not zero the
        service-lifetime residency."""
        for field in FIELD_METRICS:
            self._flow(field, getattr(other, field))
        if other.plane_level_set:
            self._set_resident(other.plane_resident_bytes)

    def serving_summary(self) -> dict:
        """Plane-store counters for the Fig-9 breakdown / serving benchmark."""
        return {
            "plane_hits": self.plane_hits,
            "plane_misses": self.plane_misses,
            "plane_dedup_hits": self.plane_dedup_hits,
            "plane_evicted_bytes": self.plane_evicted_bytes,
            "plane_resident_bytes": self.plane_resident_bytes,
            "bytes_h2d": self.bytes_h2d,
            "bytes_reshard": self.bytes_reshard,
            "recalibrations": self.recalibrations,
            "theta_swaps": self.theta_swaps,
            "theta_drift": self.theta_drift,
            "reservoir_cost": self.reservoir_cost,
        }

    def wall_summary(self) -> dict:
        """Pipeline wall seconds; pipelined_wall is the effective critical
        path (step2 + refine - overlap) the streaming pump achieves."""
        return {
            "step2_wall": self.step2_wall,
            "refine_wall": self.refine_wall,
            "overlap_wall": self.overlap_wall,
            "pipelined_wall": self.step2_wall + self.refine_wall
            - self.overlap_wall,
            "step2_dispatch_wall": self.step2_dispatch_wall,
            "step2_pull_wall": self.step2_pull_wall,
            "step2_overlap_wall": self.step2_overlap_wall,
            "step2_conjunct_evals": self.step2_conjunct_evals,
        }

    @property
    def total(self) -> float:
        return self.labeling + self.construction + self.inference + self.refinement

    def breakdown(self) -> dict:
        return {
            "labeling": self.labeling,
            "construction": self.construction,
            "inference": self.inference,
            "refinement": self.refinement,
            "total": self.total,
        }


_INT_FIELDS = {f.name for f in dataclasses.fields(CostLedger)
               if f.type == "int"}


def ledger_from_metrics(registry) -> CostLedger:
    """Reconstruct a CostLedger from a bound MetricsRegistry — the
    derivability invariant of DESIGN.md §7: for any ledger with
    ``bind_metrics(fresh_registry)``, ``ledger_from_metrics(registry) ==
    ledger`` (tests/test_obs.py pins it).  A registry shared by several
    ledgers derives their absorbed sum."""
    out = CostLedger()
    for field, metric in FIELD_METRICS.items():
        v = registry.value(metric)
        setattr(out, field, int(v) if field in _INT_FIELDS else v)
    gauge = GAUGE_METRICS["plane_resident_bytes"]
    if registry.has(gauge):
        out.plane_resident_bytes = int(registry.value(gauge))
        out.plane_level_set = True
    return out


def naive_join_cost(texts_l, texts_r, join_prompt_overhead_tokens: int = 40) -> float:
    """Cost of the naive all-pairs LLM join (cost-ratio denominator)."""
    tl = [n_tokens(t) for t in texts_l]
    tr = [n_tokens(t) for t in texts_r]
    total_in = sum(tl) * len(tr) + sum(tr) * len(tl) \
        + join_prompt_overhead_tokens * len(tl) * len(tr)
    total_out = len(tl) * len(tr)
    return (total_in * PRICE_JOIN_LLM_IN + total_out * PRICE_JOIN_LLM_OUT) / 1e6
