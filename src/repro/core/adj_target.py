"""Target adjustment (§6.3–6.4, Appx B): T' = adj-target(k+, r, T, δ).

Theory recap.  For a conjunction of r predicates, Lemma 6.2 shows the
worst-case positive-pair distance dataset is axis-aligned with an even split:
``D*_r = {x e_i : x in [n+/r], i in [r]}`` (plus n+ mod r zero points for
non-divisible n+), valid for r ≤ n+/(n+(1−T)−1) ≈ 1/(1−T) — the same bound
Alg 4 enforces on clause count.  For r=1 this degenerates to the all-distinct
1-D dataset of the classical cascade-threshold analyses [28, 65], which our
implementation reproduces (see tests).  A threshold vector Θ with per-dim
admitted counts ``c_i`` has true recall (z + Σ c_i)/n+, so the *bad*
thresholds are exactly ``Σ c_i ≤ B_max = ceil(T n+) − 1 − z``.

For a uniform sample S of k+ points, the largest observed recall any bad
threshold can reach is ``(z_s + M*)/k+`` where ``M* = max Σ t_i`` subject to
``Σ s_i(t_i) ≤ B_max`` and ``s_i(t)`` = rank (value) of the t-th smallest
sampled point in dim i — optimal thresholds capture per-dim *prefixes* of
sampled points.  M* is a grouped prefix-knapsack solved exactly by DP, once
per Monte-Carlo trial; one DP yields the failure probability for *every*
candidate T' simultaneously.

Appx B corrections: Hoeffding MC-error inflation (δ1), Hoeffding bounds on
the unknown n+ (δ2, evaluated at both endpoints + midpoint and maxed), and
the threshold-selection budget δ3 = 8δ/10.  Results are cached on disk —
the computation is data-independent (paper: "computed offline").
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import os
from typing import Optional

import numpy as np


def cache_dir() -> str:
    """Resolve the on-disk curve cache directory at call time.

    ``REPRO_ADJ_CACHE`` wins; the default derives the repo root from this
    file's location (src/repro/core/ -> three parents up) so any checkout
    — dev container, CI workspace, a colleague's clone — caches inside its
    own tree instead of scribbling on a hardcoded absolute path.
    """
    env = os.environ.get("REPRO_ADJ_CACHE")
    if env:
        return env
    root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    return os.path.join(root, ".cache", "adj_target")


def _worst_case_maxcap(k_plus: int, r: int, n_plus: int, target: float,
                       n_trials: int, seed: int = 0) -> np.ndarray:
    """Distribution of max achievable sampled-capture (z_s + M*) over trials.

    Returns an int array (n_trials,) of the best observed-recall *count* any
    bad threshold attains on a random k+-subset of D*_{r, n+}.
    """
    if r == 0:
        return np.zeros(n_trials, np.int64)
    u = n_plus // r                                   # points per dim (Lemma 6.2)
    z = n_plus - u * r                                # zero points (divisibility)
    b_max = int(math.ceil(target * n_plus)) - 1 - z
    if b_max < 0:
        return np.zeros(n_trials, np.int64)           # no bad thresholds exist
    if k_plus >= n_plus:
        # sample = entire dataset: best bad threshold captures exactly B_max
        return np.full(n_trials, z + min(b_max, u * r), np.int64)
    rng = np.random.default_rng(seed)
    out = np.empty(n_trials, np.int64)
    chunk = max(1, min(512, n_trials, int(2e7 / max(n_plus, 1)) + 1))
    m_cap = k_plus                                     # dp budget dimension
    done = 0
    while done < n_trials:
        b = min(chunk, n_trials - done)
        # sample k+ ids w/o replacement from [0, n+) per trial
        ids = np.argpartition(rng.random((b, n_plus), dtype=np.float32),
                              k_plus, axis=1)[:, :k_plus]
        z_s = (ids < z).sum(axis=1)                    # sampled zero points
        # per-dim sampled ranks
        nz = ids - z
        dim = nz // u                                  # dim index (invalid for zero pts)
        rank = nz % u + 1                              # 1..u
        dp = np.full((b, m_cap + 1), np.inf, np.float64)
        dp[:, 0] = 0.0
        for i in range(r):
            mask = (nz >= 0) & (dim == i)
            # sorted ranks per trial for dim i, padded with inf
            rr = np.where(mask, rank, np.iinfo(np.int64).max).astype(np.float64)
            rr.sort(axis=1)
            t_max = int(mask.sum(axis=1).max()) if mask.any() else 0
            if t_max == 0:
                continue
            costs = rr[:, :t_max]                      # s_i(t), inf-padded
            costs[costs > 1e17] = np.inf
            new_dp = dp.copy()
            for t in range(1, t_max + 1):
                cand = dp[:, : m_cap + 1 - t] + costs[:, t - 1 : t]
                np.minimum(new_dp[:, t:], cand, out=new_dp[:, t:])
            dp = new_dp
        # M* = max m with dp[m] <= B_max
        feasible = dp <= b_max
        mstar = np.where(feasible.any(axis=1),
                         feasible.shape[1] - 1 - np.argmax(feasible[:, ::-1], axis=1),
                         0)
        out[done : done + b] = z_s + mstar
        done += b
    return out


def _cache_key(**kw) -> str:
    s = json.dumps(kw, sort_keys=True)
    return hashlib.sha1(s.encode()).hexdigest()[:16]


def failure_curve(k_plus: int, r: int, n_plus: int, target: float,
                  n_trials: int, seed: int = 0, cache: bool = True) -> np.ndarray:
    """P_{T'} for T' = (T + i/k+) — returns P(max count >= m) for m=0..k+."""
    key = _cache_key(k=k_plus, r=r, n=n_plus, t=round(target, 6), N=n_trials, s=seed)
    path = os.path.join(cache_dir(), key + ".npy")
    if cache and os.path.exists(path):
        return np.load(path)
    caps = _worst_case_maxcap(k_plus, r, n_plus, target, n_trials, seed)
    # tail[m] = P(caps >= m)
    counts = np.bincount(caps, minlength=k_plus + 2)[: k_plus + 2]
    tail = counts[::-1].cumsum()[::-1] / n_trials
    if cache:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        np.save(path, tail)
    return tail


@dataclasses.dataclass
class AdjTargetResult:
    t_prime: float
    delta1: float
    delta2: float
    delta3: float
    mc_error: float
    n_bounds: tuple


def adj_target(k_plus: int, r: int, target: float, delta: float, *,
               n_pairs: int, k_sample: int, n_plus_hat: Optional[int] = None,
               n_trials: int = 20000, seed: int = 0) -> AdjTargetResult:
    """Alg 7 (adj-target-est): smallest T' with bounded failure probability.

    k_plus: observed positives in the threshold sample (size k_sample) from
    n_pairs total pairs; n_plus_hat overrides the point estimate (testing).
    """
    if r == 0:
        return AdjTargetResult(target, 0, 0, delta, 0.0, (0, 0))
    # --- n+ bounds (Hoeffding, Appx B) ------------------------------------
    delta2 = delta / 10.0
    p_hat = k_plus / max(k_sample, 1)
    eps = math.sqrt(math.log(1.0 / delta2) / (2.0 * max(k_sample, 1)))
    n_lo = max(int(math.floor((p_hat - eps) * n_pairs)), k_plus)
    n_hi = min(int(math.ceil((p_hat + eps) * n_pairs)), n_pairs)
    if n_plus_hat is not None:
        n_lo = n_hi = n_plus_hat
    # --- delta split (Appx B) ----------------------------------------------
    width = max(2.0 * k_plus * n_pairs * eps, 1.0)
    delta1 = delta / (10.0 * width)
    delta3 = 8.0 * delta / 10.0
    mc_err = math.sqrt(math.log(1.0 / max(delta1, 1e-300)) / (2.0 * n_trials))

    evals = sorted({n_lo, (n_lo + n_hi) // 2, n_hi})
    tails = [failure_curve(k_plus, r, n, target, n_trials, seed) for n in evals]
    tail = np.max(np.stack(tails), axis=0) + mc_err

    # smallest T' = T + i/k+ with P <= delta3; failure: count >= ceil(k+ T')
    for i in range(1, k_plus + 1):
        t_prime = target + i / k_plus
        if t_prime > 1.0 + 1e-12:
            break
        m_req = int(math.ceil(k_plus * t_prime - 1e-9))
        if m_req > k_plus:
            break
        if tail[m_req] <= delta3:
            return AdjTargetResult(min(t_prime, 1.0), delta1, delta2, delta3,
                                   mc_err, (n_lo, n_hi))
    # infeasible: require perfect observed recall
    return AdjTargetResult(1.0, delta1, delta2, delta3, mc_err, (n_lo, n_hi))
