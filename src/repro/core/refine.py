"""RefinementPump — step ⑨ as a consumer of the step-② candidate stream.

``fdj_join`` historically barriered on the full candidate set before the
first refinement oracle call, so end-to-end latency was step-② wall time
*plus* refinement wall time.  The pump overlaps them: the caller thread
drives ``CnfEngine.evaluate_stream`` (JAX dispatch must stay on one
thread) and feeds each ``CandidateChunk`` into a *bounded* queue; a single
worker thread drains the queue and issues batched oracle calls.  The
bounded queue gives backpressure — the engine stalls rather than buffering
an unbounded candidate backlog when the oracle is the slow side.

Two refinement modes, matching core.join step ⑨:

  * ``refine_batch(pairs) -> accepted set`` — the precision-1 path: pairs
    are oracle-labeled in batches of ``batch_pairs`` as chunks land.  One
    worker thread means the caller's label cache and CostLedger need no
    locking (the producer thread never touches them during refinement).
  * ``final(sorted_pairs) -> accepted set`` — the Appx-C precision-subset
    path: the Hoeffding ladder needs distance quantiles over the *whole*
    candidate set, so chunks are only accumulated and ``final`` runs once
    at drain time.  Output is bit-identical to the barrier path by
    construction (the sorted union equals ``evaluate().candidates``).

Wall accounting (recorded into ``CostLedger`` when one is passed):
``step2_wall`` is time spent inside the engine stream, ``refine_wall``
time inside oracle refinement, ``overlap_wall`` the portion of the two
that ran concurrently — barrier mode is ``step2 + refine``; a perfectly
pipelined run approaches ``max(step2, refine)``.
"""

from __future__ import annotations

import dataclasses
import queue
import sys
import threading
import time
from typing import Callable, Iterable, Optional

from repro.engine.base import CandidateChunk, EngineStats
from repro.obs.trace import current_tracer

_DONE = object()                       # queue sentinel


@dataclasses.dataclass
class PumpStats:
    step2_wall: float = 0.0            # engine time producing chunks
    refine_wall: float = 0.0           # oracle time refining them
    overlap_wall: float = 0.0          # concurrency actually achieved
    total_wall: float = 0.0            # end-to-end pump wall
    chunks: int = 0
    batches: int = 0                   # oracle call batches issued
    # engine-internal pipeline split (EngineStats sums; DESIGN.md §3): the
    # double-buffered sharded backend keeps a band step in flight while
    # the pump refines the previous chunk, so engine_overlap_s > 0 here
    # means step ② compute hid under oracle refinement as well as under
    # the engine's own host pulls.
    engine_dispatch_s: float = 0.0
    engine_pull_s: float = 0.0
    engine_overlap_s: float = 0.0
    engine_conjunct_evals: int = 0     # (pair, clause) evals step ② did
    # chunks the worker's failure handler consumed-and-discarded after its
    # refine callback died: the producer may race a few puts in before it
    # notices the failure, and silence here would look like refined work
    chunks_dropped: int = 0


@dataclasses.dataclass
class PumpResult:
    pairs: set                         # accepted (i, j)
    candidates: list                   # sorted union of all chunk candidates
    engine_stats: Optional[EngineStats]
    stats: PumpStats


class RefinementPump:
    def __init__(self, refine_batch: Optional[Callable] = None, *,
                 final: Optional[Callable] = None,
                 batch_pairs: int = 512, max_queue_chunks: int = 4):
        if (refine_batch is None) == (final is None):
            raise ValueError("exactly one of refine_batch/final is required")
        self.refine_batch = refine_batch
        self.final = final
        self.batch_pairs = int(batch_pairs)
        self.max_queue_chunks = int(max_queue_chunks)
        if self.batch_pairs <= 0 or self.max_queue_chunks <= 0:
            raise ValueError("batch_pairs and max_queue_chunks must be >= 1")
        # the stats of the most recent run(), kept observable even when
        # run() raises (a dead worker's PumpResult never materializes but
        # its chunks_dropped count still matters to the caller)
        self.last_stats: Optional[PumpStats] = None

    def run(self, chunks: Iterable[CandidateChunk],
            ledger=None) -> PumpResult:
        """Drain ``chunks`` (engine work happens in this thread's ``next``
        calls), refining concurrently; returns accepted pairs + accounting."""
        stats = PumpStats()
        self.last_stats = stats
        accepted: set = set()
        candidates: list = []
        chunk_stats: list = []
        refine_s = [0.0]               # worker-written, read after join()
        failure: list = []

        q: queue.Queue = queue.Queue(maxsize=self.max_queue_chunks)
        # tracing across the thread boundary (DESIGN.md §7): contextvars do
        # not cross threading.Thread, so the worker gets the tracer and its
        # parent span (this thread's innermost open span — the query/join
        # root) captured *here*, by closure.  Worker batches render on
        # their own "refine-pump" track: they run concurrently with
        # band_step slices and must not share a lane with them.
        tracer = current_tracer()
        pump_parent = tracer.current_span() if tracer else None
        metrics = getattr(ledger, "metrics", None)

        def worker():
            pending: list = []
            done_seen = False

            def flush(batch):
                t0 = time.perf_counter()
                accepted.update(self.refine_batch(batch))
                t1 = time.perf_counter()
                refine_s[0] += t1 - t0
                stats.batches += 1
                if tracer:
                    tracer.record_span(
                        "refine_batch", t0, t1, parent=pump_parent,
                        track="refine-pump",
                        attrs={"pairs": len(batch), "batch": stats.batches})
                if metrics is not None:
                    metrics.inc("refine.batches")
                    metrics.inc("refine.pairs", len(batch))

            try:
                while True:
                    item = q.get()
                    if metrics is not None:
                        metrics.set_gauge("refine.queue_depth", q.qsize())
                    if item is _DONE:
                        done_seen = True
                        if pending:
                            flush(pending)
                        return
                    pending.extend(item)
                    # cursor, not repeated slicing: one giant chunk (the
                    # degenerate refine-everything path) stays O(pairs)
                    start = 0
                    while len(pending) - start >= self.batch_pairs:
                        flush(pending[start: start + self.batch_pairs])
                        start += self.batch_pairs
                    if start:
                        pending = pending[start:]
            except BaseException as e:   # surface in the caller, not stderr
                failure.append(e)
                # sink mode: keep consuming until the producer's _DONE so a
                # plain blocking q.put always completes — the wakeup the
                # producer relies on — and count what worker death throws
                # away instead of discarding it silently.  (If the tail
                # flush above raised, _DONE was already consumed: don't
                # block on a queue nobody will feed again.)
                while not done_seen:
                    if q.get() is _DONE:
                        return
                    stats.chunks_dropped += 1

        t_start = time.perf_counter()
        w = None
        if self.refine_batch is not None:
            w = threading.Thread(target=worker, name="refine-pump", daemon=True)
            w.start()

        it = iter(chunks)
        try:
            while not failure:               # dead worker: stop driving step ②
                t0 = time.perf_counter()
                chunk = next(it, _DONE)
                stats.step2_wall += time.perf_counter() - t0
                if chunk is _DONE:
                    break
                stats.chunks += 1
                candidates.extend(chunk.candidates)
                chunk_stats.append(chunk.stats)
                if w is not None and chunk.candidates:
                    # plain blocking put — bounded, so it backpressures
                    # step ② when the oracle is the slow side, and safe:
                    # a dead worker's failure handler keeps consuming
                    # until _DONE, so this can never hang (and never
                    # busy-waits producer wall into step2_wall)
                    q.put(chunk.candidates)
                    if metrics is not None:
                        metrics.set_gauge("refine.queue_depth", q.qsize())
        finally:
            # the engine stream may raise mid-sweep: still shut the worker
            # down (discarding queued-but-unrefined chunks) so no thread
            # outlives run() mutating the label cache / ledger
            if w is not None:
                if sys.exc_info()[0] is not None:
                    while True:
                        try:
                            q.get_nowait()
                        except queue.Empty:
                            break
                q.put(_DONE)
                w.join()

        if w is not None and failure:
            raise failure[0]
        candidates.sort()
        if self.final is not None:
            t0 = time.perf_counter()
            accepted = set(self.final(candidates))
            t1 = time.perf_counter()
            refine_s[0] += t1 - t0
            if tracer:
                tracer.record_span("refine_final", t0, t1,
                                   parent=pump_parent,
                                   attrs={"candidates": len(candidates)})

        stats.refine_wall = refine_s[0]
        stats.total_wall = time.perf_counter() - t_start
        stats.overlap_wall = max(
            0.0, min(stats.step2_wall, stats.refine_wall,
                     stats.step2_wall + stats.refine_wall - stats.total_wall))
        if ledger is not None:
            ledger.record_walls(stats.step2_wall, stats.refine_wall,
                                stats.overlap_wall)
        engine_stats = (EngineStats.merged(chunk_stats)
                        if any(s is not None for s in chunk_stats) else None)
        if engine_stats is not None:
            engine_stats.n_candidates = len(candidates)
            stats.engine_dispatch_s = engine_stats.dispatch_wall_s
            stats.engine_pull_s = engine_stats.pull_wall_s
            stats.engine_overlap_s = engine_stats.overlap_s
            stats.engine_conjunct_evals = engine_stats.conjunct_evals
            if ledger is not None:
                ledger.record_engine_stats(engine_stats)
        return PumpResult(pairs=accepted, candidates=candidates,
                          engine_stats=engine_stats, stats=stats)
