"""Candidate featurization generation (§5, Alg 1–3).

``get_candidate_featurizations`` drives the iterative loop:
propose → extract on the sample → evaluate cost-to-cover → feed worst-covered
positives (and the negatives that explain them) back to the proposer.

The proposer is the Alg-2 "LLM": an abstract interface.  Offline runs use the
schema-aware ``SimulatedProposer`` (repro/data/synth.py) that exhibits the
paper's observed behaviors — proposing redundant/erroneous featurizations
first and *fixing extraction errors* when shown failing examples.  The real
backend would render the Appx-I prompts (repro/core/prompts.py).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Protocol, Sequence

import numpy as np

from repro.core.costs import CostLedger
from repro.core.featurize import FeaturizationSpec


class FeaturizationProposer(Protocol):
    """The Alg-2 LLM pipeline behind get-featurization-from-examples."""

    def propose(self, example_pairs: Sequence[tuple], example_labels: np.ndarray,
                existing: Sequence[FeaturizationSpec], join_prompt: str,
                ledger: CostLedger) -> list:
        ...


class FeatureExtractor(Protocol):
    """Extraction + vectorization backend (dataset-owned; charges ledger)."""

    def pair_distances(self, specs: Sequence[FeaturizationSpec],
                       pairs: Sequence[tuple], ledger: CostLedger) -> np.ndarray:
        ...


def cost_to_cover(dists: np.ndarray, labels: np.ndarray) -> np.ndarray:
    """c_Φ(l,r) for every positive pair (Alg 3 lines 2-3).

    dists: (k, F) distances on the sample; labels: (k,) bool.
    Returns (k+,) minimum-over-featurizations count of negatives with
    distance <= the positive's distance.
    """
    k, f = dists.shape
    if f == 0:
        return np.full(int(labels.sum()), int((~labels).sum()), np.int64)
    pos = dists[labels]                                  # (k+, F)
    neg = dists[~labels]                                 # (k-, F)
    costs = np.empty((pos.shape[0], f), np.int64)
    for j in range(f):
        sn = np.sort(neg[:, j])
        costs[:, j] = np.searchsorted(sn, pos[:, j], side="right")
    return costs.min(axis=1)


@dataclasses.dataclass
class PickedExamples:
    pairs: list
    labels: np.ndarray


def evaluate_and_pick_examples(dists: np.ndarray, labels: np.ndarray,
                               sample_pairs: Sequence[tuple], alpha: int,
                               beta: int, rng: np.random.Generator
                               ) -> Optional[PickedExamples]:
    """Alg 3. Returns None when featurizations cover every positive well."""
    labels = labels.astype(bool)
    pos_idx = np.flatnonzero(labels)
    neg_idx = np.flatnonzero(~labels)
    c = cost_to_cover(dists, labels)
    if c.size == 0 or c.max() < alpha:
        return None
    half = max(beta // 2, 1)
    worst = pos_idx[np.argsort(c)[::-1][:half]]          # largest cost-to-cover
    # negatives "explaining" the cost: distance <= some selected positive's
    if dists.shape[1] > 0:
        pos_d = dists[worst]                             # (h, F)
        neg_d = dists[neg_idx]                           # (k-, F)
        expl = (neg_d[None, :, :] <= pos_d[:, None, :]).any(axis=(0, 2))
        cand_neg = neg_idx[expl]
    else:
        cand_neg = neg_idx
    if cand_neg.size > half:
        cand_neg = rng.choice(cand_neg, size=half, replace=False)
    chosen = np.concatenate([worst, cand_neg])
    return PickedExamples(pairs=[sample_pairs[i] for i in chosen],
                          labels=labels[chosen])


def get_candidate_featurizations(
    sample_pairs: Sequence[tuple],
    sample_labels: np.ndarray,
    proposer: FeaturizationProposer,
    extractor: FeatureExtractor,
    join_prompt: str,
    ledger: CostLedger,
    *,
    alpha: int = 3,
    beta: int = 20,
    max_iter: int = 8,
    seed: int = 0,
) -> list:
    """Alg 1. Returns the final list of FeaturizationSpec."""
    rng = np.random.default_rng(seed)
    labels = np.asarray(sample_labels, bool)
    k = len(sample_pairs)
    init = rng.choice(k, size=min(beta, k), replace=False)
    examples = PickedExamples([sample_pairs[i] for i in init], labels[init])
    specs: list = []
    for it in range(max_iter):
        new = proposer.propose(examples.pairs, examples.labels, specs,
                               join_prompt, ledger)
        # dedupe by key; a re-proposed name with bumped version replaces
        by_name = {s.name: s for s in specs}
        for s in new:
            if s.name not in by_name or s.version > by_name[s.name].version:
                by_name[s.name] = s
        specs = list(by_name.values())
        dists = extractor.pair_distances(specs, sample_pairs, ledger)
        examples = evaluate_and_pick_examples(dists, labels, sample_pairs,
                                              alpha, beta, rng)
        if examples is None:
            break
    return specs
