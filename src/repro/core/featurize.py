"""Featurizations at runtime.

A featurization φ = (d, X_L, X_R) is materialized as ``FeatureData``:
per-record extracted values vectorized into one of three representations so
the quadratic distance pass is pure array math (and kernel-friendly):

  * ``embed``    — unit vectors (n, D); d = clip(0.5 − 0.5·dot, 0, 1)
                   [semantic; also word_overlap via l2-normalized hashed
                   token k-hot vectors — one MXU-friendly dot-product path]
  * ``scalar``   — floats (n,); d = |x−y| / scale (clipped to 1)   [arithmetic/date]

All distances live in [0, 1]; missing extractions yield distance 1 (max),
matching Appx D's cross-featurization normalization so thresholds within a
clause can be tied (Lemma D.1 min-reduction).  Missing values are encoded
*inside* the arrays so the Pallas kernel needs no extra mask planes:
vector rows are augmented asymmetrically as [e, m, 1] (L) and [e, 1, m] (R)
with m = −2 for missing rows, making the pair dot ≤ −2 ⇒ clipped distance 1;
scalar missing is +1e9 on L and −1e9 on R.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core.llm import HashedNgramEmbedder

MISSING_DIST = 1.0
TOKENSET_DIM = 128


@dataclasses.dataclass(frozen=True)
class FeaturizationSpec:
    """What the generation LLM proposes (Alg 2 output)."""
    name: str
    description: str
    distance_kind: str          # semantic | word_overlap | arithmetic | date
    extractor_kind: str         # llm | code
    field: str                  # dataset field targeted by the extractor
    version: int = 0            # bumped when the LLM "fixes" an extractor

    @property
    def key(self) -> str:
        return f"{self.name}@v{self.version}"


@dataclasses.dataclass
class FeatureData:
    spec: FeaturizationSpec
    kind: str                   # embed | scalar
    data_l: np.ndarray          # embed: (n, D+2) augmented; scalar: (n,)
    data_r: np.ndarray
    scale: float = 1.0

    def distance_block(self, idx_l: np.ndarray, idx_r: np.ndarray) -> np.ndarray:
        """Dense block of pairwise distances (|idx_l|, |idx_r|) in [0,1]."""
        a = self.data_l[idx_l]
        b = self.data_r[idx_r]
        if self.kind == "embed":
            return np.clip(0.5 - 0.5 * (a @ b.T), 0.0, 1.0)
        if self.kind == "scalar":
            return np.clip(np.abs(a[:, None] - b[None, :]), 0.0, 1.0)
        raise ValueError(self.kind)

    def pair_distances(self, pairs: Sequence[tuple]) -> np.ndarray:
        """Distances for an explicit pair list (no n^2 materialization)."""
        il = np.asarray([p[0] for p in pairs])
        ir = np.asarray([p[1] for p in pairs])
        a = self.data_l[il]
        b = self.data_r[ir]
        if self.kind == "embed":
            return np.clip(0.5 - 0.5 * np.sum(a * b, axis=-1), 0.0, 1.0)
        if self.kind == "scalar":
            return np.clip(np.abs(a - b), 0.0, 1.0)
        raise ValueError(self.kind)


# ---------------------------------------------------------------------------
# vectorizers: raw extracted values -> FeatureData arrays
# ---------------------------------------------------------------------------

def _augment(vecs: np.ndarray, missing: np.ndarray, side: str) -> np.ndarray:
    """Append [m, 1] (L) / [1, m] (R) marker dims; m=-2 on missing rows."""
    n = vecs.shape[0]
    m = np.where(missing, -2.0, 0.0).astype(np.float32)
    one = np.ones(n, np.float32)
    cols = (m, one) if side == "l" else (one, m)
    return np.concatenate([vecs, cols[0][:, None], cols[1][:, None]], axis=1)


def vectorize(spec: FeaturizationSpec, values_l: list, values_r: list,
              embedder: Optional[HashedNgramEmbedder] = None) -> FeatureData:
    """values: list of str|float|None per record (None = failed extraction)."""
    if spec.distance_kind in ("semantic", "word_overlap"):
        if spec.distance_kind == "semantic":
            emb = embedder or HashedNgramEmbedder(dim=128)
            vl, ml = _embed_values(values_l, emb)
            vr, mr = _embed_values(values_r, emb)
        else:
            vl, ml = _tokenset(values_l)
            vr, mr = _tokenset(values_r)
        return FeatureData(spec, "embed",
                           _augment(vl, ml, "l"), _augment(vr, mr, "r"))
    if spec.distance_kind in ("arithmetic", "date"):
        a = np.asarray([np.nan if v is None else float(v) for v in values_l], np.float64)
        b = np.asarray([np.nan if v is None else float(v) for v in values_r], np.float64)
        finite = np.concatenate([a[np.isfinite(a)], b[np.isfinite(b)]])
        if spec.distance_kind == "date":
            scale = 30.0                       # one month normalizes to 1.0
        else:
            scale = float(np.percentile(finite, 95) - np.percentile(finite, 5)) \
                if finite.size else 1.0
            scale = max(scale, 1e-9)
        a = np.where(np.isnan(a), 1e9, a / scale).astype(np.float32)
        b = np.where(np.isnan(b), -1e9, b / scale).astype(np.float32)
        return FeatureData(spec, "scalar", a, b, scale=scale)
    raise ValueError(spec.distance_kind)


def _embed_values(values: list, emb: HashedNgramEmbedder):
    texts = ["" if v is None else str(v) for v in values]
    out = emb.embed(texts)
    missing = np.asarray([v is None or str(v) == "" for v in values], bool)
    out[missing] = 0.0
    return out, missing


def _tokenset(values: list):
    from repro.core.llm import _stable_hash
    out = np.zeros((len(values), TOKENSET_DIM), np.float32)
    for i, v in enumerate(values):
        if v is None:
            continue
        for w in str(v).lower().replace(",", " ").replace(";", " ").split():
            out[i, _stable_hash(w, seed=7) % TOKENSET_DIM] = 1.0
    norms = np.linalg.norm(out, axis=1)
    missing = norms < 0.5
    out[~missing] /= norms[~missing][:, None]
    return out, missing


def distance_stack(feats: Sequence[FeatureData], pairs: Sequence[tuple]) -> np.ndarray:
    """(len(pairs), len(feats)) distance matrix for explicit pairs."""
    return np.stack([f.pair_distances(pairs) for f in feats], axis=1) \
        if feats else np.zeros((len(pairs), 0), np.float32)
