"""LLM / embedding-model interfaces and their simulated backends.

The paper's evaluation (§8.1) simulates every ``L_p`` call by returning the
known ground truth while charging the cost of the prompt that would have been
sent.  ``SimulatedOracle`` reproduces that exactly.  ``ServingOracle`` is the
real backend: it batches join prompts through the JAX serving engine with any
``--arch`` backbone (see repro/serving) — used by the end-to-end examples.

The embedding model E is simulated with a hashed character-n-gram encoder —
deterministic, cheap, and (by construction) exhibits the paper's failure
mode: similarity degrades as text accumulates join-irrelevant content.
"""

from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import Callable, Optional, Sequence

import numpy as np

from repro.core.costs import CostLedger, n_tokens


class Oracle:
    """Evaluates the join predicate L_p on pairs of texts."""

    def label_pairs(self, pairs: Sequence[tuple], kind: str = "labeling") -> np.ndarray:
        raise NotImplementedError


@dataclasses.dataclass
class SimulatedOracle(Oracle):
    """Ground-truth-backed oracle with token-accurate cost accounting.

    ``truth(i, j) -> bool`` resolves against dataset ground truth;
    texts_l/texts_r used only to build (and price) the prompt.

    ``latency_s`` models the API round-trip of the L_p backend: each
    ``label_pairs`` batch sleeps ``latency_s`` per pair, once, after
    labeling.  The sleep releases the GIL — exactly the wait a real
    deployment overlaps across concurrent queries, which is what makes
    the fleet's concurrent-vs-serial wall comparison honest instead of
    a pure-Python GIL fight.  Answers and dollar charges are unaffected.
    """
    texts_l: Sequence[str]
    texts_r: Sequence[str]
    truth: Callable[[int, int], bool]
    join_prompt: str = "Do {l} and {r} satisfy the join condition? Answer yes or no."
    ledger: CostLedger = dataclasses.field(default_factory=CostLedger)
    calls: int = 0
    latency_s: float = 0.0

    def label_pairs(self, pairs, kind: str = "labeling") -> np.ndarray:
        out = np.zeros(len(pairs), dtype=bool)
        for n, (i, j) in enumerate(pairs):
            prompt = self.join_prompt.format(l=self.texts_l[i], r=self.texts_r[j])
            tok = n_tokens(prompt)
            if kind == "labeling":
                self.ledger.charge_label(tok)
            else:
                self.ledger.charge_refine(tok)
            out[n] = bool(self.truth(i, j))
            self.calls += 1
        if self.latency_s and pairs:
            time.sleep(self.latency_s * len(pairs))
        return out


# ---------------------------------------------------------------------------
# Embedding model
# ---------------------------------------------------------------------------

def _stable_hash(s: str, seed: int = 0) -> int:
    return int.from_bytes(hashlib.blake2b(
        s.encode(), digest_size=8, key=seed.to_bytes(8, "little")).digest(), "little")


@dataclasses.dataclass
class HashedNgramEmbedder:
    """Deterministic hashed char-n-gram embedding (simulated E).

    Embeds the *whole string* into ``dim`` buckets of 3..5-grams, l2
    normalized. Cosine similarity behaves like a real text embedding for
    short homogeneous strings and dilutes as irrelevant text is added.
    """
    dim: int = 256
    ngram: tuple = (3, 4, 5)
    ledger: Optional[CostLedger] = None

    def embed(self, texts: Sequence[str]) -> np.ndarray:
        out = np.zeros((len(texts), self.dim), np.float32)
        for i, t in enumerate(texts):
            s = t.lower()
            if self.ledger is not None:
                self.ledger.charge_embedding(n_tokens(t))
            for n in self.ngram:
                for k in range(max(len(s) - n + 1, 1)):
                    h = _stable_hash(s[k : k + n], seed=n)
                    out[i, h % self.dim] += 1.0 if (h >> 32) % 2 else -1.0
            norm = np.linalg.norm(out[i])
            if norm > 0:
                out[i] /= norm
        return out


def semantic_distance_matrix(e_l: np.ndarray, e_r: np.ndarray) -> np.ndarray:
    """(1 - cosine)/2 in [0,1] for unit-normalized embeddings."""
    return (1.0 - e_l @ e_r.T) * 0.5
