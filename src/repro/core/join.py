"""FDJ — the final algorithm (Alg 6) plus the precision extension (Appx C).

``fdj_join`` wires the full pipeline:
  1. uniform sample S, oracle labels          (cost: labeling)
  2. candidate featurizations (Alg 1-3)       (cost: construction+inference)
  3. logical scaffold (Alg 4) on S
  4. second sample S', labels                 (cost: labeling)
  5. T' = adj-target(k+, r, T, δ·)            (offline MC, cached)
  6. Θ* = argmin FPR s.t. recall_{S'} >= T'   (Eq 4)
  7. full-corpus extraction for used featurizations (cost: inference)
  8. blocked CNF evaluation over L×R -> Ŷ     (repro.engine backend)
  9. refinement: oracle on Ŷ                  (cost: refinement) — precision 1
     (or Appx-C featurization-precision subsets when T_P < 1)

With ``stream_refinement=True`` steps ⑧ and ⑨ are pipelined: the engine's
``evaluate_stream`` emits per-chunk candidates that a ``RefinementPump``
(core.refine) refines concurrently, so end-to-end wall approaches
max(step ②, refinement) instead of their sum.  Output pairs and ledger
totals are identical to barrier mode (tests/test_refine_pump.py).

Evaluation (recall/precision vs ground truth) and the Fig-9 cost breakdown
come back in ``JoinResult``.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Optional, Sequence

import numpy as np

from repro.core import generation, scaffold as scaffold_lib
from repro.core.adj_target import adj_target
from repro.core.bargain import bargain_precision_subset
from repro.core.costs import CostLedger
from repro.core.featurize import FeaturizationSpec
from repro.core.refine import RefinementPump
from repro.core.scaffold import Scaffold, min_fpr_thresholds


@dataclasses.dataclass
class FDJConfig:
    recall_target: float = 0.9
    precision_target: float = 1.0
    delta: float = 0.1
    gen_positives: int = 50        # positives for featurization gen + scaffold
    thresh_positives: int = 200    # positives for threshold selection
    alpha: int = 3                 # cost-to-cover convergence bound (Alg 3)
    beta: int = 20                 # demonstration examples per LLM call
    gamma: float = 0.05            # min cost improvement to extend scaffold
    max_iter: int = 8              # Alg 1 iterations
    mc_trials: int = 20000
    block: int = 4096              # L/R block edge for step-2 evaluation
    engine: str = "numpy"          # numpy | pallas | sharded (repro.engine)
    stream_refinement: bool = False  # pipeline step ⑨ over step ②'s stream
    refine_batch_pairs: int = 512  # oracle batch size inside the pump
    pump_queue_chunks: int = 4     # bounded chunk queue (engine backpressure)
    seed: int = 0


@dataclasses.dataclass
class JoinResult:
    pairs: set                     # final output pairs (i, j)
    recall: float
    precision: float
    cost: CostLedger
    scaffold: Scaffold
    specs: list
    theta: np.ndarray
    t_prime: float
    candidate_count: int
    met_target: bool
    engine_stats: Optional[object] = None   # repro.engine.EngineStats of step ②


def _sample_pairs(n_l: int, n_r: int, k: int, rng) -> list:
    idx = rng.choice(n_l * n_r, size=min(k, n_l * n_r), replace=False)
    return [(int(i // n_r), int(i % n_r)) for i in idx]


def fdj_join(dataset, oracle, proposer, extractor, cfg: FDJConfig) -> JoinResult:
    """dataset: repro.data.synth.JoinDataset; oracle: core.llm.Oracle;
    proposer/extractor: generation protocol impls (dataset-owned)."""
    rng = np.random.default_rng(cfg.seed)
    ledger = oracle.ledger
    n_l, n_r = dataset.n_l, dataset.n_r
    n_pairs = n_l * n_r
    rate = max(dataset.n_positive, 1) / n_pairs
    label_cache: dict = {}

    def label(pairs, kind):
        new = [p for p in pairs if p not in label_cache]
        if new:
            labs = oracle.label_pairs(new, kind=kind)
            for p, l in zip(new, labs):
                label_cache[p] = bool(l)
        return np.asarray([label_cache[p] for p in pairs], bool)

    # --- 1. generation sample ------------------------------------------------
    k_gen = min(int(math.ceil(cfg.gen_positives / rate * 1.25)), n_pairs)
    s1 = _sample_pairs(n_l, n_r, k_gen, rng)
    y1 = label(s1, "labeling")

    # --- 2. candidate featurizations ----------------------------------------
    specs = generation.get_candidate_featurizations(
        s1, y1, proposer, extractor, dataset.join_prompt, ledger,
        alpha=cfg.alpha, beta=cfg.beta, max_iter=cfg.max_iter, seed=cfg.seed)

    # --- 3. scaffold ----------------------------------------------------------
    d1 = extractor.pair_distances(specs, s1, ledger)
    max_clauses = max(int(math.floor(1.0 / max(1.0 - cfg.recall_target, 1e-9))), 1)
    sc = scaffold_lib.get_logical_scaffold(d1, y1, cfg.recall_target,
                                           gamma=cfg.gamma, max_clauses=max_clauses)
    if sc.n_clauses == 0:
        # no featurization helps: degenerate to refine-everything (still valid)
        sc = Scaffold(clauses=[])

    # --- 4. threshold sample --------------------------------------------------
    k_thr = min(int(math.ceil(cfg.thresh_positives / rate * 1.25)), n_pairs)
    s2 = _sample_pairs(n_l, n_r, k_thr, rng)
    y2 = label(s2, "labeling")
    k_plus = int(y2.sum())

    # --- 5-6. adjusted target + thresholds ------------------------------------
    used = sc.used_featurizations()
    used_specs = [specs[i] for i in used]
    remap = {f: i for i, f in enumerate(used)}
    sc_local = Scaffold(clauses=[[remap[f] for f in c] for c in sc.clauses])
    delta_recall = cfg.delta if cfg.precision_target >= 1.0 else cfg.delta / 2.0
    if sc_local.n_clauses and k_plus > 0:
        adj = adj_target(k_plus, sc_local.n_clauses, cfg.recall_target,
                         delta_recall, n_pairs=n_pairs, k_sample=len(s2),
                         n_trials=cfg.mc_trials, seed=cfg.seed)
        t_prime = adj.t_prime
        d2 = extractor.pair_distances(used_specs, s2, ledger)
        cd2 = sc_local.clause_distances(d2)
        thr = min_fpr_thresholds(cd2, y2, t_prime)
        theta = thr.theta
        feasible = thr.feasible
    else:
        t_prime = 1.0
        theta = np.zeros(0)
        feasible = False

    # --- 8-9. candidate production + refinement --------------------------------
    # degenerate scaffold: decomposition admits everything (always-sound)
    degenerate = not feasible or not sc_local.n_clauses
    engine_stats = None
    if cfg.stream_refinement:
        if degenerate:
            chunk_iter = iter([_degenerate_chunk(n_l, n_r)])
        else:
            chunk_iter = _stream_cnf(extractor, used_specs, sc_local, theta,
                                     ledger, cfg)
        if cfg.precision_target >= 1.0:
            def refine_chunk(batch):
                labs = label(batch, "refinement")
                return {p for p, l in zip(batch, labs) if l}
            pump = RefinementPump(refine_chunk,
                                  batch_pairs=cfg.refine_batch_pairs,
                                  max_queue_chunks=cfg.pump_queue_chunks)
        else:
            # Appx C needs quantiles over the whole candidate set: the pump
            # accumulates the stream and runs the ladder once at drain time
            pump = RefinementPump(
                final=lambda cands: _precision_extension(
                    cands, used_specs, extractor, label, ledger, cfg, rng),
                max_queue_chunks=cfg.pump_queue_chunks)
        pr = pump.run(chunk_iter, ledger=ledger)
        out_pairs = pr.pairs
        cand_arr = pr.candidates
        engine_stats = pr.engine_stats
    else:
        if degenerate:
            candidates = [(i, j) for i in range(n_l) for j in range(n_r)]
        else:
            candidates, engine_stats = _evaluate_cnf(extractor, used_specs,
                                                     sc_local, theta, ledger,
                                                     cfg)
        out_pairs = set()
        cand_arr = list(candidates)
        t0 = time.perf_counter()
        if cfg.precision_target >= 1.0:
            labs = label(cand_arr, "refinement")
            out_pairs = {p for p, l in zip(cand_arr, labs) if l}
        else:
            out_pairs = _precision_extension(cand_arr, used_specs, extractor,
                                             label, ledger, cfg, rng)
        ledger.record_walls(engine_stats.wall_s if engine_stats else 0.0,
                            time.perf_counter() - t0, 0.0)

    truth = dataset.truth_set
    tp = len(out_pairs & truth)
    recall = tp / max(len(truth), 1)
    precision = tp / max(len(out_pairs), 1) if out_pairs else 1.0
    return JoinResult(
        pairs=out_pairs, recall=recall, precision=precision, cost=ledger,
        scaffold=sc, specs=specs, theta=theta, t_prime=t_prime,
        candidate_count=len(cand_arr),
        met_target=(recall >= cfg.recall_target - 1e-12
                    and precision >= cfg.precision_target - 1e-12),
        engine_stats=engine_stats,
    )


def _evaluate_cnf(extractor, used_specs, sc: Scaffold, theta: np.ndarray,
                  ledger: CostLedger, cfg: FDJConfig):
    """Step 2: CNF evaluation over the full cross product via repro.engine.

    Returns (candidates, EngineStats).  Engine selection/backends live in
    ``repro.engine`` (DESIGN.md section 2); this function only materializes
    the used featurizations (charging the ledger) and dispatches.
    """
    from repro.engine import get_engine

    feats = extractor.materialize(used_specs, ledger)    # full-corpus FeatureData
    opts = {"block": cfg.block} if cfg.engine == "numpy" else {}
    res = get_engine(cfg.engine, **opts).evaluate(feats, sc.clauses, theta)
    return res.candidates, res.stats


def _stream_cnf(extractor, used_specs, sc: Scaffold, theta: np.ndarray,
                ledger: CostLedger, cfg: FDJConfig):
    """Streaming step ②: same materialization/charges as ``_evaluate_cnf``
    but hands back the engine's chunk iterator for the RefinementPump."""
    from repro.engine import get_engine

    feats = extractor.materialize(used_specs, ledger)
    opts = {"block": cfg.block} if cfg.engine == "numpy" else {}
    return get_engine(cfg.engine, **opts).evaluate_stream(
        feats, sc.clauses, theta)


def _degenerate_chunk(n_l: int, n_r: int):
    """Refine-everything fallback as a single stream emission (stats-free,
    mirroring the barrier fallback's engine_stats=None)."""
    from repro.engine.base import CandidateChunk
    pairs = [(i, j) for i in range(n_l) for j in range(n_r)]
    return CandidateChunk(pairs, None, 0)


def _precision_extension(cand_pairs, used_specs, extractor, label, ledger,
                         cfg: FDJConfig, rng) -> set:
    """Appx C: per-featurization precision subsets skip refinement."""
    if not cand_pairs:
        return set()
    remaining = np.arange(len(cand_pairs))
    accepted: set = set()
    r = max(len(used_specs), 1)
    delta1 = cfg.delta / (2.0 * r)
    for spec in used_specs:
        if remaining.size == 0:
            break
        pairs_sub = [cand_pairs[i] for i in remaining]
        d = extractor.pair_distances([spec], pairs_sub, ledger)[:, 0]

        def label_fn(idx):
            return label([pairs_sub[i] for i in idx], "refinement")

        mask = bargain_precision_subset(d, label_fn, cfg.precision_target,
                                        delta1, rng=rng)
        accepted |= {pairs_sub[i] for i in np.flatnonzero(mask)}
        remaining = remaining[~mask]
    # leftover pairs: oracle refinement (precision 1 on them)
    left = [cand_pairs[i] for i in remaining]
    labs = label(left, "refinement")
    accepted |= {p for p, l in zip(left, labs) if l}
    return accepted
