"""FDJ — the final algorithm (Alg 6) plus the precision extension (Appx C).

``fdj_join`` wires the full pipeline:
  1. uniform sample S, oracle labels          (cost: labeling)
  2. candidate featurizations (Alg 1-3)       (cost: construction+inference)
  3. logical scaffold (Alg 4) on S
  4. second sample S', labels                 (cost: labeling)
  5. T' = adj-target(k+, r, T, δ·)            (offline MC, cached)
  6. Θ* = argmin FPR s.t. recall_{S'} >= T'   (Eq 4)
  7. full-corpus extraction for used featurizations (cost: inference)
  8. blocked CNF evaluation over L×R -> Ŷ     (repro.engine backend)
  9. refinement: oracle on Ŷ                  (cost: refinement) — precision 1
     (or Appx-C featurization-precision subsets when T_P < 1)

The pipeline is split at its natural serving seam (DESIGN.md §4):
``plan_join`` runs steps ①–⑥ (corpus-size-free, O(sample)) and returns a
``JoinPlan``; ``execute_join`` runs steps ⑦–⑨ against any corpus shape.
Step ⑦ goes through a pluggable *plane provider* — by default the
extractor's ``materialize`` (cold path), in serving the
``FeaturePlaneStore`` (device-resident planes, zero re-extraction).
``fdj_join`` composes the two; outputs are identical to the historical
monolith for the precision-1 path, while the Appx-C path (T_P < 1) now
draws its subset samples from a fresh ``seed + 1`` stream — a deliberate
change so a replayed plan (serving) executes byte-identically to a cold
run, at the cost of different (equally valid) samples than pre-split
runs at the same seed.

With ``stream_refinement=True`` steps ⑧ and ⑨ are pipelined: the engine's
``evaluate_stream`` emits per-chunk candidates that a ``RefinementPump``
(core.refine) refines concurrently, so end-to-end wall approaches
max(step ②, refinement) instead of their sum.  Output pairs and ledger
totals are identical to barrier mode (tests/test_refine_pump.py).

Evaluation (recall/precision vs ground truth) and the Fig-9 cost breakdown
come back in ``JoinResult``.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Optional, Sequence

import numpy as np

from repro.core import generation, scaffold as scaffold_lib
from repro.core.adj_target import adj_target
from repro.core.bargain import bargain_precision_subset
from repro.core.costs import CostLedger
from repro.core.refine import RefinementPump
from repro.core.scaffold import Scaffold, min_fpr_thresholds
from repro.obs.trace import current_tracer


@dataclasses.dataclass
class FDJConfig:
    recall_target: float = 0.9
    precision_target: float = 1.0
    delta: float = 0.1
    gen_positives: int = 50        # positives for featurization gen + scaffold
    thresh_positives: int = 200    # positives for threshold selection
    alpha: int = 3                 # cost-to-cover convergence bound (Alg 3)
    beta: int = 20                 # demonstration examples per LLM call
    gamma: float = 0.05            # min cost improvement to extend scaffold
    max_iter: int = 8              # Alg 1 iterations
    mc_trials: int = 20000
    block: int = 4096              # L/R block edge for step-2 evaluation
    engine: str = "numpy"          # numpy | pallas | sharded (repro.engine)
    pods: int = 1                  # sharded engine: pod-axis width — builds a
    #   3-D (pod, data, model) join mesh (distributed.mesh.make_join_mesh)
    #   when > 1 and no explicit mesh is in engine_opts; execution-only,
    #   never part of a serving plan key (same candidate set on any mesh)
    engine_opts: dict = dataclasses.field(default_factory=dict)
    #   extra get_engine kwargs (tile sizes etc.) — either flat kwargs for
    #   cfg.engine, or keyed per engine name ({"pallas": {...}, ...}) so a
    #   per-query engine override picks its own opts; execution-only,
    #   never part of a serving plan key
    stream_refinement: bool = False  # pipeline step ⑨ over step ②'s stream
    refine_batch_pairs: int = 512  # oracle batch size inside the pump
    pump_queue_chunks: int = 4     # bounded chunk queue (engine backpressure)
    prefetch_depth: Optional[int] = None  # sharded engine: band steps in
    #   flight at once (None = engine default, 2; 1 = serial A/B control);
    #   execution-only, never part of a serving plan key
    order_conjuncts: bool = True   # evaluate conjuncts in the plan's
    #   measured cheapest-and-most-selective-first order (plan_join rates
    #   them on the threshold sample for free; candidate set is invariant
    #   — the conjunction commutes); False = the scaffold's natural order,
    #   the A/B control.  Execution-only, never part of a serving plan key
    recalibrate: bool = True       # serving: keep cached plans' theta
    #   calibrated online — after appends shift plane distributions, the
    #   JoinService refreshes a labeled reservoir, re-runs adj_target +
    #   the device threshold sweep, and hot-swaps theta when the cached
    #   value no longer meets the refreshed target (DESIGN.md §4a);
    #   execution-only, never part of a serving plan key
    reservoir_cap: int = 4096      # max labeled reservoir pairs per plan
    seed: int = 0

    def with_overrides(self, **overrides) -> "FDJConfig":
        """A copy with ``overrides`` applied — the one sanctioned way to
        derive a per-query config from a base config (``QueryOptions``
        resolves through here).  Unknown field names raise immediately
        instead of silently vanishing into ``dataclasses.replace``'s
        error text at some downstream call site."""
        unknown = set(overrides) - {f.name for f in dataclasses.fields(self)}
        if unknown:
            raise TypeError(
                f"unknown FDJConfig field(s) {sorted(unknown)}; valid "
                f"fields: {sorted(f.name for f in dataclasses.fields(self))}")
        return dataclasses.replace(self, **overrides)


# QueryOptions field -> FDJConfig field it overrides (``stream`` is the
# historical serving spelling of ``stream_refinement``)
_OPT_CFG_FIELDS = {
    "engine": "engine",
    "stream": "stream_refinement",
    "recall_target": "recall_target",
    "precision_target": "precision_target",
    "delta": "delta",
}


@dataclasses.dataclass(frozen=True)
class QueryOptions:
    """One typed request against a serving surface (DESIGN.md §8).

    This is the single options path shared by ``JoinService.query``,
    ``JoinService.append_right`` and ``JoinFleet.submit`` — it replaces
    the historical five special-cased kwargs + open-ended
    ``**cfg_overrides`` sprawl (kept alive as deprecation shims that
    route through here, parity-tested byte-identical).

    The five named fields are the common per-request knobs; anything else
    an ``FDJConfig`` carries goes through ``overrides`` (validated by
    ``FDJConfig.with_overrides``, so typos raise at submit time, not at
    some engine call site).  ``refresh_plan`` / ``incremental`` are
    serving execution directives, not config: they never enter the plan
    key."""
    engine: Optional[str] = None          # numpy | pallas | sharded
    stream: Optional[bool] = None         # pipeline refinement over step ②
    recall_target: Optional[float] = None
    precision_target: Optional[float] = None
    delta: Optional[float] = None
    refresh_plan: bool = False            # drop the cached plan, re-plan
    incremental: bool = True              # allow the delta-join fast path
    overrides: dict = dataclasses.field(default_factory=dict)
    #   any further FDJConfig fields (mc_trials, engine_opts, seed, ...)

    @classmethod
    def from_legacy(cls, *, refresh_plan: bool = False,
                    incremental: bool = True, **kw) -> "QueryOptions":
        """Adapter for the pre-fleet kwarg surface: the five named kwargs
        map onto typed fields, everything else lands in ``overrides``."""
        named = {k: kw.pop(k) for k in list(kw)
                 if k in _OPT_CFG_FIELDS and k != "stream"}
        if "stream" in kw:
            named["stream"] = kw.pop("stream")
        return cls(refresh_plan=refresh_plan, incremental=incremental,
                   overrides=kw, **named)

    def resolve(self, base: FDJConfig) -> FDJConfig:
        """The effective per-request config: ``base`` with this request's
        named fields and ``overrides`` applied (named fields win)."""
        merged = dict(self.overrides)
        for opt_field, cfg_field in _OPT_CFG_FIELDS.items():
            v = getattr(self, opt_field)
            if v is not None:
                merged[cfg_field] = v
        if not merged:
            return base
        return base.with_overrides(**merged)


@dataclasses.dataclass
class JoinPlan:
    """Output of steps ①–⑥: the featurized decomposition plus thresholds.

    A plan is a pure function of (dataset content, cfg, seed) and contains
    nothing corpus-shape-specific beyond what the samples baked in — the
    serving layer caches it across repeated queries and carries it forward
    over delta appends (the delta-join contract, DESIGN.md §4)."""
    specs: list                    # all proposed featurizations
    scaffold: Scaffold             # clauses over `specs` indices
    used_specs: list               # specs the scaffold references
    sc_local: Scaffold             # scaffold remapped onto used_specs
    theta: np.ndarray              # per-clause thresholds (Eq 4)
    t_prime: float                 # adjusted recall target (step ⑤)
    feasible: bool                 # Eq-4 feasibility on S'
    # the labeled threshold sample S' itself, retained so the serving layer
    # can seed a per-plan calibration reservoir (join_service recalibration:
    # after appends shift plane distributions, adj_target + the device sweep
    # re-run on the reservoir and hot-swap ``theta``).  Labels were already
    # charged by step ④ — carrying them is free.
    calib_pairs: Optional[list] = None
    calib_labels: Optional[np.ndarray] = None
    # measured conjunct evaluation order (scaffold.ordered_conjuncts on
    # S′'s clause distances — free, they were computed for threshold
    # selection anyway).  A permutation of range(n_clauses) or None; pure
    # execution hint: candidate set is invariant under it.  Serving keeps
    # it with the cached plan and refreshes it on theta recalibration.
    conjunct_order: Optional[list] = None

    @property
    def degenerate(self) -> bool:
        """No usable decomposition: refine-everything fallback (sound)."""
        return not self.feasible or not self.sc_local.n_clauses


@dataclasses.dataclass
class JoinResult:
    pairs: set                     # final output pairs (i, j)
    recall: float
    precision: float
    cost: CostLedger
    scaffold: Scaffold
    specs: list
    theta: np.ndarray
    t_prime: float
    candidate_count: int
    met_target: bool
    engine_stats: Optional[object] = None   # repro.engine.EngineStats of step ②
    candidates: Optional[list] = None       # sorted step-② survivors (serving
                                            # keeps them for delta-join merges)


def make_label_fn(oracle, cache: dict) -> Callable:
    """Cached oracle labeling: each pair is charged at most once per cache."""
    def label(pairs, kind):
        new = [p for p in pairs if p not in cache]
        if new:
            labs = oracle.label_pairs(new, kind=kind)
            for p, l in zip(new, labs):
                cache[p] = bool(l)
        return np.asarray([cache[p] for p in pairs], bool)
    return label


def _sample_pairs(n_l: int, n_r: int, k: int, rng) -> list:
    idx = rng.choice(n_l * n_r, size=min(k, n_l * n_r), replace=False)
    return [(int(i // n_r), int(i % n_r)) for i in idx]


def plan_join(dataset, oracle, proposer, extractor, cfg: FDJConfig, *,
              ledger: Optional[CostLedger] = None,
              label: Optional[Callable] = None) -> JoinPlan:
    """Steps ①–⑥: sample, generate featurizations, scaffold, thresholds."""
    # planning is recorded as one retroactive span with stage-boundary
    # events (sampled/featurized/scaffolded/thresholds) — plan_join runs
    # once per query, so the collection cost is irrelevant
    tracer = current_tracer()
    t_plan0 = time.perf_counter()
    marks: list = []
    rng = np.random.default_rng(cfg.seed)
    ledger = ledger if ledger is not None else oracle.ledger
    if label is None:
        label = make_label_fn(oracle, {})
    n_l, n_r = dataset.n_l, dataset.n_r
    n_pairs = n_l * n_r
    rate = max(dataset.n_positive, 1) / n_pairs

    # --- 1. generation sample ------------------------------------------------
    k_gen = min(int(math.ceil(cfg.gen_positives / rate * 1.25)), n_pairs)
    s1 = _sample_pairs(n_l, n_r, k_gen, rng)
    y1 = label(s1, "labeling")
    marks.append(("sampled", time.perf_counter(), {"pairs": len(s1)}))

    # --- 2. candidate featurizations ----------------------------------------
    specs = generation.get_candidate_featurizations(
        s1, y1, proposer, extractor, dataset.join_prompt, ledger,
        alpha=cfg.alpha, beta=cfg.beta, max_iter=cfg.max_iter, seed=cfg.seed)
    marks.append(("featurized", time.perf_counter(), {"specs": len(specs)}))

    # --- 3. scaffold ----------------------------------------------------------
    d1 = extractor.pair_distances(specs, s1, ledger)
    max_clauses = max(int(math.floor(1.0 / max(1.0 - cfg.recall_target, 1e-9))), 1)
    sc = scaffold_lib.get_logical_scaffold(d1, y1, cfg.recall_target,
                                           gamma=cfg.gamma, max_clauses=max_clauses)
    if sc.n_clauses == 0:
        # no featurization helps: degenerate to refine-everything (still valid)
        sc = Scaffold(clauses=[])
    marks.append(("scaffolded", time.perf_counter(),
                  {"clauses": sc.n_clauses}))

    # --- 4. threshold sample --------------------------------------------------
    k_thr = min(int(math.ceil(cfg.thresh_positives / rate * 1.25)), n_pairs)
    s2 = _sample_pairs(n_l, n_r, k_thr, rng)
    y2 = label(s2, "labeling")
    k_plus = int(y2.sum())

    # --- 5-6. adjusted target + thresholds ------------------------------------
    used = sc.used_featurizations()
    used_specs = [specs[i] for i in used]
    remap = {f: i for i, f in enumerate(used)}
    sc_local = Scaffold(clauses=[[remap[f] for f in c] for c in sc.clauses])
    delta_recall = cfg.delta if cfg.precision_target >= 1.0 else cfg.delta / 2.0
    if sc_local.n_clauses and k_plus > 0:
        adj = adj_target(k_plus, sc_local.n_clauses, cfg.recall_target,
                         delta_recall, n_pairs=n_pairs, k_sample=len(s2),
                         n_trials=cfg.mc_trials, seed=cfg.seed)
        t_prime = adj.t_prime
        d2 = extractor.pair_distances(used_specs, s2, ledger)
        cd2 = sc_local.clause_distances(d2)
        # Eq-4 selection goes through the device sweep (threshold_sweep
        # kernel grid + coordinate refinement; greedy remains the numpy
        # fallback and the never-worse A/B baseline)
        thr = min_fpr_thresholds(cd2, y2, t_prime, method="auto")
        theta = thr.theta
        feasible = thr.feasible
        # rate each conjunct's selectivity on the same S′ distances —
        # free measurement, consumed by the engines' short-circuit
        conjunct_order = scaffold_lib.ordered_conjuncts(
            cd2, theta, sc_local.clauses)
    else:
        t_prime = 1.0
        theta = np.zeros(0)
        feasible = False
        conjunct_order = None

    if tracer:
        marks.append(("thresholds", time.perf_counter(),
                      {"feasible": feasible, "t_prime": t_prime}))
        tracer.record_span(
            "plan", t_plan0, time.perf_counter(),
            attrs={"specs": len(specs), "clauses": sc_local.n_clauses,
                   "feasible": feasible}, events=marks)
    return JoinPlan(specs=specs, scaffold=sc, used_specs=used_specs,
                    sc_local=sc_local, theta=theta, t_prime=t_prime,
                    feasible=feasible, calib_pairs=list(s2),
                    calib_labels=np.asarray(y2, bool),
                    conjunct_order=conjunct_order)


def execute_join(dataset, oracle, extractor, cfg: FDJConfig, plan: JoinPlan,
                 *, plane_provider: Optional[Callable] = None,
                 ledger: Optional[CostLedger] = None,
                 label: Optional[Callable] = None,
                 keep_candidates: bool = False) -> JoinResult:
    """Steps ⑦–⑨: materialize planes, evaluate the CNF, refine.

    ``plane_provider(used_specs, ledger) -> Sequence[FeatureData]`` is the
    step-⑦ seam: default is the extractor's full-corpus ``materialize``
    (cold); the serving layer passes the FeaturePlaneStore's ``provide``
    (device-resident, charges only misses).

    ``keep_candidates=True`` retains the sorted step-② survivor list on
    the result (the serving layer needs it for delta-join merges); one-
    shot callers leave it off so a degenerate plan doesn't pin O(n_l·n_r)
    tuples past the join.
    """
    ledger = ledger if ledger is not None else oracle.ledger
    if label is None:
        label = make_label_fn(oracle, {})
    # fresh, plan-independent stream for the Appx-C subset sampler so a
    # replayed plan (serving) executes byte-identically to a cold run
    rng = np.random.default_rng(cfg.seed + 1)
    provider = plane_provider or \
        (lambda specs, led: extractor.materialize(specs, led))
    n_l, n_r = dataset.n_l, dataset.n_r

    # --- 7. plane materialization ---------------------------------------------
    feats: Sequence = []
    need_planes = (not plan.degenerate) or \
        (cfg.precision_target < 1.0 and plan.used_specs)
    if need_planes:
        with current_tracer().span("extract", specs=len(plan.used_specs)):
            feats = provider(plan.used_specs, ledger)

    # --- 8-9. candidate production + refinement --------------------------------
    # degenerate scaffold: decomposition admits everything (always-sound)
    engine_stats = None
    if cfg.stream_refinement:
        if plan.degenerate:
            chunk_iter = _degenerate_chunks(n_l, n_r)
        else:
            chunk_iter = _stream_cnf(feats, plan.sc_local, plan.theta, cfg,
                                     order=plan.conjunct_order)
        if cfg.precision_target >= 1.0:
            def refine_chunk(batch):
                labs = label(batch, "refinement")
                return {p for p, l in zip(batch, labs) if l}
            pump = RefinementPump(refine_chunk,
                                  batch_pairs=cfg.refine_batch_pairs,
                                  max_queue_chunks=cfg.pump_queue_chunks)
        else:
            # Appx C needs quantiles over the whole candidate set: the pump
            # accumulates the stream and runs the ladder once at drain time
            pump = RefinementPump(
                final=lambda cands: _precision_extension(
                    cands, feats, label, cfg, rng),
                max_queue_chunks=cfg.pump_queue_chunks)
        pr = pump.run(chunk_iter, ledger=ledger)
        out_pairs = pr.pairs
        cand_arr = pr.candidates
        n_cands = len(cand_arr)
        engine_stats = pr.engine_stats
    elif plan.degenerate and cfg.precision_target >= 1.0:
        # refine-everything fallback, labeled in bounded row blocks: the
        # barrier path used to materialize the full n_l*n_r cross product
        # as one host list (PR 5 fixed only the streaming path).  Per-pair
        # oracle refinement needs no global view, so label block by block.
        from repro.engine.base import iter_cross_product_chunks
        out_pairs = set()
        n_cands = 0
        cand_arr = [] if keep_candidates else None
        tracer = current_tracer()
        t0 = time.perf_counter()
        for block in iter_cross_product_chunks(n_l, n_r):
            tb0 = time.perf_counter()
            labs = label(block, "refinement")
            out_pairs |= {p for p, l in zip(block, labs) if l}
            n_cands += len(block)
            if cand_arr is not None:
                cand_arr.extend(block)
            if tracer:
                tracer.record_span("refine_batch", tb0, time.perf_counter(),
                                   attrs={"pairs": len(block)})
        ledger.record_walls(0.0, time.perf_counter() - t0, 0.0)
    else:
        if plan.degenerate:
            # Appx-C (T_P < 1) needs whole-candidate-set quantiles: the
            # full list is materialized for the precision ladder only
            candidates = [(i, j) for i in range(n_l) for j in range(n_r)]
        else:
            candidates, engine_stats = _evaluate_cnf(
                feats, plan.sc_local, plan.theta, cfg,
                order=plan.conjunct_order)
        out_pairs = set()
        cand_arr = list(candidates)
        n_cands = len(cand_arr)
        tracer = current_tracer()
        t0 = time.perf_counter()
        if cfg.precision_target >= 1.0:
            labs = label(cand_arr, "refinement")
            out_pairs = {p for p, l in zip(cand_arr, labs) if l}
        else:
            out_pairs = _precision_extension(cand_arr, feats, label, cfg, rng)
        t1 = time.perf_counter()
        if tracer:
            tracer.record_span("refine_batch", t0, t1,
                               attrs={"pairs": n_cands})
        ledger.record_walls(engine_stats.wall_s if engine_stats else 0.0,
                            t1 - t0, 0.0)
        ledger.record_engine_stats(engine_stats)

    truth = dataset.truth_set
    tp = len(out_pairs & truth)
    recall = tp / max(len(truth), 1)
    precision = tp / max(len(out_pairs), 1) if out_pairs else 1.0
    return JoinResult(
        pairs=out_pairs, recall=recall, precision=precision, cost=ledger,
        scaffold=plan.scaffold, specs=plan.specs, theta=plan.theta,
        t_prime=plan.t_prime,
        candidate_count=n_cands,
        met_target=(recall >= cfg.recall_target - 1e-12
                    and precision >= cfg.precision_target - 1e-12),
        engine_stats=engine_stats,
        candidates=sorted(cand_arr) if keep_candidates and cand_arr is not None
        else None,
    )


def fdj_join(dataset, oracle, proposer, extractor, cfg: FDJConfig,
             plane_provider: Optional[Callable] = None) -> JoinResult:
    """dataset: repro.data.synth.JoinDataset; oracle: core.llm.Oracle;
    proposer/extractor: generation protocol impls (dataset-owned)."""
    ledger = oracle.ledger
    label = make_label_fn(oracle, {})   # shared: refinement reuses sample labels
    with current_tracer().span("fdj_join", engine=cfg.engine,
                               stream=cfg.stream_refinement):
        plan = plan_join(dataset, oracle, proposer, extractor, cfg,
                         ledger=ledger, label=label)
        return execute_join(dataset, oracle, extractor, cfg, plan,
                            plane_provider=plane_provider, ledger=ledger,
                            label=label)


def apply_conjunct_order(clauses: list, theta: np.ndarray,
                         order: Optional[list]):
    """Permute (clauses, theta) jointly by the plan's measured evaluation
    order.  A no-op (the natural order) when ``order`` is None; raises if
    ``order`` is not a permutation of the clause indices — a stale order
    from a structurally different scaffold must never silently misalign
    thresholds with clauses."""
    if order is None:
        return clauses, theta
    if sorted(order) != list(range(len(clauses))):
        raise ValueError(
            f"conjunct order {order} is not a permutation of "
            f"{len(clauses)} clauses")
    return [clauses[i] for i in order], theta[np.asarray(order, int)]


def _ordered_cnf(sc: Scaffold, theta: np.ndarray, cfg: FDJConfig,
                 order: Optional[list]):
    if not cfg.order_conjuncts:
        order = None
    return apply_conjunct_order(sc.clauses, theta, order)


def _evaluate_cnf(feats, sc: Scaffold, theta: np.ndarray, cfg: FDJConfig,
                  order: Optional[list] = None):
    """Step 2: CNF evaluation over the full cross product via repro.engine.

    Returns (candidates, EngineStats).  Engine selection/backends live in
    ``repro.engine`` (DESIGN.md section 2); materialization/charging
    happened upstream through the plane provider.  ``order`` is the plan's
    measured conjunct order — an execution hint only (the candidate set
    is invariant; all three backends get the same permuted clause list,
    so cross-backend parity is preserved)."""
    clauses, th = _ordered_cnf(sc, theta, cfg, order)
    res = _get_engine(cfg).evaluate(feats, clauses, th)
    return res.candidates, res.stats


def _stream_cnf(feats, sc: Scaffold, theta: np.ndarray, cfg: FDJConfig,
                order: Optional[list] = None):
    """Streaming step ②: hands back the engine's chunk iterator for the
    RefinementPump."""
    clauses, th = _ordered_cnf(sc, theta, cfg, order)
    return _get_engine(cfg).evaluate_stream(feats, clauses, th)


def _get_engine(cfg: FDJConfig):
    from repro.engine import ENGINES, get_engine

    opts = dict(cfg.engine_opts)
    if opts and set(opts) <= set(ENGINES):   # per-engine keyed mapping
        opts = dict(opts.get(cfg.engine, {}))
    if cfg.engine == "numpy":
        opts.setdefault("block", cfg.block)
    if cfg.engine == "sharded":
        if cfg.prefetch_depth is not None:
            opts.setdefault("prefetch_depth", cfg.prefetch_depth)
        if cfg.pods > 1 and "mesh" not in opts:
            from repro.distributed.mesh import make_join_mesh
            opts["mesh"] = make_join_mesh(n_pods=cfg.pods)
    return get_engine(cfg.engine, **opts)


def _degenerate_chunks(n_l: int, n_r: int):
    """Refine-everything fallback as a bounded-chunk stream (stats-free,
    mirroring the barrier fallback's engine_stats=None).  Chunked by the
    same policy as the engines' vacuous-conjunction path so the
    RefinementPump's bounded queue — not one host list — is what limits
    resident pairs."""
    from repro.engine.base import CandidateChunk, iter_cross_product_chunks
    for idx, pairs in enumerate(iter_cross_product_chunks(n_l, n_r)):
        yield CandidateChunk(pairs, None, idx)


def _precision_extension(cand_pairs, feats, label, cfg: FDJConfig,
                         rng) -> set:
    """Appx C: per-featurization precision subsets skip refinement.

    Distances come from the materialized planes (``feats``) — identical
    values to the historical per-pair extractor path, and identical
    charges whenever step ② ran (those records were first-touch charged by
    step ⑦).  One deliberate divergence: on a *degenerate* plan the
    monolith extracted lazily per surviving pair set, while this path
    materializes the used specs up front — full-corpus charges for a
    corner the decomposition already failed to prune.  Free on the serving
    warm path where the planes are store-resident."""
    if not cand_pairs:
        return set()
    remaining = np.arange(len(cand_pairs))
    accepted: set = set()
    r = max(len(feats), 1)
    delta1 = cfg.delta / (2.0 * r)
    for fd in feats:
        if remaining.size == 0:
            break
        pairs_sub = [cand_pairs[i] for i in remaining]
        d = fd.pair_distances(pairs_sub)

        def label_fn(idx):
            return label([pairs_sub[i] for i in idx], "refinement")

        mask = bargain_precision_subset(d, label_fn, cfg.precision_target,
                                        delta1, rng=rng)
        accepted |= {pairs_sub[i] for i in np.flatnonzero(mask)}
        remaining = remaining[~mask]
    # leftover pairs: oracle refinement (precision 1 on them)
    left = [cand_pairs[i] for i in remaining]
    labs = label(left, "refinement")
    accepted |= {p for p, l in zip(left, labs) if l}
    return accepted
