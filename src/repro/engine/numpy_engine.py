"""Single-host blocked numpy backend (reference semantics).

This is the loop that used to live inline in ``core/join.py``: iterate
(L-block, R-block) tiles, build each clause's min-distance plane with
``FeatureData.distance_block``, AND the per-clause passes, and collect the
surviving indices.  Early exit when a block's conjunction empties
(``early_reject``; disable for the full-width A/B control).

Streaming: one ``CandidateChunk`` per L-row block (the outer loop), each
covering that row strip across all of R — so chunks arrive row-major
sorted and globally ordered.

It is the semantic oracle for the other backends — every engine must match
its candidate set exactly (tests/test_engines.py, tests/test_streaming.py).
Conjunct-eval accounting is per backend (block-granular here, tile/band-
granular on device), so only the candidate set — never the eval count —
is compared across backends.
"""

from __future__ import annotations

import numpy as np

from repro.engine.base import ChunkDelta, CnfEngine


class NumpyEngine(CnfEngine):
    name = "numpy"

    def __init__(self, block: int = 4096, early_reject: bool = True):
        self.block = int(block)
        self.early_reject = bool(early_reject)

    def _evaluate_stream(self, feats, clauses, thetas, n_l, n_r):
        block = self.block
        early_reject = self.early_reject
        theta = np.asarray(thetas, np.float64)
        for i0 in range(0, n_l, block):
            il = np.arange(i0, min(i0 + block, n_l))
            out = []
            evals = 0                  # (pair, clause) evals for this strip
            for j0 in range(0, n_r, block):
                jr = np.arange(j0, min(j0 + block, n_r))
                ok = None
                for ci, clause in enumerate(clauses):
                    cd = None
                    for f in clause:
                        d = feats[f].distance_block(il, jr)
                        cd = d if cd is None else np.minimum(cd, d)
                    pas = cd <= theta[ci]
                    evals += il.size * jr.size
                    ok = pas if ok is None else (ok & pas)
                    if early_reject and not ok.any():
                        break
                if ok is None or not ok.any():
                    continue
                ii, jj = np.nonzero(ok)
                out.extend(zip((il[ii]).tolist(), (jr[jj]).tolist()))
            # host-resident compute: no device traffic in any direction
            yield ChunkDelta(out, conjunct_evals=evals)
