"""CnfEngine — the step-② evaluation-engine interface.

Step ② of FDJ (Alg 6) evaluates the featurized decomposition — a CNF with
per-clause tied thresholds (Lemma D.1 form) — over the full L×R cross
product and returns the surviving candidate pairs.  Everything downstream
(refinement, precision subsets) is O(candidates); everything upstream
(featurization) is O(n_l + n_r); this stage is the only O(n_l · n_r)
compute in the system, so it gets its own subsystem with three backends:

  * ``numpy``   — single-host blocked loop (reference semantics)
  * ``pallas``  — single-device fused kernel, packed-bitmask host transfer
  * ``sharded`` — shard_map over the mesh "data" axis with on-device
                  candidate extraction; host traffic is O(candidates)

All backends must return the *identical* candidate set for identical
inputs (guarded by tests/test_engines.py).  Engines also report
``EngineStats`` so benchmarks can compare host-transfer bytes — the
scaling axis the sharded backend exists to fix.

Semantics contract (shared across backends, enforced here):

  * empty clause list ⇒ vacuous conjunction ⇒ every pair is a candidate;
  * distances are clipped to [0, 1]; a pair passes clause ``c`` iff the
    min distance over the clause's featurizations is <= theta[c];
  * missing values are encoded inside the feature arrays (distance 1), so
    a clause whose features are all missing only passes when theta >= 1;
  * candidates are returned as a row-major-sorted list of (i, j) tuples.

Streaming contract (DESIGN.md §3a): ``evaluate_stream`` yields
``CandidateChunk``s incrementally as the backend scans the plane — the
numpy/pallas backends emit one chunk per L-row block, the sharded backend
one chunk per R-chunk scan step.  Chunks are pairwise disjoint, each
chunk's candidates are row-major sorted *within* the chunk, and the sorted
union over all chunks is bit-identical to ``evaluate().candidates``
(``evaluate`` is literally a drain of the stream).  Downstream consumers
(core.refine.RefinementPump) may start refining a chunk while the engine
is still producing the next one.
"""

from __future__ import annotations

import abc
import dataclasses
import time
from typing import Iterator, Optional, Sequence

from repro.obs.trace import Tracer, current_tracer

# vacuous-conjunction (empty clause list) emissions are chunked so one host
# list never materializes the whole n_l x n_r cross product: each chunk
# covers whole L rows and holds at most ~this many pairs (one row minimum)
VACUOUS_CHUNK_PAIRS = 1 << 16


def iter_cross_product_chunks(n_l: int, n_r: int):
    """Bounded row-block emission of the full n_l x n_r cross product:
    yields row-major-sorted pair lists of whole L rows, each at most
    ~VACUOUS_CHUNK_PAIRS pairs (one row minimum).  The single chunking
    policy shared by the engines' vacuous-conjunction path and the
    degenerate-plan stream in core.join — nothing for a degenerate
    extent (n_l == 0 or n_r == 0)."""
    rows_per = max(1, VACUOUS_CHUNK_PAIRS // max(n_r, 1))
    for i0 in range(0, n_l, rows_per):
        yield [(i, j) for i in range(i0, min(i0 + rows_per, n_l))
               for j in range(n_r)]


@dataclasses.dataclass
class EngineStats:
    """Per-evaluation accounting, for the engine-comparison benchmark."""
    engine: str
    n_l: int = 0
    n_r: int = 0
    n_candidates: int = 0
    wall_s: float = 0.0
    # host wall split for pipelined backends (sharded double buffering,
    # DESIGN.md §3): dispatch_wall_s is time spent enqueueing device steps
    # (async — no host sync), pull_wall_s is time blocked pulling counts/
    # bases/candidate shards and filtering padding.  overlap_s is the
    # portion of this chunk's host work (pull + consumer hold) during
    # which a *successor* step was already in flight on the device — the
    # serial loop scores exactly 0, so a pipeline silently degrading to
    # serial is visible in accounting (benchmarks/run.py gates it).
    # Whole-evaluation values are the per-chunk sums (``merged``).
    dispatch_wall_s: float = 0.0
    pull_wall_s: float = 0.0
    overlap_s: float = 0.0
    # bytes moved device -> host to recover the candidate set.  The numpy
    # backend computes on the host (0 by definition); the pallas backend
    # pulls the packed n_l×n_r/8 bitmask; the sharded backend pulls only
    # per-device counts plus the compacted (i, j) pairs.
    bytes_to_host: int = 0
    # bytes moved host -> device to stage the feature planes for this
    # evaluation.  Cold path: the full packed plane set.  Warm serving path
    # (planes already device-resident via serving.planes): 0 — the
    # invariant the FeaturePlaneStore exists to provide (DESIGN.md §4).
    bytes_h2d: int = 0
    # bytes moved device -> device to lay store-resident planes out on the
    # sharded engine's mesh.  Paid at most once per (plane set, mesh): the
    # sharded assembly is memoized, so warm serving queries report 0 (the
    # multi-pod serving invariant, DESIGN.md §4).  Always 0 for the
    # single-device backends.
    bytes_reshard: int = 0
    # (pair, clause) evaluations actually computed for this chunk — the
    # honest FLOPs proxy behind the conjunct short-circuit (DESIGN.md §3).
    # Counts padded pairs and retry re-attempts (work the device really
    # did), so an "optimization" that merely moves work elsewhere cannot
    # hide.  Full-width CNF charges n_pairs * n_clauses; early rejection
    # charges 1 clause for every tile/band whose first-conjunct popcount
    # was zero.
    conjunct_evals: int = 0

    @property
    def plane_bytes(self) -> int:
        """Size of the full boolean match plane — the O(n²) yardstick."""
        return self.n_l * self.n_r

    @property
    def flops_per_candidate(self) -> float:
        """Conjunct evaluations per surviving candidate — the step-② cost
        ratio the short-circuit is gated on (lower is better)."""
        return self.conjunct_evals / max(self.n_candidates, 1)

    def as_dict(self) -> dict:
        return {
            "engine": self.engine, "n_l": self.n_l, "n_r": self.n_r,
            "n_candidates": self.n_candidates, "wall_s": self.wall_s,
            "dispatch_wall_s": self.dispatch_wall_s,
            "pull_wall_s": self.pull_wall_s,
            "overlap_s": self.overlap_s,
            "bytes_to_host": self.bytes_to_host,
            "bytes_h2d": self.bytes_h2d,
            "bytes_reshard": self.bytes_reshard,
            "plane_bytes": self.plane_bytes,
            "conjunct_evals": self.conjunct_evals,
            "flops_per_candidate": self.flops_per_candidate,
        }

    @classmethod
    def merged(cls, deltas: Sequence["EngineStats"]) -> "EngineStats":
        """Aggregate per-chunk stat deltas into whole-evaluation stats."""
        deltas = [d for d in deltas if d is not None]
        if not deltas:
            return cls("none")
        out = cls(deltas[0].engine, n_l=deltas[0].n_l, n_r=deltas[0].n_r)
        for d in deltas:
            out.n_candidates += d.n_candidates
            out.wall_s += d.wall_s
            out.dispatch_wall_s += d.dispatch_wall_s
            out.pull_wall_s += d.pull_wall_s
            out.overlap_s += d.overlap_s
            out.bytes_to_host += d.bytes_to_host
            out.bytes_h2d += d.bytes_h2d
            out.bytes_reshard += d.bytes_reshard
            out.conjunct_evals += d.conjunct_evals
        return out


@dataclasses.dataclass
class EngineResult:
    candidates: list                   # sorted [(i, j), ...]
    stats: EngineStats


@dataclasses.dataclass
class ChunkDelta:
    """One backend emission of ``_evaluate_stream``: the chunk's pairs plus
    its per-chunk accounting.  Backends without a dispatch/pull split (the
    host-resident numpy loop, the pallas mask pull) may instead yield the
    legacy ``(pairs, bytes_to_host, bytes_h2d, bytes_reshard)`` tuple —
    ``_stream_checked`` normalizes both forms."""
    pairs: list
    bytes_to_host: int = 0
    bytes_h2d: int = 0
    bytes_reshard: int = 0
    dispatch_s: float = 0.0            # host time enqueueing device steps
    pull_s: float = 0.0                # host time pulling + filtering
    overlap_s: float = 0.0             # host work done with a step in flight
    conjunct_evals: int = 0            # (pair, clause) evals this chunk did
    # optional tracing payload (DESIGN.md §7) — backends that measure their
    # own sub-phase timestamps attach them here and ``_stream_checked``
    # turns them into child slices of the chunk's ``band_step[k]`` span.
    # ``trace`` is a list of ``{"name", "t0", "t1", "attrs"}`` dicts (perf-
    # counter seconds), ``trace_events`` a list of ``(name, ts, attrs)``
    # instants (overflow / invalidate / redispatch), ``track`` the
    # rendering lane (the sharded ring uses one lane per ring slot so
    # concurrent steps render side by side instead of mis-nesting).  All
    # three are ignored — and should stay None — when tracing is off.
    trace: Optional[list] = None
    trace_events: Optional[list] = None
    track: Optional[str] = None


@dataclasses.dataclass
class CandidateChunk:
    """One streamed emission of step ②: a disjoint slice of the candidate
    set, sorted row-major within the chunk, plus the per-chunk stats delta
    (wall seconds spent producing *this* chunk, bytes pulled for it)."""
    candidates: list                   # sorted [(i, j), ...] for this chunk
    stats: EngineStats                 # delta, not cumulative
    index: int = 0                     # chunk ordinal in emission order


class CnfEngine(abc.ABC):
    """One step-② backend.  Subclasses implement ``_evaluate_stream``."""

    name: str = "abstract"

    def evaluate(self, feats: Sequence, clauses: Sequence, thetas) -> EngineResult:
        """Batch evaluation — a thin drain of ``evaluate_stream``.

        feats: list of core.featurize.FeatureData (full corpus);
        clauses: CNF over feature indices; thetas: per-clause thresholds."""
        t0 = time.perf_counter()
        cands: list = []
        chunks = list(self.evaluate_stream(feats, clauses, thetas))
        for ch in chunks:
            cands.extend(ch.candidates)
        cands.sort()
        stats = EngineStats.merged([ch.stats for ch in chunks])
        stats.n_candidates = len(cands)
        stats.wall_s = time.perf_counter() - t0
        return EngineResult(cands, stats)

    def evaluate_stream(self, feats: Sequence, clauses: Sequence,
                        thetas) -> Iterator[CandidateChunk]:
        """Yield disjoint ``CandidateChunk``s; sorted union ≡ ``evaluate``.

        Per-chunk ``stats.wall_s`` measures engine time only: the clock
        stops while the consumer holds the chunk, so a slow consumer does
        not inflate step-② accounting."""
        # validate eagerly (this is not itself a generator): a bad call
        # raises here, at the call site, not at the consumer's first next()
        thetas = tuple(thetas)         # bind once: callers may pass iterators
        if len(clauses) != len(thetas):
            raise ValueError(
                f"{len(clauses)} clauses but {len(thetas)} thresholds")
        n_l, n_r = corpus_shape(feats, clauses)
        return self._stream_checked(feats, clauses, thetas, n_l, n_r)

    def _stream_checked(self, feats, clauses, thetas, n_l, n_r):
        # tracing (DESIGN.md §7): band_step spans are recorded
        # *retroactively* from timestamps the loop measures anyway — a span
        # held open across ``yield`` would bill consumer hold time to the
        # engine.  NULL_TRACER is falsy, so the untraced hot loop pays one
        # truthiness check per chunk and zero allocations.
        tracer = current_tracer()
        t_prev = time.perf_counter()
        if not clauses:
            # vacuous conjunction: admit everything without touching a
            # backend — emitted in bounded row-block chunks so the stream
            # (and a RefinementPump behind it) never holds one host list of
            # the whole n_l x n_r cross product on a large corpus
            idx = 0
            for cands in iter_cross_product_chunks(n_l, n_r):
                t_now = time.perf_counter()
                if tracer:
                    tracer.record_span(
                        f"band_step[{idx}]", t_prev, t_now,
                        attrs={"engine": self.name, "vacuous": True,
                               "candidates": len(cands)})
                yield CandidateChunk(
                    cands, EngineStats(self.name, n_l=n_l, n_r=n_r,
                                       n_candidates=len(cands),
                                       wall_s=t_now - t_prev),
                    idx)
                idx += 1
                t_prev = time.perf_counter()
            if idx == 0:               # degenerate extent: one empty chunk
                yield CandidateChunk(
                    [], EngineStats(self.name, n_l=n_l, n_r=n_r,
                                    wall_s=time.perf_counter() - t_prev), 0)
            return
        for idx, delta in enumerate(
                self._evaluate_stream(feats, clauses, thetas, n_l, n_r)):
            if not isinstance(delta, ChunkDelta):
                delta = ChunkDelta(*delta)
            pairs = sorted(delta.pairs)
            t_now = time.perf_counter()
            if tracer:
                self._trace_band_step(tracer, idx, delta, len(pairs),
                                      t_prev, t_now)
            yield CandidateChunk(
                pairs, EngineStats(self.name, n_l=n_l, n_r=n_r,
                                   n_candidates=len(pairs),
                                   wall_s=t_now - t_prev,
                                   dispatch_wall_s=delta.dispatch_s,
                                   pull_wall_s=delta.pull_s,
                                   overlap_s=delta.overlap_s,
                                   bytes_to_host=delta.bytes_to_host,
                                   bytes_h2d=delta.bytes_h2d,
                                   bytes_reshard=delta.bytes_reshard,
                                   conjunct_evals=delta.conjunct_evals), idx)
            t_prev = time.perf_counter()

    def _trace_band_step(self, tracer: Tracer, idx, delta, n_pairs,
                         t_prev, t_now):
        """Record one chunk's ``band_step[idx]`` span plus any backend-
        provided sub-slices (sharded dispatch/pull windows).  The step span
        opens at the earliest sub-slice start — for a prefetched ring step
        that is the *enqueue* instant, which predates ``t_prev``, so steps
        overlap in time and each rides its own ring-slot track."""
        slices = delta.trace or ()
        t0 = min([t_prev] + [s["t0"] for s in slices])
        step = tracer.record_span(
            f"band_step[{idx}]", t0, t_now, track=delta.track,
            attrs={"engine": self.name, "candidates": n_pairs,
                   "bytes_to_host": delta.bytes_to_host,
                   "conjunct_evals": delta.conjunct_evals},
            events=delta.trace_events)
        for s in slices:
            tracer.record_span(s["name"], s["t0"], s["t1"], parent=step,
                               track=delta.track, attrs=s.get("attrs"))

    @abc.abstractmethod
    def _evaluate_stream(self, feats, clauses, thetas, n_l: int, n_r: int):
        """Yields a ``ChunkDelta`` (or the legacy 4-tuple ``(pairs,
        bytes_to_host, bytes_h2d, bytes_reshard)``) per backend-defined
        chunk; chunks must be disjoint and together cover
        the exact candidate set.  ``bytes_h2d`` is the plane upload
        attributed to the chunk (backends stage planes once, so only the
        first chunk of a cold evaluation carries a nonzero value; 0
        throughout when planes are already device-resident);
        ``bytes_reshard`` likewise carries the sharded backend's one-time
        device-to-device mesh layout cost on the first chunk."""


def corpus_shape(feats: Sequence, clauses: Sequence) -> tuple:
    """(n_l, n_r) from the feature arrays; validates cross-feature agreement."""
    if not feats:
        raise ValueError("no featurizations materialized")
    shapes = {(f.data_l.shape[0], f.data_r.shape[0]) for f in feats}
    if len(shapes) != 1:
        raise ValueError(f"inconsistent corpus shapes across features: {shapes}")
    for c in clauses:
        for fi in c:
            if not 0 <= fi < len(feats):
                raise ValueError(f"clause references feature {fi}, "
                                 f"have {len(feats)}")
    return next(iter(shapes))
