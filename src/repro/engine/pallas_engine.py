"""Single-device Pallas backend.

Thin wrapper over ``kernels.fused_cnf_join.ops``: the fused kernel grids
over the padded (n_l, n_r) plane, writes the packed uint32 bitmask, and
the mask is pulled to the host and unpacked there — host traffic is
O(n_l · n_r / 8).  Fine for one device and modest corpora; the sharded
backend exists for everything bigger.

Streaming: the kernel runs one ``l_block``-row strip at a time
(``ops.evaluate_corpus_stream``), yielding a ``CandidateChunk`` per strip
— same total mask traffic, but candidates for early rows reach the
refinement pump while later strips are still gridding.
"""

from __future__ import annotations

from typing import Optional

from repro.engine.base import CnfEngine


class PallasEngine(CnfEngine):
    name = "pallas"

    def __init__(self, tl: int = 256, tr: int = 512,
                 interpret: Optional[bool] = None,
                 l_block: Optional[int] = None, early_reject: bool = True):
        """l_block: rows per streamed chunk (multiple of tl; default 4*tl).
        early_reject=False forces full-width CNF on every tile (the A/B
        control for the conjunct_evals gate)."""
        self.tl = int(tl)
        self.tr = int(tr)
        self.interpret = interpret
        self.l_block = int(l_block) if l_block else 4 * self.tl
        self.early_reject = bool(early_reject)
        if self.l_block % self.tl != 0:
            raise ValueError(
                f"l_block={self.l_block} must be a multiple of tl={tl}")

    def _evaluate_stream(self, feats, clauses, thetas, n_l, n_r):
        from repro.kernels.fused_cnf_join import ops as cnf_ops
        yield from cnf_ops.evaluate_corpus_stream(
            feats, clauses, thetas, tl=self.tl, tr=self.tr,
            l_block=self.l_block, interpret=self.interpret,
            early_reject=self.early_reject)
