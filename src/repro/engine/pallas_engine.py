"""Single-device Pallas backend.

Thin wrapper over ``kernels.fused_cnf_join.ops.evaluate_corpus``: the fused
kernel grids over the padded (n_l, n_r) plane, writes the packed uint32
bitmask, and the *whole mask* is pulled to the host and unpacked there —
host traffic is O(n_l · n_r / 8).  Fine for one device and modest corpora;
the sharded backend exists for everything bigger.
"""

from __future__ import annotations

from typing import Optional

from repro.engine.base import CnfEngine


class PallasEngine(CnfEngine):
    name = "pallas"

    def __init__(self, tl: int = 256, tr: int = 512,
                 interpret: Optional[bool] = None):
        self.tl = int(tl)
        self.tr = int(tr)
        self.interpret = interpret

    def _evaluate(self, feats, clauses, thetas, n_l, n_r):
        from repro.kernels.fused_cnf_join import ops as cnf_ops
        pairs, mask_bytes = cnf_ops.evaluate_corpus(
            feats, clauses, thetas, tl=self.tl, tr=self.tr,
            interpret=self.interpret, return_mask_bytes=True)
        return pairs, mask_bytes
