"""Step-② evaluation engines (see DESIGN.md §2-3).

``get_engine("numpy" | "pallas" | "sharded", **opts)`` is the single entry
point used by ``core.join``, ``launch.join`` and ``benchmarks.engines``.
"""

from __future__ import annotations

from repro.engine.base import (CandidateChunk, CnfEngine, EngineResult,
                               EngineStats)

ENGINES = ("numpy", "pallas", "sharded")


def get_engine(name: str, **opts) -> CnfEngine:
    if name == "numpy":
        from repro.engine.numpy_engine import NumpyEngine
        return NumpyEngine(**opts)
    if name == "pallas":
        from repro.engine.pallas_engine import PallasEngine
        return PallasEngine(**opts)
    if name == "sharded":
        from repro.engine.sharded import ShardedEngine
        return ShardedEngine(**opts)
    raise ValueError(f"unknown engine {name!r}; expected one of {ENGINES}")


__all__ = ["CandidateChunk", "CnfEngine", "EngineResult", "EngineStats",
           "ENGINES", "get_engine"]
