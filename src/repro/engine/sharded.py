"""Sharded streaming backend: shard_map over the mesh L-sharding axes.

Layout (see DESIGN.md §3):

  * L rows are sharded over the mesh's L axes — ``("pod", "data")`` on a
    multi-pod mesh, ``("data",)`` otherwise: each of the
    ``l_shards = n_pods * n_data`` shards owns a contiguous block of
    ``rows_shard = padded_n_l / l_shards`` rows (embedding and scalar
    planes sliced with ``P(None, ("pod", "data"), ...)``);
  * R is replicated (the within-pod broadcast) and *streamed*: a host
    loop walks R in ``r_chunk``-column bands.  On a pod mesh the bands
    are **round-robined across pods** — at host step ``k`` pod ``p``
    works band ``(k + p * stride) % n_chunks`` — so the P pods occupy P
    distinct column bands at any instant while every pod still covers
    every band over the full sweep (its L shard exists nowhere else, so
    it must).  Within a pod the band is split across the "model" axis:
    each (data, model) device evaluates its L rows × an
    ``r_chunk / n_model``-column sub-band.  Device-resident working
    state stays O(rows_shard · r_chunk / n_model), never O(rows_shard ·
    n_r);
  * per step the fused CNF Pallas kernel produces the packed uint32 mask
    (grid = rows_shard/tl × r_sub/tr tiles), which is immediately
    compacted on-device into a per-device (i, j) candidate buffer via
    popcount + prefix-sum (engine.extract) — the mask never leaves HBM;
  * candidate counts are prefix-summed **hierarchically**: within each
    pod first (all_gather over ("data", "model")), then across pods
    (all_gather of the per-pod totals over "pod") —
    ``extract.hierarchical_offsets``.  That cross-pod gather of int32
    totals is the *only* collective that crosses a pod boundary: pod
    interconnect carries candidate counts, never feature planes or
    masks (asserted on the (2, 16, 16) dry-run via
    ``distributed.hlo_analysis.pod_crossing_stats``);
  * the band loop runs a **depth-k prefetch ring** (``prefetch_depth``,
    default 2 ≡ the PR-5 double buffer): up to ``k`` band steps are
    dispatched (JAX async dispatch — no host sync) before the host
    blocks pulling the oldest step's counts, bases and candidate shards,
    so successor bands' kernels run while the host filters padding,
    sorts, and the consumer holds the previous chunk — deeper rings
    ride out slower/burstier host pulls.  Per chunk the host pulls one
    int32 count, one int32 global base and one int32 conjunct-eval
    counter per device plus the first ``count`` buffer rows
    (``jax.device_get``): O(candidates) transfer total, and the first
    candidates surface after one scan step.  Batch ``evaluate`` is a
    drain of this same stream.  ``prefetch_depth=1`` (≡ the legacy
    ``double_buffer=False``) is the serial A/B control — the ring holds
    nothing while the host pulls or the consumer holds, so its
    ``overlap_s`` is exactly 0 *and* every dispatch wall lands in its
    own chunk's ``dispatch_wall_s`` (no post-yield tail dispatch
    leaking into the consumer's hold window).  Overlap is accounted,
    not assumed: per-chunk ``dispatch_wall_s`` / ``pull_wall_s`` and an
    ``overlap_s`` that is exactly 0 when the loop degrades to serial
    (``benchmarks/run.py`` gates it against the committed baselines);
  * CNF evaluation **short-circuits** (``early_reject``, default on):
    the kernel evaluates the first conjunct unconditionally and runs
    the rest only where the first passed somewhere in the tile — a band
    whose first-conjunct popcount is zero costs 1 clause, not C (the
    jnp reference path makes the same skip per sub-band via
    ``lax.cond``).  The candidate set is identical either way; the work
    actually done is pulled per step as an int32 eval counter and
    surfaced as ``EngineStats.conjunct_evals``, so the win is measured,
    never assumed.  Conjunct *ordering* (most selective first, measured
    on the plan's threshold sample) happens upstream in core.join —
    the engine evaluates whatever clause order it is handed.

Each step is L-complete (all shards' row blocks × one band per pod), so
steps partition the candidate set — disjoint by construction, sorted
within the chunk by ``base.evaluate_stream``.

Capacity is bounded-and-retried, never silently truncated: the on-device
count keeps growing past the buffer; overflow is detected per (pod,
data, model) shard and the host reruns *that step* — invalidating and
re-dispatching **all** in-flight successor steps at the grown capacity,
so a retry can never emit a chunk computed at a stale buffer size no
matter how deep the ring was.  Capacities are
carried **per shard** across the steps of one sweep (``extract.
grow_caps``: only the overflowing shard grows ≥4×; the uniform SPMD
dispatch buffer is the per-shard max), and they are *sweep-local*: a
dense join grows buffers for its own remaining steps, never for later
evaluations through a shared (serving) engine — ``self.capacity`` is
construction-time config and is never mutated (the last sweep's final
sizes are exposed as ``last_sweep_caps`` / ``last_sweep_capacity`` for
tests and diagnostics).  Padded rows/cols (tile alignment) are filtered
on the host — O(candidates) work.

The engine itself is reusable across stores and meshes: the evaluation
mesh is resolved per call (a mesh passed at construction wins; otherwise
the plane set's attached mesh, else the shared host mesh) and never
pinned on the instance.

On CPU the kernel runs in interpret mode on a 1-device "data" mesh, so
the same code path is exercised by tests; on a pod the identical program
lowers onto the (16, 16) / (2, 16, 16) production meshes from
``distributed.mesh`` (``make_join_mesh``).
"""

from __future__ import annotations

import collections
import contextlib
import dataclasses
import threading
import time
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.engine import extract
from repro.engine.base import ChunkDelta, CnfEngine
from repro.obs.trace import current_tracer


@dataclasses.dataclass
class _InFlight:
    """One dispatched-but-unpulled band step of the prefetch ring."""
    k: int                             # host step index
    cap: int                           # per-device buffer rows it was built at
    buf: object                        # device arrays (futures until pulled)
    cnt: object
    base: object
    evals: object                      # per-device int32 conjunct-eval units
    t_enq: float = 0.0                 # perf_counter at enqueue (trace)
    events: list = dataclasses.field(default_factory=list)  # trace instants


_HOST_MESH = None                      # shared default mesh: stable cache key
_HOST_MESH_LOCK = threading.Lock()     # fleet: engines resolve it concurrently


def _default_mesh():
    global _HOST_MESH
    with _HOST_MESH_LOCK:
        if _HOST_MESH is None:
            from repro.distributed.mesh import make_host_mesh
            _HOST_MESH = make_host_mesh()
        return _HOST_MESH


def _mesh_geometry(mesh):
    """(l_axes, n_pods, n_data, n_model) for any engine-usable mesh."""
    from repro.distributed.mesh import l_shard_axes
    names = mesh.axis_names
    if "data" not in names:
        raise ValueError(f"mesh {names} has no 'data' axis")
    n_pods = mesh.shape.get("pod", 1) if "pod" in names else 1
    n_model = mesh.shape.get("model", 1) if "model" in names else 1
    return l_shard_axes(mesh), n_pods, mesh.shape["data"], n_model


class ShardedEngine(CnfEngine):
    name = "sharded"

    def __init__(self, mesh=None, *, tl: int = 128, tr: int = 128,
                 r_chunk: Optional[int] = None, capacity: Optional[int] = None,
                 interpret: Optional[bool] = None, use_kernel: bool = True,
                 double_buffer: bool = True,
                 prefetch_depth: Optional[int] = None,
                 early_reject: bool = True,
                 scheduler=None):
        """mesh: any mesh with a "data" axis and optional "pod" / "model"
        axes.  When None, the mesh is resolved *per evaluation* — the
        plane set's attached mesh, else make_host_mesh() — so one engine
        can serve stores on different meshes; only a mesh passed here is
        honored across evaluations.  tl/tr: kernel tile edges
        (tr % 32 == 0).  r_chunk: R stream band (multiple of n_model*tr;
        default 4*tr*n_model).  capacity: initial per-device per-step
        candidate buffer (default heuristic); overflow grows a per-shard
        working copy >=4x within the sweep, never this config value.
        use_kernel=False swaps the Pallas kernel for the jnp reference —
        identical math, faster under CPU emulation (and the default-
        sensible choice for many-device dry-run meshes).
        prefetch_depth: how many band steps may be in flight at once
        (the ring; default 2 ≡ the classic double buffer, 1 = serial).
        double_buffer=False is the legacy spelling of prefetch_depth=1
        (an explicit prefetch_depth wins).  early_reject=False disables
        the conjunct short-circuit — full-width CNF on every band, the
        A/B control the conjunct_evals gate compares against.
        scheduler: the cross-query band-step gate (serving/fleet.py
        ``BandScheduler``).  When set, every band-step *enqueue* runs
        under ``scheduler.step()`` — a fleet running several queries on
        one mesh interleaves their band steps in admission order instead
        of letting one query's whole sweep monopolize the device queue.
        Only dispatch is gated; pulls/filtering proceed ungated, so one
        query's host work overlaps another's device compute."""
        if tr % 32 != 0:
            raise ValueError(f"tr={tr} must be a multiple of 32 (packed mask)")
        self.mesh = mesh
        self.tl = int(tl)
        self.tr = int(tr)
        self.r_chunk = int(r_chunk) if r_chunk else None
        if self.r_chunk and self.r_chunk % self.tr != 0:
            # necessary on any mesh; the full tr*n_model divisibility is
            # checked once the mesh (and its model-axis width) is known
            raise ValueError(
                f"r_chunk={self.r_chunk} must be a multiple of tr={tr}")
        self.capacity = capacity
        self.interpret = interpret
        self.use_kernel = use_kernel
        self.double_buffer = bool(double_buffer)
        if prefetch_depth is not None and int(prefetch_depth) < 1:
            raise ValueError(
                f"prefetch_depth={prefetch_depth} must be >= 1 (1 = serial)")
        self.prefetch_depth = int(prefetch_depth) if prefetch_depth else None
        self.early_reject = bool(early_reject)
        self.scheduler = scheduler
        # diagnostics only (tests, the dry-run report): the per-shard
        # capacities the most recent sweep ended at.  Not config — the
        # next evaluation starts from ``self.capacity`` again.
        self.last_sweep_caps: Optional[np.ndarray] = None

    @property
    def effective_prefetch_depth(self) -> int:
        """The ring depth evaluations run at: an explicit ``prefetch_depth``
        wins; otherwise 2 (double buffer) or 1 (``double_buffer=False``)."""
        if self.prefetch_depth is not None:
            return self.prefetch_depth
        return 2 if self.double_buffer else 1

    @property
    def last_sweep_capacity(self) -> int:
        """Max per-shard capacity the most recent sweep ended at (0 if the
        engine has not evaluated yet)."""
        if self.last_sweep_caps is None:
            return 0
        return int(self.last_sweep_caps.max())

    # class-level: engines are often constructed per join (get_engine in
    # core/join.py), so an instance cache would always be cold.  Bounded:
    # thetas are continuous per-join values, so keys rarely repeat across
    # joins and an unbounded dict would leak compiled programs for the
    # process lifetime.
    _programs: dict = {}               # build key -> jitted shard_map program
    _PROGRAM_CACHE_MAX = 32
    # fleet: concurrent queries dispatch through per-query engines that all
    # share this class-level cache; the lock covers lookup + LRU reorder +
    # insert (held through a cold compile too, so two threads racing the
    # same key compile once, not twice)
    _programs_lock = threading.Lock()

    def _resolve_r_chunk(self, n_model: int) -> int:
        r_chunk = self.r_chunk if self.r_chunk else 4 * self.tr * n_model
        if r_chunk % (self.tr * n_model) != 0:
            raise ValueError(
                f"r_chunk={r_chunk} must be a multiple of tr*n_model="
                f"{self.tr * n_model} (each of the {n_model} model-axis "
                f"devices kernels a whole-tile sub-band)")
        return r_chunk

    # -- device program -----------------------------------------------------

    def _build(self, mesh, kclauses, thetas, rows_shard, cap, r_chunk,
               n_chunks):
        # jax.jit caches on function identity; without memoizing here every
        # chunk step would re-trace and re-compile an identical program.
        # The key carries every value the closure bakes in (the step index
        # is a traced argument, so one program serves the whole R sweep;
        # n_chunks is baked into the per-pod band rotation).
        interpret = self.interpret
        if interpret is None:
            interpret = jax.default_backend() == "cpu"
        key = (mesh, kclauses, thetas, rows_shard, cap, r_chunk, n_chunks,
               self.tl, self.tr, self.use_kernel, interpret,
               self.early_reject)
        with ShardedEngine._programs_lock:
            cached = ShardedEngine._programs.get(key)
            if cached is not None:
                # LRU, not FIFO: re-insert on hit so eviction tracks recency —
                # a hot serving program must survive any number of one-off
                # joins churning the other slots (dict preserves insert order)
                ShardedEngine._programs.pop(key)
                ShardedEngine._programs[key] = cached
                return cached
            fn = self._build_uncached(mesh, kclauses, thetas, rows_shard, cap,
                                      r_chunk, n_chunks, interpret)
            while len(ShardedEngine._programs) >= self._PROGRAM_CACHE_MAX:
                ShardedEngine._programs.pop(
                    next(iter(ShardedEngine._programs)))
            ShardedEngine._programs[key] = fn
            return fn

    def _build_uncached(self, mesh, kclauses, thetas, rows_shard, cap,
                        r_chunk, n_chunks, interpret):
        from repro.kernels.fused_cnf_join import ref as cref
        from repro.kernels.fused_cnf_join.kernel import cnf_join_block
        tl, tr = self.tl, self.tr
        use_kernel = self.use_kernel
        early_reject = self.early_reject
        l_axes, n_pods, n_data, n_model = _mesh_geometry(mesh)
        has_pod = len(l_axes) == 2
        has_model = "model" in mesh.axis_names
        r_sub = r_chunk // n_model
        # pods enter the band rotation evenly spread across the R extent
        stride = max(1, n_chunks // n_pods)
        inner_axes = ("data", "model") if has_model else ("data",)

        def body(emb_l, emb_r, scal_l, scal_r, k):
            pod = lax.axis_index("pod") if has_pod else jnp.int32(0)
            data = lax.axis_index("data")
            model = lax.axis_index("model") if has_model else jnp.int32(0)
            shard = pod * n_data + data
            row0 = shard * rows_shard
            band = (k + pod * stride) % n_chunks
            col0 = band * r_chunk + model * r_sub
            erk = lax.dynamic_slice_in_dim(emb_r, col0, r_sub, axis=1)
            srk = lax.dynamic_slice_in_dim(scal_r, col0, r_sub, axis=1)
            # evals: conjunct-eval units this device really computed —
            # kernel path: clauses per tile, summed over the tile grid
            # (unit = tl*tr pairs); ref path: clauses for the whole
            # sub-band (unit = rows_shard*r_sub pairs).  Device-local
            # (no collective): the host pulls one int32 per device,
            # alongside the counts, and converts units to pair-clause
            # evals.
            if use_kernel:
                packed, evals_grid = cnf_join_block(
                    emb_l, erk, scal_l, srk, kclauses, thetas, tl=tl, tr=tr,
                    interpret=interpret, early_reject=early_reject,
                    with_evals=True)
                evals = jnp.sum(evals_grid, dtype=jnp.int32)
            else:
                ok, evals = cref.cnf_join_ref_counted(
                    emb_l, erk, scal_l, srk, kclauses, thetas,
                    early_reject=early_reject)
                packed = cref.pack_mask(ok)
            buf, cnt = extract.extract_pairs(packed, capacity=cap,
                                             row_offset=row0,
                                             col_offset=col0)
            base, _ = extract.hierarchical_offsets(
                cnt, inner_axes=inner_axes,
                inner_index=data * n_model + model,
                pod_axis="pod" if has_pod else None)
            return buf, cnt[None], base[None], evals[None]

        row_spec = l_axes[0] if len(l_axes) == 1 else l_axes
        dev_axes = l_axes + (("model",) if has_model else ())
        fn = shard_map(
            body, mesh=mesh,
            in_specs=(P(None, row_spec, None), P(None, None, None),
                      P(None, row_spec), P(None, None), P()),
            out_specs=(P(dev_axes, None), P(dev_axes), P(dev_axes),
                       P(dev_axes)),
            check_rep=False)   # pallas_call has no replication rule
        return jax.jit(fn)

    # -- evaluation ---------------------------------------------------------

    def _resolve_mesh(self, feats):
        """The evaluation mesh for this call — resolved fresh every time.

        A mesh passed at construction always wins; otherwise a serving
        plane set carries its store's mesh (pre-sharded residency,
        DESIGN.md §4), else the shared host mesh.  Never cached on the
        instance: an engine reused across stores/joins with different
        meshes must not keep the first plane set's mesh."""
        return self.mesh or getattr(feats, "mesh", None) or _default_mesh()

    def _evaluate_stream(self, feats, clauses, thetas, n_l, n_r):
        from repro.kernels.fused_cnf_join import ops as cnf_ops

        mesh = self._resolve_mesh(feats)
        l_axes, n_pods, n_data, n_model = _mesh_geometry(mesh)
        l_shards = n_pods * n_data
        n_dev = l_shards * n_model
        r_chunk = self._resolve_r_chunk(n_model)

        # pad L to a multiple of l_shards*tl (equal shards, tile-aligned
        # rows) and R to a multiple of r_chunk (whole stream steps).
        # stage_planes uploads a host pack once directly onto the mesh
        # layout — or assembles on device from a resident plane set
        # (serving store) with zero H2D, paying a one-time D2D reshard
        # that is memoized on the plane set (warm queries: 0 bytes).
        tracer = current_tracer()
        t_stage0 = time.perf_counter()
        staged = cnf_ops.stage_planes(feats, clauses, tl=l_shards * self.tl,
                                      tr=r_chunk, mesh=mesh, l_axes=l_axes)
        if tracer:
            tracer.record_span(
                "stage_planes", t_stage0, time.perf_counter(),
                attrs={"bytes_h2d": staged.bytes_h2d,
                       "bytes_reshard": staged.bytes_reshard})
        kclauses = staged.kclauses
        pl_n, pr_n = staged.emb_l.shape[1], staged.emb_r.shape[1]
        rows_shard = pl_n // l_shards
        n_chunks = pr_n // r_chunk
        args = staged.arrays
        thetas = tuple(float(t) for t in thetas)

        # per-(pod, data, model)-shard capacities, local to THIS sweep:
        # growth persists across the sweep's remaining steps but never
        # mutates the engine — a shared serving engine that once hit a
        # dense join must not over-allocate every later query.
        caps = np.full(n_dev, self.capacity or max(4096, 4 * rows_shard),
                       np.int64)
        timing = {"dispatch": 0.0}
        # host conversion factor from device eval *units* to (pair,
        # clause) evaluations: the kernel counts per tile, the jnp
        # reference per whole sub-band (see body)
        unit_pairs = (self.tl * self.tr if self.use_kernel
                      else rows_shard * (r_chunk // n_model))

        sched = self.scheduler

        def dispatch(k) -> _InFlight:
            """Enqueue band step k at the current uniform capacity (JAX
            async dispatch: returns futures, no host sync).  Under a fleet
            scheduler the enqueue itself is the scheduling point: steps
            from concurrent queries take turns in ticket order."""
            cap = int(caps.max())
            t0 = time.perf_counter()
            with sched.step() if sched is not None \
                    else contextlib.nullcontext():
                fn = self._build(mesh, kclauses, thetas, rows_shard, cap,
                                 r_chunk, n_chunks)
                buf, cnt, base, evals = fn(*args, jnp.int32(k))
            timing["dispatch"] += time.perf_counter() - t0
            return _InFlight(k, cap, buf, cnt, base, evals, t_enq=t0)

        def pull_counts(step):
            """Block on step's counts + eval units; returns (counts,
            pair-clause evals, bytes pulled)."""
            counts = np.asarray(jax.device_get(step.cnt))
            ev = np.asarray(jax.device_get(step.evals))
            return counts, int(ev.sum()) * unit_pairs, counts.nbytes + ev.nbytes

        depth = self.effective_prefetch_depth
        ring: collections.deque = collections.deque()   # oldest first
        next_k = 0
        hold_overlap = 0.0             # consumer hold with a step in flight
        while ring or next_k < n_chunks:
            # keep up to `depth` steps in flight: refill BEFORE blocking on
            # the oldest step's pull, so successor bands compute while the
            # host pulls/filters and the consumer holds the chunk.  At
            # depth 1 this is the serial loop — the ring is empty during
            # the pull and the hold, and each step's dispatch wall lands
            # in its own chunk (no post-yield tail dispatch).
            while len(ring) < depth and next_k < n_chunks:
                ring.append(dispatch(next_k))
                next_k += 1
            step = ring.popleft()
            k = step.k
            t_enq = step.t_enq         # first enqueue: the in-flight window
            step_events = step.events  # opens here even across retries
            t_pull0 = time.perf_counter()
            bytes_to_host = 0
            conjunct_evals = 0         # includes retry attempts: real work
            counts, ev, nb = pull_counts(step)
            conjunct_evals += ev
            bytes_to_host += nb
            while (counts > step.cap).any():
                # overflow: grow only the overflowing shards (>=4x each,
                # extract.grow_caps); counts are exact true totals, so the
                # retried step — dispatched at the new per-shard max —
                # cannot overflow again.  Every in-flight successor in the
                # ring was built at the stale capacity: invalidate them
                # all (drop the futures) and re-dispatch them right after
                # the retry, in order, so the pipeline stays full and no
                # chunk is ever emitted at a stale size.
                caps[:] = extract.grow_caps(caps, counts)
                t_retry0 = time.perf_counter()
                successors = [s.k for s in ring]
                if tracer:
                    step_events.append(
                        ("overflow", t_retry0,
                         {"counts_max": int(counts.max()),
                          "cap": step.cap}))
                    if successors:
                        step_events.append(
                            ("invalidate", t_retry0, {"steps": successors}))
                ring.clear()
                step = dispatch(k)
                for kk in successors:
                    redis = dispatch(kk)
                    if tracer:
                        redis.events.append(
                            ("redispatch", redis.t_enq, {"cap": redis.cap}))
                    ring.append(redis)
                t_pull0 += time.perf_counter() - t_retry0   # it's dispatch,
                counts, ev, nb = pull_counts(step)          # not pull
                conjunct_evals += ev
                bytes_to_host += nb
            cap = step.cap
            bases = np.asarray(jax.device_get(step.base))
            bytes_to_host += bases.nbytes
            expect = np.cumsum(counts) - counts
            if not np.array_equal(bases, expect):
                raise RuntimeError(
                    "hierarchical candidate-count prefix-sum disagrees with "
                    f"host bookkeeping: device bases {bases.tolist()} vs "
                    f"expected {expect.tolist()}")
            chunk_h2d = staged.bytes_h2d if k == 0 else 0
            chunk_reshard = staged.bytes_reshard if k == 0 else 0
            # pull each device's first `count` buffer rows straight off its
            # shard (no jit dispatch: a jnp slice of the global array would
            # compile one distributed program per (device, count) pair —
            # minutes of churn on a 512-device dry-run mesh).  The slice is
            # the transfer a production DMA would move: O(candidates).
            out = []
            for sh in step.buf.addressable_shards:
                d = (sh.index[0].start or 0) // cap
                take = int(counts[d])
                if not take:
                    continue
                seg = np.asarray(sh.data)[:take]
                bytes_to_host += seg.nbytes
                out.append((d, seg))
            out = [seg for _, seg in sorted(out, key=lambda t: t[0])]
            if out:
                arr = np.concatenate(out, axis=0)
                keep = (arr[:, 0] < n_l) & (arr[:, 1] < n_r)  # drop padding
                arr = arr[keep]
                pairs = list(zip(arr[:, 0].tolist(), arr[:, 1].tolist()))
            else:
                pairs = []
            t_pull1 = time.perf_counter()
            pull_s = t_pull1 - t_pull0
            dispatch_s, timing["dispatch"] = timing["dispatch"], 0.0
            # overlap accounting: host work done while a successor step was
            # in flight on the device — this pull/filter window, plus the
            # time the consumer held the previous chunk.  Exactly 0 for the
            # depth-1 (serial) ring, so a pipeline that silently degrades
            # to serial is visible in EngineStats (and gated in
            # benchmarks/run.py).
            overlap_s = (pull_s if ring else 0.0) + hold_overlap
            trace = track = None
            if tracer:
                # the "dispatch" slice is the *in-flight window* (enqueue →
                # pull-begin): at depth ≥ 2 it contains predecessors' pull
                # windows — the ring overlap, visible as cross-track slice
                # overlap in Perfetto; at depth 1 it never does.  The host
                # enqueue wall itself rides along as ``enqueue_s`` (that is
                # what reconciles against wall.step2_dispatch_s).
                trace = [
                    {"name": "dispatch", "t0": t_enq, "t1": t_pull0,
                     "attrs": {"enqueue_s": dispatch_s, "cap": cap,
                               "band": k}},
                    {"name": "pull", "t0": t_pull0, "t1": t_pull1,
                     "attrs": {"bytes": bytes_to_host,
                               "candidates": len(pairs)}},
                ]
                track = f"ring{k % depth}"
            t_yield = time.perf_counter()
            yield ChunkDelta(pairs, bytes_to_host, chunk_h2d, chunk_reshard,
                             dispatch_s=dispatch_s, pull_s=pull_s,
                             overlap_s=overlap_s,
                             conjunct_evals=conjunct_evals,
                             trace=trace, trace_events=step_events or None,
                             track=track)
            hold = time.perf_counter() - t_yield
            hold_overlap = hold if ring else 0.0
        self.last_sweep_caps = caps.copy()
