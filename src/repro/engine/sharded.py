"""Sharded streaming backend: shard_map over the mesh "data" axis.

Layout (see DESIGN.md §3):

  * L rows are sharded over the mesh's "data" axis — each device owns a
    contiguous block of ``rows_shard = padded_n_l / n_dev`` rows (embedding
    and scalar planes sliced with ``P(None, "data", ...)``);
  * R is replicated and *streamed*: a host loop walks R in chunks of
    ``r_chunk`` rows, so device-resident working state is
    O(rows_shard · r_chunk), never O(rows_shard · n_r);
  * per chunk the fused CNF Pallas kernel produces the packed uint32 mask
    (grid = rows_shard/tl × r_chunk/tr tiles), which is immediately
    compacted on-device into a per-chunk (i, j) candidate buffer via
    popcount + prefix-sum (engine.extract) — the mask never leaves HBM;
  * after **each** chunk the host pulls one int32 count per device plus
    the first ``count`` buffer rows (``jax.device_get``) and *emits* the
    chunk's global pairs downstream: O(candidates) transfer total, and the
    first candidates surface after one scan step instead of after the
    whole R sweep.  Batch ``evaluate`` is a drain of this same stream.

Each chunk is L-complete (all devices' row blocks × one R column band),
so chunks partition the candidate set by R columns — disjoint by
construction, sorted within the chunk by ``base.evaluate_stream``.

Capacity is bounded-and-retried, never silently truncated: the on-device
count keeps growing past the buffer, the host detects overflow per chunk
and reruns *that chunk* with a ≥4× buffer.  Padded rows/cols (tile
alignment) are filtered on the host — O(candidates) work.

On CPU the kernel runs in interpret mode on a 1-device "data" mesh, so the
same code path is exercised by tests; on a pod the identical program lowers
onto the (16, 16) production mesh from ``distributed.mesh``.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.engine import extract
from repro.engine.base import CnfEngine


_HOST_MESH = None                      # shared default mesh: stable cache key


def _default_mesh():
    global _HOST_MESH
    if _HOST_MESH is None:
        from repro.distributed.mesh import make_host_mesh
        _HOST_MESH = make_host_mesh()
    return _HOST_MESH


class ShardedEngine(CnfEngine):
    name = "sharded"

    def __init__(self, mesh=None, *, tl: int = 128, tr: int = 128,
                 r_chunk: Optional[int] = None, capacity: Optional[int] = None,
                 interpret: Optional[bool] = None, use_kernel: bool = True):
        """mesh: any mesh with a "data" axis (default: make_host_mesh()).
        tl/tr: kernel tile edges (tr % 32 == 0).  r_chunk: R stream chunk
        (multiple of tr; default 4*tr).  capacity: initial per-device
        per-chunk candidate buffer (default heuristic, grows >=4x on
        overflow).  use_kernel=False swaps the Pallas kernel for the jnp
        reference — identical math, faster under CPU emulation."""
        if tr % 32 != 0:
            raise ValueError(f"tr={tr} must be a multiple of 32 (packed mask)")
        self.mesh = mesh
        self.tl = int(tl)
        self.tr = int(tr)
        self.r_chunk = int(r_chunk) if r_chunk else 4 * self.tr
        if self.r_chunk % self.tr != 0:
            raise ValueError(f"r_chunk={self.r_chunk} must be a multiple of tr={tr}")
        self.capacity = capacity
        self.interpret = interpret
        self.use_kernel = use_kernel

    # class-level: engines are often constructed per join (get_engine in
    # core/join.py), so an instance cache would always be cold.  Bounded:
    # thetas are continuous per-join values, so keys rarely repeat across
    # joins and an unbounded dict would leak compiled programs for the
    # process lifetime.
    _programs: dict = {}               # build key -> jitted shard_map program
    _PROGRAM_CACHE_MAX = 32

    # -- device program -----------------------------------------------------

    def _build(self, mesh, kclauses, thetas, rows_shard, cap):
        # jax.jit caches on function identity; without memoizing here every
        # chunk step would re-trace and re-compile an identical program.
        # The key carries every value the closure bakes in (the chunk index
        # is a traced argument, so one program serves the whole R sweep).
        interpret = self.interpret
        if interpret is None:
            interpret = jax.default_backend() == "cpu"
        key = (mesh, kclauses, thetas, rows_shard, cap,
               self.tl, self.tr, self.r_chunk, self.use_kernel, interpret)
        cached = ShardedEngine._programs.get(key)
        if cached is not None:
            return cached
        fn = self._build_uncached(mesh, kclauses, thetas, rows_shard, cap,
                                  interpret)
        while len(ShardedEngine._programs) >= self._PROGRAM_CACHE_MAX:
            ShardedEngine._programs.pop(next(iter(ShardedEngine._programs)))
        ShardedEngine._programs[key] = fn
        return fn

    def _build_uncached(self, mesh, kclauses, thetas, rows_shard, cap,
                        interpret):
        from repro.kernels.fused_cnf_join import ref as cref
        from repro.kernels.fused_cnf_join.kernel import cnf_join_block
        tl, tr, r_chunk = self.tl, self.tr, self.r_chunk
        use_kernel = self.use_kernel

        def body(emb_l, emb_r, scal_l, scal_r, k):
            row0 = lax.axis_index("data") * rows_shard
            erk = lax.dynamic_slice_in_dim(emb_r, k * r_chunk, r_chunk, axis=1)
            srk = lax.dynamic_slice_in_dim(scal_r, k * r_chunk, r_chunk, axis=1)
            if use_kernel:
                packed = cnf_join_block(emb_l, erk, scal_l, srk, kclauses,
                                        thetas, tl=tl, tr=tr,
                                        interpret=interpret)
            else:
                packed = cref.pack_mask(cref.cnf_join_ref(
                    emb_l, erk, scal_l, srk, kclauses, thetas))
            buf, cnt = extract.extract_pairs(packed, capacity=cap,
                                             row_offset=row0,
                                             col_offset=k * r_chunk)
            return buf, cnt[None]

        fn = shard_map(
            body, mesh=mesh,
            in_specs=(P(None, "data", None), P(None, None, None),
                      P(None, "data"), P(None, None), P()),
            out_specs=(P("data", None), P("data")),
            check_rep=False)   # pallas_call has no replication rule
        return jax.jit(fn)

    # -- evaluation ---------------------------------------------------------

    def _evaluate_stream(self, feats, clauses, thetas, n_l, n_r):
        from repro.kernels.fused_cnf_join import ops as cnf_ops

        if self.mesh is None:
            self.mesh = _default_mesh()
        mesh = self.mesh
        if "data" not in mesh.axis_names:
            raise ValueError(f"mesh {mesh.axis_names} has no 'data' axis")
        ndev = mesh.shape["data"]

        # pad L to a multiple of ndev*tl (equal shards, tile-aligned rows)
        # and R to a multiple of r_chunk (whole stream steps).  stage_planes
        # uploads a host pack once — or assembles on device from a resident
        # plane set (serving store) with zero H2D.  On a multi-device mesh a
        # store-resident (single-device) array is resharded device-to-device
        # by jit, which still never re-pays the host link.
        emb_l, emb_r, scal_l, scal_r, kclauses, _, _, h2d = \
            cnf_ops.stage_planes(feats, clauses, tl=ndev * self.tl,
                                 tr=self.r_chunk)
        pl_n, pr_n = emb_l.shape[1], emb_r.shape[1]
        rows_shard = pl_n // ndev
        n_chunks = pr_n // self.r_chunk
        args = (emb_l, emb_r, scal_l, scal_r)
        thetas = tuple(float(t) for t in thetas)

        cap = self.capacity or max(4096, 4 * rows_shard)
        for k in range(n_chunks):
            while True:
                fn = self._build(mesh, kclauses, thetas, rows_shard, cap)
                buf, cnt = fn(*args, jnp.int32(k))
                counts = np.asarray(jax.device_get(cnt))
                if (counts <= cap).all():
                    break
                # counts are exact true totals (extract never clamps), so one
                # retry of this chunk sized >=4x (and >= the true max) suffices
                cap = max(4 * cap, -(-int(max(counts)) // 1024) * 1024)
            self.capacity = cap        # start here next chunk: no repeat retry
            chunk_h2d = h2d if k == 0 else 0
            bytes_to_host = counts.nbytes
            out = []
            for d in range(ndev):
                take = int(counts[d])
                if not take:
                    continue
                seg = np.asarray(buf[d * cap: d * cap + take])  # O(cands) pull
                bytes_to_host += seg.nbytes
                out.append(seg)
            if not out:
                yield [], bytes_to_host, chunk_h2d
                continue
            pairs = np.concatenate(out, axis=0)
            keep = (pairs[:, 0] < n_l) & (pairs[:, 1] < n_r)    # drop padding
            pairs = pairs[keep]
            yield (list(zip(pairs[:, 0].tolist(), pairs[:, 1].tolist())),
                   bytes_to_host, chunk_h2d)
