"""Sharded streaming backend: shard_map over the mesh L-sharding axes.

Layout (see DESIGN.md §3):

  * L rows are sharded over the mesh's L axes — ``("pod", "data")`` on a
    multi-pod mesh, ``("data",)`` otherwise: each of the
    ``l_shards = n_pods * n_data`` shards owns a contiguous block of
    ``rows_shard = padded_n_l / l_shards`` rows (embedding and scalar
    planes sliced with ``P(None, ("pod", "data"), ...)``);
  * R is replicated (the within-pod broadcast) and *streamed*: a host
    loop walks R in ``r_chunk``-column bands.  On a pod mesh the bands
    are **round-robined across pods** — at host step ``k`` pod ``p``
    works band ``(k + p * stride) % n_chunks`` — so the P pods occupy P
    distinct column bands at any instant while every pod still covers
    every band over the full sweep (its L shard exists nowhere else, so
    it must).  Within a pod the band is split across the "model" axis:
    each (data, model) device evaluates its L rows × an
    ``r_chunk / n_model``-column sub-band.  Device-resident working
    state stays O(rows_shard · r_chunk / n_model), never O(rows_shard ·
    n_r);
  * per step the fused CNF Pallas kernel produces the packed uint32 mask
    (grid = rows_shard/tl × r_sub/tr tiles), which is immediately
    compacted on-device into a per-device (i, j) candidate buffer via
    popcount + prefix-sum (engine.extract) — the mask never leaves HBM;
  * candidate counts are prefix-summed **hierarchically**: within each
    pod first (all_gather over ("data", "model")), then across pods
    (all_gather of the per-pod totals over "pod") —
    ``extract.hierarchical_offsets``.  That cross-pod gather of int32
    totals is the *only* collective that crosses a pod boundary: pod
    interconnect carries candidate counts, never feature planes or
    masks (asserted on the (2, 16, 16) dry-run via
    ``distributed.hlo_analysis.pod_crossing_stats``);
  * after **each** step the host pulls one int32 count plus one int32
    global base per device and the first ``count`` buffer rows
    (``jax.device_get``) and *emits* the step's global pairs downstream:
    O(candidates) transfer total, and the first candidates surface after
    one scan step.  Batch ``evaluate`` is a drain of this same stream.

Each step is L-complete (all shards' row blocks × one band per pod), so
steps partition the candidate set — disjoint by construction, sorted
within the chunk by ``base.evaluate_stream``.

Capacity is bounded-and-retried, never silently truncated: the on-device
count keeps growing past the buffer; overflow is detected per (pod,
data, model) shard and the host reruns *that step* with a ≥4× buffer
(SPMD programs share one buffer shape, so the retry recomputes every
pod's band; only the step's emission changes).  Padded rows/cols (tile
alignment) are filtered on the host — O(candidates) work.

On CPU the kernel runs in interpret mode on a 1-device "data" mesh, so
the same code path is exercised by tests; on a pod the identical program
lowers onto the (16, 16) / (2, 16, 16) production meshes from
``distributed.mesh`` (``make_join_mesh``).
"""

from __future__ import annotations

from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.engine import extract
from repro.engine.base import CnfEngine


_HOST_MESH = None                      # shared default mesh: stable cache key


def _default_mesh():
    global _HOST_MESH
    if _HOST_MESH is None:
        from repro.distributed.mesh import make_host_mesh
        _HOST_MESH = make_host_mesh()
    return _HOST_MESH


def _mesh_geometry(mesh):
    """(l_axes, n_pods, n_data, n_model) for any engine-usable mesh."""
    from repro.distributed.mesh import l_shard_axes
    names = mesh.axis_names
    if "data" not in names:
        raise ValueError(f"mesh {names} has no 'data' axis")
    n_pods = mesh.shape.get("pod", 1) if "pod" in names else 1
    n_model = mesh.shape.get("model", 1) if "model" in names else 1
    return l_shard_axes(mesh), n_pods, mesh.shape["data"], n_model


class ShardedEngine(CnfEngine):
    name = "sharded"

    def __init__(self, mesh=None, *, tl: int = 128, tr: int = 128,
                 r_chunk: Optional[int] = None, capacity: Optional[int] = None,
                 interpret: Optional[bool] = None, use_kernel: bool = True):
        """mesh: any mesh with a "data" axis and optional "pod" / "model"
        axes (default: the plane set's attached mesh, else
        make_host_mesh()).  tl/tr: kernel tile edges (tr % 32 == 0).
        r_chunk: R stream band (multiple of n_model*tr; default
        4*tr*n_model).  capacity: initial per-device per-step candidate
        buffer (default heuristic, grows >=4x on overflow).
        use_kernel=False swaps the Pallas kernel for the jnp reference —
        identical math, faster under CPU emulation (and the default-
        sensible choice for many-device dry-run meshes)."""
        if tr % 32 != 0:
            raise ValueError(f"tr={tr} must be a multiple of 32 (packed mask)")
        self.mesh = mesh
        self.tl = int(tl)
        self.tr = int(tr)
        self.r_chunk = int(r_chunk) if r_chunk else None
        if self.r_chunk and self.r_chunk % self.tr != 0:
            # necessary on any mesh; the full tr*n_model divisibility is
            # checked once the mesh (and its model-axis width) is known
            raise ValueError(
                f"r_chunk={self.r_chunk} must be a multiple of tr={tr}")
        self.capacity = capacity
        self.interpret = interpret
        self.use_kernel = use_kernel

    # class-level: engines are often constructed per join (get_engine in
    # core/join.py), so an instance cache would always be cold.  Bounded:
    # thetas are continuous per-join values, so keys rarely repeat across
    # joins and an unbounded dict would leak compiled programs for the
    # process lifetime.
    _programs: dict = {}               # build key -> jitted shard_map program
    _PROGRAM_CACHE_MAX = 32

    def _resolve_r_chunk(self, n_model: int) -> int:
        r_chunk = self.r_chunk if self.r_chunk else 4 * self.tr * n_model
        if r_chunk % (self.tr * n_model) != 0:
            raise ValueError(
                f"r_chunk={r_chunk} must be a multiple of tr*n_model="
                f"{self.tr * n_model} (each of the {n_model} model-axis "
                f"devices kernels a whole-tile sub-band)")
        return r_chunk

    # -- device program -----------------------------------------------------

    def _build(self, mesh, kclauses, thetas, rows_shard, cap, r_chunk,
               n_chunks):
        # jax.jit caches on function identity; without memoizing here every
        # chunk step would re-trace and re-compile an identical program.
        # The key carries every value the closure bakes in (the step index
        # is a traced argument, so one program serves the whole R sweep;
        # n_chunks is baked into the per-pod band rotation).
        interpret = self.interpret
        if interpret is None:
            interpret = jax.default_backend() == "cpu"
        key = (mesh, kclauses, thetas, rows_shard, cap, r_chunk, n_chunks,
               self.tl, self.tr, self.use_kernel, interpret)
        cached = ShardedEngine._programs.get(key)
        if cached is not None:
            return cached
        fn = self._build_uncached(mesh, kclauses, thetas, rows_shard, cap,
                                  r_chunk, n_chunks, interpret)
        while len(ShardedEngine._programs) >= self._PROGRAM_CACHE_MAX:
            ShardedEngine._programs.pop(next(iter(ShardedEngine._programs)))
        ShardedEngine._programs[key] = fn
        return fn

    def _build_uncached(self, mesh, kclauses, thetas, rows_shard, cap,
                        r_chunk, n_chunks, interpret):
        from repro.kernels.fused_cnf_join import ref as cref
        from repro.kernels.fused_cnf_join.kernel import cnf_join_block
        tl, tr = self.tl, self.tr
        use_kernel = self.use_kernel
        l_axes, n_pods, n_data, n_model = _mesh_geometry(mesh)
        has_pod = len(l_axes) == 2
        has_model = "model" in mesh.axis_names
        r_sub = r_chunk // n_model
        # pods enter the band rotation evenly spread across the R extent
        stride = max(1, n_chunks // n_pods)
        inner_axes = ("data", "model") if has_model else ("data",)

        def body(emb_l, emb_r, scal_l, scal_r, k):
            pod = lax.axis_index("pod") if has_pod else jnp.int32(0)
            data = lax.axis_index("data")
            model = lax.axis_index("model") if has_model else jnp.int32(0)
            shard = pod * n_data + data
            row0 = shard * rows_shard
            band = (k + pod * stride) % n_chunks
            col0 = band * r_chunk + model * r_sub
            erk = lax.dynamic_slice_in_dim(emb_r, col0, r_sub, axis=1)
            srk = lax.dynamic_slice_in_dim(scal_r, col0, r_sub, axis=1)
            if use_kernel:
                packed = cnf_join_block(emb_l, erk, scal_l, srk, kclauses,
                                        thetas, tl=tl, tr=tr,
                                        interpret=interpret)
            else:
                packed = cref.pack_mask(cref.cnf_join_ref(
                    emb_l, erk, scal_l, srk, kclauses, thetas))
            buf, cnt = extract.extract_pairs(packed, capacity=cap,
                                             row_offset=row0,
                                             col_offset=col0)
            base, _ = extract.hierarchical_offsets(
                cnt, inner_axes=inner_axes,
                inner_index=data * n_model + model,
                pod_axis="pod" if has_pod else None)
            return buf, cnt[None], base[None]

        row_spec = l_axes[0] if len(l_axes) == 1 else l_axes
        dev_axes = l_axes + (("model",) if has_model else ())
        fn = shard_map(
            body, mesh=mesh,
            in_specs=(P(None, row_spec, None), P(None, None, None),
                      P(None, row_spec), P(None, None), P()),
            out_specs=(P(dev_axes, None), P(dev_axes), P(dev_axes)),
            check_rep=False)   # pallas_call has no replication rule
        return jax.jit(fn)

    # -- evaluation ---------------------------------------------------------

    def _evaluate_stream(self, feats, clauses, thetas, n_l, n_r):
        from repro.kernels.fused_cnf_join import ops as cnf_ops

        if self.mesh is None:
            # a serving plane set carries its store's mesh (pre-sharded
            # residency, DESIGN.md §4); otherwise fall back to the host mesh
            self.mesh = getattr(feats, "mesh", None) or _default_mesh()
        mesh = self.mesh
        l_axes, n_pods, n_data, n_model = _mesh_geometry(mesh)
        l_shards = n_pods * n_data
        r_chunk = self._resolve_r_chunk(n_model)

        # pad L to a multiple of l_shards*tl (equal shards, tile-aligned
        # rows) and R to a multiple of r_chunk (whole stream steps).
        # stage_planes uploads a host pack once directly onto the mesh
        # layout — or assembles on device from a resident plane set
        # (serving store) with zero H2D, paying a one-time D2D reshard
        # that is memoized on the plane set (warm queries: 0 bytes).
        staged = cnf_ops.stage_planes(feats, clauses, tl=l_shards * self.tl,
                                      tr=r_chunk, mesh=mesh, l_axes=l_axes)
        kclauses = staged.kclauses
        pl_n, pr_n = staged.emb_l.shape[1], staged.emb_r.shape[1]
        rows_shard = pl_n // l_shards
        n_chunks = pr_n // r_chunk
        args = staged.arrays
        thetas = tuple(float(t) for t in thetas)

        cap = self.capacity or max(4096, 4 * rows_shard)
        for k in range(n_chunks):
            while True:
                fn = self._build(mesh, kclauses, thetas, rows_shard, cap,
                                 r_chunk, n_chunks)
                buf, cnt, base = fn(*args, jnp.int32(k))
                counts = np.asarray(jax.device_get(cnt))
                if (counts <= cap).all():
                    break
                # counts are exact true totals (extract never clamps), so one
                # retry of this step sized >=4x (and >= the true max) suffices
                cap = max(4 * cap, -(-int(max(counts)) // 1024) * 1024)
            self.capacity = cap        # start here next step: no repeat retry
            bases = np.asarray(jax.device_get(base))
            expect = np.cumsum(counts) - counts
            if not np.array_equal(bases, expect):
                raise RuntimeError(
                    "hierarchical candidate-count prefix-sum disagrees with "
                    f"host bookkeeping: device bases {bases.tolist()} vs "
                    f"expected {expect.tolist()}")
            chunk_h2d = staged.bytes_h2d if k == 0 else 0
            chunk_reshard = staged.bytes_reshard if k == 0 else 0
            bytes_to_host = counts.nbytes + bases.nbytes
            # pull each device's first `count` buffer rows straight off its
            # shard (no jit dispatch: a jnp slice of the global array would
            # compile one distributed program per (device, count) pair —
            # minutes of churn on a 512-device dry-run mesh).  The slice is
            # the transfer a production DMA would move: O(candidates).
            out = []
            for sh in buf.addressable_shards:
                d = (sh.index[0].start or 0) // cap
                take = int(counts[d])
                if not take:
                    continue
                seg = np.asarray(sh.data)[:take]
                bytes_to_host += seg.nbytes
                out.append((d, seg))
            out = [seg for _, seg in sorted(out, key=lambda t: t[0])]
            if not out:
                yield [], bytes_to_host, chunk_h2d, chunk_reshard
                continue
            pairs = np.concatenate(out, axis=0)
            keep = (pairs[:, 0] < n_l) & (pairs[:, 1] < n_r)    # drop padding
            pairs = pairs[keep]
            yield (list(zip(pairs[:, 0].tolist(), pairs[:, 1].tolist())),
                   bytes_to_host, chunk_h2d, chunk_reshard)
