"""On-device candidate extraction from the packed CNF bitmask.

The fused kernel emits a uint32 mask packed 32 R-neighbours per word.
Pulling that mask to the host costs n_l·n_r/8 bytes regardless of how few
pairs survive — at corpus scale the transfer, not the kernel, dominates.
``compact_append`` turns the mask into a dense buffer of (i, j) index
pairs *on the device* via popcount + prefix-sum compaction:

  1. ``lax.population_count`` per word  -> per-word candidate counts;
  2. exclusive prefix-sum over words (row-major) -> per-word base offsets;
  3. per-word bit expansion + intra-word exclusive prefix-sum -> bit slots;
  4. scatter (i, j) into the output buffer at base+slot (OOB writes drop).

The buffer has a fixed capacity (scatter targets must be static under
jit); overflow is *detected, never silent* — the returned count keeps
growing past capacity, so the caller compares count vs capacity and
retries bigger.  Host traffic becomes O(candidates): one scalar count plus
8 bytes per surviving pair.
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp
from jax import lax

_CAP_QUANTUM = 1024                    # capacities round up to this


def grow_caps(caps, counts):
    """Per-shard capacity growth after an overflowed step (DESIGN.md §3).

    caps:   int array, one sweep-carried capacity per (pod, data, model)
            shard; counts: that step's exact per-shard candidate counts
            (``compact_append`` never clamps, so they are true totals).

    Only shards whose count exceeded their capacity grow — each to
    ``max(4 * its own capacity, count rounded up to 1 KiB of rows)``.  The
    ≥4× rule bounds retries per shard; applying it *per shard* means one
    hot shard no longer compounds the whole sweep's buffer: the uniform
    SPMD dispatch capacity is ``caps.max()``, and a later overflow on a
    previously-cold shard grows from that shard's own small capacity, not
    from the hot shard's inflated one.  Returns a new array; input caps
    are never shrunk.
    """
    caps = np.asarray(caps, np.int64)
    counts = np.asarray(counts, np.int64)
    need = -(-counts // _CAP_QUANTUM) * _CAP_QUANTUM
    return np.where(counts > caps, np.maximum(4 * caps, need), caps)


def compact_append(packed, buf, count, *, row_offset=0, col_offset=0):
    """Append the set bits of ``packed`` to ``buf`` as (i, j) pairs.

    packed: uint32 (nl, nw) mask (nw words of 32 R-columns each)
    buf:    int32 (capacity, 2) output buffer (scatter target)
    count:  int32 scalar — pairs already in ``buf``; the write cursor
    row_offset/col_offset: global coordinates of packed[0, 0]'s bit 0
      (traced values are fine — e.g. ``lax.axis_index`` inside shard_map)

    Returns (buf, new_count).  new_count may exceed capacity — that means
    the tail was dropped and the caller must retry with a larger buffer.
    """
    capacity = buf.shape[0]
    nl, nw = packed.shape
    counts = lax.population_count(packed).astype(jnp.int32)          # (nl, nw)
    flat = counts.reshape(-1)
    word_base = (jnp.cumsum(flat) - flat).reshape(nl, nw)            # exclusive
    bitpos = jnp.arange(32, dtype=jnp.uint32)
    bits = ((packed[:, :, None] >> bitpos) & jnp.uint32(1)).astype(jnp.int32)
    intra = jnp.cumsum(bits, axis=-1) - bits                         # exclusive
    pos = count + word_base[:, :, None] + intra                      # (nl,nw,32)
    pos = jnp.where(bits == 1, pos, capacity)                        # unset -> OOB
    rows = jnp.broadcast_to(
        jnp.arange(nl, dtype=jnp.int32)[:, None, None] + row_offset, bits.shape)
    cols = jnp.broadcast_to(
        jnp.arange(nw, dtype=jnp.int32)[None, :, None] * 32
        + jnp.arange(32, dtype=jnp.int32)[None, None, :] + col_offset,
        bits.shape)
    pairs = jnp.stack([rows, cols], axis=-1).reshape(-1, 2)
    buf = buf.at[pos.reshape(-1)].set(pairs, mode="drop")
    return buf, count + flat.sum()


def hierarchical_offsets(count, *, inner_axes, inner_index, pod_axis=None):
    """Global exclusive offset of this device's candidates, prefix-summed
    hierarchically: within the pod first, then across pods (DESIGN.md §3).

    count:       int32 scalar — this device's candidate count
    inner_axes:  mesh axis names spanning one pod (e.g. ("data", "model"))
    inner_index: this device's linear index over ``inner_axes`` (row-major
                 in the given axis order) — a traced value from
                 ``lax.axis_index`` composition
    pod_axis:    the cross-pod axis name, or None on a single-pod mesh

    Two collectives, both over *counts only*:

      1. ``all_gather(count, inner_axes)`` — within-pod, one int32 per
         device in the pod; the exclusive cumsum at ``inner_index`` is the
         device's base inside its pod;
      2. ``all_gather(pod_total, pod_axis)`` — the **only cross-pod
         collective in the engine**, one int32 per pod.  This is the
         multi-pod design invariant the dry-run asserts via
         ``distributed.hlo_analysis``: inter-pod links carry candidate
         counts, never feature planes or masks.

    Returns (global_base int32, pod_counts) where ``pod_counts`` is the
    within-pod gathered count vector (the host cross-checks its emission
    bookkeeping against the returned bases).
    """
    pod_counts = lax.all_gather(count, inner_axes)            # (pod devs,)
    excl = jnp.cumsum(pod_counts) - pod_counts
    base = excl[inner_index]
    if pod_axis is None:
        return base, pod_counts
    pod_total = pod_counts.sum()
    totals = lax.all_gather(pod_total, pod_axis)              # counts only
    p = lax.axis_index(pod_axis)
    pod_base = (jnp.cumsum(totals) - totals)[p]
    return pod_base + base, pod_counts


def extract_pairs(packed, *, capacity, row_offset=0, col_offset=0):
    """One-shot compaction of a packed mask into a fresh buffer.

    Returns (buf int32 (capacity, 2), count int32).  Entries past ``count``
    are -1 filler; count > capacity signals overflow (see compact_append).
    """
    buf = jnp.full((capacity, 2), -1, jnp.int32)
    return compact_append(packed, buf, jnp.zeros((), jnp.int32),
                          row_offset=row_offset, col_offset=col_offset)
