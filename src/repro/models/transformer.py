"""Unified causal LM covering all assigned architectures.

The layer stack is decomposed into *segments*: a prefix of ``num_layers %
period`` unrolled layers followed by ``num_layers // period`` scanned
repetitions of the block-pattern period.  Scanning keeps HLO size and compile
time bounded for 60-100 layer models; remat is applied per scanned period.

Blocks are dispatched on ``BlockKind``; each block owns its params subtree,
optional recurrent/KV state, and an aux-loss scalar (MoE).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.common.config import BlockKind, FFNKind, ModelConfig
from repro.distributed.mesh import AxisEnv
from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import (
    ParamDef, ParamTree, abstract_tree, count_tree, dense_ffn, dense_ffn_defs,
    embedding_defs, init_tree, rms_norm, softcap, spec_tree, stack_defs,
)


# ---------------------------------------------------------------------------
# single block
# ---------------------------------------------------------------------------

def block_defs(cfg: ModelConfig, kind: str, ffn: str) -> ParamTree:
    d = cfg.d_model
    defs: ParamTree = {"ln1": ParamDef((d,), (None,), init="ones")}
    if kind == BlockKind.ATTN.value:
        defs["attn"] = attn.gqa_defs(cfg)
    elif kind == BlockKind.MLA.value:
        defs["attn"] = attn.mla_defs(cfg)
    elif kind == BlockKind.CROSS_ATTN.value:
        defs["attn"] = attn.cross_attn_defs(cfg)
    elif kind == BlockKind.MAMBA2.value:
        defs["mixer"] = ssm_lib.mamba2_defs(cfg)
    elif kind == BlockKind.SLSTM.value:
        defs["mixer"] = ssm_lib.slstm_defs(cfg)
    elif kind == BlockKind.MLSTM.value:
        defs["mixer"] = ssm_lib.mlstm_defs(cfg)
    else:
        raise ValueError(kind)
    if ffn == FFNKind.DENSE.value and cfg.d_ff > 0:
        defs["ln2"] = ParamDef((d,), (None,), init="ones")
        defs["ffn"] = dense_ffn_defs(d, cfg.d_ff)
    elif ffn == FFNKind.MOE.value:
        defs["ln2"] = ParamDef((d,), (None,), init="ones")
        defs["ffn"] = moe_lib.moe_defs(cfg)
    return defs


def block_state_defs(cfg: ModelConfig, kind: str, batch: int, capacity: int) -> dict:
    if kind == BlockKind.ATTN.value:
        return attn.gqa_cache_defs(cfg, batch, capacity)
    if kind == BlockKind.MLA.value:
        return attn.mla_cache_defs(cfg, batch, capacity)
    if kind == BlockKind.CROSS_ATTN.value:
        return {}
    if kind == BlockKind.MAMBA2.value:
        return ssm_lib.mamba2_state_defs(cfg, batch)
    if kind == BlockKind.SLSTM.value:
        return ssm_lib.slstm_state_defs(cfg, batch)
    if kind == BlockKind.MLSTM.value:
        return ssm_lib.mlstm_state_defs(cfg, batch)
    raise ValueError(kind)


def block_forward(
    params: ParamTree,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    cfg: ModelConfig,
    kind: str,
    ffn: str,
    state: Optional[dict],
    memory: Optional[jnp.ndarray],
    compute_dtype,
    use_ep: bool,
    mesh=None,
    env=None,
    valid_from=None,
    valid=None,
):
    """Returns (x_out, new_state, aux_loss)."""
    h = rms_norm(x, params["ln1"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    new_state = state
    if kind == BlockKind.ATTN.value:
        h, new_state = attn.gqa_attention(params["attn"], h, positions, cfg, state,
                                          compute_dtype, valid_from=valid_from)
    elif kind == BlockKind.MLA.value:
        h, new_state = attn.mla_attention(params["attn"], h, positions, cfg, state,
                                          compute_dtype, valid_from=valid_from)
    elif kind == BlockKind.CROSS_ATTN.value:
        mem = memory
        if mem is None:
            mem = jnp.zeros((x.shape[0], 1, cfg.d_model), x.dtype)
        h = attn.cross_attention(params["attn"], h, mem, cfg, compute_dtype)
    elif kind == BlockKind.MAMBA2.value:
        h, new_state = ssm_lib.mamba2_forward(params["mixer"], h, cfg, state,
                                              compute_dtype, valid=valid)
    elif kind == BlockKind.SLSTM.value:
        h, new_state = ssm_lib.slstm_forward(params["mixer"], h, cfg, state,
                                             compute_dtype, valid=valid)
    elif kind == BlockKind.MLSTM.value:
        h, new_state = ssm_lib.mlstm_forward(params["mixer"], h, cfg, state,
                                             compute_dtype, valid=valid)
    x = x + h
    if "ffn" in params:
        h = rms_norm(x, params["ln2"], cfg.norm_eps)
        if ffn == FFNKind.MOE.value:
            if use_ep and mesh is not None:
                h, aux = moe_lib.moe_block_sharded(params["ffn"], h, cfg, mesh, env,
                                                   compute_dtype)
            else:
                h, aux = moe_lib.moe_ffn_dense(params["ffn"], h, cfg, compute_dtype)
        else:
            h = dense_ffn(params["ffn"], h, compute_dtype)
        x = x + h
    return x, new_state, aux


# ---------------------------------------------------------------------------
# segments: prefix (unrolled) + scanned periods
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Segments:
    prefix: tuple          # tuple[(kind, ffn)] unrolled layers
    period: tuple          # tuple[(kind, ffn)] one scanned period
    n_periods: int


def plan_segments(cfg: ModelConfig) -> Segments:
    pattern = cfg.pattern
    ffns = [cfg._layer_ffn(k) for k in pattern]
    if cfg.moe is not None:
        step = cfg.moe.moe_layer_step
        for i in range(len(ffns)):
            if ffns[i] == FFNKind.MOE.value and (
                    i < cfg.moe.first_dense_layers or i % step != step - 1):
                ffns[i] = FFNKind.DENSE.value
    layers = tuple(zip(pattern, ffns))
    if not cfg.scan_layers:
        return Segments(prefix=layers, period=(), n_periods=0)
    p = len(cfg.block_pattern)
    if cfg.cross_attn_every:
        p = _lcm(p, cfg.cross_attn_every)
    if cfg.moe is not None and cfg.moe.moe_layer_step > 1:
        p = _lcm(p, cfg.moe.moe_layer_step)
    # find the longest suffix that is periodic with period p
    n = len(layers)
    n_periods = 0
    while n_periods < n // p:
        cand = n - (n_periods + 1) * p
        if cand < 0:
            break
        seg = layers[cand : cand + p]
        ok = all(layers[cand + j * p : cand + (j + 1) * p] == seg
                 for j in range(n_periods + 1))
        if not ok:
            break
        n_periods += 1
    if n_periods <= 1:
        return Segments(prefix=layers, period=(), n_periods=0)
    prefix_len = n - n_periods * p
    return Segments(prefix=layers[:prefix_len], period=layers[prefix_len : prefix_len + p],
                    n_periods=n_periods)


def _lcm(a: int, b: int) -> int:
    import math
    return a * b // math.gcd(a, b)


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------

def model_defs(cfg: ModelConfig) -> ParamTree:
    segs = plan_segments(cfg)
    defs: ParamTree = {
        "embed": embedding_defs(cfg.vocab_size, cfg.d_model),
        "ln_f": ParamDef((cfg.d_model,), (None,), init="ones"),
        "prefix": {str(i): block_defs(cfg, k, f) for i, (k, f) in enumerate(segs.prefix)},
    }
    if segs.n_periods:
        period_defs = {str(j): block_defs(cfg, k, f) for j, (k, f) in enumerate(segs.period)}
        defs["scanned"] = stack_defs(period_defs, segs.n_periods)
    if not cfg.tie_embeddings:
        defs["unembed"] = ParamDef((cfg.d_model, cfg.vocab_size), ("fsdp", "tp"), scale=1.0)
    if cfg.frontend_embed_dim:
        defs["frontend_proj"] = ParamDef(
            (cfg.frontend_embed_dim, cfg.d_model), (None, "fsdp"))
    if cfg.cross_attn_every > 0 and cfg.frontend_embed_dim:
        defs["memory_proj"] = ParamDef(
            (cfg.frontend_embed_dim, cfg.d_model), (None, "fsdp"))
    return defs


def init_params(cfg: ModelConfig, key) -> ParamTree:
    return init_tree(model_defs(cfg), key)


def param_specs(cfg: ModelConfig, env: AxisEnv) -> ParamTree:
    return spec_tree(model_defs(cfg), env)


def abstract_params(cfg: ModelConfig) -> ParamTree:
    return abstract_tree(model_defs(cfg))


def count_params(cfg: ModelConfig) -> int:
    return count_tree(model_defs(cfg))


def active_param_count(cfg: ModelConfig) -> int:
    """Params touched per token (MoE: top_k routed + shared experts only)."""
    total = count_params(cfg)
    if cfg.moe is None:
        return total
    segs = plan_segments(cfg)
    layers = list(segs.prefix) + list(segs.period) * segs.n_periods
    n_moe = sum(1 for _, f in layers if f == FFNKind.MOE.value)
    mo = cfg.moe
    inactive = (mo.num_experts - mo.top_k) * 3 * cfg.d_model * mo.expert_d_ff
    return total - n_moe * inactive


def _leaf_sd(x):
    return (isinstance(x, tuple) and len(x) == 3 and isinstance(x[0], tuple)
            and isinstance(x[2], str))


def state_defs(cfg: ModelConfig, batch: int, capacity: int) -> dict:
    """Per-layer decode state (KV cache / SSM state): (shape, logical, dtype)."""
    segs = plan_segments(cfg)
    out = {"prefix": {str(i): block_state_defs(cfg, k, batch, capacity)
                      for i, (k, _) in enumerate(segs.prefix)}}
    if segs.n_periods:
        period = {str(j): block_state_defs(cfg, k, batch, capacity)
                  for j, (k, _) in enumerate(segs.period)}
        out["scanned"] = jax.tree.map(
            lambda sd: ((segs.n_periods,) + sd[0], (None,) + tuple(sd[1]), sd[2]),
            period, is_leaf=_leaf_sd)
    else:
        out["scanned"] = None
    return out


def init_state(cfg: ModelConfig, batch: int, capacity: int) -> dict:
    return jax.tree.map(lambda sd: jnp.zeros(sd[0], sd[2]),
                        state_defs(cfg, batch, capacity), is_leaf=_leaf_sd)


def state_specs(cfg: ModelConfig, env: AxisEnv, batch: int, capacity: int,
                batch_logical: Optional[str] = "batch") -> dict:
    def mk(sd):
        logical = tuple(batch_logical if l == "batch" else l for l in sd[1])
        return env.resolve(logical)
    return jax.tree.map(mk, state_defs(cfg, batch, capacity), is_leaf=_leaf_sd)


def abstract_state(cfg: ModelConfig, batch: int, capacity: int) -> dict:
    return jax.tree.map(lambda sd: jax.ShapeDtypeStruct(sd[0], sd[2]),
                        state_defs(cfg, batch, capacity), is_leaf=_leaf_sd)


def forward(
    params: ParamTree,
    tokens: jnp.ndarray,            # (B, S) int32 — or (B, S, F) frontend embeds
    cfg: ModelConfig,
    *,
    positions: Optional[jnp.ndarray] = None,
    state: Optional[dict] = None,
    memory: Optional[jnp.ndarray] = None,
    use_ep: bool = False,
    mesh=None,
    sp_constraint: Optional[Callable] = None,
    valid_from=None,
):
    """Returns (logits, new_state, aux_loss).

    valid_from: optional (B,) int32 — positions below it are left-pads
    (serving batches); masked in attention and identity in SSM recurrences.
    """
    compute_dtype = jnp.dtype(cfg.dtype)
    if cfg.param_cast == "once":
        # cast before the scan: FSDP all-gathers then move compute-dtype
        # bytes instead of f32 (grad flows back through the cast).
        params = jax.tree.map(
            lambda p: p.astype(compute_dtype)
            if p.ndim >= 2 and p.dtype == jnp.float32 else p, params)
    segs = plan_segments(cfg)
    if tokens.ndim == 3:
        x = jnp.einsum("bsf,fd->bsd", tokens.astype(compute_dtype),
                       params["frontend_proj"].astype(compute_dtype))
    else:
        x = params["embed"]["embedding"].astype(compute_dtype)[tokens]
    if memory is not None and "memory_proj" in params:
        memory = jnp.einsum("bmf,fd->bmd", memory.astype(compute_dtype),
                            params["memory_proj"].astype(compute_dtype))
    b, s = x.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    if sp_constraint is not None:
        x = sp_constraint(x)
    aux_total = jnp.zeros((), jnp.float32)
    new_state = {"prefix": {}, "scanned": None} if state is not None else None

    env = AxisEnv.from_mesh(mesh) if mesh is not None else None
    valid = None
    if valid_from is not None and s > 1:
        # physical prefill positions (cache slots 0..s-1); decode steps (s=1)
        # are always real tokens — `positions` may be logical (RoPE) ones.
        valid = jnp.arange(s)[None, :] >= valid_from[:, None]

    # prefix (unrolled; remat per block to match the scanned segments)
    for i, (kind, ffn) in enumerate(segs.prefix):
        st = state["prefix"][str(i)] if state is not None else None

        def blk(pp, xx, ss, _kind=kind, _ffn=ffn):
            return block_forward(pp, xx, positions, cfg, _kind, _ffn, ss,
                                 memory, compute_dtype, use_ep, mesh, env,
                                 valid_from, valid)

        if cfg.remat:
            policy = (jax.checkpoint_policies.nothing_saveable
                      if cfg.remat_policy == "nothing_saveable"
                      else jax.checkpoint_policies.checkpoint_dots)
            blk = jax.checkpoint(blk, policy=policy, prevent_cse=False)
        x, st2, aux = blk(params["prefix"][str(i)], x, st)
        if sp_constraint is not None:
            x = sp_constraint(x)
        aux_total = aux_total + aux
        if state is not None:
            new_state["prefix"][str(i)] = st2

    # scanned periods
    if segs.n_periods:
        def period_fn(carry, layer_in):
            x, positions = carry
            layer_params, layer_state = layer_in
            new_layer_state = {} if layer_state is not None else None
            aux_p = jnp.zeros((), jnp.float32)
            for j, (kind, ffn) in enumerate(segs.period):
                st = layer_state[str(j)] if layer_state is not None else None
                x, st2, aux = block_forward(layer_params[str(j)], x, positions, cfg,
                                            kind, ffn, st, memory, compute_dtype, use_ep,
                                            mesh, env, valid_from, valid)
                if sp_constraint is not None:
                    x = sp_constraint(x)
                aux_p = aux_p + aux
                if layer_state is not None:
                    new_layer_state[str(j)] = st2
            return (x, positions), (new_layer_state, aux_p)

        if cfg.remat:
            policy = (jax.checkpoint_policies.nothing_saveable
                      if cfg.remat_policy == "nothing_saveable"
                      else jax.checkpoint_policies.checkpoint_dots)
            period_fn = jax.checkpoint(period_fn, policy=policy, prevent_cse=False)
        scan_state = state["scanned"] if state is not None else None
        (x, _), (scan_new_state, aux_ps) = jax.lax.scan(
            period_fn, (x, positions),
            (params["scanned"], scan_state),
        )
        aux_total = aux_total + jnp.sum(aux_ps)
        if state is not None:
            new_state["scanned"] = scan_new_state

    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    if cfg.tie_embeddings:
        w_out = params["embed"]["embedding"].astype(compute_dtype).T
    else:
        w_out = params["unembed"].astype(compute_dtype)
    logits = jnp.einsum("bsd,dv->bsv", x, w_out)
    logits = softcap(logits.astype(jnp.float32), cfg.logits_softcap)
    return logits, new_state, aux_total
