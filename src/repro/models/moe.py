"""Mixture-of-Experts FFN with expert parallelism.

Two execution paths:

* ``dense`` — per-token top-k routing combined with a dense per-expert einsum
  over a capacity-gathered buffer. Used for smoke tests and single-device runs.
* ``ep`` — production path: tokens are scattered into fixed-capacity
  per-destination-shard buffers, exchanged with ``lax.all_to_all`` over the
  ``model`` (expert) mesh axis inside shard_map, computed against the local
  expert shard, and returned.  Fixed shapes throughout (capacity-factor
  dropping), fully differentiable (scatter/gather + einsum only).

Aux losses: switch-style load-balance loss + router z-loss.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig, MoEConfig
from repro.models.layers import ParamDef, ParamTree


def moe_defs(cfg: ModelConfig) -> ParamTree:
    mo = cfg.moe
    d = cfg.d_model
    defs = {
        "router": ParamDef((d, mo.num_experts), (None, None), scale=0.1),
        "w_gate": ParamDef((mo.num_experts, d, mo.expert_d_ff), ("expert", "fsdp", None)),
        "w_up": ParamDef((mo.num_experts, d, mo.expert_d_ff), ("expert", "fsdp", None)),
        "w_down": ParamDef((mo.num_experts, mo.expert_d_ff, d), ("expert", None, "fsdp")),
    }
    if mo.num_shared_experts > 0:
        ff = mo.shared_d_ff * mo.num_shared_experts
        defs["shared"] = {
            "w_gate": ParamDef((d, ff), ("fsdp", None)),
            "w_up": ParamDef((d, ff), ("fsdp", None)),
            "w_down": ParamDef((ff, d), (None, "fsdp")),
        }
    return defs


def _router(params, x_flat, mo: MoEConfig):
    """x_flat: (T, d) -> weights (T,k), ids (T,k), aux_loss scalar."""
    logits = jnp.einsum("td,de->te", x_flat.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, mo.top_k)
    weights = weights / jnp.clip(jnp.sum(weights, axis=-1, keepdims=True), 1e-9)
    # load-balance loss (Switch): E * sum_e f_e * p_e
    e = mo.num_experts
    counts = jnp.zeros((e,), jnp.float32).at[ids.reshape(-1)].add(1.0)
    frac_tokens = counts / jnp.maximum(jnp.sum(counts), 1.0)
    frac_probs = jnp.mean(probs, axis=0)
    aux = mo.router_aux_loss_coef * e * jnp.sum(frac_tokens * frac_probs)
    aux = aux + mo.router_z_loss_coef * jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return weights, ids, aux


def _expert_ffn(w_gate, w_up, w_down, x, compute_dtype):
    """x: (E, C, d); weights: (E, d, ff)/(E, ff, d)."""
    g = jnp.einsum("ecd,edf->ecf", x, w_gate.astype(compute_dtype))
    u = jnp.einsum("ecd,edf->ecf", x, w_up.astype(compute_dtype))
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, w_down.astype(compute_dtype))


def _capacity_gather(x_flat, flat_ids, flat_w, num_buckets, capacity):
    """Scatter token copies into (num_buckets, capacity, d) buffers.

    Returns (buf, tok_idx, w_in_buf, bucket_pos) where invalid slots carry
    tok_idx == T (out-of-range, dropped on combine).
    """
    t, d = x_flat.shape
    n = flat_ids.shape[0]
    # position of each choice within its bucket (stable, order-of-arrival)
    onehot = jax.nn.one_hot(flat_ids, num_buckets, dtype=jnp.int32)     # (N, B)
    pos = jnp.cumsum(onehot, axis=0) - 1                                 # (N, B)
    pos = jnp.sum(pos * onehot, axis=1)                                  # (N,)
    valid = pos < capacity
    tok_of_choice = jnp.arange(n) // (n // t)                            # (N,)
    b_idx = jnp.where(valid, flat_ids, num_buckets)                      # drop
    p_idx = jnp.where(valid, pos, 0)
    buf = jnp.zeros((num_buckets, capacity, d), x_flat.dtype)
    buf = buf.at[b_idx, p_idx].set(x_flat[tok_of_choice], mode="drop")
    tok_idx = jnp.full((num_buckets, capacity), t, jnp.int32)
    tok_idx = tok_idx.at[b_idx, p_idx].set(tok_of_choice, mode="drop")
    w_buf = jnp.zeros((num_buckets, capacity), flat_w.dtype)
    w_buf = w_buf.at[b_idx, p_idx].set(flat_w, mode="drop")
    return buf, tok_idx, w_buf


def moe_ffn_dense(params, x, cfg: ModelConfig, compute_dtype=jnp.bfloat16):
    """Single-shard capacity-based MoE (smoke tests / reference)."""
    mo = cfg.moe
    b, s, d = x.shape
    x_flat = x.reshape(-1, d)
    t = x_flat.shape[0]
    weights, ids, aux = _router(params, x_flat, mo)
    flat_ids = ids.reshape(-1)
    flat_w = weights.reshape(-1).astype(compute_dtype)
    capacity = max(int(math.ceil(t * mo.top_k / mo.num_experts * mo.capacity_factor)), 4)
    buf, tok_idx, w_buf = _capacity_gather(x_flat, flat_ids, flat_w, mo.num_experts, capacity)
    y = _expert_ffn(params["w_gate"], params["w_up"], params["w_down"], buf, compute_dtype)
    y = y * w_buf[..., None]
    out = jnp.zeros((t + 1, d), y.dtype).at[tok_idx.reshape(-1)].add(y.reshape(-1, d), mode="drop")
    out = out[:t].reshape(b, s, d)
    if mo.num_shared_experts > 0:
        from repro.models.layers import dense_ffn
        out = out + dense_ffn(params["shared"], x, compute_dtype)
    return out, aux


def moe_ffn_ep_replicated(params, x, cfg: ModelConfig, axis_name: str = "model",
                          compute_dtype=jnp.bfloat16):
    """EP path for token sets *replicated* over the expert axis (decode).

    Each shard routes the full local token set but keeps only choices landing
    on its local experts; outputs are psum-combined over the expert axis.
    """
    mo = cfg.moe
    my_shard = jax.lax.axis_index(axis_name)
    e_loc = params["w_gate"].shape[0]
    b, s, d = x.shape
    x_flat = x.reshape(-1, d)
    t = x_flat.shape[0]
    weights, ids, aux = _router(params, x_flat, mo)
    flat_ids = ids.reshape(-1)
    flat_w = weights.reshape(-1).astype(compute_dtype)
    mine = (flat_ids // e_loc) == my_shard
    local_ids = jnp.where(mine, flat_ids % e_loc, e_loc)      # e_loc => dropped
    cap = max(int(math.ceil(t * mo.top_k / mo.num_experts * mo.capacity_factor)), 4)
    buf, tok_idx, w_buf = _capacity_gather(x_flat, local_ids, flat_w, e_loc, cap)
    y = _expert_ffn(params["w_gate"], params["w_up"], params["w_down"], buf, compute_dtype)
    y = y * w_buf[..., None]
    out = jnp.zeros((t + 1, d), y.dtype).at[tok_idx.reshape(-1)].add(
        y.reshape(-1, d), mode="drop")[:t]
    out = jax.lax.psum(out, axis_name)
    out = out.reshape(b, s, d)
    if mo.num_shared_experts > 0:
        from repro.models.layers import dense_ffn
        out = out + dense_ffn(params["shared"], x, compute_dtype)
    return out, aux


def moe_ffn_ep(params, x, cfg: ModelConfig, axis_name: str = "model",
               compute_dtype=jnp.bfloat16):
    """Expert-parallel MoE body. Must run inside shard_map.

    x: (B_loc, S_loc, d) — local token shard. Expert weights arrive as local
    shards (E_loc, d, ff). Router/shared weights are replicated.
    """
    mo = cfg.moe
    ways = jax.lax.axis_size(axis_name)
    e_loc = params["w_gate"].shape[0]          # local expert count
    b, s, d = x.shape
    x_flat = x.reshape(-1, d)
    t = x_flat.shape[0]

    weights, ids, aux = _router(params, x_flat, mo)
    flat_ids = ids.reshape(-1)
    flat_w = weights.reshape(-1).astype(compute_dtype)

    # --- dispatch to destination shards -----------------------------------
    dest = flat_ids // e_loc
    send_cap = max(int(math.ceil(t * mo.top_k / ways * mo.capacity_factor)), 4)
    send, tok_idx, w_send = _capacity_gather(x_flat, dest, flat_w, ways, send_cap)
    # carry local expert id alongside (drop slots get id 0, weight 0)
    le_buf = jnp.zeros((ways, send_cap), jnp.int32)
    onehot = jax.nn.one_hot(dest, ways, dtype=jnp.int32)
    pos = jnp.sum((jnp.cumsum(onehot, axis=0) - 1) * onehot, axis=1)
    valid = pos < send_cap
    bi = jnp.where(valid, dest, ways)
    pi = jnp.where(valid, pos, 0)
    le_buf = le_buf.at[bi, pi].set(flat_ids % e_loc, mode="drop")

    recv = jax.lax.all_to_all(send, axis_name, split_axis=0, concat_axis=0, tiled=True)
    le_recv = jax.lax.all_to_all(le_buf, axis_name, split_axis=0, concat_axis=0, tiled=True)
    w_recv = jax.lax.all_to_all(w_send, axis_name, split_axis=0, concat_axis=0, tiled=True)

    # --- local expert compute ----------------------------------------------
    r = ways * send_cap
    recv_flat = recv.reshape(r, d)
    le_flat = le_recv.reshape(r)
    w_flat = w_recv.reshape(r)
    # invalid slots have weight zero; bucket them anyway (harmless)
    cap2 = max(int(math.ceil(r / e_loc * mo.capacity_factor)), 4)
    ebuf, ridx, w_ebuf = _capacity_gather(recv_flat, le_flat, w_flat, e_loc, cap2)
    y = _expert_ffn(params["w_gate"], params["w_up"], params["w_down"], ebuf, compute_dtype)
    y = y * w_ebuf[..., None]
    # scatter back to recv layout, weighted
    y_recv = jnp.zeros((r + 1, d), y.dtype).at[ridx.reshape(-1)].add(
        y.reshape(-1, d), mode="drop")[:r]
    y_send = jax.lax.all_to_all(
        y_recv.reshape(ways, send_cap, d), axis_name, split_axis=0, concat_axis=0, tiled=True)

    # --- combine ------------------------------------------------------------
    out = jnp.zeros((t + 1, d), y_send.dtype).at[tok_idx.reshape(-1)].add(
        y_send.reshape(-1, d), mode="drop")[:t]
    out = out.reshape(b, s, d)
    if mo.num_shared_experts > 0:
        from repro.models.layers import dense_ffn
        out = out + dense_ffn(params["shared"], x, compute_dtype)
    return out, aux


def moe_block_sharded(params, x, cfg: ModelConfig, mesh, env,
                      compute_dtype=jnp.bfloat16):
    """shard_map wrapper: expert weights arrive as local shards; FSDP-sharded
    dims are re-gathered in compute dtype inside the body.

    Chooses the all-to-all path when the sequence dim is SP-sharded over the
    expert axis (train/prefill), else the replicated-token psum path (decode).
    """
    from jax.sharding import PartitionSpec as P

    model_ways = mesh.shape.get("model", 1)
    b, s, d = x.shape
    sp_ok = s % model_ways == 0 and s >= model_ways and s > 1
    batch_ax = env.batch if (b % max(_ways(mesh, env.batch), 1) == 0
                             and b >= _ways(mesh, env.batch)) else ()
    bspec = _axspec(batch_ax)
    x_spec = P(bspec, "model" if sp_ok else None, None)

    pspec = {
        "router": P(None, None),
        "w_gate": P("model", _axspec(env.fsdp), None),
        "w_up": P("model", _axspec(env.fsdp), None),
        "w_down": P("model", None, _axspec(env.fsdp)),
    }
    if "shared" in params:
        pspec["shared"] = {
            "w_gate": P(_axspec(env.fsdp), None),
            "w_up": P(_axspec(env.fsdp), None),
            "w_down": P(None, _axspec(env.fsdp)),
        }

    def body(params_l, x_l):
        # re-gather FSDP-sharded weight dims in compute dtype
        fs = env.fsdp
        pl = dict(params_l)
        if (not sp_ok) and model_ways > 1:
            # decode: keep weights sharded; activation-flow partial sums
            out, aux = moe_ffn_ep_replicated_dsharded(
                params_l, x_l, cfg, "model", tuple(fs), compute_dtype)
            vary = tuple(batch_ax) + tuple(fs)
            if vary:
                aux = jax.lax.pmean(aux, vary)
            return out, aux
        if fs:
            pl["w_gate"] = _gather(params_l["w_gate"].astype(compute_dtype), fs, 1)
            pl["w_up"] = _gather(params_l["w_up"].astype(compute_dtype), fs, 1)
            pl["w_down"] = _gather(params_l["w_down"].astype(compute_dtype), fs, 2)
            if "shared" in params_l:
                pl["shared"] = {
                    "w_gate": _gather(params_l["shared"]["w_gate"].astype(compute_dtype), fs, 0),
                    "w_up": _gather(params_l["shared"]["w_up"].astype(compute_dtype), fs, 0),
                    "w_down": _gather(params_l["shared"]["w_down"].astype(compute_dtype), fs, 1),
                }
        if sp_ok and model_ways > 1:
            out, aux = moe_ffn_ep(pl, x_l, cfg, "model", compute_dtype)
            vary = tuple(batch_ax) + ("model",)
        elif model_ways > 1:
            out, aux = moe_ffn_ep_replicated(pl, x_l, cfg, "model", compute_dtype)
            vary = tuple(batch_ax)           # tokens replicated over model
        else:
            out, aux = moe_ffn_dense(pl, x_l, cfg, compute_dtype)
            vary = tuple(batch_ax)
        if vary:
            aux = jax.lax.pmean(aux, vary)
        return out, aux

    fn = jax.shard_map(body, mesh=mesh, in_specs=(pspec, x_spec),
                       out_specs=(x_spec, P()))
    return fn(params, x)


def _ways(mesh, axes):
    n = 1
    for a in axes:
        n *= mesh.shape.get(a, 1)
    return n


def _axspec(axes):
    if not axes:
        return None
    return axes[0] if len(axes) == 1 else tuple(axes)


def _gather(x, axes, dim):
    for a in reversed(axes):
        x = jax.lax.all_gather(x, a, axis=dim, tiled=True)
    return x


def moe_ffn_ep_replicated_dsharded(params, x, cfg: ModelConfig, axis_name,
                                   fsdp_axes, compute_dtype=jnp.bfloat16):
    """Decode-path EP without weight gathers (activation-flow partials).

    Expert weights stay FSDP-sharded on the d_model dim; each shard computes
    a partial matmul on its d-slice of the (few) decode tokens and partial
    sums are combined with psum — moving KB of activations instead of GB of
    weights per layer per step.  §Perf hillclimb for the decode cells.
    """
    mo = cfg.moe
    my_shard = jax.lax.axis_index(axis_name)
    e_loc, d_loc = params["w_gate"].shape[0], params["w_gate"].shape[1]
    b, s, d = x.shape
    x_flat = x.reshape(-1, d)
    t = x_flat.shape[0]
    weights, ids, aux = _router(params, x_flat, mo)
    flat_ids = ids.reshape(-1)
    flat_w = weights.reshape(-1).astype(compute_dtype)
    mine = (flat_ids // e_loc) == my_shard
    local_ids = jnp.where(mine, flat_ids % e_loc, e_loc)
    cap = max(int(math.ceil(t * mo.top_k / mo.num_experts * mo.capacity_factor)), 4)

    # flattened fsdp shard index and this shard's d-slice of the tokens
    fi = jnp.zeros((), jnp.int32)
    for a in fsdp_axes:
        fi = fi * jax.lax.axis_size(a) + jax.lax.axis_index(a)
    x_loc = jax.lax.dynamic_slice_in_dim(x_flat, fi * d_loc, d_loc, axis=1)

    buf, tok_idx, w_buf = _capacity_gather(x_loc.astype(compute_dtype),
                                           local_ids, flat_w, e_loc, cap)
    g = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"].astype(compute_dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, params["w_up"].astype(compute_dtype))
    if fsdp_axes:
        gu = jax.lax.psum(jnp.stack([g, u]), fsdp_axes)   # one fused psum
        g, u = gu[0], gu[1]
    h = jax.nn.silu(g) * u
    y = jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(compute_dtype))
    y = y * w_buf[..., None]                              # (E_loc, cap, d_loc)
    out_loc = jnp.zeros((t + 1, d_loc), y.dtype).at[tok_idx.reshape(-1)].add(
        y.reshape(-1, d_loc), mode="drop")[:t]
    out_loc = jax.lax.psum(out_loc, axis_name)            # combine experts

    if mo.num_shared_experts > 0:
        sh = params["shared"]
        gs = jnp.einsum("td,df->tf", x_loc.astype(compute_dtype),
                        sh["w_gate"].astype(compute_dtype))
        us = jnp.einsum("td,df->tf", x_loc.astype(compute_dtype),
                        sh["w_up"].astype(compute_dtype))
        if fsdp_axes:
            gus = jax.lax.psum(jnp.stack([gs, us]), fsdp_axes)
            gs, us = gus[0], gus[1]
        ys = jnp.einsum("tf,fd->td", jax.nn.silu(gs) * us,
                        sh["w_down"].astype(compute_dtype))
        out_loc = out_loc + ys
    if fsdp_axes:
        out = out_loc
        for a in reversed(fsdp_axes):
            out = jax.lax.all_gather(out, a, axis=1, tiled=True)
    else:
        out = out_loc
    return out.reshape(b, s, d), aux
