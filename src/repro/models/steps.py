"""Step functions: loss / train_step / prefill_step / decode_step.

These are the programs lowered by the dry-run and launched by the trainers.
They are pure functions of (params, opt_state, batch) so pjit handles all
distribution via the spec trees from ``transformer``.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig, TrainConfig
from repro.models import transformer
from repro.optim import adamw


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Token-mean CE. logits: (B,S,V) f32, labels: (B,S) int32 (-1 = pad)."""
    mask = labels >= 0
    safe = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (logz - ll) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1)


def loss_fn(params, batch, cfg: ModelConfig, *, use_ep=False, mesh=None,
            sp_constraint=None):
    logits, _, aux = transformer.forward(
        params, batch["tokens"], cfg,
        memory=batch.get("memory"), use_ep=use_ep, mesh=mesh,
        sp_constraint=sp_constraint)
    ce = cross_entropy(logits, batch["labels"])
    return ce + aux, {"ce": ce, "aux": aux}


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig, *, use_ep=False,
                    mesh=None, sp_constraint=None, donate=True):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    Supports gradient accumulation (tcfg.microbatch) and gradient compression
    of the cross-shard payload (tcfg.grad_compression) — with pjit, gradients
    are reduced automatically; compression is applied pre-update so the
    mean-reduce payload is the compressed dtype.
    """
    lr_fn = adamw.cosine_schedule(tcfg)
    bf_grads = tcfg.grads_dtype == "bfloat16"

    def fwd(params, batch):
        return loss_fn(params, batch, cfg, use_ep=use_ep, mesh=mesh,
                       sp_constraint=sp_constraint)

    def grad_fn(params, batch):
        """value_and_grad; with grads_dtype=bfloat16 the differentiated tree
        is a bf16 copy so cross-shard cotangent reductions move bf16."""
        if not bf_grads:
            return jax.value_and_grad(fwd, has_aux=True)(params, batch)
        cast = jax.tree.map(
            lambda p: p.astype(jnp.bfloat16)
            if p.ndim >= 2 and p.dtype == jnp.float32 else p, params)
        out, grads = jax.value_and_grad(fwd, has_aux=True)(cast, batch)
        return out, grads

    def train_step(params, opt_state, batch):
        if tcfg.microbatch and tcfg.microbatch < batch["tokens"].shape[0]:
            nmb = batch["tokens"].shape[0] // tcfg.microbatch

            def mb(i):
                sl = jax.tree.map(
                    lambda x: jax.lax.dynamic_slice_in_dim(
                        x, i * tcfg.microbatch, tcfg.microbatch, 0), batch)
                return grad_fn(params, sl)

            def body(carry, i):
                (loss_a, met_a), g_a = carry
                (loss, met), g = mb(i)
                g_sum = jax.tree.map(jnp.add, g_a, g)
                return ((loss_a + loss, jax.tree.map(jnp.add, met_a, met)), g_sum), None

            (loss0, met0), g0 = mb(0)
            ((loss_t, met_t), g_t), _ = jax.lax.scan(
                body, ((loss0, met0), g0), jnp.arange(1, nmb))
            loss = loss_t / nmb
            metrics = jax.tree.map(lambda x: x / nmb, met_t)
            grads = jax.tree.map(lambda g: g / nmb, g_t)
        else:
            (loss, metrics), grads = grad_fn(params, batch)

        if tcfg.grad_compression != "none":
            payload, deq = adamw.compress_grads(grads, tcfg.grad_compression)
            grads = deq(payload)
        params2, opt2, opt_metrics = adamw.adamw_update(params, grads, opt_state, tcfg, lr_fn)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return params2, opt2, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, capacity: int, *, use_ep=False, mesh=None,
                      sp_constraint=None):
    """prefill_step(params, tokens[, memory]) -> (state, last_logits)."""

    def prefill_step(params, tokens, memory=None, valid_from=None, positions=None):
        b = tokens.shape[0]
        state = transformer.init_state(cfg, b, capacity)
        logits, new_state, _ = transformer.forward(
            params, tokens, cfg, state=state, memory=memory,
            use_ep=use_ep, mesh=mesh, sp_constraint=sp_constraint,
            valid_from=valid_from, positions=positions)
        return new_state, logits[:, -1]

    return prefill_step


def make_decode_step(cfg: ModelConfig, *, use_ep=False, mesh=None):
    """decode_step(params, state, token, pos) -> (state, logits).

    token: (B,1) int32 or (B,1,F) frontend embeds; pos: (B,1) positions.
    """

    def decode_step(params, state, token, pos, valid_from=None):
        logits, new_state, _ = transformer.forward(
            params, token, cfg, positions=pos, state=state,
            use_ep=use_ep, mesh=mesh, valid_from=valid_from)
        return new_state, logits[:, -1]

    return decode_step


def greedy_sample(logits: jnp.ndarray) -> jnp.ndarray:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)
