"""Core layer primitives: parameter definitions, norms, FFNs, embeddings.

Parameters are declared once as ``ParamDef`` (shape + logical dim names +
initializer); the same declaration yields (a) materialized weights, (b) a
matching PartitionSpec tree for pjit, and (c) exact parameter counts via
``jax.eval_shape`` — no dual bookkeeping.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.mesh import AxisEnv


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple
    logical: tuple              # logical dim names, same length as shape
    init: str = "normal"        # normal | zeros | ones | scaled_normal
    scale: float = 1.0
    dtype: str = "float32"

    def initialize(self, key) -> jnp.ndarray:
        if self.init == "zeros":
            return jnp.zeros(self.shape, self.dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, self.dtype)
        fan_in = self.shape[0] if len(self.shape) > 1 else max(self.shape[0], 1)
        std = self.scale / math.sqrt(fan_in)
        return (jax.random.normal(key, self.shape) * std).astype(self.dtype)


ParamTree = dict  # nested dict of ParamDef / arrays


def init_tree(defs: ParamTree, key) -> ParamTree:
    """Materialize a tree of ParamDef into arrays with per-leaf keys."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=lambda x: isinstance(x, ParamDef))
    keys = jax.random.split(key, len(leaves))
    vals = [d.initialize(k) for d, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def spec_tree(defs: ParamTree, env: AxisEnv) -> ParamTree:
    return jax.tree.map(
        lambda d: env.resolve(d.logical),
        defs,
        is_leaf=lambda x: isinstance(x, ParamDef),
    )


def abstract_tree(defs: ParamTree) -> ParamTree:
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype),
        defs,
        is_leaf=lambda x: isinstance(x, ParamDef),
    )


def count_tree(defs: ParamTree) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=lambda x: isinstance(x, ParamDef))
    return int(sum(int(np.prod(d.shape)) for d in leaves))


def stack_defs(defs: ParamTree, n: int) -> ParamTree:
    """Prepend a scan (layers) dim to every ParamDef in the tree."""
    return jax.tree.map(
        lambda d: ParamDef((n,) + d.shape, (None,) + d.logical, d.init, d.scale, d.dtype),
        defs,
        is_leaf=lambda x: isinstance(x, ParamDef),
    )


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)).astype(dt)


def swiglu(x: jnp.ndarray, w_gate, w_up, w_down, compute_dtype) -> jnp.ndarray:
    g = jnp.einsum("...d,df->...f", x, w_gate.astype(compute_dtype))
    u = jnp.einsum("...d,df->...f", x, w_up.astype(compute_dtype))
    h = jax.nn.silu(g) * u
    return jnp.einsum("...f,fd->...d", h, w_down.astype(compute_dtype))


def dense_ffn_defs(d_model: int, d_ff: int) -> ParamTree:
    return {
        "w_gate": ParamDef((d_model, d_ff), ("fsdp", "tp")),
        "w_up": ParamDef((d_model, d_ff), ("fsdp", "tp")),
        "w_down": ParamDef((d_ff, d_model), ("tp", "fsdp")),
    }


def dense_ffn(params, x, compute_dtype) -> jnp.ndarray:
    return swiglu(x, params["w_gate"], params["w_up"], params["w_down"], compute_dtype)


def embedding_defs(vocab: int, d_model: int) -> ParamTree:
    return {"embedding": ParamDef((vocab, d_model), ("tp", "fsdp"), scale=1.0)}


def softcap(logits: jnp.ndarray, cap: float) -> jnp.ndarray:
    if cap <= 0.0:
        return logits
    return cap * jnp.tanh(logits / cap)
