"""State-space / recurrent blocks: Mamba2 (chunked SSD), xLSTM (mLSTM, sLSTM).

Training/prefill uses the chunked-parallel forms (quadratic only within a
fixed ``chunk``, linear across chunks via ``lax.scan``); decode uses O(1)
recurrent state updates — these are the sub-quadratic paths that make the
``long_500k`` cell runnable.

All state math in float32; projections in the model compute dtype.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.models.layers import ParamDef, ParamTree, rms_norm


# ---------------------------------------------------------------------------
# Mamba2 (SSD), single B/C group
# ---------------------------------------------------------------------------

def _mamba_dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nh = s.num_heads or (d_in // s.head_dim)
    return d_in, nh, s.head_dim, s.state_dim


def mamba2_defs(cfg: ModelConfig) -> ParamTree:
    s = cfg.ssm
    d = cfg.d_model
    d_in, nh, p, n = _mamba_dims(cfg)
    conv_ch = d_in + 2 * n
    return {
        "in_proj": ParamDef((d, 2 * d_in + 2 * n + nh), ("fsdp", "tp")),
        "conv_w": ParamDef((s.conv_dim, conv_ch), (None, "tp"), scale=0.5),
        "conv_b": ParamDef((conv_ch,), ("tp",), init="zeros"),
        "A_log": ParamDef((nh,), (None,), init="ones"),
        "D": ParamDef((nh,), (None,), init="ones"),
        "dt_bias": ParamDef((nh,), (None,), init="zeros"),
        "norm": ParamDef((d_in,), ("tp",), init="ones"),
        "out_proj": ParamDef((d_in, d), ("tp", "fsdp")),
    }


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv. x: (B,S,C), w: (K,C). state: (B,K-1,C) or None."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(k))
    new_state = xp[:, -(k - 1) :, :] if k > 1 else pad[:, :0]
    return out + b[None, None, :], new_state


def _ssd_chunked(xh, dt, a_log, bmat, cmat, chunk, h0=None):
    """Chunked SSD scan.

    xh: (B,S,H,P) dt: (B,S,H) bmat/cmat: (B,S,N). Returns y (B,S,H,P), h_last
    (B,H,N,P).
    """
    b, s, h, p = xh.shape
    n = bmat.shape[-1]
    nc = s // chunk
    c = chunk
    f32 = jnp.float32
    xh = xh.astype(f32).reshape(b, nc, c, h, p)
    dt = dt.astype(f32).reshape(b, nc, c, h)
    bm = bmat.astype(f32).reshape(b, nc, c, n)
    cm = cmat.astype(f32).reshape(b, nc, c, n)
    a = -jnp.exp(a_log.astype(f32))                     # (H,) negative
    da = dt * a[None, None, None, :]                    # (B,nc,c,H) log-decay
    cum = jnp.cumsum(da, axis=2)                        # inclusive cumsum
    # intra-chunk: L[i,j] = exp(cum_i - cum_j) for i >= j (uses decay after j)
    li = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,c_i,c_j,H)
    mask = jnp.tril(jnp.ones((c, c), bool))
    li = jnp.where(mask[None, None, :, :, None], li, -jnp.inf)
    lmat = jnp.exp(li)
    scores = jnp.einsum("bkin,bkjn->bkij", cm, bm)      # (B,nc,c,c)
    wdt = dt                                             # input scaled by dt
    y_intra = jnp.einsum("bkij,bkijh,bkjh,bkjhp->bkihp", scores, lmat, wdt, xh)
    # chunk end states
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)      # (B,nc,c,H)
    state_k = jnp.einsum("bkch,bkch,bkcn,bkchp->bkhnp", decay_to_end, wdt, bm, xh)
    chunk_decay = jnp.exp(cum[:, :, -1, :])              # (B,nc,H)

    def step(h_prev, inp):
        st, dec = inp                                    # (B,H,N,P), (B,H)
        h_new = h_prev * dec[:, :, None, None] + st
        return h_new, h_prev

    init = jnp.zeros((b, h, n, p), f32) if h0 is None else h0.astype(f32)
    h_last, h_prevs = jax.lax.scan(
        step,
        init,
        (jnp.moveaxis(state_k, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                # (B,nc,H,N,P)
    y_inter = jnp.einsum("bkcn,bkhnp,bkch->bkchp", cm, h_prevs, jnp.exp(cum))
    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y, h_last


def mamba2_forward(params, x, cfg: ModelConfig, state=None, compute_dtype=jnp.bfloat16,
                   valid=None):
    """x: (B,S,d). state: None (train/prefill from zero) or dict for decode.

    state = {"conv": (B,K-1,C), "ssm": (B,H,N,P)}; decode requires S small
    (typically 1) and uses the recurrent update.  valid: optional (B,S) bool
    — invalid (left-pad) steps are identity in the recurrence (dt = 0).
    """
    s_cfg = cfg.ssm
    d_in, nh, p, n = _mamba_dims(cfg)
    bsz, seq, _ = x.shape
    proj = jnp.einsum("bsd,de->bse", x, params["in_proj"].astype(compute_dtype))
    z, xr, bmat, cmat, dt = jnp.split(
        proj, [d_in, 2 * d_in, 2 * d_in + n, 2 * d_in + 2 * n], axis=-1)
    conv_in = jnp.concatenate([xr, bmat, cmat], axis=-1)
    if valid is not None:
        # zero pads so conv windows see exactly the zero-init boundary
        conv_in = conv_in * valid.astype(conv_in.dtype)[:, :, None]
    conv_state = None if state is None else state["conv"]
    conv_out, new_conv = _causal_conv(conv_in, params["conv_w"].astype(compute_dtype),
                                      params["conv_b"].astype(compute_dtype), conv_state)
    conv_out = jax.nn.silu(conv_out)
    xr = conv_out[..., :d_in]
    bmat = conv_out[..., d_in : d_in + n]
    cmat = conv_out[..., d_in + n :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    if valid is not None:
        dt = dt * valid.astype(jnp.float32)[:, :, None]
    xh = xr.reshape(bsz, seq, nh, p)

    if state is None or seq > 1:
        h0 = None if state is None else state["ssm"]
        pad = (-seq) % s_cfg.chunk
        if pad:
            xh_p = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            b_p = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
            c_p = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
        else:
            xh_p, dt_p, b_p, c_p = xh, dt, bmat, cmat
        y, h_last = _ssd_chunked(xh_p, dt_p, params["A_log"], b_p, c_p, s_cfg.chunk, h0)
        y = y[:, :seq]
    else:
        # recurrent single step
        h = state["ssm"].astype(jnp.float32)             # (B,H,N,P)
        a = -jnp.exp(params["A_log"].astype(jnp.float32))
        da = jnp.exp(dt[:, 0] * a[None, :])              # (B,H)
        upd = jnp.einsum("bh,bn,bhp->bhnp", dt[:, 0], bmat[:, 0].astype(jnp.float32),
                         xh[:, 0].astype(jnp.float32))
        h_last = h * da[:, :, None, None] + upd
        y = jnp.einsum("bn,bhnp->bhp", cmat[:, 0].astype(jnp.float32), h_last)[:, None]

    y = y + params["D"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(bsz, seq, d_in).astype(compute_dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(compute_dtype))
    new_state = {"conv": new_conv.astype(jnp.float32), "ssm": h_last}
    return out, new_state


def mamba2_state_defs(cfg: ModelConfig, batch: int) -> dict:
    s = cfg.ssm
    d_in, nh, p, n = _mamba_dims(cfg)
    return {
        "conv": ((batch, s.conv_dim - 1, d_in + 2 * n), ("batch", None, "tp"), "float32"),
        "ssm": ((batch, nh, n, p), ("batch", "tp", None, None), "float32"),
    }


# ---------------------------------------------------------------------------
# xLSTM — mLSTM (matrix memory, chunked parallel) and sLSTM (scan)
# ---------------------------------------------------------------------------

def _xlstm_dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nh = cfg.num_heads
    p = d_in // nh
    return d_in, nh, p


def mlstm_defs(cfg: ModelConfig) -> ParamTree:
    d = cfg.d_model
    d_in, nh, p = _xlstm_dims(cfg)
    return {
        "w_up": ParamDef((d, 2 * d_in), ("fsdp", "tp")),
        "w_q": ParamDef((d_in, d_in), ("fsdp", "tp")),
        "w_k": ParamDef((d_in, d_in), ("fsdp", "tp")),
        "w_v": ParamDef((d_in, d_in), ("fsdp", "tp")),
        "w_if": ParamDef((d_in, 2 * nh), ("tp", None), scale=0.1),
        "b_if": ParamDef((2 * nh,), (None,), init="zeros"),
        "skip": ParamDef((d_in,), ("tp",), init="ones"),
        "norm": ParamDef((d_in,), ("tp",), init="ones"),
        "w_down": ParamDef((d_in, d), ("tp", "fsdp")),
    }


def _mlstm_chunked(q, k, v, ig, fg, chunk, state=None):
    """Stabilized chunked mLSTM.

    q,k,v: (B,S,H,P); ig/fg raw gate pre-activations (B,S,H).
    Returns y (B,S,H,P), state dict {"C": (B,H,P,P), "n": (B,H,P), "m": (B,H)}.
    """
    b, s, h, p = q.shape
    nc, c = s // chunk, chunk
    f32 = jnp.float32
    q, k, v = (t.astype(f32).reshape(b, nc, c, h, p) for t in (q, k, v))
    logf = jax.nn.log_sigmoid(fg.astype(f32)).reshape(b, nc, c, h)
    logi = ig.astype(f32).reshape(b, nc, c, h)
    cf = jnp.cumsum(logf, axis=2)                       # inclusive
    # intra-chunk log weights: D[i,j] = cf_i - cf_j + logi_j  (i >= j)
    dmat = cf[:, :, :, None, :] - cf[:, :, None, :, :] + logi[:, :, None, :, :]
    mask = jnp.tril(jnp.ones((c, c), bool))
    dmat = jnp.where(mask[None, None, :, :, None], dmat, -jnp.inf)
    # stabilizer per (b,k,i,h)
    m_intra = jnp.max(dmat, axis=3)                      # (B,nc,c,H)
    # inter-chunk carried state
    if state is None:
        c0 = jnp.zeros((b, h, p, p), f32)
        n0 = jnp.zeros((b, h, p), f32)
        m0 = jnp.full((b, h), -jnp.inf, f32)
    else:
        c0, n0, m0 = state["C"].astype(f32), state["n"].astype(f32), state["m"].astype(f32)

    # per-chunk summaries for the recurrence
    decay_to_end = cf[:, :, -1:, :] - cf + logi          # (B,nc,c,H) weight of j into end-state
    m_loc = jnp.max(decay_to_end, axis=2)                # (B,nc,H)

    def step(carry, inp):
        c_prev, n_prev, m_prev = carry
        kv_k, kn_k, dec_k, mloc_k = inp
        # dec_k: (B,H) total log decay of chunk; mloc_k: (B,H) local max
        m_new = jnp.maximum(m_prev + dec_k, mloc_k)
        scale_old = jnp.exp(m_prev + dec_k - m_new)[:, :, None]
        scale_loc = jnp.exp(mloc_k - m_new)[:, :, None]
        c_new = c_prev * scale_old[..., None] + kv_k * scale_loc[..., None]
        n_new = n_prev * scale_old + kn_k * scale_loc
        return (c_new, n_new, m_new), (c_prev, n_prev, m_prev)

    w_end = jnp.exp(decay_to_end - m_loc[:, :, None, :])             # (B,nc,c,H)
    kv = jnp.einsum("bkch,bkchp,bkchq->bkhpq", w_end, k, v)          # (B,nc,H,P,P)
    kn = jnp.einsum("bkch,bkchp->bkhp", w_end, k)
    dec = cf[:, :, -1, :]
    (c_l, n_l, m_l), (c_prevs, n_prevs, m_prevs) = jax.lax.scan(
        step, (c0, n0, m0),
        (jnp.moveaxis(kv, 1, 0), jnp.moveaxis(kn, 1, 0),
         jnp.moveaxis(dec, 1, 0), jnp.moveaxis(m_loc, 1, 0)))
    c_prevs = jnp.moveaxis(c_prevs, 0, 1)                # (B,nc,H,P,P)
    n_prevs = jnp.moveaxis(n_prevs, 0, 1)
    m_prevs = jnp.moveaxis(m_prevs, 0, 1)                # (B,nc,H)

    # combine intra + inter with joint stabilizer
    m_inter = m_prevs[:, :, None, :] + cf                # (B,nc,c,H)
    m_tot = jnp.maximum(jnp.maximum(m_intra, m_inter), -1e30)
    w_intra = jnp.exp(dmat - m_tot[:, :, :, None, :])    # (B,nc,c,c,H)
    w_inter = jnp.exp(m_inter - m_tot)                   # (B,nc,c,H)
    qs = q / math.sqrt(p)
    scores = jnp.einsum("bkihp,bkjhp->bkijh", qs, k)
    y_intra = jnp.einsum("bkijh,bkijh,bkjhq->bkihq", scores, w_intra, v)
    den_intra = jnp.einsum("bkijh,bkijh->bkih", scores, w_intra)
    y_inter = jnp.einsum("bkchp,bkhpq,bkch->bkchq", qs, c_prevs, w_inter)
    den_inter = jnp.einsum("bkchp,bkhp,bkch->bkch", qs, n_prevs, w_inter)
    den = jnp.maximum(jnp.abs(den_intra + den_inter), jnp.exp(-m_tot))
    y = (y_intra + y_inter) / den[..., None]
    y = y.reshape(b, s, h, p)
    return y, {"C": c_l, "n": n_l, "m": m_l}


def _mlstm_step(q, k, v, ig, fg, state):
    """Single-token stabilized mLSTM update. q,k,v: (B,H,P); ig/fg: (B,H)."""
    f32 = jnp.float32
    q, k, v = q.astype(f32), k.astype(f32), v.astype(f32)
    p = q.shape[-1]
    logf = jax.nn.log_sigmoid(fg.astype(f32))
    logi = ig.astype(f32)
    c_p, n_p, m_p = state["C"].astype(f32), state["n"].astype(f32), state["m"].astype(f32)
    m_new = jnp.maximum(logf + m_p, logi)
    sf = jnp.exp(logf + m_p - m_new)[..., None]
    si = jnp.exp(logi - m_new)[..., None]
    c_new = c_p * sf[..., None] + si[..., None] * (k[..., :, None] * v[..., None, :])
    n_new = n_p * sf + si * k
    qs = q / math.sqrt(p)
    num = jnp.einsum("bhp,bhpq->bhq", qs, c_new)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhp,bhp->bh", qs, n_new)), jnp.exp(-m_new))
    y = num / den[..., None]
    return y, {"C": c_new, "n": n_new, "m": m_new}


def mlstm_forward(params, x, cfg: ModelConfig, state=None, compute_dtype=jnp.bfloat16,
                  valid=None):
    """mLSTM block. x: (B,S,d). valid: (B,S) bool — pads are identity."""
    d_in, nh, p = _xlstm_dims(cfg)
    b, s, _ = x.shape
    up = jnp.einsum("bsd,de->bse", x, params["w_up"].astype(compute_dtype))
    xm, z = jnp.split(up, 2, axis=-1)
    q = jnp.einsum("bse,ef->bsf", xm, params["w_q"].astype(compute_dtype)).reshape(b, s, nh, p)
    k = jnp.einsum("bse,ef->bsf", xm, params["w_k"].astype(compute_dtype)).reshape(b, s, nh, p)
    v = jnp.einsum("bse,ef->bsf", xm, params["w_v"].astype(compute_dtype)).reshape(b, s, nh, p)
    gates = jnp.einsum("bse,eg->bsg", xm, params["w_if"].astype(compute_dtype))
    gates = gates.astype(jnp.float32) + params["b_if"].astype(jnp.float32)
    ig, fg = gates[..., :nh], gates[..., nh:]
    if valid is not None:
        vmask = valid.astype(jnp.float32)[:, :, None]
        ig = jnp.where(vmask > 0, ig, -1e30)     # no input at pads
        fg = jnp.where(vmask > 0, fg, 30.0)      # no decay at pads

    if s == 1 and state is not None:
        y, new_state = _mlstm_step(q[:, 0], k[:, 0], v[:, 0], ig[:, 0], fg[:, 0], state)
        y = y[:, None]
    else:
        chunk = min(cfg.ssm.chunk if cfg.ssm else 256, s)
        pad = (-s) % chunk
        if pad:
            q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            # pads: i -> 0 (no input), f -> 1 (no decay of carried state)
            ig = jnp.pad(ig, ((0, 0), (0, pad), (0, 0)), constant_values=-1e30)
            fg = jnp.pad(fg, ((0, 0), (0, pad), (0, 0)), constant_values=30.0)
        y, new_state = _mlstm_chunked(q, k, v, ig, fg, chunk, state)
        y = y[:, :s]
    y = y.reshape(b, s, d_in).astype(compute_dtype)
    y = y + params["skip"].astype(compute_dtype)[None, None, :] * xm
    y = rms_norm(y, params["norm"], cfg.norm_eps)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, params["w_down"].astype(compute_dtype))
    return out, new_state


def mlstm_state_defs(cfg: ModelConfig, batch: int) -> dict:
    _, nh, p = _xlstm_dims(cfg)
    return {
        "C": ((batch, nh, p, p), ("batch", None, "tp", None), "float32"),
        "n": ((batch, nh, p), ("batch", None, "tp"), "float32"),
        "m": ((batch, nh), ("batch", None), "float32"),
    }


# ---------------------------------------------------------------------------
# sLSTM — scalar-memory recurrent block with exponential gating
# ---------------------------------------------------------------------------

def slstm_defs(cfg: ModelConfig) -> ParamTree:
    d = cfg.d_model
    nh = cfg.num_heads
    p = d // nh
    return {
        # tp on head_dim (always divisible), not on the small head count
        "w_in": ParamDef((d, 4, nh, p), ("fsdp", None, None, "tp")),
        "r": ParamDef((nh, p, 4, p), (None, "tp", None, None), scale=0.5),
        "b": ParamDef((4, nh, p), (None, None, "tp"), init="zeros"),
        "norm": ParamDef((d,), (None,), init="ones"),
        "w_out": ParamDef((d, d), ("fsdp", "tp")),
    }


def slstm_forward(params, x, cfg: ModelConfig, state=None, compute_dtype=jnp.bfloat16,
                  valid=None):
    """sLSTM block, sequential scan over time. x: (B,S,d)."""
    d = cfg.d_model
    nh = cfg.num_heads
    p = d // nh
    b, s, _ = x.shape
    f32 = jnp.float32
    wx = jnp.einsum("bsd,dghp->bsghp", x, params["w_in"].astype(compute_dtype)).astype(f32)
    wx = wx + params["b"].astype(f32)[None, None]
    r = params["r"].astype(f32)
    valid_t = (jnp.ones((b, s), bool) if valid is None else valid.astype(bool))

    if state is None:
        h0 = jnp.zeros((b, nh, p), f32)
        c0 = jnp.zeros((b, nh, p), f32)
        n0 = jnp.ones((b, nh, p), f32)
        m0 = jnp.zeros((b, nh, p), f32)
    else:
        h0, c0, n0, m0 = (state[k].astype(f32) for k in ("h", "c", "n", "m"))

    def step(carry, inp):
        wx_t, v_t = inp
        h, c, n, m = carry
        rec = jnp.einsum("bhp,hpgq->bghq", h, r)
        g = wx_t + rec                                    # (B,4,H,P)
        zt = jnp.tanh(g[:, 0])
        it = g[:, 1]
        ft = g[:, 2]
        ot = jax.nn.sigmoid(g[:, 3])
        logf = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(logf + m, it)
        i_s = jnp.exp(it - m_new)
        f_s = jnp.exp(logf + m - m_new)
        c_new = f_s * c + i_s * zt
        n_new = jnp.maximum(f_s * n + i_s, 1e-6)
        h_new = ot * c_new / n_new
        vm = v_t[:, None, None]                           # (B,1,1) pad carry-through
        out = (jnp.where(vm, h_new, h), jnp.where(vm, c_new, c),
               jnp.where(vm, n_new, n), jnp.where(vm, m_new, m))
        return out, out[0]

    (h_l, c_l, n_l, m_l), hs = jax.lax.scan(
        step, (h0, c0, n0, m0),
        (jnp.moveaxis(wx, 1, 0), jnp.moveaxis(valid_t, 1, 0)))
    y = jnp.moveaxis(hs, 0, 1).reshape(b, s, d).astype(compute_dtype)
    y = rms_norm(y, params["norm"], cfg.norm_eps)
    out = jnp.einsum("bsd,de->bse", y, params["w_out"].astype(compute_dtype))
    new_state = {"h": h_l, "c": c_l, "n": n_l, "m": m_l}
    return out, new_state


def slstm_state_defs(cfg: ModelConfig, batch: int) -> dict:
    nh = cfg.num_heads
    p = cfg.d_model // nh
    sd = ((batch, nh, p), ("batch", None, "tp"), "float32")
    return {"h": sd, "c": sd, "n": sd, "m": sd}
