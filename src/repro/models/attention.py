"""Attention blocks: GQA (+RoPE), MLA (DeepSeek-V2 latent attention), cross-attn.

All variants support three execution modes used by the launchers:
  * train/prefill: full-sequence causal attention, returns updated cache
  * decode: single-token query against a fixed-capacity KV cache

MLA keeps the paper-faithful (naive) path — materialize per-head K/V from the
latent — and an ``absorb`` decode path (weight absorption: score against the
512-dim latent cache directly), which is one of the beyond-paper perf levers
recorded in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.models.layers import ParamDef, ParamTree


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, D); positions: (B, S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                     # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, D/2)
    cos = jnp.cos(angles)[..., None, :]              # (B, S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def gqa_defs(cfg: ModelConfig) -> ParamTree:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    return {
        "wq": ParamDef((d, cfg.num_heads, hd), ("fsdp", "tp", None)),
        "wk": ParamDef((d, cfg.num_kv_heads, hd), ("fsdp", "tp", None)),
        "wv": ParamDef((d, cfg.num_kv_heads, hd), ("fsdp", "tp", None)),
        "wo": ParamDef((cfg.num_heads, hd, d), ("tp", None, "fsdp")),
    }


def _sdpa(q, k, v, *, causal: bool, q_offset, softcap: float = 0.0,
          valid_from=None, scores_dtype=None):
    """q: (B,S,H,D) k/v: (B,T,Hkv,D) with H = G*Hkv. Grouped causal attention.

    q_offset: scalar position offset of q[.,0] relative to k[.,0] (decode).
    valid_from: optional (B,) int32 — cache/key positions < valid_from[b]
    are masked (left-padded serving batches).
    """
    b, s, h, d = q.shape
    t, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    q = q.reshape(b, s, hkv, g, d)
    sdt = scores_dtype or jnp.float32
    scores = jnp.einsum("bshgd,bthd->bhgst", q.astype(jnp.float32),
                        k.astype(jnp.float32))
    scores = scores / math.sqrt(d)
    if softcap > 0.0:
        scores = softcap * jnp.tanh(scores / softcap)
    if causal:
        qpos = jnp.arange(s) + q_offset
        kpos = jnp.arange(t)
        mask = (kpos[None, :] <= qpos[:, None])[None]            # (1, S, T)
        if valid_from is not None:
            mask = mask & (kpos[None, None, :] >= valid_from[:, None, None])
        scores = jnp.where(mask[:, None, None], scores, -1e30)
    # probs materialized in sdt (bf16 halves one of the two S^2 planes)
    probs = jax.nn.softmax(scores, axis=-1).astype(sdt)
    out = jnp.einsum("bhgst,bthd->bshgd", probs, v.astype(sdt))
    return out.reshape(b, s, h, d).astype(v.dtype)


def gqa_attention(
    params: ParamTree,
    x: jnp.ndarray,                      # (B, S, D)
    positions: jnp.ndarray,              # (B, S)
    cfg: ModelConfig,
    cache: Optional[dict] = None,        # {"k","v": (B, T, Hkv, hd), "index": scalar}
    compute_dtype=jnp.bfloat16,
    valid_from=None,
):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(compute_dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(compute_dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(compute_dtype))
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    sdt = jnp.dtype(cfg.attn_scores_dtype)
    new_cache = None
    if cache is None:
        out = _sdpa(q, k, v, causal=True, q_offset=0, valid_from=valid_from,
                    scores_dtype=sdt)
    else:
        idx = cache["index"]
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), idx, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), idx, axis=1)
        # mask out unwritten cache slots via causal offset
        out = _sdpa(q, ck, cv, causal=True, q_offset=idx, valid_from=valid_from,
                    scores_dtype=sdt)
        new_cache = {"k": ck, "v": cv, "index": idx + x.shape[1]}
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(compute_dtype))
    return out, new_cache


def gqa_cache_defs(cfg: ModelConfig, batch: int, capacity: int) -> dict:
    hd = cfg.resolved_head_dim
    shape = (batch, capacity, cfg.num_kv_heads, hd)
    return {
        "k": (shape, ("batch", None, "tp", None), "bfloat16"),
        "v": (shape, ("batch", None, "tp", None), "bfloat16"),
        "index": ((), (), "int32"),
    }


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (DeepSeek-V2)
# ---------------------------------------------------------------------------

def mla_defs(cfg: ModelConfig) -> ParamTree:
    d, m = cfg.d_model, cfg.mla
    assert m is not None
    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
    defs = {
        # latent KV down-projection + decoupled rope key
        "w_dkv": ParamDef((d, m.kv_lora_rank), ("fsdp", "tp")),
        "w_kr": ParamDef((d, m.qk_rope_head_dim), ("fsdp", None)),
        "kv_norm": ParamDef((m.kv_lora_rank,), (None,), init="ones"),
        # up-projections from latent to per-head K(nope)/V
        "w_uk": ParamDef((m.kv_lora_rank, cfg.num_heads, m.qk_nope_head_dim), (None, "tp", None)),
        "w_uv": ParamDef((m.kv_lora_rank, cfg.num_heads, m.v_head_dim), (None, "tp", None)),
        "wo": ParamDef((cfg.num_heads, m.v_head_dim, d), ("tp", None, "fsdp")),
    }
    if m.q_lora_rank:
        defs["w_dq"] = ParamDef((d, m.q_lora_rank), ("fsdp", "tp"))
        defs["q_norm"] = ParamDef((m.q_lora_rank,), (None,), init="ones")
        defs["w_uq"] = ParamDef((m.q_lora_rank, cfg.num_heads, qd), (None, "tp", None))
    else:
        defs["wq"] = ParamDef((d, cfg.num_heads, qd), ("fsdp", "tp", None))
    return defs


def _mla_q(params, x, cfg: ModelConfig, compute_dtype):
    m = cfg.mla
    from repro.models.layers import rms_norm
    if m.q_lora_rank:
        cq = jnp.einsum("bsd,dr->bsr", x, params["w_dq"].astype(compute_dtype))
        cq = rms_norm(cq, params["q_norm"], cfg.norm_eps)
        q = jnp.einsum("bsr,rhk->bshk", cq, params["w_uq"].astype(compute_dtype))
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(compute_dtype))
    return q


def mla_attention(
    params: ParamTree,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    cfg: ModelConfig,
    cache: Optional[dict] = None,   # {"ckv": (B,T,R), "kr": (B,T,Dr), "index"}
    compute_dtype=jnp.bfloat16,
    absorb: bool = True,
    valid_from=None,
):
    """DeepSeek-V2 attention. Cache stores only (latent 512 + rope 64) per tok.

    absorb=True scores queries against the latent directly (W_uk folded into
    q) — the optimized decode path; absorb=False materializes K/V per head
    (paper-faithful reference path, used for training/prefill).
    """
    from repro.models.layers import rms_norm
    m = cfg.mla
    b, s, _ = x.shape
    q = _mla_q(params, x, cfg, compute_dtype)                     # (B,S,H,qd)
    q_nope = q[..., : m.qk_nope_head_dim]
    q_rope = apply_rope(q[..., m.qk_nope_head_dim :], positions, cfg.rope_theta)

    ckv = jnp.einsum("bsd,dr->bsr", x, params["w_dkv"].astype(compute_dtype))
    ckv = rms_norm(ckv, params["kv_norm"], cfg.norm_eps)
    kr = jnp.einsum("bsd,dk->bsk", x, params["w_kr"].astype(compute_dtype))
    kr = apply_rope(kr[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]

    new_cache = None
    if cache is not None:
        idx = cache["index"]
        ckv = jax.lax.dynamic_update_slice_in_dim(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), idx, axis=1)
        kr = jax.lax.dynamic_update_slice_in_dim(
            cache["kr"], kr.astype(cache["kr"].dtype), idx, axis=1)
        new_cache = {"ckv": ckv, "kr": kr, "index": idx + s}
        q_offset = idx
    else:
        q_offset = 0
    t = ckv.shape[1]

    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    sdt = jnp.dtype(cfg.attn_scores_dtype)
    if absorb:
        # fold W_uk into q: q_lat (B,S,H,R); scores = q_lat . ckv + q_rope . kr
        q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, params["w_uk"].astype(compute_dtype))
        s_nope = jnp.einsum("bshr,btr->bhst", q_lat.astype(jnp.float32),
                            ckv.astype(jnp.float32))
        s_rope = jnp.einsum("bshk,btk->bhst", q_rope.astype(jnp.float32),
                            kr.astype(jnp.float32))
        scores = (s_nope + s_rope) * scale
        qpos = jnp.arange(s) + q_offset
        kpos = jnp.arange(t)
        mask = (kpos[None, :] <= qpos[:, None])[None]
        if valid_from is not None:
            mask = mask & (kpos[None, None, :] >= valid_from[:, None, None])
        scores = jnp.where(mask[:, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(sdt)
        # out = probs @ V = probs @ (ckv W_uv): fold combine into latent too
        o_lat = jnp.einsum("bhst,btr->bshr", probs, ckv.astype(sdt))
        out = jnp.einsum("bshr,rhv->bshv", o_lat.astype(compute_dtype),
                         params["w_uv"].astype(compute_dtype))
    else:
        k_nope = jnp.einsum("btr,rhk->bthk", ckv, params["w_uk"].astype(compute_dtype))
        v = jnp.einsum("btr,rhv->bthv", ckv, params["w_uv"].astype(compute_dtype))
        k = jnp.concatenate(
            [k_nope,
             jnp.broadcast_to(kr[:, :, None, :],
                              (b, t, cfg.num_heads, m.qk_rope_head_dim))],
            axis=-1)
        qf = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = _sdpa(qf, k, v, causal=True, q_offset=q_offset, valid_from=valid_from,
                scores_dtype=sdt)
    out = jnp.einsum("bshv,hvd->bsd", out, params["wo"].astype(compute_dtype))
    return out, new_cache


def mla_cache_defs(cfg: ModelConfig, batch: int, capacity: int) -> dict:
    m = cfg.mla
    return {
        "ckv": ((batch, capacity, m.kv_lora_rank), ("batch", None, "tp"), "bfloat16"),
        "kr": ((batch, capacity, m.qk_rope_head_dim), ("batch", None, None), "bfloat16"),
        "index": ((), (), "int32"),
    }


# ---------------------------------------------------------------------------
# Cross-attention (VLM / audio memory)
# ---------------------------------------------------------------------------

def cross_attn_defs(cfg: ModelConfig) -> ParamTree:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    return {
        "wq": ParamDef((d, cfg.num_heads, hd), ("fsdp", "tp", None)),
        "wk": ParamDef((d, cfg.num_kv_heads, hd), ("fsdp", "tp", None)),
        "wv": ParamDef((d, cfg.num_kv_heads, hd), ("fsdp", "tp", None)),
        "wo": ParamDef((cfg.num_heads, hd, d), ("tp", None, "fsdp")),
        "gate": ParamDef((), (), init="zeros"),
    }


def cross_attention(
    params: ParamTree,
    x: jnp.ndarray,              # (B, S, D)
    memory: jnp.ndarray,         # (B, M, D) — precomputed patch/frame embeddings
    cfg: ModelConfig,
    compute_dtype=jnp.bfloat16,
):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(compute_dtype))
    k = jnp.einsum("bmd,dhk->bmhk", memory.astype(compute_dtype),
                   params["wk"].astype(compute_dtype))
    v = jnp.einsum("bmd,dhk->bmhk", memory.astype(compute_dtype),
                   params["wv"].astype(compute_dtype))
    out = _sdpa(q, k, v, causal=False, q_offset=0)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(compute_dtype))
    return jnp.tanh(params["gate"].astype(jnp.float32)).astype(out.dtype) * out
