"""Streaming-vs-batch parity for every step-② backend.

The streaming contract (engine/base.py, DESIGN.md §3a): chunks are
pairwise disjoint, sorted within the chunk, and their sorted union is
bit-identical to ``evaluate().candidates`` — for ragged corpus sizes, the
empty scaffold, an all-missing feature column, and the sharded backend's
overflow-retry path.
"""

import pytest

from repro.core.costs import CostLedger
from repro.core.featurize import FeaturizationSpec, vectorize
from repro.data.cnf_fixtures import representative_cnf
from repro.data.simulated_llm import SimulatedExtractor
from repro.data import synth
from repro.engine import ENGINES, get_engine
from repro.engine.base import CandidateChunk

# small tiles: keep interpret-mode pallas fast; ragged sizes exercise
# padding; l_block/block/r_chunk chosen so every backend emits >1 chunk
_OPTS = {
    "numpy": dict(block=32),
    "pallas": dict(tl=32, tr=64, l_block=32),
    "sharded": dict(tl=32, tr=32, r_chunk=64),
}


def _assert_stream_matches_batch(name, feats, clauses, thetas, opts=None):
    opts = opts if opts is not None else _OPTS[name]
    chunks = list(get_engine(name, **opts).evaluate_stream(
        feats, clauses, thetas))
    batch = get_engine(name, **opts).evaluate(feats, clauses, thetas)
    union = [p for ch in chunks for p in ch.candidates]
    assert len(union) == len(set(union)), f"{name}: chunks overlap"
    assert sorted(union) == batch.candidates, (
        f"{name}: union of {len(chunks)} chunks != batch candidates")
    for ch in chunks:
        assert isinstance(ch, CandidateChunk)
        assert ch.candidates == sorted(ch.candidates), (
            f"{name}: chunk {ch.index} not sorted")
        assert ch.stats.n_candidates == len(ch.candidates)
    assert [ch.index for ch in chunks] == list(range(len(chunks)))
    # byte accounting decomposes over chunks (evaluate is a drain)
    assert sum(ch.stats.bytes_to_host for ch in chunks) \
        == batch.stats.bytes_to_host
    return chunks, batch


def _materialized_cnf(ds):
    specs, clauses, thetas = representative_cnf(ds)
    feats = SimulatedExtractor(ds).materialize(specs, CostLedger())
    return feats, clauses, thetas


@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("mk_ds", [
    # 74 x 74: not a multiple of any tile edge -> padding exercised
    lambda: synth.police_records(n_incidents=37, reports_per_incident=2,
                                 seed=5),
    # 101 x 101: ragged on both sides for tr=64 / r_chunk=64
    lambda: synth.citations(n_docs=101, seed=9),
], ids=["police_ragged", "citations_ragged"])
def test_stream_parity_on_synth_datasets(engine, mk_ds):
    ds = mk_ds()
    feats, clauses, thetas = _materialized_cnf(ds)
    chunks, batch = _assert_stream_matches_batch(engine, feats, clauses,
                                                 thetas)
    assert batch.stats.n_candidates > 0          # non-degenerate join
    assert len(chunks) > 1, f"{engine}: expected multiple chunks"


@pytest.mark.parametrize("engine", ENGINES)
def test_stream_parity_empty_scaffold(engine):
    """Zero clauses = vacuous conjunction: the stream emits every pair."""
    ds = synth.police_records(n_incidents=10, reports_per_incident=2, seed=1)
    feats, _, _ = _materialized_cnf(ds)
    chunks, batch = _assert_stream_matches_batch(engine, feats, [], [])
    assert len(batch.candidates) == ds.n_l * ds.n_r


@pytest.mark.parametrize("engine", ENGINES)
def test_stream_parity_all_missing_feature_column(engine):
    """A featurization that failed on every record streams no candidates."""
    n_l, n_r = 41, 53                            # ragged on purpose
    vals_l = [f"item {i % 7}" for i in range(n_l)]
    vals_r = [f"item {i % 7}" for i in range(n_r)]
    ok_spec = FeaturizationSpec("name", "", "word_overlap", "llm", "name")
    dead_spec = FeaturizationSpec("dead", "", "semantic", "llm", "dead")
    feats = [vectorize(ok_spec, vals_l, vals_r),
             vectorize(dead_spec, [None] * n_l, [None] * n_r)]

    # dead feature alone: every chunk is empty
    chunks, batch = _assert_stream_matches_batch(engine, feats, [[1]], [0.9])
    assert batch.candidates == []

    # disjunction with a live feature: stream matches the live-only stream
    _, dis = _assert_stream_matches_batch(engine, feats, [[0, 1]], [0.3])
    _, live = _assert_stream_matches_batch(engine, feats, [[0]], [0.3])
    assert dis.candidates == live.candidates
    assert len(dis.candidates) > 0


def test_sharded_stream_overflow_retry():
    """An undersized per-chunk buffer must grow (>=4x) mid-stream and the
    union must still be the complete candidate set — no truncated chunk."""
    n = 40
    spec = FeaturizationSpec("name", "", "word_overlap", "llm", "name")
    # every pair matches: per-chunk candidates >> tiny initial capacity
    feats = [vectorize(spec, ["same text"] * n, ["same text"] * n)]
    opts = dict(tl=32, tr=32, r_chunk=32, capacity=8)
    eng = get_engine("sharded", **opts)
    chunks = list(eng.evaluate_stream(feats, [[0]], [0.5]))
    assert eng.last_sweep_capacity >= 4 * 8      # the >=4x growth rule
    assert eng.capacity == 8                     # config never mutated
    union = sorted(p for ch in chunks for p in ch.candidates)
    assert union == [(i, j) for i in range(n) for j in range(n)]
    for ch in chunks:                            # no chunk silently truncated
        assert len(ch.candidates) == ch.stats.n_candidates


@pytest.mark.parametrize("engine", ENGINES)
def test_vacuous_conjunction_streams_in_bounded_chunks(engine, monkeypatch):
    """The empty-clause-list path must emit the cross product in bounded
    row-block chunks, never one host list of all n_l*n_r pairs (the
    streaming contract — and RefinementPump memory — on large corpora)."""
    import repro.engine.base as base_mod
    monkeypatch.setattr(base_mod, "VACUOUS_CHUNK_PAIRS", 7)
    n_l, n_r = 5, 3
    spec = FeaturizationSpec("name", "", "word_overlap", "llm", "name")
    feats = [vectorize(spec, [f"l{i}" for i in range(n_l)],
                       [f"r{j}" for j in range(n_r)])]
    chunks = list(get_engine(engine, **_OPTS[engine]).evaluate_stream(
        feats, [], []))
    # 7 // 3 = 2 rows per chunk -> 3 chunks of 6, 6, 3 pairs
    assert [len(ch.candidates) for ch in chunks] == [6, 6, 3]
    assert [ch.index for ch in chunks] == [0, 1, 2]
    union = [p for ch in chunks for p in ch.candidates]
    assert len(union) == len(set(union))          # disjoint
    assert sorted(union) == [(i, j) for i in range(n_l) for j in range(n_r)]
    for ch in chunks:
        assert ch.candidates == sorted(ch.candidates)
        assert ch.stats.n_candidates == len(ch.candidates)
    # batch drain still equals the full cross product (backend parity:
    # all three engines share this path, and evaluate is a drain)
    batch = get_engine(engine, **_OPTS[engine]).evaluate(feats, [], [])
    assert batch.candidates == sorted(union)


def _banded_density_fixture():
    """33 x 128 corpus whose matches all live in R band [64, 96): with
    r_chunk=32 the sweep is 4 steps and only step 2 overflows — the
    deterministic retry-mid-pipeline fixture."""
    n_l, n_r = 33, 128
    texts_l = ["same text"] * n_l
    texts_r = ["zzz yyy"] * 64 + ["same text"] * 32 + ["zzz yyy"] * 32
    spec = FeaturizationSpec("name", "", "word_overlap", "llm", "name")
    feats = [vectorize(spec, texts_l, texts_r)]
    want = [(i, j) for i in range(n_l) for j in range(64, 96)]
    return feats, want


@pytest.mark.parametrize("ring_opts", [
    dict(double_buffer=True), dict(double_buffer=False),
    dict(prefetch_depth=1), dict(prefetch_depth=2), dict(prefetch_depth=4),
], ids=["db", "serial", "depth1", "depth2", "depth4"])
def test_sharded_retry_mid_pipeline_drops_and_duplicates_nothing(ring_opts):
    """capacity=1 with matches confined to a mid-sweep band: the overflow
    retry fires while successor steps are already in flight (up to depth-1
    of them at prefetch_depth=4), all of which must be invalidated and
    re-dispatched at the grown capacity — every chunk emitted exactly
    once, none truncated, none duplicated."""
    feats, want = _banded_density_fixture()
    eng = get_engine("sharded", tl=32, tr=32, r_chunk=32, capacity=1,
                     **ring_opts)
    chunks = list(eng.evaluate_stream(feats, [[0]], [0.25]))
    assert len(chunks) == 4                      # one per R band
    union = [p for ch in chunks for p in ch.candidates]
    assert len(union) == len(set(union)), "retry duplicated a chunk"
    assert sorted(union) == want, "retry dropped or truncated a chunk"
    for ch in chunks:
        assert len(ch.candidates) == ch.stats.n_candidates
    assert eng.last_sweep_capacity >= 33 * 32    # grew to the hot band
    assert eng.capacity == 1                     # config untouched
    # parity with the oracle on the same fixture
    assert sorted(union) == get_engine("numpy").evaluate(
        feats, [[0]], [0.25]).candidates


def test_sharded_overlap_accounting_pipelined_vs_serial():
    """overlap_s is the degradation signal: > 0 when the prefetch ring
    kept a successor step in flight during host pulls, exactly 0 when
    forced serial (the property benchmarks/run.py gates)."""
    ds = synth.police_records(n_incidents=37, reports_per_incident=2, seed=5)
    feats, clauses, thetas = _materialized_cnf(ds)
    db = get_engine("sharded", **_OPTS["sharded"]).evaluate(
        feats, clauses, thetas)
    serial = get_engine("sharded", double_buffer=False,
                        **_OPTS["sharded"]).evaluate(feats, clauses, thetas)
    depth1 = get_engine("sharded", prefetch_depth=1,
                        **_OPTS["sharded"]).evaluate(feats, clauses, thetas)
    deep = get_engine("sharded", prefetch_depth=4,
                      **_OPTS["sharded"]).evaluate(feats, clauses, thetas)
    assert db.candidates == serial.candidates == depth1.candidates \
        == deep.candidates
    assert db.stats.overlap_s > 0
    assert deep.stats.overlap_s > 0
    # depth 1 (and its legacy spelling double_buffer=False) is genuinely
    # serial: the ring is empty during every pull, so overlap is exactly
    # 0.0 — not merely small
    assert serial.stats.overlap_s == 0.0
    assert depth1.stats.overlap_s == 0.0
    for st in (db.stats, serial.stats, depth1.stats, deep.stats):
        assert st.dispatch_wall_s > 0 and st.pull_wall_s > 0


def test_sharded_prefetch_depth_resolution_and_validation():
    """double_buffer=False is the legacy spelling of prefetch_depth=1; an
    explicit prefetch_depth always wins; depth < 1 is rejected."""
    assert get_engine("sharded").effective_prefetch_depth == 2
    assert get_engine(
        "sharded", double_buffer=False).effective_prefetch_depth == 1
    assert get_engine(
        "sharded", double_buffer=False,
        prefetch_depth=4).effective_prefetch_depth == 4
    assert get_engine("sharded", prefetch_depth=1).effective_prefetch_depth \
        == 1
    with pytest.raises(ValueError, match="prefetch_depth"):
        get_engine("sharded", prefetch_depth=0)


@pytest.mark.parametrize("depth", [1, 2, 4])
def test_sharded_prefetch_depth_stream_parity(depth):
    """Every ring depth must produce the same disjoint sorted chunks as
    the batch drain — on the ragged corpus, the empty scaffold, and the
    vacuous conjunction."""
    opts = dict(_OPTS["sharded"], prefetch_depth=depth)
    ds = synth.police_records(n_incidents=37, reports_per_incident=2, seed=5)
    feats, clauses, thetas = _materialized_cnf(ds)
    chunks, batch = _assert_stream_matches_batch("sharded", feats, clauses,
                                                 thetas, opts)
    assert batch.stats.n_candidates > 0
    assert len(chunks) > 1
    _assert_stream_matches_batch("sharded", feats, [], [], opts)


def test_program_cache_is_lru_not_fifo(monkeypatch):
    """A repeatedly-hit key must survive _PROGRAM_CACHE_MAX insertions of
    one-off keys: hits refresh recency, so churn evicts the cold slots."""
    from repro.engine.sharded import ShardedEngine

    monkeypatch.setattr(ShardedEngine, "_programs", {})
    builds = []

    def fake_build(self, mesh, kclauses, thetas, rows_shard, cap, r_chunk,
                   n_chunks, interpret):
        builds.append(thetas)
        return ("program", thetas)

    monkeypatch.setattr(ShardedEngine, "_build_uncached", fake_build)
    eng = get_engine("sharded")
    hot = eng._build("mesh", (), (0.5,), 32, 8, 64, 4)
    for i in range(2 * ShardedEngine._PROGRAM_CACHE_MAX):
        eng._build("mesh", (), (float(i) + 10.0,), 32, 8, 64, 4)  # churn
        assert eng._build("mesh", (), (0.5,), 32, 8, 64, 4) is hot, (
            f"hot program evicted after {i + 1} one-off insertions")
    assert builds.count((0.5,)) == 1             # never rebuilt
    assert len(ShardedEngine._programs) <= ShardedEngine._PROGRAM_CACHE_MAX


def test_stream_wall_clock_excludes_consumer_time():
    """Per-chunk wall measures engine time only: a slow consumer must not
    inflate step-② accounting (the pump relies on this split)."""
    import time
    ds = synth.police_records(n_incidents=20, reports_per_incident=2, seed=2)
    feats, clauses, thetas = _materialized_cnf(ds)
    stream = get_engine("numpy", block=8).evaluate_stream(
        feats, clauses, thetas)
    walls = []
    for ch in stream:
        walls.append(ch.stats.wall_s)
        time.sleep(0.05)                         # consumer stalls 50 ms/chunk
    assert len(walls) > 1
    assert sum(walls) < 0.05                     # engine time stays its own
