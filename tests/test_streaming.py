"""Streaming-vs-batch parity for every step-② backend.

The streaming contract (engine/base.py, DESIGN.md §3a): chunks are
pairwise disjoint, sorted within the chunk, and their sorted union is
bit-identical to ``evaluate().candidates`` — for ragged corpus sizes, the
empty scaffold, an all-missing feature column, and the sharded backend's
overflow-retry path.
"""

import pytest

from repro.core.costs import CostLedger
from repro.core.featurize import FeaturizationSpec, vectorize
from repro.data.cnf_fixtures import representative_cnf
from repro.data.simulated_llm import SimulatedExtractor
from repro.data import synth
from repro.engine import ENGINES, get_engine
from repro.engine.base import CandidateChunk

# small tiles: keep interpret-mode pallas fast; ragged sizes exercise
# padding; l_block/block/r_chunk chosen so every backend emits >1 chunk
_OPTS = {
    "numpy": dict(block=32),
    "pallas": dict(tl=32, tr=64, l_block=32),
    "sharded": dict(tl=32, tr=32, r_chunk=64),
}


def _assert_stream_matches_batch(name, feats, clauses, thetas, opts=None):
    opts = opts if opts is not None else _OPTS[name]
    chunks = list(get_engine(name, **opts).evaluate_stream(
        feats, clauses, thetas))
    batch = get_engine(name, **opts).evaluate(feats, clauses, thetas)
    union = [p for ch in chunks for p in ch.candidates]
    assert len(union) == len(set(union)), f"{name}: chunks overlap"
    assert sorted(union) == batch.candidates, (
        f"{name}: union of {len(chunks)} chunks != batch candidates")
    for ch in chunks:
        assert isinstance(ch, CandidateChunk)
        assert ch.candidates == sorted(ch.candidates), (
            f"{name}: chunk {ch.index} not sorted")
        assert ch.stats.n_candidates == len(ch.candidates)
    assert [ch.index for ch in chunks] == list(range(len(chunks)))
    # byte accounting decomposes over chunks (evaluate is a drain)
    assert sum(ch.stats.bytes_to_host for ch in chunks) \
        == batch.stats.bytes_to_host
    return chunks, batch


def _materialized_cnf(ds):
    specs, clauses, thetas = representative_cnf(ds)
    feats = SimulatedExtractor(ds).materialize(specs, CostLedger())
    return feats, clauses, thetas


@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("mk_ds", [
    # 74 x 74: not a multiple of any tile edge -> padding exercised
    lambda: synth.police_records(n_incidents=37, reports_per_incident=2,
                                 seed=5),
    # 101 x 101: ragged on both sides for tr=64 / r_chunk=64
    lambda: synth.citations(n_docs=101, seed=9),
], ids=["police_ragged", "citations_ragged"])
def test_stream_parity_on_synth_datasets(engine, mk_ds):
    ds = mk_ds()
    feats, clauses, thetas = _materialized_cnf(ds)
    chunks, batch = _assert_stream_matches_batch(engine, feats, clauses,
                                                 thetas)
    assert batch.stats.n_candidates > 0          # non-degenerate join
    assert len(chunks) > 1, f"{engine}: expected multiple chunks"


@pytest.mark.parametrize("engine", ENGINES)
def test_stream_parity_empty_scaffold(engine):
    """Zero clauses = vacuous conjunction: the stream emits every pair."""
    ds = synth.police_records(n_incidents=10, reports_per_incident=2, seed=1)
    feats, _, _ = _materialized_cnf(ds)
    chunks, batch = _assert_stream_matches_batch(engine, feats, [], [])
    assert len(batch.candidates) == ds.n_l * ds.n_r


@pytest.mark.parametrize("engine", ENGINES)
def test_stream_parity_all_missing_feature_column(engine):
    """A featurization that failed on every record streams no candidates."""
    n_l, n_r = 41, 53                            # ragged on purpose
    vals_l = [f"item {i % 7}" for i in range(n_l)]
    vals_r = [f"item {i % 7}" for i in range(n_r)]
    ok_spec = FeaturizationSpec("name", "", "word_overlap", "llm", "name")
    dead_spec = FeaturizationSpec("dead", "", "semantic", "llm", "dead")
    feats = [vectorize(ok_spec, vals_l, vals_r),
             vectorize(dead_spec, [None] * n_l, [None] * n_r)]

    # dead feature alone: every chunk is empty
    chunks, batch = _assert_stream_matches_batch(engine, feats, [[1]], [0.9])
    assert batch.candidates == []

    # disjunction with a live feature: stream matches the live-only stream
    _, dis = _assert_stream_matches_batch(engine, feats, [[0, 1]], [0.3])
    _, live = _assert_stream_matches_batch(engine, feats, [[0]], [0.3])
    assert dis.candidates == live.candidates
    assert len(dis.candidates) > 0


def test_sharded_stream_overflow_retry():
    """An undersized per-chunk buffer must grow (>=4x) mid-stream and the
    union must still be the complete candidate set — no truncated chunk."""
    n = 40
    spec = FeaturizationSpec("name", "", "word_overlap", "llm", "name")
    # every pair matches: per-chunk candidates >> tiny initial capacity
    feats = [vectorize(spec, ["same text"] * n, ["same text"] * n)]
    opts = dict(tl=32, tr=32, r_chunk=32, capacity=8)
    eng = get_engine("sharded", **opts)
    chunks = list(eng.evaluate_stream(feats, [[0]], [0.5]))
    assert eng.capacity >= 4 * 8                 # the >=4x growth rule
    union = sorted(p for ch in chunks for p in ch.candidates)
    assert union == [(i, j) for i in range(n) for j in range(n)]
    for ch in chunks:                            # no chunk silently truncated
        assert len(ch.candidates) == ch.stats.n_candidates


def test_stream_wall_clock_excludes_consumer_time():
    """Per-chunk wall measures engine time only: a slow consumer must not
    inflate step-② accounting (the pump relies on this split)."""
    import time
    ds = synth.police_records(n_incidents=20, reports_per_incident=2, seed=2)
    feats, clauses, thetas = _materialized_cnf(ds)
    stream = get_engine("numpy", block=8).evaluate_stream(
        feats, clauses, thetas)
    walls = []
    for ch in stream:
        walls.append(ch.stats.wall_s)
        time.sleep(0.05)                         # consumer stalls 50 ms/chunk
    assert len(walls) > 1
    assert sum(walls) < 0.05                     # engine time stays its own
