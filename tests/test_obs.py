"""Observability spine (DESIGN.md §7): tracer, metrics, ledger binding.

Load-bearing invariants under test:
  * tracing is *inert*: traced and untraced runs return bit-identical
    candidate sets on all three backends, and the disabled-path guard
    (`if tracer:` against falsy NULL_TRACER) allocates nothing;
  * span trees survive the RefinementPump thread boundary (worker-side
    batch spans parent to the span captured on the spawning thread);
  * the prefetch ring's dispatch∩pull overlap is positive in the
    exported trace at depth 2 and exactly zero at depth 1;
  * `ledger_from_metrics(registry)` reconstructs any ledger bound to a
    fresh registry (the ledger↔metrics derivability invariant), and
    JoinService keeps it live across a whole query/append stream;
  * `CostLedger.absorb` never lets a ledger that skipped the plane
    store clobber the absorbed-into resident-bytes level.
"""

import tracemalloc

import pytest

from repro.core.costs import CostLedger, ledger_from_metrics
from repro.core.featurize import vectorize  # noqa: F401  (parity helper dep)
from repro.core.refine import RefinementPump
from repro.data import synth
from repro.data.cnf_fixtures import representative_cnf
from repro.data.simulated_llm import SimulatedExtractor
from repro.engine import ENGINES, get_engine
from repro.engine.base import CandidateChunk, EngineStats
from repro.launch import trace_report
from repro.obs import (NULL_SPAN, NULL_TRACER, MetricsRegistry, Tracer,
                       current_tracer, to_trace_events, use_tracer,
                       validate_trace)

_OPTS = {
    "numpy": dict(block=64),
    "pallas": dict(tl=32, tr=64),
    "sharded": dict(tl=32, tr=32, r_chunk=64),
}


# --- tracer core ------------------------------------------------------------

def test_span_nesting_retro_parents_and_events():
    tr = Tracer()
    with tr.span("root", kind="test") as root:
        with tr.span("child") as child:
            tr.event("mark", attrs_go_here=1)
        # retroactive spans default-parent to the innermost open span
        retro = tr.record_span("late", root.t0, root.t0 + 0.5,
                               attrs={"n": 3},
                               events=[("tick", root.t0 + 0.1, {"i": 0})])
    spans = {s.name: s for s in tr.spans()}
    assert spans["child"].parent_id == root.span_id
    assert retro.parent_id == root.span_id
    assert spans["root"].parent_id is None
    assert spans["root"].t1 is not None and root.attrs["kind"] == "test"
    assert child.events[0].name == "mark"
    assert retro.events[0].name == "tick" and retro.events[0].attrs == {"i": 0}
    # explicit parent beats the stack
    other = tr.record_span("explicit", 0.0, 1.0, parent=child)
    assert other.parent_id == child.span_id


def test_ambient_tracer_contextvar_and_null_default():
    assert current_tracer() is NULL_TRACER
    assert not NULL_TRACER and not current_tracer()
    t = Tracer()
    with use_tracer(t):
        assert current_tracer() is t and current_tracer()
        with use_tracer(None):                 # None ⇒ tracing stays off
            assert current_tracer() is NULL_TRACER
        assert current_tracer() is t
    assert current_tracer() is NULL_TRACER


def test_null_tracer_is_inert_and_guard_allocates_nothing():
    # unguarded accidental use returns shared singletons
    with NULL_TRACER.span("x", a=1) as sp:
        assert sp is NULL_SPAN
    assert NULL_TRACER.record_span("x", 0.0, 1.0) is NULL_SPAN
    assert NULL_TRACER.spans() == []

    tracer = current_tracer()
    assert tracer is NULL_TRACER

    def band_loop(n):
        # the instrumented hot-loop shape: one truthiness branch; the
        # attr dict is never built when tracing is off
        acc = 0
        for i in range(n):
            if tracer:
                tracer.record_span("band_step", 0.0, 1.0,
                                   attrs={"candidates": i})
            acc += i
        return acc

    band_loop(100)                             # warm bytecode/caches
    tracemalloc.start()
    band_loop(10_000)
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    assert peak < 1024, f"disabled-path band loop allocated {peak} bytes"


# --- metrics ----------------------------------------------------------------

def test_histogram_quantiles_within_log_bucket_error():
    reg = MetricsRegistry()
    vals = [0.001 * (i + 1) for i in range(1000)]    # 1ms .. 1s uniform
    for v in vals:
        reg.observe("lat", v)
    h = reg.histogram("lat")
    s = h.summary()
    assert s["count"] == 1000 and abs(s["sum"] - sum(vals)) < 1e-9
    assert s["min"] == vals[0] and s["max"] == vals[-1]
    for q, true in [(0.50, 0.5005), (0.90, 0.9005), (0.99, 0.9905)]:
        est = h.quantile(q)
        assert abs(est - true) / true < 0.15, (q, est, true)
    assert s["p50"] <= s["p90"] <= s["p99"] <= s["max"]


def test_histogram_underflow_and_empty():
    reg = MetricsRegistry()
    assert reg.histogram("h").summary()["p50"] == 0.0
    reg.observe("h", 0.0)
    reg.observe("h", -5.0)
    assert reg.histogram("h").quantile(0.5) == 0.0


def test_registry_as_dict_flattens_histograms():
    reg = MetricsRegistry()
    reg.inc("c", 2)
    reg.set_gauge("g", 7)
    reg.observe("h", 1.0)
    d = reg.as_dict()
    assert d["c"] == 2 and d["g"] == 7.0
    assert d["h.count"] == 1 and d["h.p50"] == 1.0


# --- ledger <-> metrics derivability ----------------------------------------

def _busy_ledger():
    led = CostLedger()
    led.charge_label(1000, 10)
    led.charge_generation(500, 200)
    led.charge_extraction(800, 80)
    led.charge_embedding(400)
    led.charge_refine(300, 3)
    led.record_walls(1.5, 0.5, 0.25)
    led.record_engine_walls(0.8, 0.4, 0.1)
    led.record_plane_traffic(hits=3, misses=1, evicted_bytes=128,
                             resident_bytes=4096, bytes_h2d=2048,
                             bytes_reshard=64)
    led.record_recalibration(swapped=True, drift=0.02, dollars=0.003)
    return led


def test_ledger_from_metrics_round_trip():
    led = _busy_ledger()
    reg = MetricsRegistry()
    led.bind_metrics(reg)                      # mid-life bind: state published
    assert ledger_from_metrics(reg) == led
    led.charge_refine(100, 1)                  # post-bind flow streams in
    led.record_plane_traffic(hits=1, resident_bytes=5000)
    assert ledger_from_metrics(reg) == led
    # int fields come back as ints, not floats
    derived = ledger_from_metrics(reg)
    assert isinstance(derived.plane_hits, int)
    assert isinstance(derived.step2_conjunct_evals, int)
    assert derived.plane_level_set


def test_shared_registry_derives_absorbed_sum():
    reg = MetricsRegistry()
    lifetime = CostLedger()                    # stays UNBOUND (absorb would
    for _ in range(3):                         # double-feed the registry)
        q = CostLedger()
        q.bind_metrics(reg)
        q.charge_refine(200, 2)
        q.record_walls(0.1, 0.05, 0.0)
        q.record_plane_traffic(hits=2, resident_bytes=1000)
        lifetime.absorb(q)
    assert ledger_from_metrics(reg) == lifetime


def test_absorb_preserves_resident_level():
    """Regression: a ledger that never touched the plane store must not
    clobber the absorbed-into resident-bytes level with its default 0."""
    svc = CostLedger()
    svc.record_plane_traffic(hits=1, resident_bytes=4096)
    storeless = CostLedger()
    storeless.charge_refine(100, 1)            # a query without plane traffic
    svc.absorb(storeless)
    assert svc.plane_resident_bytes == 4096 and svc.plane_level_set
    toucher = CostLedger()
    toucher.record_plane_traffic(hits=1, resident_bytes=8192)
    svc.absorb(toucher)                        # a real level does transfer
    assert svc.plane_resident_bytes == 8192


# --- tracing is inert: candidate-set parity ---------------------------------

def _materialized_cnf(ds):
    specs, clauses, thetas = representative_cnf(ds)
    feats = SimulatedExtractor(ds).materialize(specs, CostLedger())
    return feats, clauses, thetas


@pytest.mark.parametrize("engine", list(ENGINES))
def test_traced_and_untraced_candidates_identical(engine):
    ds = synth.police_records(n_incidents=20, reports_per_incident=2, seed=7)
    feats, clauses, thetas = _materialized_cnf(ds)
    eng = get_engine(engine, **_OPTS[engine])
    plain = eng.evaluate(feats, clauses, thetas)
    tr = Tracer()
    with use_tracer(tr):
        traced = get_engine(engine, **_OPTS[engine]).evaluate(
            feats, clauses, thetas)
    assert traced.candidates == plain.candidates
    names = {s.name for s in tr.spans()}
    assert any(n.startswith("band_step[") for n in names)
    assert validate_trace(to_trace_events(tr)) == []


# --- pump spans cross the worker-thread boundary ----------------------------

def _chunks(groups):
    out = []
    for i, g in enumerate(groups):
        stats = EngineStats("scripted", n_l=10, n_r=10, n_candidates=len(g),
                            wall_s=0.001, bytes_to_host=8 * len(g))
        out.append(CandidateChunk(sorted(g), stats, i))
    return out


def test_pump_batch_spans_parent_to_query_root_across_thread():
    tr = Tracer()
    reg = MetricsRegistry()
    led = CostLedger()
    led.bind_metrics(reg)
    groups = [[(i, j) for j in range(3)] for i in range(4)]
    with use_tracer(tr):
        with tr.span("query_root") as root:
            pump = RefinementPump(lambda b: set(b), batch_pairs=4,
                                  max_queue_chunks=2)
            res = pump.run(iter(_chunks(groups)), ledger=led)
    assert res.pairs == {p for g in groups for p in g}
    batches = [s for s in tr.spans() if s.name == "refine_batch"]
    assert batches, "pump recorded no refine_batch spans"
    assert all(s.parent_id == root.span_id for s in batches)
    assert any(s.thread != root.thread for s in batches), \
        "worker-side spans should be recorded on the pump thread"
    assert all(s.track == "refine-pump" for s in batches)
    # pump metrics flowed through the bound registry
    assert reg.value("refine.batches") == len(batches)
    assert reg.value("refine.pairs") == sum(len(g) for g in groups)
    assert reg.has("refine.queue_depth")


# --- prefetch-ring overlap geometry -----------------------------------------

def _ring_trace(depth):
    ds = synth.citations(n_docs=101, seed=9)   # 4 R bands at r_chunk=32
    feats, clauses, thetas = _materialized_cnf(ds)
    eng = get_engine("sharded", tl=32, tr=32, r_chunk=32,
                     prefetch_depth=depth)
    tr = Tracer()
    with use_tracer(tr):
        res = eng.evaluate(feats, clauses, thetas)
    obj = to_trace_events(tr)
    assert validate_trace(obj) == []
    return res, obj


def test_ring_overlap_positive_at_depth2_zero_at_depth1():
    res1, obj1 = _ring_trace(1)
    res2, obj2 = _ring_trace(2)
    assert res1.candidates == res2.candidates  # ring depth never changes output
    s1, s2 = trace_report._slices(obj1), trace_report._slices(obj2)
    assert len([s for s in s2 if s["name"] == "pull"]) >= 3
    assert trace_report.ring_overlap_s(s1) == 0.0
    assert trace_report.ring_overlap_s(s2) > 0.0
    # depth 2 uses two ring-slot tracks; depth 1 serializes on one
    assert len({s["tid"] for s in s2 if s["name"] == "pull"}) == 2
    assert len({s["tid"] for s in s1 if s["name"] == "pull"}) == 1


def test_trace_reconciles_with_ledger_walls():
    res, obj = _ring_trace(2)
    led = CostLedger()
    led.record_engine_stats(res.stats)
    led.record_walls(res.stats.wall_s, 0.0, 0.0)
    obj["fdj"] = {"wall_summary": led.wall_summary()}
    assert trace_report.check(obj) == [], trace_report.check(obj)
    checks = trace_report.reconcile(obj, trace_report._slices(obj))
    assert {c[0] for c in checks} >= {
        "Σ pull slices vs step2_pull_wall",
        "Σ dispatch enqueue_s vs step2_dispatch_wall",
    }


# --- serving keeps the derivability invariant live --------------------------

def _ledgers_close(a, b):
    """Field-wise equality up to float association order: the registry
    accumulates per-charge deltas, the lifetime ledger per-query sums."""
    import dataclasses
    import math
    for f in dataclasses.fields(CostLedger):
        if not f.compare:
            continue
        va, vb = getattr(a, f.name), getattr(b, f.name)
        if not math.isclose(va, vb, rel_tol=1e-9, abs_tol=1e-12):
            return False, (f.name, va, vb)
    return True, None


def test_join_service_metrics_always_derive_lifetime_ledger():
    from repro.core.join import FDJConfig
    from repro.serving.join_service import JoinService, hold_out_right
    ds = synth.movies_pages(n_movies=20, cast_size=4, filler_sentences=1,
                            seed=3)
    base, delta = hold_out_right(ds, n_delta=4)
    cfg = FDJConfig(engine="numpy", engine_opts=_OPTS["numpy"], seed=0,
                    mc_trials=4000)
    svc = JoinService(base, cfg)
    svc.query()
    ok, why = _ledgers_close(ledger_from_metrics(svc.metrics), svc.ledger)
    assert ok, why
    svc.query()
    svc.append_right(delta)
    svc.query()
    ok, why = _ledgers_close(ledger_from_metrics(svc.metrics), svc.ledger)
    assert ok, why
    assert svc.metrics.value("serve.plan_hits") >= 1.0
    assert svc.metrics.histogram("serve.query_wall_s").count == 3
