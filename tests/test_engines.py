"""Engine parity: every step-② backend returns the identical candidate set.

The numpy blocked loop is the semantic oracle; the Pallas (interpret) and
sharded streaming backends must match it bit-for-bit — including ragged
(non-tile-multiple) corpus sizes, an empty scaffold, and a feature column
that failed extraction on every record.
"""

import pytest

from repro.core.costs import CostLedger
from repro.core.featurize import FeaturizationSpec, vectorize
from repro.data.cnf_fixtures import representative_cnf
from repro.data.simulated_llm import SimulatedExtractor
from repro.data import synth
from repro.engine import ENGINES, get_engine
from repro.engine.base import EngineStats

# small tiles: keep interpret-mode pallas fast; ragged sizes exercise padding
_OPTS = {
    "numpy": dict(block=64),
    "pallas": dict(tl=32, tr=64),
    "sharded": dict(tl=32, tr=32, r_chunk=64),
}


def _all_engines(feats, clauses, thetas):
    out = {}
    for name in ENGINES:
        out[name] = get_engine(name, **_OPTS[name]).evaluate(
            feats, clauses, thetas)
    return out


def _assert_parity(results):
    base = results["numpy"].candidates
    for name, res in results.items():
        assert res.candidates == base, (
            f"{name} disagrees with numpy: "
            f"{len(res.candidates)} vs {len(base)} candidates")
    return base


# --- dataset-driven cases ---------------------------------------------------

def _materialized_cnf(ds):
    """The shared representative scaffold (same one the benchmark runs)."""
    specs, clauses, thetas = representative_cnf(ds)
    feats = SimulatedExtractor(ds).materialize(specs, CostLedger())
    return feats, clauses, thetas


@pytest.mark.parametrize("mk_cnf,mk_ds", [
    # n = 74 / 74: not a multiple of any tile edge -> padding exercised
    (_materialized_cnf, lambda: synth.police_records(n_incidents=37,
                                               reports_per_incident=2, seed=5)),
    # 101 x 101: ragged on both sides for tr=64 / r_chunk=64
    (_materialized_cnf, lambda: synth.citations(n_docs=101, seed=9)),
], ids=["police_ragged", "citations_ragged"])
def test_engine_parity_on_synth_datasets(mk_cnf, mk_ds):
    ds = mk_ds()
    feats, clauses, thetas = mk_cnf(ds)
    results = _all_engines(feats, clauses, thetas)
    base = _assert_parity(results)
    assert len(base) > 0                      # non-degenerate join
    for res in results.values():
        assert res.stats.n_l == ds.n_l and res.stats.n_r == ds.n_r
        assert res.stats.n_candidates == len(base)


def test_engine_parity_empty_scaffold():
    """Zero clauses = vacuous conjunction: every pair is a candidate."""
    ds = synth.police_records(n_incidents=10, reports_per_incident=2, seed=1)
    feats, _, _ = _materialized_cnf(ds)
    results = _all_engines(feats, [], [])
    base = _assert_parity(results)
    assert len(base) == ds.n_l * ds.n_r


def test_engine_parity_all_missing_feature_column():
    """A featurization that failed on every record: clauses using it alone
    admit nothing (theta < 1); in a disjunction the partner carries it."""
    n_l, n_r = 41, 53                          # ragged on purpose
    vals_l = [f"item {i % 7}" for i in range(n_l)]
    vals_r = [f"item {i % 7}" for i in range(n_r)]
    ok_spec = FeaturizationSpec("name", "", "word_overlap", "llm", "name")
    dead_spec = FeaturizationSpec("dead", "", "semantic", "llm", "dead")
    feats = [vectorize(ok_spec, vals_l, vals_r),
             vectorize(dead_spec, [None] * n_l, [None] * n_r)]

    # dead feature alone: no candidates anywhere
    results = _all_engines(feats, [[1]], [0.9])
    assert _assert_parity(results) == []

    # disjunction with a live feature: behaves exactly like the live feature
    results_dis = _all_engines(feats, [[0, 1]], [0.3])
    results_live = _all_engines(feats, [[0]], [0.3])
    assert _assert_parity(results_dis) == _assert_parity(results_live)
    assert len(results_dis["numpy"].candidates) > 0


def test_sharded_capacity_overflow_is_retried_not_truncated():
    """An undersized initial buffer must grow and still return everything."""
    n = 40
    spec = FeaturizationSpec("name", "", "word_overlap", "llm", "name")
    # every pair matches: candidate count n*n >> tiny capacity
    feats = [vectorize(spec, ["same text"] * n, ["same text"] * n)]
    eng = get_engine("sharded", tl=32, tr=32, r_chunk=64, capacity=64)
    res = eng.evaluate(feats, [[0]], [0.5])
    assert len(res.candidates) == n * n
    assert res.candidates == get_engine("numpy").evaluate(
        feats, [[0]], [0.5]).candidates


def test_sharded_capacity_one_forces_retry_on_every_chunk():
    """Worst-case fixture: capacity=1 overflows on every R chunk; the >=4x
    retry rule must recover the complete candidate set in both batch and
    streaming modes, with no chunk silently truncated."""
    n = 33                                     # ragged vs tl/tr/r_chunk
    spec = FeaturizationSpec("name", "", "word_overlap", "llm", "name")
    feats = [vectorize(spec, ["same text"] * n, ["same text"] * n)]
    want = [(i, j) for i in range(n) for j in range(n)]

    eng = get_engine("sharded", tl=32, tr=32, r_chunk=32, capacity=1)
    res = eng.evaluate(feats, [[0]], [0.5])
    assert res.candidates == want
    assert eng.last_sweep_capacity >= 4        # grew by >=4x, never clamped
    assert eng.capacity == 1                   # config survives the sweep

    eng2 = get_engine("sharded", tl=32, tr=32, r_chunk=32, capacity=1)
    chunks = list(eng2.evaluate_stream(feats, [[0]], [0.5]))
    assert len(chunks) == 2                    # padded R = 64 -> two chunks
    for ch in chunks:                          # each chunk complete, counted
        assert len(ch.candidates) == ch.stats.n_candidates > 0
    assert sorted(p for ch in chunks for p in ch.candidates) == want


def test_sharded_capacity_growth_is_sweep_local():
    """A shared (serving) engine that once hit a dense join must not
    over-allocate every later query: capacity growth persists across the
    steps of one sweep only, never on the engine."""
    n = 40
    spec = FeaturizationSpec("name", "", "word_overlap", "llm", "name")
    dense = [vectorize(spec, ["same text"] * n, ["same text"] * n)]
    sparse = [vectorize(spec, ["aaa bbb"] * n, ["zzz yyy"] * n)]
    eng = get_engine("sharded", tl=32, tr=32, r_chunk=64, capacity=64)

    res = eng.evaluate(dense, [[0]], [0.5])
    assert len(res.candidates) == n * n
    assert eng.last_sweep_capacity >= 4 * 64   # the dense sweep grew
    assert eng.capacity == 64                  # ...but not the config

    res2 = eng.evaluate(sparse, [[0]], [0.25])  # nothing matches
    assert res2.candidates == []
    # the sparse sweep started from the configured capacity, not the
    # dense join's grown one (the cross-join leak this test pins)
    assert eng.last_sweep_capacity == 64
    # per-shard vector exposed for diagnostics: uniform on this 1-device
    # mesh, and exactly the configured value after the clean sweep
    assert list(eng.last_sweep_caps) == [64]


def test_sharded_host_bytes_scale_with_candidates():
    """Acceptance: sharded transfer is O(candidates), not O(n_l*n_r)."""
    ds = synth.police_records(n_incidents=50, reports_per_incident=2, seed=3)
    feats, clauses, thetas = _materialized_cnf(ds)
    res = get_engine("sharded", **_OPTS["sharded"]).evaluate(
        feats, clauses, thetas)
    s = res.stats
    # counts vector + 8 bytes per extracted pair (before padding filter),
    # with a small allowance for tile-padding extras; far below the plane
    assert s.bytes_to_host <= 8 * (s.n_candidates + 64) + 1024
    assert s.bytes_to_host < s.plane_bytes / 4


def test_engine_stats_shape():
    ds = synth.police_records(n_incidents=20, reports_per_incident=2, seed=2)
    feats, clauses, thetas = _materialized_cnf(ds)
    res = get_engine("numpy").evaluate(feats, clauses, thetas)
    assert isinstance(res.stats, EngineStats)
    d = res.stats.as_dict()
    assert d["engine"] == "numpy" and d["plane_bytes"] == ds.n_l * ds.n_r


def test_unknown_engine_rejected():
    with pytest.raises(ValueError, match="unknown engine"):
        get_engine("cuda")


def test_mismatched_thetas_rejected():
    ds = synth.police_records(n_incidents=10, reports_per_incident=2)
    feats, clauses, _ = _materialized_cnf(ds)
    with pytest.raises(ValueError, match="thresholds"):
        get_engine("numpy").evaluate(feats, clauses, [0.5])
