"""QueryOptions API consolidation (ISSUE 9 satellite): one typed request
surface shared by ``JoinService.query``, ``append_right`` and
``JoinFleet.submit``; the historical kwarg surface survives only as a
deprecation shim routed through ``QueryOptions.from_legacy`` — and the
two forms are parity-tested byte-identical here.
"""

import pytest

from repro.core.join import FDJConfig, QueryOptions
from repro.data import synth
from repro.serving.join_service import JoinService, hold_out_right


def _ds(seed=3, n=12):
    return synth.police_records(n_incidents=n, reports_per_incident=2,
                                seed=seed)


def _cfg(**kw):
    kw.setdefault("mc_trials", 4000)
    return FDJConfig(engine="numpy", engine_opts=dict(block=64), seed=0,
                     **kw)


# --- the adapter itself -----------------------------------------------------

def test_from_legacy_maps_named_kwargs_and_overrides():
    opts = QueryOptions.from_legacy(engine="numpy", stream=True,
                                    recall_target=0.8, mc_trials=3000)
    assert opts == QueryOptions(engine="numpy", stream=True,
                                recall_target=0.8,
                                overrides={"mc_trials": 3000})


def test_resolve_applies_named_fields_over_overrides():
    base = _cfg()
    cfg = QueryOptions(recall_target=0.8, stream=True,
                       overrides={"mc_trials": 2000}).resolve(base)
    assert cfg.recall_target == 0.8
    assert cfg.stream_refinement is True
    assert cfg.mc_trials == 2000
    assert base.recall_target != 0.8            # base untouched
    assert QueryOptions().resolve(base) is base  # no-op request: same cfg


def test_unknown_override_raises_at_resolve_time():
    with pytest.raises(TypeError):
        QueryOptions(overrides={"no_such_knob": 1}).resolve(_cfg())


# --- the service surface ----------------------------------------------------

def test_legacy_kwargs_warn_and_match_options_byte_identically():
    ds = _ds()
    new = JoinService(ds, _cfg())
    old = JoinService(ds, _cfg())
    r_new = new.query(QueryOptions(recall_target=0.85, stream=True,
                                   overrides={"mc_trials": 3000}))
    with pytest.warns(DeprecationWarning):
        r_old = old.query(recall_target=0.85, stream=True, mc_trials=3000)
    assert r_old.pairs == r_new.pairs
    assert r_old.join.t_prime == r_new.join.t_prime
    assert r_old.join.recall == r_new.join.recall
    assert r_old.join.candidate_count == r_new.join.candidate_count
    assert r_old.cost.total == r_new.cost.total


def test_refresh_plan_kwarg_is_also_shimmed():
    ds = _ds()
    svc = JoinService(ds, _cfg())
    svc.query()                                  # no legacy kwargs: no warn
    with pytest.warns(DeprecationWarning):
        r = svc.query(refresh_plan=True)
    assert r.plan_hit is False
    r = svc.query(QueryOptions(refresh_plan=True))   # typed form: silent
    assert r.plan_hit is False


def test_both_forms_together_raise():
    svc = JoinService(_ds(), _cfg())
    with pytest.raises(TypeError, match="not both"):
        svc.query(QueryOptions(), recall_target=0.9)


def test_append_right_validates_options():
    ds, pool = hold_out_right(_ds(n=14), 4)
    svc = JoinService(ds, _cfg())
    svc.query()
    with pytest.raises(TypeError):
        svc.append_right(pool, QueryOptions(overrides={"bogus": 1}))
    info = svc.append_right(pool, QueryOptions())    # valid shape accepted
    assert info["rows"] == len(pool.texts)
