"""Vectorized extraction charging: the per-spec batched ledger pass must
produce the same totals as the historical per-record host loop, charge
each record at most once, and expose per-side delta extraction for the
serving plane store."""

import numpy as np
import pytest

from repro.core.costs import CostLedger, n_tokens
from repro.data import synth
from repro.data.cnf_fixtures import representative_cnf
from repro.data.simulated_llm import SimulatedExtractor


def _per_record_reference(ds, specs) -> CostLedger:
    """The pre-vectorization charging loop, reimplemented verbatim."""
    ext = SimulatedExtractor(ds)
    led = CostLedger()
    for spec in specs:
        for side, texts in (("l", ds.texts_l), ("r", ds.texts_r)):
            vals = ext._extract_side(spec, side)
            for i in range(len(texts)):
                if spec.extractor_kind == "llm":
                    led.charge_extraction(n_tokens(texts[i]) + 30,
                                          n_tokens(str(vals[i] or "")) + 2)
                if spec.distance_kind == "semantic":
                    led.charge_embedding(n_tokens(str(vals[i] or "")) + 1)
    return led


@pytest.mark.parametrize("mk", [
    lambda: synth.police_records(n_incidents=14, reports_per_incident=2,
                                 seed=5),
    lambda: synth.citations(n_docs=40, seed=2),
], ids=["police", "citations"])
def test_vectorized_materialize_ledger_parity(mk):
    ds = mk()
    specs, _, _ = representative_cnf(ds)
    ref = _per_record_reference(ds, specs)
    led = CostLedger()
    SimulatedExtractor(ds).materialize(specs, led)
    assert led.inference == pytest.approx(ref.inference, rel=1e-9)
    assert led.total == pytest.approx(ref.total, rel=1e-9)


def test_materialize_charges_first_touch_only():
    ds = synth.police_records(n_incidents=10, reports_per_incident=2, seed=1)
    specs, _, _ = representative_cnf(ds)
    ext = SimulatedExtractor(ds)
    led = CostLedger()
    ext.materialize(specs, led)
    cold = led.inference
    assert cold > 0
    ext.materialize(specs, led)                        # idempotent re-charge
    assert led.inference == cold
    # pair_distances over already-materialized records charges nothing new
    ext.pair_distances(specs, [(0, 0), (3, 7)], led)
    assert led.inference == cold


def test_extract_values_charges_exactly_the_requested_rows():
    ds = synth.police_records(n_incidents=10, reports_per_incident=2, seed=1)
    specs, _, _ = representative_cnf(ds)
    spec = specs[0]
    ext = SimulatedExtractor(ds)
    led = CostLedger()
    head = ext.extract_values(spec, "r", led, idx=np.arange(0, 5))
    part = led.inference
    assert part > 0 and len(head) == 5
    # same rows again: free; remaining rows: the rest of the full-side cost
    ext.extract_values(spec, "r", led, idx=np.arange(0, 5))
    assert led.inference == part
    full_vals = ext.extract_values(spec, "r", led)
    assert len(full_vals) == ds.n_r
    ref = CostLedger()
    SimulatedExtractor(ds).extract_values(spec, "r", ref)
    assert led.inference == pytest.approx(ref.inference, rel=1e-9)
    # values agree with the cached extraction
    assert full_vals[:5] == head
