"""Per-kernel validation: shape/dtype sweeps, interpret-mode vs ref oracle."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels.fused_cnf_join import ops as cnf_ops, ref as cnf_ref
from repro.kernels.fused_cnf_join.kernel import SCAL, VEC, cnf_join_block
from repro.kernels.threshold_sweep.ops import (candidate_grid, sweep,
                                               sweep_counts)
from repro.kernels.threshold_sweep.ref import threshold_sweep_ref


def _mk_inputs(rng, fv, fs, nl, nr, d, dtype):
    el = rng.normal(size=(fv, nl, d)).astype(dtype)
    er = rng.normal(size=(fv, nr, d)).astype(dtype)
    el /= np.linalg.norm(el, axis=-1, keepdims=True)
    er /= np.linalg.norm(er, axis=-1, keepdims=True)
    sl = rng.uniform(0, 1.5, size=(max(fs, 1), nl)).astype(dtype)
    sr = rng.uniform(0, 1.5, size=(max(fs, 1), nr)).astype(dtype)
    return el, er, sl, sr


@pytest.mark.parametrize("nl,nr,d,tl,tr", [
    (128, 128, 128, 64, 128),
    (256, 512, 128, 128, 256),
    (256, 256, 256, 256, 256),
    (512, 256, 128, 128, 128),
])
def test_cnf_kernel_shapes(nl, nr, d, tl, tr):
    rng = np.random.default_rng(nl + nr)
    el, er, sl, sr = _mk_inputs(rng, 2, 1, nl, nr, d, np.float32)
    clauses = (((VEC, 0), (SCAL, 0)), ((VEC, 1),))
    thetas = (0.45, 0.52)
    packed = cnf_join_block(jnp.asarray(el), jnp.asarray(er), jnp.asarray(sl),
                            jnp.asarray(sr), clauses, thetas, tl=tl, tr=tr,
                            interpret=True)
    expect = cnf_ref.cnf_join_ref(jnp.asarray(el), jnp.asarray(er),
                                  jnp.asarray(sl), jnp.asarray(sr),
                                  clauses, thetas)
    got = cnf_ref.unpack_mask(np.asarray(packed), nr)
    assert np.array_equal(got, np.asarray(expect))


@pytest.mark.parametrize("structure", [
    (((VEC, 0),),),
    (((SCAL, 0),),),
    (((VEC, 0), (VEC, 1)), ((SCAL, 0),)),
    (((VEC, 0),), ((VEC, 1),), ((SCAL, 0), (VEC, 0))),
])
def test_cnf_kernel_clause_structures(structure):
    rng = np.random.default_rng(7)
    el, er, sl, sr = _mk_inputs(rng, 2, 1, 128, 128, 128, np.float32)
    thetas = tuple(0.3 + 0.1 * i for i in range(len(structure)))
    packed = cnf_join_block(jnp.asarray(el), jnp.asarray(er), jnp.asarray(sl),
                            jnp.asarray(sr), structure, thetas, tl=64, tr=64,
                            interpret=True)
    expect = cnf_ref.cnf_join_ref(jnp.asarray(el), jnp.asarray(er),
                                  jnp.asarray(sl), jnp.asarray(sr),
                                  structure, thetas)
    assert np.array_equal(cnf_ref.unpack_mask(np.asarray(packed), 128),
                          np.asarray(expect))


def test_cnf_corpus_vs_numpy_join_path():
    """evaluate_corpus (padding, packing, missing encoding) == numpy engine."""
    from repro.core.costs import CostLedger
    from repro.core.featurize import FeaturizationSpec
    from repro.data.simulated_llm import SimulatedExtractor
    from repro.data.synth import police_records

    ds = police_records(n_incidents=40, reports_per_incident=2)
    ext = SimulatedExtractor(ds)
    led = CostLedger()
    specs = [FeaturizationSpec("incident_date", "", "arithmetic", "llm", "incident_date"),
             FeaturizationSpec("officer_names", "", "word_overlap", "llm", "officer_names"),
             FeaturizationSpec("location", "", "semantic", "llm", "location")]
    feats = ext.materialize(specs, led)
    clauses = [[0], [1, 2]]
    th = [0.02, 0.35]
    got = set(cnf_ops.evaluate_corpus(feats, clauses, th, tl=32, tr=64))
    il, jr = np.arange(ds.n_l), np.arange(ds.n_r)
    ok = None
    for ci, cl in enumerate(clauses):
        cd = None
        for f in cl:
            d = feats[f].distance_block(il, jr)
            cd = d if cd is None else np.minimum(cd, d)
        pas = cd <= th[ci]
        ok = pas if ok is None else ok & pas
    want = set(zip(*[x.tolist() for x in np.nonzero(ok)]))
    assert got == want


@pytest.mark.parametrize("k,c,g", [(300, 1, 50), (700, 3, 200), (1024, 5, 64)])
def test_threshold_sweep(k, c, g):
    rng = np.random.default_rng(k)
    cd = rng.uniform(0, 1, size=(k, c)).astype(np.float32)
    labels = rng.random(k) < 0.3
    th = rng.uniform(0, 1, size=(g, c)).astype(np.float32)
    pos, sel = sweep(cd, labels, th, tg=64, tk=256)
    expect = np.asarray(threshold_sweep_ref(
        jnp.asarray(cd), jnp.asarray(labels.astype(np.float32)), jnp.asarray(th)))
    np.testing.assert_allclose(pos, expect[:, 0], rtol=1e-6)
    np.testing.assert_allclose(sel, expect[:, 1], rtol=1e-6)


def test_threshold_sweep_grid_helper():
    rng = np.random.default_rng(0)
    pos = rng.uniform(0, 1, size=(40, 2)).astype(np.float32)
    grid = candidate_grid(pos, max_per_dim=5)
    assert grid.shape[1] == 2 and grid.shape[0] <= 25


def test_missing_value_encoding_forces_max_distance():
    """Augmented [e,m,1]/[e,1,m] rows make missing pairs distance 1."""
    from repro.core.featurize import FeaturizationSpec, vectorize
    spec = FeaturizationSpec("f", "", "word_overlap", "llm", "f")
    fd = vectorize(spec, ["alpha beta", None, "gamma"], ["alpha beta", "delta", None])
    d = fd.distance_block(np.arange(3), np.arange(3))
    assert d[0, 0] < 0.01            # identical token sets
    assert np.all(d[1, :] >= 0.999)  # missing left row
    assert np.all(d[:, 2] >= 0.999)  # missing right row


def _count_oracle(cd, labels, th):
    """Plain-numpy (pos, sel) counts — the ground truth both the kernel
    and the jitted ref must reproduce, pad rows or not."""
    selm = np.all(cd[None, :, :] <= th[:, None, :], axis=-1)
    return ((selm & labels[None, :]).sum(axis=1).astype(np.float32),
            selm.sum(axis=1).astype(np.float32))


def test_threshold_sweep_pad_rows_not_counted():
    """Regression: cd used to be padded with +inf, relying on ``inf <= th``
    being false — but ``inf <= inf`` is TRUE, so any +inf threshold column
    (emitted for positive-free samples, hit by all-missing features) counted
    every pad row into ``sel``.  With k=100 under a 256-row tile, the old
    kernel reported sel=256 for an all-+inf theta; the explicit validity
    mask must report exactly k."""
    k, c = 100, 2
    rng = np.random.default_rng(5)
    cd = rng.uniform(0, 1, size=(k, c)).astype(np.float32)
    labels = rng.random(k) < 0.4
    th = np.array([[np.inf, np.inf],       # admits every real row — and,
                                           # before the fix, every pad row
                   [np.inf, 0.5],
                   [-np.inf, 0.5]],        # admits nothing (d >= 0 > -inf)
                  np.float32)
    pos, sel = sweep(cd, labels, th, tg=64, tk=256)
    want_pos, want_sel = _count_oracle(cd, labels, th)
    np.testing.assert_array_equal(sel, want_sel)
    np.testing.assert_array_equal(pos, want_pos)
    assert sel[0] == k and pos[0] == labels.sum()
    assert sel[2] == 0 and pos[2] == 0


def test_threshold_sweep_inf_distances_ragged_tiles():
    """±inf thresholds and +inf distances through non-tile-multiple k and
    G — kernel, jitted ref, and plain numpy all agree exactly."""
    k, c, g = 333, 3, 37                   # 333 % 128 != 0, 37 % 16 != 0
    rng = np.random.default_rng(9)
    cd = rng.uniform(0, 1, size=(k, c)).astype(np.float32)
    cd[rng.random(size=(k, c)) < 0.08] = np.inf   # failed extractions
    labels = rng.random(k) < 0.3
    th = rng.uniform(0, 1, size=(g, c)).astype(np.float32)
    th[0] = np.inf
    th[-1] = -np.inf
    th[5, 1] = np.inf                      # mixed row
    pos, sel = sweep(cd, labels, th, tg=16, tk=128)
    want_pos, want_sel = _count_oracle(cd, labels, th)
    np.testing.assert_array_equal(pos, want_pos)
    np.testing.assert_array_equal(sel, want_sel)
    ref = np.asarray(threshold_sweep_ref(
        jnp.asarray(cd), jnp.asarray(labels.astype(np.float32)),
        jnp.asarray(th)))
    np.testing.assert_array_equal(ref[:, 0], want_pos)
    np.testing.assert_array_equal(ref[:, 1], want_sel)


def test_sweep_counts_dispatcher_parity():
    """The guarantee path's ``sweep_counts`` (jitted jnp ref on CPU, the
    pallas kernel on accelerators) is bit-for-bit the padded kernel."""
    rng = np.random.default_rng(11)
    k, c, g = 500, 2, 90
    cd = rng.uniform(0, 1, size=(k, c)).astype(np.float32)
    labels = rng.random(k) < 0.25
    th = rng.uniform(0, 1, size=(g, c)).astype(np.float32)
    th[3] = np.inf
    pos_d, sel_d = sweep_counts(cd, labels, th)
    pos_k, sel_k = sweep(cd, labels, th, tg=64, tk=256)
    np.testing.assert_array_equal(pos_d, pos_k)
    np.testing.assert_array_equal(sel_d, sel_k)
    # empty grid: well-defined empty counts, no kernel launch
    pos_e, sel_e = sweep_counts(cd, labels, np.zeros((0, c), np.float32))
    assert pos_e.shape == (0,) and sel_e.shape == (0,)


def test_candidate_grid_cap_and_recall_corner():
    """The cartesian grid is capped (no 24^C blowup) and always contains
    the per-dim positive-max corner, so recall-1 stays reachable."""
    rng = np.random.default_rng(3)
    pos = rng.uniform(0, 1, size=(600, 5)).astype(np.float32)
    grid = candidate_grid(pos, max_per_dim=24, max_grid=512)
    assert grid.shape[1] == 5
    # the shrink loop bounds prod(counts) by max_grid; the appended
    # recall-1 corner can at most double each axis
    assert grid.shape[0] <= 512 * 2 ** 5
    assert grid.shape[0] < 24 ** 5 / 100
    corner = pos.max(axis=0)
    assert any(np.allclose(row, corner) for row in grid), \
        "per-dim positive max (recall-1 corner) missing from the grid"
    # degenerate: no clauses
    empty = candidate_grid(np.zeros((4, 0), np.float32))
    assert empty.shape == (1, 0)
