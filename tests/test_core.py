"""FDJ core unit tests: cost-to-cover, scaffold search, thresholds,
adj-target, BARGAIN primitives, end-to-end guarantees."""

import math

import numpy as np
import pytest

from repro.core import generation, scaffold as sl
from repro.core.adj_target import adj_target, failure_curve
from repro.core.bargain import (bargain_precision_subset,
                                optimal_cascade_threshold,
                                recall_guarded_threshold, supg_threshold)
from repro.core.scaffold import get_logical_scaffold, min_fpr_thresholds


def test_cost_to_cover_separable():
    """A perfectly separating featurization gives cost-to-cover 0."""
    rng = np.random.default_rng(0)
    pos = rng.uniform(0.0, 0.2, size=50)
    neg = rng.uniform(0.5, 1.0, size=200)
    d = np.concatenate([pos, neg])[:, None]
    labels = np.concatenate([np.ones(50, bool), np.zeros(200, bool)])
    c = generation.cost_to_cover(d, labels)
    assert c.max() == 0


def test_cost_to_cover_counts_exact():
    d = np.array([[0.5], [0.1], [0.3], [0.7]])      # pos at 0.5, negs 0.1/0.3/0.7
    labels = np.array([True, False, False, False])
    c = generation.cost_to_cover(d, labels)
    assert c.tolist() == [2]                         # two negatives <= 0.5


def test_min_fpr_1d_exact():
    d = np.array([0.1, 0.2, 0.3, 0.15, 0.25, 0.9])
    labels = np.array([True, True, True, False, False, False])
    r = min_fpr_thresholds(d[:, None], labels, 1.0)       # keep all positives
    assert r.feasible and r.theta[0] == pytest.approx(0.3)
    assert r.fpr == pytest.approx(2 / 3)                  # 0.15, 0.25 admitted
    r2 = min_fpr_thresholds(d[:, None], labels, 0.66)     # may drop one positive
    # need = ceil(0.66*3) = 2 positives -> theta 0.2 admits neg 0.15 only
    assert r2.feasible and r2.theta[0] == pytest.approx(0.2)
    assert r2.fpr == pytest.approx(1 / 3)


def test_min_fpr_multidim_greedy_feasibility():
    rng = np.random.default_rng(1)
    k = 400
    labels = rng.random(k) < 0.25
    cd = rng.uniform(0, 1, size=(k, 3))
    cd[labels] *= 0.4                               # positives closer
    for t in (0.8, 0.9, 0.95):
        r = min_fpr_thresholds(cd, labels, t)
        assert r.feasible
        sel = np.all(cd <= r.theta[None, :], axis=1)
        got = (sel & labels).sum() / labels.sum()
        assert got >= t - 1e-9                       # observed recall met
        assert r.fpr <= 1.0


def test_scaffold_greedy_improves_and_respects_cap():
    rng = np.random.default_rng(2)
    k = 500
    labels = rng.random(k) < 0.2
    good = np.where(labels, rng.uniform(0, 0.2, k), rng.uniform(0, 1, k))
    noise = rng.uniform(0, 1, size=(k, 3))
    d = np.column_stack([good, noise])
    sc = get_logical_scaffold(d, labels, 0.9, gamma=0.05, max_clauses=2)
    assert 1 <= sc.n_clauses <= 2
    assert 0 in sc.used_featurizations()            # the informative feature
    cost = sl.scaffold_cost(d, labels, sc, 0.9)
    assert cost < 1.0                               # better than admit-all


def test_adj_target_monotone_and_bounds():
    r1 = adj_target(200, 1, 0.9, 0.1, n_pairs=10**6, k_sample=20000,
                    n_plus_hat=10000, n_trials=3000)
    r3 = adj_target(200, 3, 0.9, 0.1, n_pairs=10**6, k_sample=20000,
                    n_plus_hat=10000, n_trials=3000)
    assert 0.9 < r1.t_prime <= r3.t_prime <= 1.0
    # empirical failure on the worst-case dataset stays below delta3
    tail = failure_curve(200, 1, 10000, 0.9, 3000, cache=False)
    m = int(math.ceil(200 * r1.t_prime - 1e-9))
    assert tail[m] <= r1.delta3


def test_adj_target_r1_matches_classical_range():
    """1-D case: T' should land in the classical ~T + 2-3 sigma band."""
    res = adj_target(200, 1, 0.9, 0.1, n_pairs=10**6, k_sample=20000,
                     n_plus_hat=10000, n_trials=5000)
    sigma = math.sqrt(0.9 * 0.1 / 200)
    assert 0.9 + sigma <= res.t_prime <= 0.9 + 5 * sigma


def test_recall_guarded_threshold_meets_target():
    fails = 0
    trials = 20
    n_plus = 4000
    for t in range(trials):
        rr = np.random.default_rng(t)
        # population: positives near 0, negatives uniform
        pop_pos = rr.uniform(0, 0.6, n_plus)
        k = 300
        samp = rr.choice(n_plus, size=k, replace=False)
        sd = pop_pos[samp]
        labels = np.ones(k, bool)
        cas = recall_guarded_threshold(sd, labels, 0.9, 0.1,
                                       n_pairs=10**6, n_trials=3000)
        true_recall = (pop_pos <= cas.tau).mean()
        fails += true_recall < 0.9
    assert fails / trials <= 0.2, f"failure rate {fails}/{trials}"


def test_supg_fails_more_often_than_guarded():
    n_plus, k, trials = 4000, 300, 30
    fails_supg = 0
    for t in range(trials):
        rr = np.random.default_rng(100 + t)
        pop_pos = rr.uniform(0, 0.6, n_plus)
        sd = pop_pos[rr.choice(n_plus, size=k, replace=False)]
        tau = supg_threshold(sd, np.ones(k, bool), 0.9)
        fails_supg += (pop_pos <= tau).mean() < 0.9
    assert fails_supg / trials > 0.25       # unadjusted: ~50% failures


def test_optimal_cascade_is_tightest():
    rng = np.random.default_rng(4)
    d = rng.uniform(0, 1, 5000)
    labels = rng.random(5000) < 0.3
    d[labels] *= 0.5
    tau = optimal_cascade_threshold(d, labels, 0.9)
    rec = (d[labels] <= tau).mean()
    assert rec >= 0.9
    # one grid step tighter would violate the target
    pos_sorted = np.sort(d[labels])
    idx = np.searchsorted(pos_sorted, tau)
    if idx >= 1:
        assert (d[labels] <= pos_sorted[idx - 1]).mean() < 0.9 + 1e-9


def test_bargain_precision_subset_sound():
    rng = np.random.default_rng(5)
    n = 2000
    d = rng.uniform(0, 1, n)
    truth = d + rng.normal(0, 0.1, n) < 0.4        # low distance => match
    calls = {"n": 0}

    def label_fn(idx):
        calls["n"] += len(idx)
        return truth[idx]

    mask = bargain_precision_subset(d, label_fn, 0.9, 0.1, rng=rng)
    if mask.any():
        assert truth[mask].mean() >= 0.75           # high-precision subset
        assert calls["n"] < n                       # cheaper than labeling all


def _clause_distance_fixture(seed, k=800, c=3):
    """Realistic clause-distance shapes: positives concentrated low with a
    heavy tail, negatives spread high — per-clause separations differ so
    the threshold surface is genuinely multi-dimensional."""
    rng = np.random.default_rng(seed)
    labels = rng.random(k) < 0.3
    cd = np.empty((k, c), np.float32)
    for j in range(c):
        a, b = 1.5 + j, 6.0 - j
        cd[:, j] = np.where(labels, rng.beta(a, b + 4, size=k),
                            rng.beta(b, a, size=k))
    return cd.astype(np.float32), labels


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("target", [0.8, 0.9, 0.95])
def test_min_fpr_device_route_never_worse_than_greedy(seed, target):
    """The tentpole A/B: the device sweep must always return a feasible
    theta whose FPR is <= the greedy baseline's (it is best-of by
    construction) and whose observed recall meets the target."""
    cd, labels = _clause_distance_fixture(seed)
    g = min_fpr_thresholds(cd, labels, target, method="greedy")
    d = min_fpr_thresholds(cd, labels, target, method="device")
    assert g.feasible and d.feasible
    assert d.recall >= target - 1e-9
    assert d.fpr <= g.fpr + 1e-12, \
        f"device sweep returned worse FPR {d.fpr} than greedy {g.fpr}"
    # auto routes to the device sweep when the kernel stack imports
    a = min_fpr_thresholds(cd, labels, target, method="auto")
    assert a.fpr == d.fpr and np.array_equal(a.theta, d.theta)


def test_min_fpr_device_c1_matches_exact_sweep():
    """C=1 is solved exactly by _sweep_1d; the device route must land on
    the same optimum (its refinement IS the exact sweep for one clause)."""
    rng = np.random.default_rng(7)
    k = 600
    labels = rng.random(k) < 0.35
    d1 = np.where(labels, rng.beta(2, 7, size=k),
                  rng.beta(6, 2, size=k)).astype(np.float32)[:, None]
    for target in (0.8, 0.9, 1.0):
        exact = min_fpr_thresholds(d1, labels, target, method="greedy")
        dev = min_fpr_thresholds(d1, labels, target, method="device")
        assert dev.feasible == exact.feasible
        np.testing.assert_allclose(dev.theta, exact.theta)
        assert abs(dev.fpr - exact.fpr) < 1e-12


def test_min_fpr_method_validation_and_edge_cases():
    cd, labels = _clause_distance_fixture(4)
    with pytest.raises(ValueError):
        min_fpr_thresholds(cd, labels, 0.9, method="exhaustive")
    # no positives: infeasible +inf theta on every route
    none = np.zeros(len(labels), bool)
    for m in ("greedy", "device", "auto"):
        r = min_fpr_thresholds(cd, none, 0.9, method=m)
        assert not r.feasible and np.all(np.isinf(r.theta))
    # zero clauses: trivially feasible empty theta
    r0 = min_fpr_thresholds(np.zeros((10, 0), np.float32),
                            labels[:10], 0.9, method="device")
    assert r0.feasible and r0.theta.shape == (0,)
