import os
import sys

# tests see the real (single) CPU device — the 512-device override is applied
# only inside repro.launch.dryrun, per the assignment contract.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
