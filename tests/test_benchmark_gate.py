"""The benchmark regression gate (benchmarks/run.py --check-against).

Pure-host: exercises ``check_against`` on synthetic baseline/fresh JSON
pairs — band semantics per metric class (wide for walls, tight for bytes
and dollars, exact for counts/flags) and the lost-coverage rule.
"""

import json
import os
import sys

import pytest

sys.path.insert(0, os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..")))

from benchmarks.run import _metric_band, check_against  # noqa: E402


@pytest.fixture
def gate_dirs(tmp_path, monkeypatch):
    """(baseline_dir, write_fresh) with RESULTS_DIR redirected to tmp."""
    import benchmarks.run as run_mod
    fresh_dir = tmp_path / "results"
    fresh_dir.mkdir()
    monkeypatch.setattr(run_mod, "RESULTS_DIR", str(fresh_dir))
    base_dir = tmp_path / "baseline"
    base_dir.mkdir()

    def write(kind, name, rows):
        d = base_dir if kind == "base" else fresh_dir
        with open(d / f"{name}.json", "w") as f:
            json.dump(rows, f)

    return str(base_dir), write


def _row(**kw):
    row = {"table": "t1", "engine": "numpy", "wall_s": 1.0,
           "bytes_to_host": 1000, "candidates": 42,
           "agrees_with_numpy": True}
    row.update(kw)
    return row


def test_identical_results_pass(gate_dirs):
    base, write = gate_dirs
    write("base", "engines", [_row()])
    write("fresh", "engines", [_row()])
    assert check_against(base, ["engines"]) == []


def test_wall_band_is_wide_but_bounded(gate_dirs):
    base, write = gate_dirs
    write("base", "engines", [_row(wall_s=1.0)])
    write("fresh", "engines", [_row(wall_s=3.4)])   # < 1.0*2.5 + 1.0
    assert check_against(base, ["engines"]) == []
    write("fresh", "engines", [_row(wall_s=3.6)])   # > band
    assert len(check_against(base, ["engines"])) == 1


def test_byte_inflation_fails(gate_dirs):
    base, write = gate_dirs
    write("base", "engines", [_row(bytes_to_host=100_000)])
    write("fresh", "engines", [_row(bytes_to_host=150_000)])
    bad = check_against(base, ["engines"])
    assert len(bad) == 1 and "bytes_to_host" in bad[0]


def test_counts_and_flags_must_match_exactly(gate_dirs):
    base, write = gate_dirs
    write("base", "engines", [_row(candidates=42, agrees_with_numpy=True)])
    write("fresh", "engines", [_row(candidates=41, agrees_with_numpy=False)])
    bad = check_against(base, ["engines"])
    assert len(bad) == 2


def test_warm_extraction_dollars_cannot_reinflate(gate_dirs):
    base, write = gate_dirs
    row = {"engine": "sharded", "mode": "warm", "wall_s": 0.01,
           "extraction_cost": 0.0, "bytes_to_device": 0,
           "bytes_reshard": 0, "pairs": 10, "agrees_with_cold": True}
    write("base", "serving", [row])
    write("fresh", "serving", [dict(row, extraction_cost=0.02)])
    bad = check_against(base, ["serving"])
    assert len(bad) == 1 and "extraction_cost" in bad[0]
    write("fresh", "serving", [dict(row, bytes_reshard=2048)])
    bad = check_against(base, ["serving"])
    assert len(bad) == 1 and "bytes_reshard" in bad[0]


def test_zero_byte_baseline_must_stay_exactly_zero(gate_dirs):
    """The generic byte band (1.1x + 1 KiB) must not apply to invariant
    zeros — warm reshard/H2D creeping back to 1000 bytes is a regression
    even though it is inside the slack."""
    base, write = gate_dirs
    row = {"engine": "sharded", "mode": "warm", "wall_s": 0.01,
           "extraction_cost": 0.0, "bytes_to_device": 0,
           "bytes_reshard": 0, "pairs": 10, "agrees_with_cold": True}
    write("base", "serving", [row])
    write("fresh", "serving", [dict(row, bytes_to_device=1000)])
    bad = check_against(base, ["serving"])
    assert len(bad) == 1 and "must stay zero" in bad[0]


def test_crashed_gated_regime_fails_the_gate(gate_dirs):
    """A regime that died before emitting results must fail the gate —
    otherwise a non-strict run would drop its rows from the comparison
    and report the gate as passed."""
    base, write = gate_dirs
    write("base", "engines", [_row()])
    bad = check_against(base, [], crashed=["engines"])
    assert len(bad) == 1 and "crashed" in bad[0]
    # crashed regimes without a gate spec are not the gate's business
    assert check_against(base, [], crashed=["table2"]) == []


def test_lost_coverage_is_a_regression(gate_dirs):
    base, write = gate_dirs
    write("base", "engines", [_row(), _row(engine="sharded")])
    write("fresh", "engines", [_row()])
    bad = check_against(base, ["engines"])
    assert len(bad) == 1 and "coverage lost" in bad[0]


def test_new_fresh_rows_are_not_regressions(gate_dirs):
    base, write = gate_dirs
    write("base", "engines", [_row()])
    write("fresh", "engines", [_row(), _row(engine="sharded")])
    assert check_against(base, ["engines"]) == []


def test_unknown_regime_and_missing_baseline_are_skipped(gate_dirs):
    base, write = gate_dirs
    write("fresh", "engines", [_row()])
    # no engines.json baseline, and a regime with no gate spec at all
    assert check_against(base, ["engines", "table2"]) == []


def test_metric_band_classes():
    assert _metric_band("wall_s") == ("wall", 2.5, 1.0)
    assert _metric_band("t_first_s") == ("wall", 2.5, 1.0)
    assert _metric_band("bytes_to_host")[:2] == ("bytes", 1.10)
    assert _metric_band("extraction_cost")[:2] == ("cost", 1.10)
    assert _metric_band("candidates") is None
    # overlap seconds are a floor (degradation-to-serial detector), never
    # the machine-dependent wall ceiling
    assert _metric_band("overlap_s")[0] == "floor"
    assert _metric_band("db_overlap_s")[0] == "floor"
    assert _metric_band("engine_overlap_s")[0] == "floor"


def test_overlap_floor_fails_only_on_collapse_to_zero(gate_dirs):
    """A nonzero overlap_s baseline collapsing to 0 means the double-
    buffered band loop silently degraded to serial — a regression even
    when the wall band is satisfied.  Any nonzero value passes (the
    absolute magnitude is machine-dependent), and a zero baseline (the
    single-chunk or non-pipelined rows) constrains nothing."""
    base, write = gate_dirs
    write("base", "engines", [_row(overlap_s=0.04)])
    write("fresh", "engines", [_row(overlap_s=0.001)])   # smaller is fine
    assert check_against(base, ["engines"]) == []
    write("fresh", "engines", [_row(overlap_s=0.0)])     # collapse fails
    bad = check_against(base, ["engines"])
    assert len(bad) == 1 and "degraded to the serial loop" in bad[0]
    write("base", "engines", [_row(overlap_s=0.0)])      # zero baseline
    write("fresh", "engines", [_row(overlap_s=0.0)])
    assert check_against(base, ["engines"]) == []


def test_wall_band_env_override(monkeypatch):
    """Slower CI runners widen only the machine-dependent wall band."""
    monkeypatch.setenv("FDJ_GATE_WALL_BAND", "6.0,30.0")
    assert _metric_band("wall_s") == ("wall", 6.0, 30.0)
    assert _metric_band("bytes_to_host")[:2] == ("bytes", 1.10)


def test_unknown_regime_in_only_is_rejected():
    import subprocess
    here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--fast", "--only",
         "enginez"],
        capture_output=True, text=True, cwd=here,
        env={**os.environ,
             "PYTHONPATH": os.path.join(here, "src")})
    assert proc.returncode != 0
    assert "unknown regime" in proc.stderr


def test_committed_baselines_exist_for_gated_regimes():
    """ci.sh points --check-against at benchmarks/baseline — the committed
    JSONs must exist for every gated regime or the gate is a no-op."""
    here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for name in ("engines", "pipeline", "serving"):
        path = os.path.join(here, "benchmarks", "baseline", f"{name}.json")
        assert os.path.exists(path), f"missing committed baseline {path}"
        with open(path) as f:
            assert json.load(f), f"empty baseline {path}"
