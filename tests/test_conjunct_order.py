"""Selectivity-ordered CNF + conjunct short-circuit tests.

The ordering invariant (DESIGN.md §3): a conjunction commutes, so the
candidate set is bit-identical under any evaluation order and with early
rejection on or off, on every backend — only ``conjunct_evals`` moves.
On a skewed-selectivity fixture the ordered short-circuit evaluation must
do strictly less (pair, clause) work than unordered full width, and a
band whose first conjunct rejects everything must emit no candidates and
charge only first-conjunct FLOPs.
"""

import numpy as np
import pytest

from repro.core import scaffold as scaffold_lib
from repro.core.costs import CostLedger
from repro.core.featurize import FeaturizationSpec, vectorize
from repro.core.join import (FDJConfig, _get_engine, apply_conjunct_order,
                             fdj_join, plan_join)
from repro.core.scaffold import ordered_conjuncts
from repro.data.cnf_fixtures import representative_cnf
from repro.data.simulated_llm import SimulatedExtractor, SimulatedProposer
from repro.data import synth
from repro.engine import ENGINES, get_engine

_OPTS = {
    "numpy": dict(block=32),
    "pallas": dict(tl=32, tr=64, l_block=32),
    "sharded": dict(tl=32, tr=32, r_chunk=64),
}


# --- ordering policy --------------------------------------------------------

def test_ordered_conjuncts_selective_first():
    """The rejecting clause goes first even when listed last."""
    # clause 0 passes every sample row at theta=0.5; clause 1 passes none
    cd = np.array([[0.1, 0.9], [0.2, 0.8], [0.1, 0.7], [0.3, 0.9]])
    theta = np.array([0.5, 0.5])
    assert ordered_conjuncts(cd, theta, [[0], [1]]) == [1, 0]


def test_ordered_conjuncts_cost_breaks_selectivity_ties():
    """Equal pass rates: the narrower (cheaper) clause goes first."""
    cd = np.array([[0.9, 0.9], [0.1, 0.1]])
    theta = np.array([0.5, 0.5])
    assert ordered_conjuncts(cd, theta, [[0, 1, 2], [0]]) == [1, 0]


def test_ordered_conjuncts_pass_everything_sorts_last():
    cd = np.array([[0.1, 0.4, 0.9], [0.2, 0.3, 0.8]])
    theta = np.array([0.5, 0.5, 0.5])                # clauses 0,1 pass all
    order = ordered_conjuncts(cd, theta, [[0], [1], [2]])
    assert order[0] == 2                             # the only rejector
    assert order[1:] == [0, 1]                       # stable among inf ranks


def test_ordered_conjuncts_empty_sample_is_identity():
    assert ordered_conjuncts(np.zeros((0, 2)), np.array([0.5, 0.5]),
                             [[0], [1]]) == [0, 1]


def test_ordered_conjuncts_rejects_width_mismatch():
    with pytest.raises(ValueError, match="disagrees"):
        ordered_conjuncts(np.zeros((3, 2)), np.array([0.5, 0.5]), [[0]])


def test_apply_conjunct_order_permutes_jointly_and_validates():
    clauses = [[0], [1, 2]]
    theta = np.array([0.3, 0.7])
    oc, ot = apply_conjunct_order(clauses, theta, [1, 0])
    assert oc == [[1, 2], [0]] and ot.tolist() == [0.7, 0.3]
    same_c, same_t = apply_conjunct_order(clauses, theta, None)
    assert same_c is clauses and same_t is theta     # None = no-op
    with pytest.raises(ValueError, match="permutation"):
        apply_conjunct_order(clauses, theta, [0, 0])
    with pytest.raises(ValueError, match="permutation"):
        apply_conjunct_order(clauses, theta, [0])


# --- the ordering invariant, per backend ------------------------------------

def _materialized_cnf(ds):
    specs, clauses, thetas = representative_cnf(ds)
    feats = SimulatedExtractor(ds).materialize(specs, CostLedger())
    return feats, clauses, thetas


@pytest.mark.parametrize("engine", ENGINES)
def test_candidate_set_invariant_under_order_and_early_reject(engine):
    """Permuted conjuncts + early rejection vs natural order full width:
    bit-identical candidates on a ragged corpus and the empty scaffold."""
    ds = synth.police_records(n_incidents=37, reports_per_incident=2, seed=5)
    feats, clauses, thetas = _materialized_cnf(ds)
    theta = np.asarray(thetas, float)
    base = get_engine(engine, early_reject=False, **_OPTS[engine]).evaluate(
        feats, clauses, thetas)
    assert base.stats.n_candidates > 0
    rev = list(reversed(range(len(clauses))))
    oc, ot = apply_conjunct_order(clauses, theta, rev)
    perm = get_engine(engine, **_OPTS[engine]).evaluate(feats, oc, list(ot))
    assert perm.candidates == base.candidates
    # empty scaffold: order is vacuous, both paths emit the cross product
    empty = get_engine(engine, **_OPTS[engine]).evaluate(feats, [], [])
    assert len(empty.candidates) == ds.n_l * ds.n_r


# --- skewed selectivity: ordered short-circuit does less work ---------------

def _skewed_fixture():
    """33 x 128, 2-clause CNF: the clause listed FIRST passes every pair;
    the clause listed second matches only R band [64, 96).  Natural order
    wastes full-width work on 3 dead bands; selectivity order puts the
    banded clause first so those bands short-circuit after one conjunct."""
    n_l, n_r = 33, 128
    texts_l = ["same text"] * n_l
    texts_r = ["zzz yyy"] * 64 + ["same text"] * 32 + ["zzz yyy"] * 32
    tag = FeaturizationSpec("tag", "", "word_overlap", "llm", "tag")
    name = FeaturizationSpec("name", "", "word_overlap", "llm", "name")
    feats = [vectorize(tag, ["x"] * n_l, ["x"] * n_r),
             vectorize(name, texts_l, texts_r)]
    clauses = [[0], [1]]                             # unselective first
    thetas = [0.5, 0.25]
    want = [(i, j) for i in range(n_l) for j in range(64, 96)]
    return feats, clauses, thetas, want


def test_skew_fixture_ordering_flips_the_clauses():
    """ordered_conjuncts on sampled clause distances picks the banded
    clause first — the measurement the plan gets for free from S'."""
    feats, clauses, thetas, _ = _skewed_fixture()
    # sample rows: clause 0 distance always 0 (passes), clause 1 mostly 1
    cd = np.array([[0.0, 1.0]] * 6 + [[0.0, 0.0]] * 2)
    assert ordered_conjuncts(cd, np.asarray(thetas, float), clauses) == [1, 0]


@pytest.mark.parametrize("engine", ENGINES)
def test_short_circuit_saves_evals_at_identical_candidates(engine):
    """Acceptance property: on the skewed regime, ordered + early-reject
    charges strictly fewer conjunct_evals than unordered full width while
    the candidate set stays bit-identical."""
    feats, clauses, thetas, want = _skewed_fixture()
    opts = dict(_OPTS[engine])
    if engine == "sharded":
        opts["capacity"] = 2048                      # no retry re-work noise
    full = get_engine(engine, early_reject=False, **opts).evaluate(
        feats, clauses, thetas)
    oc, ot = apply_conjunct_order(clauses, np.asarray(thetas, float), [1, 0])
    ordered = get_engine(engine, **opts).evaluate(feats, oc, list(ot))
    assert full.candidates == ordered.candidates == sorted(want)
    assert 0 < ordered.stats.conjunct_evals < full.stats.conjunct_evals, (
        f"{engine}: ordered={ordered.stats.conjunct_evals} "
        f"full={full.stats.conjunct_evals}")
    assert ordered.stats.flops_per_candidate < full.stats.flops_per_candidate


def test_dead_band_skips_tail_conjuncts_and_emits_nothing():
    """Zero-popcount whole-band skip, per chunk: a band whose first
    conjunct rejects everything emits no candidates and is charged at
    exactly the 1-conjunct rate; the hot band pays full width."""
    feats, clauses, thetas, want = _skewed_fixture()
    oc, ot = apply_conjunct_order(clauses, np.asarray(thetas, float), [1, 0])
    eng = get_engine("sharded", tl=32, tr=32, r_chunk=32, capacity=2048)
    chunks = list(eng.evaluate_stream(feats, oc, list(ot)))
    assert len(chunks) == 4                          # one per R band
    assert [bool(ch.candidates) for ch in chunks] == [False, False, True,
                                                      False]
    assert sorted(chunks[2].candidates) == want
    # 33 L rows pad to 64 (tl=32); each band covers 64 x 32 padded pairs
    band_pairs = 64 * 32
    for ch in (chunks[0], chunks[1], chunks[3]):     # dead bands: 1 conjunct
        assert ch.stats.conjunct_evals == band_pairs
    assert chunks[2].stats.conjunct_evals == 2 * band_pairs


def test_numpy_engine_block_skip_charges_first_clause_only():
    """The oracle backend's per-block accounting: an all-dead block stops
    after clause 1 when early_reject is on, and never when it is off."""
    feats, clauses, thetas, want = _skewed_fixture()
    oc, ot = apply_conjunct_order(clauses, np.asarray(thetas, float), [1, 0])
    on = get_engine("numpy", block=32).evaluate(feats, oc, list(ot))
    off = get_engine("numpy", block=32, early_reject=False).evaluate(
        feats, oc, list(ot))
    assert on.candidates == off.candidates == sorted(want)
    # 33x128 in 32-blocks: 2x4 (L, R) blocks; only R block [64, 96) is
    # hot.  off: every block pays both clauses; on: the 6 dead blocks
    # (R bands 0, 1, 3) stop after the banded clause.
    n_pairs = 33 * 128
    dead_pairs = 33 * 96
    assert off.stats.conjunct_evals == 2 * n_pairs
    assert on.stats.conjunct_evals == 2 * (n_pairs - dead_pairs) + dead_pairs


# --- plan/config plumbing ---------------------------------------------------

def _stack(seed=3):
    ds = synth.police_records(n_incidents=30, reports_per_incident=2,
                              seed=seed)
    return ds, ds.make_oracle(), SimulatedProposer(ds), \
        SimulatedExtractor(ds, seed=seed)


def test_plan_join_measures_conjunct_order():
    ds, oracle, proposer, extractor = _stack()
    cfg = FDJConfig(engine="numpy", seed=3, block=32)
    plan = plan_join(ds, oracle, proposer, extractor, cfg)
    assert not plan.degenerate
    c = plan.sc_local.n_clauses
    assert sorted(plan.conjunct_order) == list(range(c))


def test_join_order_toggle_is_output_invariant():
    """order_conjuncts=False (debug escape hatch) changes nothing but the
    evaluation order: pairs, recall, candidate count all identical."""
    ds, oracle, proposer, extractor = _stack()
    a = fdj_join(ds, oracle, proposer, extractor,
                 FDJConfig(engine="numpy", seed=3, block=32))
    ds2, oracle2, proposer2, extractor2 = _stack()
    b = fdj_join(ds2, oracle2, proposer2, extractor2,
                 FDJConfig(engine="numpy", seed=3, block=32,
                           order_conjuncts=False))
    assert a.pairs == b.pairs
    assert a.recall == b.recall
    assert a.candidate_count == b.candidate_count


def test_fdjconfig_prefetch_depth_reaches_engine():
    eng = _get_engine(FDJConfig(engine="sharded", prefetch_depth=4))
    assert eng.effective_prefetch_depth == 4
    default = _get_engine(FDJConfig(engine="sharded"))
    assert default.effective_prefetch_depth == 2
