"""Static analysis pass (repro.analysis): clean-tree verdicts + seeded
violations.

Each analyzer is regression-tested from both sides: the real tree must
come back clean (the CI gate), and a synthetic module seeded with each
violation class must be caught — an analyzer that silently stops
matching is itself a regression.  Analyzers take ``(path, source)``
pairs, so the fixtures feed through the exact code CI runs.
"""

import json
import subprocess
import sys
import threading
from pathlib import Path

import pytest

from repro.analysis.checkers import (check_legacy_kwargs,
                                     check_metric_names,
                                     check_tracer_guards, check_wallclock,
                                     run_checkers)
from repro.analysis.hlo_contracts import (DEFAULT_CONTRACTS,
                                          check_program, dump_manifest,
                                          load_manifest)
from repro.analysis.lockgraph import build_lock_graph, render_text, to_dot

REPO = Path(__file__).resolve().parents[1]


# ---------------------------------------------------------------------------
# lock graph: clean tree
# ---------------------------------------------------------------------------

class TestLockGraphTree:
    def setup_method(self):
        self.g = build_lock_graph()

    def test_tree_has_no_findings(self):
        assert not self.g.findings, "\n".join(str(f) for f in self.g.findings)

    def test_known_nodes_discovered(self):
        names = set(self.g.nodes)
        for expected in (
            "obs.trace.Tracer._lock",
            "obs.metrics.MetricsRegistry._lock",
            "serving.planes.FeaturePlaneStore._lock",
            "serving.fleet.JoinFleet._cond",
            "serving.fleet.JoinFleet._mlock",
            "serving.fleet.BandScheduler._cond",
            "serving.join_service.PlanLibrary._lock",
            "serving.join_service.PlanLibrary.lease.lk",
            "engine.sharded.ShardedEngine._programs_lock",
            "engine.sharded._HOST_MESH_LOCK",
        ):
            assert expected in names, f"lock node {expected} not discovered"

    def test_known_order_edges_present(self):
        edges = self.g.edge_set()
        # the real cross-lock orders the threaded stack relies on: the
        # witness cross-validates these during the fleet stress test
        for e in (
            ("serving.fleet.JoinFleet._cond",
             "serving.planes.FeaturePlaneStore._lock"),
            ("serving.fleet.JoinFleet._mlock",
             "obs.metrics.MetricsRegistry._lock"),
            ("serving.fleet.JoinFleet._mlock",
             "serving.planes.FeaturePlaneStore._lock"),
            ("serving.planes.FeaturePlaneStore._lock",
             "obs.metrics.MetricsRegistry._lock"),
            ("serving.join_service.PlanLibrary.lease.lk",
             "serving.join_service.PlanLibrary._lock"),
        ):
            assert e in edges, f"expected order edge missing: {e}"

    def test_store_rlock_self_loop_allowed(self):
        # FeaturePlaneStore._lock is an RLock re-entered by design
        # (_provide -> _evict_to_budget): a self-loop edge, not a finding
        n = "serving.planes.FeaturePlaneStore._lock"
        assert self.g.nodes[n].kind == "RLock"
        assert (n, n) in self.g.edge_set()

    def test_lease_blocking_hold_is_waived_and_visible(self):
        # label_pairs IS held under the planning lease by design — the
        # waiver must be reported, never silently dropped
        assert any("label_pairs" in w and "lease" in w
                   for w in self.g.waived), self.g.waived

    def test_renderers(self):
        txt = render_text(self.g)
        assert "order edges" in txt and "no lock-order" in txt
        dot = to_dot(self.g)
        assert dot.startswith("digraph lock_order")
        assert "JoinFleet._mlock" in dot


# ---------------------------------------------------------------------------
# lock graph: seeded violations
# ---------------------------------------------------------------------------

def _mod(name, body):
    return (f"src/repro/{name}.py", body)


class TestLockGraphSeeded:
    def test_cycle_detected(self):
        g = build_lock_graph([_mod("aa", """
import threading

class A:
    def __init__(self):
        self._la = threading.Lock()
        self._lb = threading.Lock()

    def fwd(self):
        with self._la:
            with self._lb:
                pass

    def bwd(self):
        with self._lb:
            with self._la:
                pass
""")])
        assert any(f.rule == "lock-cycle" for f in g.findings), \
            [str(f) for f in g.findings]

    def test_cross_class_cycle_through_calls(self):
        g = build_lock_graph([_mod("bb", """
import threading

class S:
    def __init__(self):
        self._lock = threading.Lock()
        self.m = M()

    def work(self):
        with self._lock:
            self.m.bump()

class M:
    def __init__(self):
        self._lock = threading.Lock()
        self.s: S = None

    def bump(self):
        with self._lock:
            pass

    def report(self):
        with self._lock:
            self.s.work()
""")])
        assert any(f.rule == "lock-cycle" for f in g.findings)

    def test_plain_lock_self_reacquire(self):
        g = build_lock_graph([_mod("cc", """
import threading

class C:
    def __init__(self):
        self._lock = threading.Lock()

    def outer(self):
        with self._lock:
            self.inner()

    def inner(self):
        with self._lock:
            pass
""")])
        assert any(f.rule == "lock-self-deadlock" for f in g.findings)

    def test_rlock_self_reacquire_allowed(self):
        g = build_lock_graph([_mod("dd", """
import threading

class D:
    def __init__(self):
        self._lock = threading.RLock()

    def outer(self):
        with self._lock:
            self.inner()

    def inner(self):
        with self._lock:
            pass
""")])
        assert not g.findings, [str(f) for f in g.findings]

    def test_blocking_under_lock(self):
        g = build_lock_graph([_mod("ee", """
import threading
import jax

class E:
    def __init__(self):
        self._lock = threading.Lock()

    def pull(self, x):
        with self._lock:
            return jax.device_get(x)
""")])
        assert any(f.rule == "lock-blocking"
                   and "jax.device_get" in f.msg for f in g.findings)

    def test_transitive_blocking_under_lock(self):
        g = build_lock_graph([_mod("ff", """
import threading
from concurrent.futures import Future

class F:
    def __init__(self):
        self._lock = threading.Lock()
        self.fut: Future = None

    def outer(self):
        with self._lock:
            self.helper()

    def helper(self):
        return self.fut.result()
""")])
        assert any(f.rule == "lock-blocking"
                   and "Future.result" in f.msg for f in g.findings)

    def test_acquire_release_pairs_tracked(self):
        # explicit .acquire()/.release() between the pair is "held"
        g = build_lock_graph([_mod("gg", """
import threading
import time

class G:
    def __init__(self):
        self._lock = threading.Lock()

    def bad(self):
        self._lock.acquire()
        time.sleep(0.1)
        self._lock.release()

    def fine(self):
        self._lock.acquire()
        self._lock.release()
        time.sleep(0.1)
""")])
        bad = [f for f in g.findings if f.rule == "lock-blocking"]
        assert len(bad) == 1 and "time.sleep" in bad[0].msg

    def test_contextmanager_yield_holds_propagate(self):
        # a cm holding a lock at yield makes callers' with-bodies held
        g = build_lock_graph([_mod("hh", """
import contextlib
import threading
import time

class H:
    def __init__(self):
        self._lock = threading.Lock()

    @contextlib.contextmanager
    def guard(self):
        with self._lock:
            yield

    def caller(self):
        with self.guard():
            time.sleep(0.1)
""")])
        assert any(f.rule == "lock-blocking" for f in g.findings)

    def test_untyped_dict_get_does_not_fabricate_edges(self):
        # name-based resolution must not bind dict .get to a repo method
        g = build_lock_graph([_mod("ii", """
import threading

class Store:
    def __init__(self):
        self._lock = threading.Lock()
        self._d = {}

    def get(self, k):
        with self._lock:
            return self._d.get(k)

class User:
    def __init__(self):
        self._lock = threading.Lock()
        self.cache = {}

    def lookup(self, k):
        with self._lock:
            return self.cache.get(k)
""")])
        # User._lock -> Store._lock would be fabricated by naive matching
        assert ("ii.User._lock", "ii.Store._lock") not in g.edge_set()
        assert not g.findings


# ---------------------------------------------------------------------------
# checkers: clean tree + seeded violations
# ---------------------------------------------------------------------------

class TestCheckers:
    def test_tree_clean(self):
        fs = run_checkers()
        assert not fs, "\n".join(str(f) for f in fs)

    def test_unguarded_tracer_call(self):
        fs = check_tracer_guards([_mod("t1", """
from repro.obs.trace import current_tracer

def hot(t0, t1):
    tracer = current_tracer()
    tracer.record_span("x", t0, t1)
""")])
        assert len(fs) == 1 and fs[0].rule == "tracer-guard"

    def test_guarded_tracer_call_ok(self):
        fs = check_tracer_guards([_mod("t2", """
from repro.obs.trace import current_tracer, Tracer

def hot(t0, t1):
    tracer = current_tracer()
    if tracer:
        tracer.record_span("x", t0, t1)
    tracer and tracer.event("y")
    with tracer.span("z"):
        pass

def helper(tracer: Tracer, t0, t1):
    # non-Optional annotation states the caller guards
    tracer.record_span("w", t0, t1)
""")])
        assert not fs, [str(f) for f in fs]

    def test_legacy_from_legacy_flagged(self):
        fs = check_legacy_kwargs([_mod("l1", """
from repro.core.join import QueryOptions

def go(svc):
    return svc.query(QueryOptions.from_legacy(engine="numpy"))
""")])
        assert any(f.rule == "legacy-kwargs" and "from_legacy" in f.msg
                   for f in fs)

    def test_legacy_query_kwargs_flagged(self):
        fs = check_legacy_kwargs([_mod("l2", """
def go(svc):
    return svc.query(engine="numpy", recall_target=0.9)
""")])
        assert len(fs) == 1 and "engine" in fs[0].msg

    def test_typed_options_query_ok(self):
        fs = check_legacy_kwargs([_mod("l3", """
from repro.core.join import QueryOptions

def go(svc):
    return svc.query(QueryOptions(engine="numpy", recall_target=0.9))
""")])
        assert not fs

    def test_unmapped_metric_name_flagged(self):
        fs = check_metric_names([_mod("m1", """
def go(metrics):
    metrics.inc("serve.plan_hits")
    metrics.inc("serve.plan_hitz")
""")])
        assert len(fs) == 1 and "serve.plan_hitz" in fs[0].msg

    def test_wallclock_flagged_on_span_path(self):
        fs = check_wallclock([("src/repro/obs/t3.py", """
import time

def span_open():
    return time.time()
""")])
        assert len(fs) == 1 and fs[0].rule == "wallclock"

    def test_wallclock_waiver_comment(self):
        fs = check_wallclock([("src/repro/obs/t4.py", """
import time

def meta():
    return time.time()  # wallclock-ok: export metadata, not span math
""")])
        assert not fs

    def test_wallclock_ignored_off_span_path(self):
        fs = check_wallclock([("src/repro/launch/t5.py", """
import time

def wall():
    return time.time()
""")])
        assert not fs


# ---------------------------------------------------------------------------
# HLO contracts
# ---------------------------------------------------------------------------

_HLO_OK = """
ENTRY %main (p0: s32[4]) -> s32[4] {
  %counts = s32[2]{0} all-gather(%p0), replica_groups={{0,4},{1,5},{2,6},{3,7}}
  %local = s32[8]{0} all-gather(%p0), replica_groups={{0,1,2,3},{4,5,6,7}}
}
"""

_HLO_INJECTED = """
ENTRY %main (p0: f32[1024]) -> f32[1024] {
  %counts = s32[2]{0} all-gather(%p0), replica_groups={{0,4},{1,5},{2,6},{3,7}}
  %planes = f32[1024]{0} all-reduce(%p0), replica_groups={{0,4},{1,5},{2,6},{3,7}}
}
"""


class TestHLOContracts:
    def setup_method(self):
        self.c = DEFAULT_CONTRACTS["sharded_chunk_step"]

    def test_counts_only_program_passes(self):
        fs, rep = check_program(_HLO_OK, self.c, n_pods=2, pod_size=4,
                                plane_bytes=1 << 20)
        assert not fs, [str(f) for f in fs]
        assert rep["cross_pod_ops"] == 1
        assert rep["collective_kinds"] == ["all-gather"]

    def test_injected_collective_fails_with_named_diff(self):
        fs, _ = check_program(_HLO_INJECTED, self.c, n_pods=2, pod_size=4,
                              plane_bytes=1 << 20)
        msgs = "\n".join(str(f) for f in fs)
        assert "all-reduce" in msgs          # named op
        assert "sharded_chunk_step" in msgs  # named manifest entry
        # flagged on all three axes: unreviewed kind, unreviewed
        # cross-pod kind, and over the counts budget
        assert sum("not in the reviewed op-set" in str(f) for f in fs) == 1
        assert any("crosses a pod boundary" in str(f) for f in fs)
        assert any("count budget" in str(f) for f in fs)

    def test_missing_count_gather_fails(self):
        hlo = """
ENTRY %main (p0: s32[4]) -> s32[4] {
  %local = s32[8]{0} all-gather(%p0), replica_groups={{0,1,2,3},{4,5,6,7}}
}
"""
        fs, _ = check_program(hlo, self.c, n_pods=2, pod_size=4,
                              plane_bytes=1 << 20)
        assert any("found no pod-crossing" in str(f) for f in fs)

    def test_single_pod_must_not_cross(self):
        fs, _ = check_program(_HLO_OK, self.c, n_pods=1, pod_size=8,
                              plane_bytes=1 << 20)
        # with pod_size=8 nothing crosses; shrink it so groups span pods
        assert not fs
        fs, _ = check_program(_HLO_OK, self.c, n_pods=1, pod_size=4,
                              plane_bytes=1 << 20)
        assert any("single-pod" in str(f) for f in fs)

    def test_manifest_round_trip(self, tmp_path):
        p = str(tmp_path / "m.json")
        dump_manifest(DEFAULT_CONTRACTS, p)
        back = load_manifest(p)
        assert back == DEFAULT_CONTRACTS

    def test_committed_manifest_loads_and_covers_chunk_step(self):
        contracts = load_manifest()
        assert "sharded_chunk_step" in contracts
        c = contracts["sharded_chunk_step"]
        assert c.require_cross_pod
        assert "all-gather" in c.collectives
        # budgets match the dry-run's historical envelope
        assert c.cross_op_budget(2) == 512
        assert c.host_pull_budget(203, 8, 2) == 8 * 203 + 12 * 16 + 1024


# ---------------------------------------------------------------------------
# PlanLibrary lease lifecycle (satellite regression)
# ---------------------------------------------------------------------------

class TestPlanLibraryLeases:
    def test_lease_entry_dropped_when_uncontended(self):
        from repro.serving.join_service import PlanLibrary
        lib = PlanLibrary()
        for i in range(100):
            with lib.lease(("fp", "fp", i)):
                pass
        assert lib._leases == {}, (
            f"{len(lib._leases)} lease locks leaked after release")

    def test_contended_lease_serializes_then_drops(self):
        from repro.serving.join_service import PlanLibrary
        lib = PlanLibrary()
        key = ("fp", "fp", 0)
        order = []
        gate = threading.Event()

        def loser():
            gate.wait()
            with lib.lease(key):
                order.append("loser")

        t = threading.Thread(target=loser)
        t.start()
        with lib.lease(key):
            gate.set()          # loser now contends while we hold it
            while lib._leases[key][1] != 2:
                pass            # spin until the waiter has registered
            order.append("winner")
        t.join()
        assert order == ["winner", "loser"]
        assert lib._leases == {}, "contended lease entry leaked"


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_cli_check_passes_on_tree(tmp_path):
    dot = tmp_path / "lockgraph.dot"
    r = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--check",
         "--dot", str(dot)],
        capture_output=True, text=True, cwd=str(REPO),
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"})
    assert r.returncode == 0, r.stdout + r.stderr
    assert "analysis: clean" in r.stdout
    assert dot.read_text().startswith("digraph lock_order")


def test_committed_manifest_is_valid_json():
    raw = json.loads((REPO / "benchmarks/baseline/hlo_manifest.json")
                     .read_text())
    assert "sharded_chunk_step" in raw["programs"]
