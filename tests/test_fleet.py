"""Multi-tenant fleet acceptance: one shared plane store behind N
concurrent tenants (ISSUE 9).

Invariants under test:
  * shared-corpus dedup — the second tenant's cold query charges $0
    extraction, moves 0 plane bytes H2D, re-pays no planning, and its
    ledger proves it (``plane_dedup_hits`` > 0);
  * fair eviction — charged bytes split evenly across an entry's owners;
    per-tenant budget pressure releases only that tenant's LRU entries
    (a shared entry drops ownership, a solely-owned one is evicted);
    global pressure takes unowned entries first, then the most-loaded
    tenant's; no registered tenants falls back to plain LRU;
  * concurrency — N threads of mixed cold/warm queries through one fleet
    return pairs byte-identical to a serial run, with consistent
    submitted/completed/failed and plan-build counters;
  * BandScheduler — FIFO ticket grants, ``interleaves`` counts owner
    switches; PlanLibrary — loaned plans are isolated deep copies.
"""

import threading
import time

import numpy as np
import pytest

from repro.core.costs import CostLedger
from repro.core.featurize import FeaturizationSpec
from repro.core.join import FDJConfig, QueryOptions
from repro.data import synth
from repro.data.cnf_fixtures import representative_cnf
from repro.data.simulated_llm import SimulatedExtractor
from repro.serving.fleet import BandScheduler, JoinFleet
from repro.serving.join_service import PlanLibrary
from repro.serving.planes import FeaturePlaneStore, corpus_fingerprint


def _ds(seed=3, n=12):
    return synth.police_records(n_incidents=n, reports_per_incident=2,
                                seed=seed)


def _cfg(**kw):
    kw.setdefault("mc_trials", 4000)
    return FDJConfig(engine="numpy", engine_opts=dict(block=64), seed=0,
                     **kw)


# --- store tenancy: charging + fair eviction --------------------------------

def _spec(name):
    return FeaturizationSpec(name, "", "word_overlap", "llm", name)


def _put(store, name, tenant=None, n=64):
    """Pin one n*4-byte scalar plane keyed by ``name``."""
    host = np.zeros(n, np.float32)
    return store.put(_spec(name), "l", "fp", [None] * n, host, "scalar",
                     1.0, tenant=tenant)


def _get(store, name, tenant=None):
    return store.get(_spec(name), "l", "fp", tenant=tenant)


def test_shared_entry_splits_charged_bytes():
    store = FeaturePlaneStore()
    store.register_tenant("a")
    store.register_tenant("b")
    _put(store, "p", tenant="a")                     # 256 bytes, a produced
    e = _get(store, "p", tenant="b")                 # b joins the owners
    assert e.owners == {"a", "b"} and e.producer == "a"
    assert store.dedup_hits == 1                     # hit off a's plane
    assert store.tenant_bytes("a") == store.tenant_bytes("b") == 128.0


def test_tenant_budget_releases_only_own_entries():
    store = FeaturePlaneStore()
    store.register_tenant("a", byte_budget=300)
    store.register_tenant("b")
    _put(store, "a1", tenant="a")
    _put(store, "b1", tenant="b")
    _put(store, "a2", tenant="a")    # a at 512 > 300: releases a's LRU (a1)
    assert _get(store, "a1") is None and store.evictions == 1
    assert _get(store, "b1") is not None             # b untouched
    assert _get(store, "a2") is not None             # newest survives
    assert store.tenant_bytes("a") == 256.0


def test_tenant_budget_on_shared_entry_drops_owner_keeps_entry():
    store = FeaturePlaneStore()
    store.register_tenant("a", byte_budget=300)
    store.register_tenant("b")
    _put(store, "p", tenant="a")
    _get(store, "p", tenant="b")                     # shared: a/b pay 128 each
    _put(store, "a2", tenant="a")    # a at 384 > 300: releases its share of p
    e = _get(store, "p")
    assert e is not None and e.owners == {"b"}       # entry survives for b
    assert store.releases == 1 and store.evictions == 0
    assert store.tenant_bytes("a") == 256.0          # only a2
    assert store.tenant_bytes("b") == 256.0          # now sole owner of p


def test_global_budget_evicts_unowned_before_owned():
    store = FeaturePlaneStore(byte_budget=600)
    store.register_tenant("a")
    _put(store, "stray")                             # unowned, oldest
    _put(store, "a1", tenant="a")
    _put(store, "a2", tenant="a")                    # 768 > 600
    assert _get(store, "stray") is None              # unowned went first
    assert _get(store, "a1") is not None and _get(store, "a2") is not None


def test_global_budget_releases_most_loaded_tenant_first():
    store = FeaturePlaneStore(byte_budget=700)
    store.register_tenant("a")
    store.register_tenant("b")
    _put(store, "a1", tenant="a")
    _put(store, "b1", tenant="b")
    _put(store, "a2", tenant="a")                    # a: 512, b: 256; 768 > 700
    assert _get(store, "a1") is None                 # a's LRU released
    assert _get(store, "b1") is not None and _get(store, "a2") is not None


def test_no_tenants_falls_back_to_plain_lru():
    store = FeaturePlaneStore(byte_budget=600)
    _put(store, "p1")
    _put(store, "p2")
    _put(store, "p3")
    assert _get(store, "p1") is None                 # oldest out
    assert _get(store, "p2") is not None and _get(store, "p3") is not None


def test_provide_dedups_across_tenants():
    ds = _ds()
    store = FeaturePlaneStore()
    store.register_tenant("a")
    store.register_tenant("b")
    specs, *_ = representative_cnf(ds)
    fp_l = corpus_fingerprint(ds.name, "l", ds.texts_l, ds.fields_l)
    fp_r = corpus_fingerprint(ds.name, "r", ds.texts_r, ds.fields_r)
    led_a, led_b = CostLedger(), CostLedger()
    store.provide(specs, SimulatedExtractor(ds), led_a, fp_l=fp_l,
                  fp_r=fp_r, tenant="a")
    h2d_after_a = store.bytes_to_device
    store.provide(specs, SimulatedExtractor(ds), led_b, fp_l=fp_l,
                  fp_r=fp_r, tenant="b")
    assert led_a.inference > 0                       # a paid the extraction
    assert led_b.inference == 0.0                    # b rode a's planes
    assert store.bytes_to_device == h2d_after_a      # and moved nothing
    assert store.dedup_hits >= 2 * len(specs)
    assert store.tenant_bytes("a") == store.tenant_bytes("b") > 0


# --- BandScheduler ----------------------------------------------------------

def test_band_scheduler_counts_steps_and_interleaves():
    sched = BandScheduler()
    order = []

    def work(tag):
        for _ in range(5):
            with sched.step():
                order.append(tag)
            time.sleep(0.001)                        # let the others arrive

    threads = [threading.Thread(target=work, args=(i,)) for i in range(3)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert sched.band_steps == 15
    # interleaves is exactly the number of consecutive grant pairs whose
    # owner differs — recomputable from the observed grant order
    assert sched.interleaves == sum(
        1 for x, y in zip(order, order[1:]) if x != y)
    assert sched.interleaves > 0


def test_band_scheduler_grants_fifo():
    sched = BandScheduler()
    order = []
    started = threading.Barrier(2)

    def late():
        started.wait()
        time.sleep(0.02)                 # arrives while "early" holds a step
        with sched.step():
            order.append("late")

    t = threading.Thread(target=late)
    t.start()
    started.wait()
    with sched.step():
        time.sleep(0.06)                 # "late" queues behind this ticket
        order.append("early")
    t.join()
    assert order == ["early", "late"]    # arrival order, not release luck


# --- PlanLibrary ------------------------------------------------------------

class _FakePlan:
    def __init__(self):
        self.thetas = [0.4]


def test_plan_library_loans_are_isolated_copies():
    lib = PlanLibrary()
    plan = _FakePlan()
    lib.put(("fp", "fp", "k"), plan)
    plan.thetas[0] = 99.0                # caller keeps mutating its own copy
    loan1 = lib.get(("fp", "fp", "k"))
    loan1.thetas[0] = -1.0               # a tenant hot-swaps theta
    loan2 = lib.get(("fp", "fp", "k"))
    assert loan2.thetas == [0.4]         # library copy never leaked
    assert lib.hits == 2 and lib.misses == 0


def test_plan_library_lru_cap_and_miss_counting():
    lib = PlanLibrary()
    for i in range(PlanLibrary._MAX + 1):
        lib.put(("fp", "fp", i), _FakePlan())
    assert lib.get(("fp", "fp", 0)) is None          # oldest evicted
    assert lib.get(("fp", "fp", PlanLibrary._MAX)) is not None
    assert lib.misses == 1 and lib.hits == 1


def test_plan_library_lease_serializes_racing_builders():
    lib = PlanLibrary()
    key = ("fp", "fp", "k")
    builds = []

    def cold_query():
        with lib.lease(key):
            plan = lib.get(key)
            if plan is None:
                time.sleep(0.02)         # a slow plan_join under the lease
                builds.append(1)
                lib.put(key, _FakePlan())

    threads = [threading.Thread(target=cold_query) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(builds) == 1              # losers woke up to a hit


# --- fleet end-to-end -------------------------------------------------------

def test_fleet_second_tenant_cold_query_is_free():
    ds = _ds()
    with JoinFleet(max_concurrent=2) as fleet:
        fleet.add_tenant("a", ds, _cfg())
        fleet.add_tenant("b", ds, _cfg())
        first = fleet.query("a")
        second = fleet.query("b")
        assert first.cost.inference > 0
        assert second.cost.inference == 0.0          # planes deduped
        assert second.cost.labeling == 0.0           # plan deduped
        assert second.cost.construction == 0.0
        assert second.cost.bytes_h2d == 0
        assert second.cost.plane_dedup_hits > 0
        assert second.pairs == first.pairs
        assert fleet.plan_library.misses == 1
        assert fleet.plan_library.hits >= 1


def test_fleet_concurrent_mixed_cold_warm_matches_serial():
    ds = _ds()
    # serial reference: same tenants, one worker, same submission order
    with JoinFleet(max_concurrent=1) as ref:
        for name in ("a", "b", "c"):
            ref.add_tenant(name, ds, _cfg())
        want = {name: [ref.query(name).pairs for _ in range(2)]
                for name in ("a", "b", "c")}

    with JoinFleet(max_concurrent=3) as fleet:
        for name in ("a", "b", "c"):
            fleet.add_tenant(name, ds, _cfg())
        # mixed cold/warm: every tenant's first query races the others'
        # colds, the second rides whatever became resident
        futures = [(name, fleet.submit(name))
                   for _ in range(2) for name in ("a", "b", "c")]
        got = {}
        for name, fut in futures:
            got.setdefault(name, []).append(fut.result().pairs)
        summary = fleet.drain()
    assert got == want                               # byte-identical results
    assert summary["submitted"] == summary["completed"] == 6
    assert summary["failed"] == 0
    assert fleet.plan_library.misses == 1            # one build, ever
    assert fleet.store.snapshot()["puts"] == ref.store.snapshot()["puts"]


def test_fleet_query_options_and_errors():
    ds = _ds()
    with JoinFleet(max_concurrent=2) as fleet:
        fleet.add_tenant("a", ds, _cfg())
        r = fleet.query("a", QueryOptions(recall_target=0.8))
        assert r.join.recall >= 0.8
        with pytest.raises(KeyError):
            fleet.submit("nobody")
        # a worker-side failure must surface at the caller, not vanish
        bad = fleet.submit("a", QueryOptions(overrides={"no_such_knob": 1}))
        with pytest.raises(TypeError):
            bad.result(timeout=30)
        assert fleet.drain()["failed"] == 1


def test_fleet_scopes_scheduler_to_sharded_engine():
    fleet = JoinFleet(max_concurrent=1)
    try:
        cfg = fleet._gated_cfg(FDJConfig(engine="numpy",
                                         engine_opts=dict(block=64)))
        # flat opts got keyed under their engine; the scheduler rides only
        # the sharded entry, so the numpy constructor never sees it
        assert cfg.engine_opts["numpy"] == dict(block=64)
        assert cfg.engine_opts["sharded"]["scheduler"] is fleet.scheduler
    finally:
        fleet.close()
