"""RefinementPump unit tests + streaming/barrier fdj_join parity.

The pump must batch oracle calls, bound its queue, and surface worker
failures; ``fdj_join(stream_refinement=True)`` must return identical
pairs, recall, candidate counts, and ledger totals to barrier mode —
including the Appx-C precision-subset path and the degenerate
empty-scaffold (refine-everything) case.
"""

import threading
import time

import pytest

from repro.core.costs import CostLedger
from repro.core.join import FDJConfig, fdj_join
from repro.core.refine import RefinementPump
from repro.core.scaffold import Scaffold
from repro.data import synth
from repro.data.simulated_llm import SimulatedExtractor, SimulatedProposer
from repro.engine.base import CandidateChunk, EngineStats


def _chunks(groups, engine="scripted"):
    out = []
    for i, g in enumerate(groups):
        stats = EngineStats(engine, n_l=10, n_r=10, n_candidates=len(g),
                            wall_s=0.001, bytes_to_host=8 * len(g))
        out.append(CandidateChunk(sorted(g), stats, i))
    return out


# --- pump units -------------------------------------------------------------

def test_pump_batches_and_accepts():
    calls = []

    def refine(batch):
        calls.append(list(batch))
        return {p for p in batch if p[0] % 2 == 0}   # accept even rows

    pump = RefinementPump(refine, batch_pairs=4, max_queue_chunks=2)
    groups = [[(i, j) for j in range(3)] for i in range(5)]
    res = pump.run(iter(_chunks(groups)))
    flat = [p for g in groups for p in sorted(g)]
    assert res.pairs == {p for p in flat if p[0] % 2 == 0}
    assert res.candidates == sorted(flat)
    # every batch except the final flush is exactly batch_pairs
    assert all(len(b) == 4 for b in calls[:-1]) and len(calls[-1]) <= 4
    assert [p for b in calls for p in b] == flat     # arrival order preserved
    assert res.stats.chunks == 5 and res.stats.batches == len(calls)
    assert res.engine_stats.n_candidates == len(flat)
    assert res.engine_stats.bytes_to_host == 8 * len(flat)


def test_pump_final_mode_runs_once_on_sorted_union():
    seen = []

    def final(cands):
        seen.append(list(cands))
        return set(cands[:2])

    pump = RefinementPump(final=final)
    res = pump.run(iter(_chunks([[(3, 0), (1, 0)], [(2, 0)]])))
    assert seen == [[(1, 0), (2, 0), (3, 0)]]        # one call, sorted union
    assert res.pairs == {(1, 0), (2, 0)}


def test_pump_requires_exactly_one_mode():
    with pytest.raises(ValueError):
        RefinementPump()
    with pytest.raises(ValueError):
        RefinementPump(lambda b: set(), final=lambda c: set())


def test_pump_worker_failure_propagates():
    def refine(batch):
        raise RuntimeError("oracle down")

    pump = RefinementPump(refine, batch_pairs=2, max_queue_chunks=1)
    with pytest.raises(RuntimeError, match="oracle down"):
        pump.run(iter(_chunks([[(0, 0), (0, 1)], [(1, 0)], [(2, 0)]])))


def test_pump_worker_failure_counts_dropped_chunks():
    """A dead worker's failure handler keeps draining the queue (so the
    producer's blocking put can never hang on a full queue) and counts
    every chunk it throws away instead of discarding it silently."""
    all_put = threading.Event()

    def refine(batch):
        # die only after the producer has queued everything: the handler
        # must then drain a deterministic 5 chunks in sink mode
        all_put.wait(5.0)
        raise RuntimeError("oracle down")

    groups = [[(i, 0)] for i in range(6)]

    def stream():
        for ch in _chunks(groups):
            yield ch
        all_put.set()                    # set on the post-last-put next()

    pump = RefinementPump(refine, batch_pairs=1, max_queue_chunks=len(groups))
    with pytest.raises(RuntimeError, match="oracle down"):
        pump.run(stream())
    assert pump.last_stats.chunks_dropped == len(groups) - 1
    assert not any(t.name == "refine-pump" for t in threading.enumerate())


def test_pump_put_blocks_without_busy_wait():
    """The producer's put is a plain blocking put: while backpressured by
    a stalled worker, no producer wall accrues to step2_wall (the old
    50 ms-poll loop charged its own spinning to step ②)."""
    release = threading.Event()

    def refine(batch):
        release.wait(5.0)
        return set(batch)

    def stream():
        for ch in _chunks([[(i, 0)] for i in range(6)]):
            yield ch                     # instant production

    pump = RefinementPump(refine, batch_pairs=1, max_queue_chunks=1)
    out = {}
    t = threading.Thread(target=lambda: out.setdefault(
        "res", pump.run(stream())))
    t.start()
    time.sleep(0.3)                      # producer sits blocked in q.put
    release.set()
    t.join(5.0)
    assert not t.is_alive()
    res = out["res"]
    assert res.pairs == {(i, 0) for i in range(6)}
    assert res.stats.chunks_dropped == 0
    # the ~0.3 s spent blocked in put() is not engine time
    assert res.stats.step2_wall < 0.05


def test_pump_engine_failure_shuts_worker_down():
    """A stream that raises mid-sweep must not leak the worker thread."""
    def refine(batch):
        return set(batch)

    def stream():
        yield _chunks([[(0, 0)]])[0]
        raise RuntimeError("engine died")

    pump = RefinementPump(refine, batch_pairs=1, max_queue_chunks=1)
    with pytest.raises(RuntimeError, match="engine died"):
        pump.run(stream())
    assert not any(t.name == "refine-pump" for t in threading.enumerate())


def test_stream_validation_fails_at_call_site():
    """evaluate_stream must validate eagerly, not at the first next()."""
    from repro.data.cnf_fixtures import representative_cnf
    from repro.data.simulated_llm import SimulatedExtractor as SE
    from repro.engine import get_engine
    ds = synth.police_records(n_incidents=10, reports_per_incident=2)
    specs, clauses, _ = representative_cnf(ds)
    feats = SE(ds).materialize(specs, CostLedger())
    with pytest.raises(ValueError, match="thresholds"):
        get_engine("numpy").evaluate_stream(feats, clauses, [0.5])


def test_pump_overlaps_slow_refinement_with_production():
    """With a slow oracle and slow producer, total << step2 + refine."""
    def refine(batch):
        time.sleep(0.04)
        return set(batch)

    def slow_stream():
        for ch in _chunks([[(i, 0), (i, 1)] for i in range(5)]):
            time.sleep(0.04)                          # engine production
            yield ch

    pump = RefinementPump(refine, batch_pairs=2, max_queue_chunks=2)
    res = pump.run(slow_stream())
    assert res.stats.step2_wall >= 0.15
    assert res.stats.refine_wall >= 0.15
    assert res.stats.overlap_wall > 0.05              # genuinely pipelined
    assert res.stats.total_wall < (res.stats.step2_wall
                                   + res.stats.refine_wall - 0.05)


def test_pump_bounded_queue_backpressures_producer():
    """A stalled worker must stop the producer after max_queue chunks."""
    release = threading.Event()
    produced = []

    def refine(batch):
        release.wait(5.0)
        return set(batch)

    def stream():
        for ch in _chunks([[(i, 0)] for i in range(8)]):
            produced.append(ch.index)
            yield ch

    pump = RefinementPump(refine, batch_pairs=1, max_queue_chunks=2)
    out = {}
    t = threading.Thread(target=lambda: out.setdefault(
        "res", pump.run(stream())))
    t.start()
    time.sleep(0.3)
    # worker holds chunk 0; queue holds 2; producer blocked on the next put:
    # far fewer than all 8 chunks may have been pulled from the stream
    assert len(produced) <= 5
    release.set()
    t.join(5.0)
    assert not t.is_alive()
    assert out["res"].pairs == {(i, 0) for i in range(8)}


# --- fdj_join parity --------------------------------------------------------

def _run_join(stream, *, precision_target=1.0, engine="numpy", seed=3,
              monkey=None):
    ds = synth.police_records(n_incidents=30, reports_per_incident=2,
                              seed=seed)
    oracle = ds.make_oracle()
    cfg = FDJConfig(engine=engine, stream_refinement=stream, seed=seed,
                    precision_target=precision_target, refine_batch_pairs=32,
                    pump_queue_chunks=2, block=32)
    return fdj_join(ds, oracle, SimulatedProposer(ds),
                    SimulatedExtractor(ds, seed=seed), cfg)


def _assert_join_parity(a, b):
    assert a.pairs == b.pairs
    assert a.recall == b.recall and a.precision == b.precision
    assert a.candidate_count == b.candidate_count
    assert a.met_target == b.met_target
    # per-pair charges are additive, so totals agree up to float-sum order
    for k, v in a.cost.breakdown().items():
        assert b.cost.breakdown()[k] == pytest.approx(v, rel=1e-9, abs=1e-12)


@pytest.mark.parametrize("engine", ["numpy", "sharded"])
def test_join_stream_parity(engine):
    a = _run_join(False, engine=engine)
    b = _run_join(True, engine=engine)
    _assert_join_parity(a, b)
    assert b.cost.step2_wall > 0                      # pump recorded walls
    assert a.cost.overlap_wall == 0.0                 # barrier: no overlap


def test_join_stream_parity_precision_subset():
    """Appx-C path: the pump defers to the ladder on the sorted union, so
    the accepted set and oracle spend match barrier mode exactly."""
    a = _run_join(False, precision_target=0.8)
    b = _run_join(True, precision_target=0.8)
    _assert_join_parity(a, b)


def test_join_stream_parity_degenerate_empty_scaffold(monkeypatch):
    """No useful featurization -> refine-everything fallback, both modes."""
    from repro.core import scaffold as scaffold_lib

    monkeypatch.setattr(scaffold_lib, "get_logical_scaffold",
                        lambda *a, **k: Scaffold(clauses=[]))
    a = _run_join(False)
    b = _run_join(True)
    _assert_join_parity(a, b)
    assert a.candidate_count == 60 * 60               # every pair refined
    assert a.engine_stats is None and b.engine_stats is None
