"""Substrate tests: checkpointing (atomicity, rotation, elastic restore),
fault-tolerant training restart, data pipeline straggler backup, optimizer,
serving engine batching equivalence."""

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.common.config import TrainConfig
from repro.configs import get_smoke
from repro.data.pipeline import (PackedLMConfig, PackedLMDataset,
                                 PrefetchLoader)
from repro.models import transformer as tr
from repro.optim import adamw
from repro.serving.engine import Request, ServeEngine


# --------------------------------------------------------------------------
# checkpointing
# --------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_rotation(tmp_path):
    d = str(tmp_path / "ck")
    tree = {"a": jnp.arange(6.0).reshape(2, 3), "b": {"c": jnp.ones(4)}}
    for step in (10, 20, 30, 40):
        ckpt.save(d, step, tree, keep=2)
    assert ckpt.latest_step(d) == 40
    names = sorted(os.listdir(d))
    assert names == ["step_00000030", "step_00000040"]    # rotation kept 2
    restored, step = ckpt.restore(d, tree)
    assert step == 40
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))


def test_checkpoint_atomicity_no_partial_reads(tmp_path):
    d = str(tmp_path / "ck")
    tree = {"w": jnp.zeros(8)}
    ckpt.save(d, 1, tree)
    # a stale .tmp dir (simulated crash mid-write) must be invisible
    os.makedirs(os.path.join(d, "step_00000002.tmp"))
    assert ckpt.latest_step(d) == 1


def test_checkpoint_incompatible_template_rejected(tmp_path):
    d = str(tmp_path / "ck")
    ckpt.save(d, 5, {"w": jnp.zeros(8)})
    with pytest.raises(ValueError):
        ckpt.restore(d, {"w": jnp.zeros(8), "extra": jnp.zeros(2)})


def test_elastic_restore_onto_new_mesh(tmp_path):
    """A checkpoint written unsharded restores onto a (different) mesh."""
    from jax.sharding import PartitionSpec as P
    from repro.distributed.mesh import make_host_mesh
    d = str(tmp_path / "ck")
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    ckpt.save(d, 1, tree)
    mesh = make_host_mesh()
    restored, _ = ckpt.restore(d, tree, mesh=mesh, specs={"w": P("data", None)})
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))
    assert restored["w"].sharding.mesh.shape["data"] == len(jax.devices())


# --------------------------------------------------------------------------
# fault-tolerant training
# --------------------------------------------------------------------------

@pytest.mark.slow
def test_train_restart_after_injected_failure(tmp_path):
    from repro.launch.train import SimulatedFailure, train
    d = str(tmp_path / "ck")
    with pytest.raises(SimulatedFailure):
        train("xlstm-350m", steps_n=12, batch=2, seq=32, ckpt_dir=d,
              ckpt_every=4, fail_at=9, log_every=100)
    assert ckpt.latest_step(d) == 8            # progress survived the crash
    out = train("xlstm-350m", steps_n=12, batch=2, seq=32, ckpt_dir=d,
                ckpt_every=4, log_every=100)   # resumes at 8, finishes
    assert np.isfinite(out["loss"])
    assert ckpt.latest_step(d) == 12


# --------------------------------------------------------------------------
# data pipeline
# --------------------------------------------------------------------------

def test_pipeline_host_sharding_partitions_docs():
    texts = [f"doc {i}" for i in range(10)]
    streams = []
    for h in range(2):
        cfg = PackedLMConfig(seq_len=8, batch_size=1, host_index=h, host_count=2)
        streams.append(PackedLMDataset(texts, cfg).stream)
    # different hosts own different documents
    assert not np.array_equal(streams[0], streams[1])


def test_prefetch_backup_on_straggler():
    texts = ["some training text here"] * 4
    ds = PackedLMDataset(texts, PackedLMConfig(seq_len=16, batch_size=2))

    class StalledLoader(PrefetchLoader):
        def _produce(self):     # producer never produces: permanent straggler
            pass

    loader = StalledLoader(ds, timeout_s=0.05)
    b = loader.next()
    assert b["tokens"].shape == (2, 16)
    assert loader.backup_batches == 1          # self-backup path exercised
    # deterministic: backup equals what the producer would have made
    np.testing.assert_array_equal(b["tokens"], ds.batch_at(0)["tokens"])
    loader.close()


# --------------------------------------------------------------------------
# optimizer
# --------------------------------------------------------------------------

def test_adamw_decreases_loss_quadratic():
    tcfg = TrainConfig(learning_rate=0.1, warmup_steps=1, total_steps=50,
                       weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    opt = adamw.init_opt_state(params)

    def loss(p):
        return jnp.sum(jnp.square(p["w"]))

    for _ in range(50):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw.adamw_update(params, g, opt, tcfg)
    assert float(loss(params)) < 0.1


def test_grad_compression_roundtrip():
    g = {"a": jnp.asarray([0.5, -1.5, 2.0]), "b": jnp.asarray([[1e-3, -1e-3]])}
    for mode in ("fp16", "int8"):
        payload, deq = adamw.compress_grads(g, mode)
        back = deq(payload)
        for k in g:
            np.testing.assert_allclose(np.asarray(back[k]), np.asarray(g[k]),
                                       atol=0.02 if mode == "int8" else 1e-3)


def test_grad_clip():
    g = {"w": jnp.full(4, 100.0)}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(200.0)
    assert float(adamw.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


# --------------------------------------------------------------------------
# serving
# --------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("arch", ["mistral-nemo-12b", "zamba2-1.2b"])
def test_engine_padded_batch_equals_single(arch):
    cfg = dataclasses.replace(get_smoke(arch), dtype="float32")
    params = tr.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=l).astype(np.int32)
               for l in (4, 7, 11)]
    eng = ServeEngine(cfg, params, batch_slots=3, capacity=48)
    reqs = [Request(p, max_new_tokens=5) for p in prompts]
    eng.run(reqs)
    for p, r in zip(prompts, reqs):
        e1 = ServeEngine(cfg, params, batch_slots=1, capacity=48)
        r1 = Request(p, max_new_tokens=5)
        e1.run([r1])
        assert r1.out_tokens == r.out_tokens
