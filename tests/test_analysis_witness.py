"""Runtime lock witness (repro.analysis.witness): unit semantics + the
threaded fleet stress test cross-validating the static lock graph.

The witness patches the ``threading.Lock``/``RLock`` factories so locks
constructed under the include paths become instrumented wrappers that
record per-thread acquisition orders by creation site.  Units prove the
mechanics (order edges, inversion detection, self-deadlock trap,
Condition compatibility, the ``device_get`` blocking guard); the stress
test runs a 3-tenant mixed cold/warm fleet under the witness and
asserts the observed orders embed into the static graph acyclically —
the lockdep-style closing of the loop between AST analysis and reality.
"""

import threading
import time
from pathlib import Path

import numpy as np
import pytest

from repro.analysis.lockgraph import build_lock_graph
from repro.analysis.witness import lock_witness

REPO = Path(__file__).resolve().parents[1]
TESTS = (str(REPO / "tests"),)


# ---------------------------------------------------------------------------
# unit semantics (locks created in THIS file via include_paths=tests/)
# ---------------------------------------------------------------------------

def test_locks_outside_include_paths_stay_raw():
    with lock_witness(include_paths=(str(REPO / "src" / "repro"),)) as w:
        lk = threading.Lock()          # created in tests/, not src/repro
        with lk:
            pass
    assert w.sites == set() and w.edges == {}
    assert type(lk) is not object and not hasattr(lk, "_site")


def test_consistent_order_records_edges_without_cycles():
    with lock_witness(include_paths=TESTS) as w:
        a = threading.Lock()
        b = threading.Lock()
        for _ in range(3):
            with a:
                with b:
                    pass
    assert len(w.sites) == 2
    assert len(w.edges) == 1           # a-site -> b-site only
    ((held, acq),) = list(w.edges)
    assert held.line < acq.line        # a constructed first
    assert w.order_cycles() == []


def test_inverted_order_across_threads_is_a_cycle():
    with lock_witness(include_paths=TESTS) as w:
        a = threading.Lock()
        b = threading.Lock()

        with a:
            with b:
                pass

        def inverted():
            with b:
                with a:                # inversion: b held while taking a
                    pass

        t = threading.Thread(target=inverted)
        t.start()
        t.join()
    cycles = w.order_cycles()
    assert len(cycles) == 1 and len(cycles[0]) == 2


def test_plain_lock_self_reacquire_raises_instead_of_hanging():
    with lock_witness(include_paths=TESTS):
        lk = threading.Lock()
        with lk:
            with pytest.raises(RuntimeError, match="self-deadlock"):
                lk.acquire()


def test_rlock_reentry_and_condition_compat():
    with lock_witness(include_paths=TESTS) as w:
        rl = threading.RLock()
        with rl:
            with rl:                   # reentry: legal, no self-edge
                pass
        cond = threading.Condition()   # backed by an instrumented RLock
        hits = []

        def waiter():
            with cond:
                while not hits:
                    cond.wait(timeout=5)
                hits.append("woke")

        t = threading.Thread(target=waiter)
        t.start()
        time.sleep(0.05)
        with cond:
            hits.append("signal")
            cond.notify()
        t.join(timeout=5)
        assert hits == ["signal", "woke"]
    assert all(h != a for (h, a) in w.edges), "reentry produced a self-edge"


def test_blocking_guard_fires_under_held_lock():
    jax = pytest.importorskip("jax")
    x = jax.numpy.arange(4)
    with lock_witness(include_paths=TESTS, guard_blocking=True) as w:
        lk = threading.Lock()
        assert int(jax.device_get(x)[3]) == 3      # unheld: passes through
        with lk:
            with pytest.raises(AssertionError, match="device_get"):
                jax.device_get(x)
        assert len(w.blocking_violations) == 1
    # guard uninstalled on exit
    assert int(jax.device_get(x)[2]) == 2


# ---------------------------------------------------------------------------
# fleet stress test under the witness (satellite: ISSUE 10)
# ---------------------------------------------------------------------------

def _police(seed):
    from repro.data import synth
    return synth.police_records(n_incidents=12, reports_per_incident=2,
                                seed=seed)


def test_fleet_stress_under_witness_validates_static_graph():
    """3 tenants (two sharing a corpus), 2 rounds of racing cold/warm
    queries: every lock the serving stack takes is instrumented.  The
    run must finish with no observed order cycle, no order that breaks
    the static graph's acyclicity when merged in, and no blocking pull
    under a held lock (the jax.device_get guard is armed throughout)."""
    from repro.core.join import FDJConfig

    static = build_lock_graph()
    assert not static.findings

    with lock_witness(guard_blocking=True) as w:
        from repro.serving.fleet import JoinFleet

        cfg = FDJConfig(engine="numpy", engine_opts=dict(block=64),
                        seed=0, mc_trials=4000)
        with JoinFleet(max_concurrent=3) as fleet:
            fleet.add_tenant("a", _police(3), cfg)
            fleet.add_tenant("b", _police(3), cfg)   # dedups against a
            fleet.add_tenant("c", _police(7), cfg)
            futures = [(name, fleet.submit(name))
                       for _ in range(2) for name in ("a", "b", "c")]
            pairs = {}
            for name, fut in futures:
                pairs.setdefault(name, []).append(fut.result(timeout=120)
                                                  .pairs)
            summary = fleet.drain()

    assert summary["completed"] == 6 and summary["failed"] == 0
    assert pairs["a"][0] == pairs["a"][1] == pairs["b"][0]  # shared corpus
    assert pairs["c"][0] == pairs["c"][1]

    # the witness actually saw the serving stack's locks...
    assert w.sites, "no instrumented lock was ever created"
    by_site = {(n.file, n.line) for n in static.nodes.values()}
    mapped = [s for s in w.sites if (s.file, s.line) in by_site]
    assert mapped, (
        f"no observed creation site mapped onto a static lock node; "
        f"sites={sorted(str(s) for s in w.sites)}")

    # ...and both the observed orders alone and their union with the
    # static graph are cycle-free
    assert w.order_cycles() == [], w.order_cycles()
    assert w.check_against(static) == []
    assert w.blocking_violations == []
