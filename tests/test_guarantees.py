"""The paper's headline guarantee, tested directly: over repeated seeded
runs, ``fdj_join`` achieves recall >= recall_target with failure rate <= δ
(Thm — recall w.h.p.), in both barrier and streaming-refinement modes.

Tier-1 runs a 5-trial smoke (alternating modes); the ≥50-trial statistical
sweep over two synth datasets is marked ``slow`` (scripts/ci.sh runs
tier-1 only; ``pytest -m slow`` runs the sweep).
"""

import math

import numpy as np
import pytest

from repro.core.join import FDJConfig, fdj_join
from repro.data import synth
from repro.data.simulated_llm import SimulatedExtractor, SimulatedProposer

TARGET, DELTA = 0.9, 0.1

_DATASETS = {
    "biodex": lambda seed: synth.biodex(n_notes=150, n_terms=40, seed=seed),
    "police": lambda seed: synth.police_records(
        n_incidents=35, reports_per_incident=2, seed=seed),
}


def _trial(mk_ds, seed: int, stream: bool) -> float:
    ds = mk_ds(seed)
    cfg = FDJConfig(recall_target=TARGET, delta=DELTA, seed=seed,
                    mc_trials=5000, stream_refinement=stream)
    res = fdj_join(ds, ds.make_oracle(), SimulatedProposer(ds),
                   SimulatedExtractor(ds, seed=seed), cfg)
    return res.recall


def test_recall_guarantee_smoke():
    """Tier-1: 5 trials alternating barrier/stream; at δ=0.1, more than one
    failure among five would put the guarantee far outside its budget."""
    fails = 0
    for seed in range(5):
        r = _trial(_DATASETS["biodex"], seed, stream=bool(seed % 2))
        fails += int(r < TARGET)
    assert fails <= 1, f"{fails}/5 trials missed recall target {TARGET}"


@pytest.mark.slow
@pytest.mark.parametrize("stream", [False, True], ids=["barrier", "stream"])
def test_recall_guarantee_sweep(stream):
    """≥50 trials across two dataset families: observed failure rate must
    stay within δ plus two-sigma binomial slack, and mean recall >= T."""
    recalls = []
    for name, mk in _DATASETS.items():
        for seed in range(25):
            recalls.append(_trial(mk, seed, stream))
    trials = len(recalls)
    assert trials >= 50
    fails = sum(r < TARGET for r in recalls)
    slack = 2.0 * math.sqrt(DELTA * (1.0 - DELTA) / trials)
    assert fails / trials <= DELTA + slack, (
        f"failure rate {fails}/{trials} exceeds δ={DELTA} (+{slack:.3f} slack)")
    assert float(np.mean(recalls)) >= TARGET


_SERVE_DATASETS = {
    "movies": lambda seed: synth.movies_pages(
        n_movies=25, cast_size=4, filler_sentences=1, seed=seed),
    "police": lambda seed: synth.police_records(
        n_incidents=30, reports_per_incident=2, seed=seed),
}


def _serving_trial(mk_ds, seed: int) -> float:
    """Cold query -> distribution-shifting append -> recalibrated query:
    the recall the *served* (recalibrated) path actually delivers."""
    from repro.serving.join_service import (JoinService, hold_out_right,
                                            perturb_rows)
    ds = mk_ds(seed)
    base, delta = hold_out_right(ds, max(ds.n_r // 4, 1))
    cfg = FDJConfig(recall_target=TARGET, delta=DELTA, seed=seed,
                    mc_trials=5000)
    svc = JoinService(base, cfg)
    svc.query()
    svc.append_right(perturb_rows(delta, seed=seed))
    return svc.query().join.recall


@pytest.mark.slow
def test_recall_guarantee_survives_shifted_appends():
    """≥50 serving trials with a scripted distribution-shifting append
    between the cold and the recalibrated query: observed failure rate of
    the *post-shift* query must stay within δ plus two-sigma binomial
    slack — recall as a serving-time invariant, not just a plan-time one."""
    recalls = []
    for name, mk in _SERVE_DATASETS.items():
        for seed in range(25):
            recalls.append(_serving_trial(mk, seed))
    trials = len(recalls)
    assert trials >= 50
    fails = sum(r < TARGET for r in recalls)
    slack = 2.0 * math.sqrt(DELTA * (1.0 - DELTA) / trials)
    assert fails / trials <= DELTA + slack, (
        f"post-shift failure rate {fails}/{trials} exceeds δ={DELTA} "
        f"(+{slack:.3f} slack)")
    assert float(np.mean(recalls)) >= TARGET
