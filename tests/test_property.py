"""Property-style tests on system invariants.

Implemented as seeded ``numpy.random`` parameter sweeps (the container has
no ``hypothesis``): each test draws many random instances from fixed seeds
and asserts the invariant on every draw.  Same invariants as the original
suite — threshold selection meets the recall target (and is monotone in
it), CNF evaluation is sound under missing values, the cost ledger adds
up — plus the kernel-vs-reference and data-pipeline determinism checks.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import generation
from repro.core.costs import CostLedger
from repro.core.featurize import FeaturizationSpec, vectorize
from repro.core.scaffold import min_fpr_thresholds
from repro.kernels.fused_cnf_join import ref as cnf_ref
from repro.kernels.fused_cnf_join.kernel import SCAL, VEC, cnf_join_block
from repro.kernels.threshold_sweep.ops import sweep
from repro.kernels.threshold_sweep.ref import threshold_sweep_ref


def _rand_instance(rng):
    """Random (clause-distance matrix, labels) like the old hypothesis strategy."""
    k = int(rng.integers(2, 61))
    f = int(rng.integers(1, 5))
    cd = rng.uniform(0, 1, size=(k, f)).astype(np.float32)
    labels = rng.random(k) < rng.uniform(0.1, 0.7)
    return cd, labels


@pytest.mark.parametrize("seed", range(8))
def test_threshold_selection_meets_observed_recall(seed):
    rng = np.random.default_rng(1000 + seed)
    for _ in range(10):
        cd, labels = _rand_instance(rng)
        if labels.sum() == 0:
            continue
        res = min_fpr_thresholds(cd, labels, 0.8)
        if res.feasible:
            sel = np.all(cd <= res.theta[None, :], axis=1)
            recall = (sel & labels).sum() / labels.sum()
            assert recall >= 0.8 - 1e-9
            assert 0.0 <= res.fpr <= 1.0


@pytest.mark.parametrize("seed", range(6))
def test_threshold_selection_monotone_in_target(seed):
    """Raising the recall target never lowers achieved recall, and (for the
    exactly-solved single-clause case) never lowers the optimal FPR —
    feasible sets are nested."""
    rng = np.random.default_rng(2000 + seed)
    for _ in range(10):
        k = int(rng.integers(5, 80))
        cd = rng.uniform(0, 1, size=(k, 1)).astype(np.float32)
        labels = rng.random(k) < 0.5
        if labels.sum() == 0:
            continue
        prev_fpr, prev_recall = -1.0, -1.0
        for target in (0.5, 0.7, 0.9, 1.0):
            res = min_fpr_thresholds(cd, labels, target)
            if not res.feasible:
                continue
            assert res.recall >= target - 1e-9
            assert res.recall >= prev_recall - 1e-12
            assert res.fpr >= prev_fpr - 1e-12
            prev_fpr, prev_recall = res.fpr, res.recall


@pytest.mark.parametrize("seed", range(6))
def test_cost_to_cover_bounds(seed):
    rng = np.random.default_rng(3000 + seed)
    for _ in range(8):
        d, labels = _rand_instance(rng)
        n_pos, n_neg = int(labels.sum()), int((~labels).sum())
        c = generation.cost_to_cover(d, labels)
        assert c.shape == (n_pos,)
        assert np.all(c >= 0) and np.all(c <= n_neg)


@pytest.mark.parametrize("seed", range(5))
def test_cnf_soundness_under_missing_values(seed):
    """A pair whose clause features are all missing is never admitted when
    every theta < 1 — the missing encoding pins its distance to 1."""
    rng = np.random.default_rng(4000 + seed)
    n = int(rng.integers(6, 20))
    kinds = ["word_overlap", "semantic", "arithmetic"]
    feats, clauses, thetas = [], [], []
    miss_l = rng.random(n) < 0.3
    miss_r = rng.random(n) < 0.3
    for fi, kind in enumerate(kinds):
        if kind == "arithmetic":
            vals_l = [None if m else float(rng.uniform(0, 50)) for m in miss_l]
            vals_r = [None if m else float(rng.uniform(0, 50)) for m in miss_r]
        else:
            vals_l = [None if m else f"tok{rng.integers(0, 9)} tok{rng.integers(0, 9)}"
                      for m in miss_l]
            vals_r = [None if m else f"tok{rng.integers(0, 9)} tok{rng.integers(0, 9)}"
                      for m in miss_r]
        spec = FeaturizationSpec(f"f{fi}", "", kind, "llm", f"f{fi}")
        feats.append(vectorize(spec, vals_l, vals_r))
        clauses.append([fi])
        thetas.append(float(rng.uniform(0.05, 0.95)))
    from repro.engine import get_engine
    res = get_engine("numpy").evaluate(feats, clauses, thetas)
    for (i, j) in res.candidates:
        assert not (miss_l[i] or miss_r[j]), \
            "pair with a missing clause feature admitted below theta<1"


@pytest.mark.parametrize("seed", range(5))
def test_cnf_kernel_equals_ref_random(seed):
    rng = np.random.default_rng(5000 + seed)
    for _ in range(3):
        n_clauses = int(rng.integers(1, 4))
        members = int(rng.integers(1, 4))
        fv, nl, nr, d = 2, 64, 64, 128
        el = rng.normal(size=(fv, nl, d)).astype(np.float32)
        er = rng.normal(size=(fv, nr, d)).astype(np.float32)
        el /= np.linalg.norm(el, axis=-1, keepdims=True)
        er /= np.linalg.norm(er, axis=-1, keepdims=True)
        sl = rng.uniform(0, 1.2, size=(2, nl)).astype(np.float32)
        sr = rng.uniform(0, 1.2, size=(2, nr)).astype(np.float32)
        clauses = tuple(
            tuple((VEC, int(rng.integers(0, fv))) if rng.random() < 0.5
                  else (SCAL, int(rng.integers(0, 2)))
                  for _ in range(members))
            for _ in range(n_clauses))
        thetas = tuple(float(rng.uniform(0.1, 0.9)) for _ in range(n_clauses))
        packed = cnf_join_block(jnp.asarray(el), jnp.asarray(er), jnp.asarray(sl),
                                jnp.asarray(sr), clauses, thetas, tl=32, tr=32,
                                interpret=True)
        expect = cnf_ref.cnf_join_ref(jnp.asarray(el), jnp.asarray(er),
                                      jnp.asarray(sl), jnp.asarray(sr),
                                      clauses, thetas)
        assert np.array_equal(cnf_ref.unpack_mask(np.asarray(packed), nr),
                              np.asarray(expect))


@pytest.mark.parametrize("seed", range(5))
def test_sweep_kernel_equals_ref_random(seed):
    rng = np.random.default_rng(6000 + seed)
    for _ in range(3):
        k = int(rng.integers(10, 400))
        c = int(rng.integers(1, 5))
        g = int(rng.integers(1, 100))
        cd = rng.uniform(0, 1, size=(k, c)).astype(np.float32)
        labels = rng.random(k) < 0.4
        th = rng.uniform(0, 1, size=(g, c)).astype(np.float32)
        pos, sel = sweep(cd, labels, th, tg=64, tk=128)
        expect = np.asarray(threshold_sweep_ref(
            jnp.asarray(cd), jnp.asarray(labels.astype(np.float32)),
            jnp.asarray(th)))
        np.testing.assert_allclose(pos, expect[:, 0], atol=1e-5)
        np.testing.assert_allclose(sel, expect[:, 1], atol=1e-5)


@pytest.mark.parametrize("seed", range(4))
def test_ledger_accounting_adds_up(seed):
    """total == sum of categories after any charge sequence; every charge
    is non-negative and increases exactly its own category."""
    rng = np.random.default_rng(7000 + seed)
    led = CostLedger()
    charges = [
        ("labeling", lambda l, t: l.charge_label(t, 1)),
        ("refinement", lambda l, t: l.charge_refine(t, 1)),
        ("construction", lambda l, t: l.charge_generation(t, t // 2 + 1)),
        ("inference", lambda l, t: l.charge_extraction(t, 1)),
        ("inference", lambda l, t: l.charge_embedding(t)),
    ]
    for _ in range(50):
        cat, fn = charges[int(rng.integers(0, len(charges)))]
        before = led.breakdown()
        fn(led, int(rng.integers(1, 2000)))
        after = led.breakdown()
        assert after[cat] > before[cat]
        for k in ("labeling", "construction", "inference", "refinement"):
            if k != cat:
                assert after[k] == before[k]
    bd = led.breakdown()
    assert bd["total"] == pytest.approx(
        bd["labeling"] + bd["construction"] + bd["inference"] + bd["refinement"])
    assert led.total == pytest.approx(bd["total"])


def test_oracle_labels_charge_ledger_per_call():
    """Oracle labeling cost is linear in the number of labeled pairs."""
    from repro.data.synth import products
    ds = products(n_products=40)
    oracle = ds.make_oracle()
    assert oracle.ledger.total == 0.0
    oracle.label_pairs([(0, 0)], kind="labeling")
    one = oracle.ledger.labeling
    assert one > 0
    oracle.label_pairs([(1, 1), (2, 2)], kind="labeling")
    assert oracle.ledger.labeling > one
    assert oracle.ledger.refinement == 0.0


@pytest.mark.parametrize("seed", range(4))
def test_tokenizer_roundtrip(seed):
    from repro.data.pipeline import ByteTokenizer
    rng = np.random.default_rng(8000 + seed)
    tok = ByteTokenizer(512)
    for _ in range(10):
        text = "".join(chr(rng.integers(32, 127))
                       for _ in range(rng.integers(1, 80)))
        assert tok.decode(tok.encode(text)) == text


@pytest.mark.parametrize("seed", [0, 7, 123])
def test_pipeline_batches_deterministic(seed):
    from repro.data.pipeline import PackedLMConfig, PackedLMDataset
    texts = [f"document {i} with some text body" for i in range(20)]
    cfg = PackedLMConfig(seq_len=32, batch_size=4, seed=seed)
    a = PackedLMDataset(texts, cfg).batch_at(7)
    b = PackedLMDataset(texts, cfg).batch_at(7)
    assert np.array_equal(a["tokens"], b["tokens"])
    assert np.array_equal(a["labels"], b["labels"])
