"""Property-based tests (hypothesis) on system invariants."""

import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import generation
from repro.core.scaffold import Scaffold, min_fpr_thresholds
from repro.kernels.fused_cnf_join import ref as cnf_ref
from repro.kernels.fused_cnf_join.kernel import SCAL, VEC, cnf_join_block
from repro.kernels.threshold_sweep.ops import sweep
from repro.kernels.threshold_sweep.ref import threshold_sweep_ref


dist_matrix = st.integers(2, 60).flatmap(
    lambda k: st.integers(1, 4).flatmap(
        lambda f: st.tuples(
            st.just((k, f)),
            st.lists(st.floats(0, 1, width=32), min_size=k * f, max_size=k * f),
            st.lists(st.booleans(), min_size=k, max_size=k))))


@given(dist_matrix)
@settings(max_examples=40, deadline=None)
def test_threshold_selection_meets_observed_recall(data):
    (k, f), flat, labels = data
    cd = np.asarray(flat, np.float32).reshape(k, f)
    labels = np.asarray(labels, bool)
    if labels.sum() == 0:
        return
    res = min_fpr_thresholds(cd, labels, 0.8)
    if res.feasible:
        sel = np.all(cd <= res.theta[None, :], axis=1)
        recall = (sel & labels).sum() / labels.sum()
        assert recall >= 0.8 - 1e-9
        assert 0.0 <= res.fpr <= 1.0


@given(dist_matrix)
@settings(max_examples=30, deadline=None)
def test_cost_to_cover_bounds(data):
    (k, f), flat, labels = data
    d = np.asarray(flat, np.float32).reshape(k, f)
    labels = np.asarray(labels, bool)
    n_pos, n_neg = int(labels.sum()), int((~labels).sum())
    c = generation.cost_to_cover(d, labels)
    assert c.shape == (n_pos,)
    assert np.all(c >= 0) and np.all(c <= n_neg)


@given(st.integers(0, 2**31 - 1), st.integers(1, 3), st.integers(1, 3))
@settings(max_examples=15, deadline=None)
def test_cnf_kernel_equals_ref_random(seed, n_clauses, members):
    rng = np.random.default_rng(seed)
    fv, nl, nr, d = 2, 64, 64, 128
    el = rng.normal(size=(fv, nl, d)).astype(np.float32)
    er = rng.normal(size=(fv, nr, d)).astype(np.float32)
    el /= np.linalg.norm(el, axis=-1, keepdims=True)
    er /= np.linalg.norm(er, axis=-1, keepdims=True)
    sl = rng.uniform(0, 1.2, size=(2, nl)).astype(np.float32)
    sr = rng.uniform(0, 1.2, size=(2, nr)).astype(np.float32)
    clauses = tuple(
        tuple((VEC, int(rng.integers(0, fv))) if rng.random() < 0.5
              else (SCAL, int(rng.integers(0, 2)))
              for _ in range(members))
        for _ in range(n_clauses))
    thetas = tuple(float(rng.uniform(0.1, 0.9)) for _ in range(n_clauses))
    packed = cnf_join_block(jnp.asarray(el), jnp.asarray(er), jnp.asarray(sl),
                            jnp.asarray(sr), clauses, thetas, tl=32, tr=32,
                            interpret=True)
    expect = cnf_ref.cnf_join_ref(jnp.asarray(el), jnp.asarray(er),
                                  jnp.asarray(sl), jnp.asarray(sr),
                                  clauses, thetas)
    assert np.array_equal(cnf_ref.unpack_mask(np.asarray(packed), nr),
                          np.asarray(expect))


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_sweep_kernel_equals_ref_random(seed):
    rng = np.random.default_rng(seed)
    k = int(rng.integers(10, 400))
    c = int(rng.integers(1, 5))
    g = int(rng.integers(1, 100))
    cd = rng.uniform(0, 1, size=(k, c)).astype(np.float32)
    labels = rng.random(k) < 0.4
    th = rng.uniform(0, 1, size=(g, c)).astype(np.float32)
    pos, sel = sweep(cd, labels, th, tg=64, tk=128)
    expect = np.asarray(threshold_sweep_ref(
        jnp.asarray(cd), jnp.asarray(labels.astype(np.float32)), jnp.asarray(th)))
    np.testing.assert_allclose(pos, expect[:, 0], atol=1e-5)
    np.testing.assert_allclose(sel, expect[:, 1], atol=1e-5)


@given(st.integers(0, 10**6))
@settings(max_examples=10, deadline=None)
def test_tokenizer_roundtrip(seed):
    from repro.data.pipeline import ByteTokenizer
    rng = np.random.default_rng(seed)
    text = "".join(chr(rng.integers(32, 127)) for _ in range(rng.integers(1, 80)))
    tok = ByteTokenizer(512)
    assert tok.decode(tok.encode(text)) == text


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_pipeline_batches_deterministic(seed):
    from repro.data.pipeline import PackedLMConfig, PackedLMDataset
    texts = [f"document {i} with some text body" for i in range(20)]
    cfg = PackedLMConfig(seq_len=32, batch_size=4, seed=seed)
    a = PackedLMDataset(texts, cfg).batch_at(7)
    b = PackedLMDataset(texts, cfg).batch_at(7)
    assert np.array_equal(a["tokens"], b["tokens"])
    assert np.array_equal(a["labels"], b["labels"])
