"""FeaturePlaneStore units: content-hash keying, byte-budget LRU eviction,
hit/miss/H2D counters, and the device-side kernel-layout assembly that
makes the warm path's zero-H2D claim true (pack_features_device must write
byte-identical arrays to the host pack path)."""

import numpy as np
import pytest

from repro.core.costs import CostLedger
from repro.core.featurize import FeaturizationSpec, vectorize
from repro.data import synth
from repro.data.cnf_fixtures import representative_cnf
from repro.data.simulated_llm import SimulatedExtractor
from repro.kernels.fused_cnf_join import ops as cnf_ops
from repro.serving.planes import (FeaturePlaneStore,
                                  corpus_fingerprint)


def _police(n=12, seed=3):
    return synth.police_records(n_incidents=n, reports_per_incident=2,
                                seed=seed)


def _provided(ds, store=None, ledger=None):
    store = store or FeaturePlaneStore()
    ext = SimulatedExtractor(ds)
    specs, clauses, thetas = representative_cnf(ds)
    fp_l = corpus_fingerprint(ds.name, "l", ds.texts_l, ds.fields_l)
    fp_r = corpus_fingerprint(ds.name, "r", ds.texts_r, ds.fields_r)
    planes = store.provide(specs, ext, ledger or CostLedger(),
                           fp_l=fp_l, fp_r=fp_r)
    return store, ext, planes, specs, clauses, thetas, (fp_l, fp_r)


# --- fingerprints -----------------------------------------------------------

def test_fingerprint_is_content_hash():
    ds = _police()
    fp1 = corpus_fingerprint(ds.name, "r", ds.texts_r, ds.fields_r)
    fp2 = corpus_fingerprint(ds.name, "r", list(ds.texts_r),
                             dict(ds.fields_r))
    assert fp1 == fp2                                  # same content, same fp
    # appended row, different side, different name: all change the fp
    assert corpus_fingerprint(ds.name, "l", ds.texts_r, ds.fields_r) != fp1
    assert corpus_fingerprint("other", "r", ds.texts_r, ds.fields_r) != fp1
    grown = corpus_fingerprint(ds.name, "r", ds.texts_r + ["new row"],
                               {k: v + [v[0]] for k, v in ds.fields_r.items()})
    assert grown != fp1


# --- provide: hits, misses, charges ----------------------------------------

def test_provide_charges_cold_then_serves_free():
    ds = _police()
    led1 = CostLedger()
    store, ext, planes, specs, *_ , fps = _provided(ds, ledger=led1)
    assert led1.inference > 0                          # cold: extraction paid
    assert store.misses == 2 * len(specs) and store.hits == 0
    assert store.bytes_to_device == sum(
        f.data_l.nbytes + f.data_r.nbytes for f in planes.feats)

    led2 = CostLedger()
    warm = store.provide(specs, SimulatedExtractor(ds), led2,
                         fp_l=fps[0], fp_r=fps[1])
    assert led2.inference == 0.0                       # warm: free
    assert store.hits == 2 * len(specs)
    # identical planes to a cold materialize
    ref = SimulatedExtractor(ds).materialize(specs, CostLedger())
    for got, want in zip(warm.feats, ref):
        np.testing.assert_array_equal(got.data_l, want.data_l)
        np.testing.assert_array_equal(got.data_r, want.data_r)
        assert got.scale == want.scale


def test_provide_is_sequence_of_feature_data():
    ds = _police()
    _, _, planes, specs, clauses, thetas, _ = _provided(ds)
    assert len(planes) == len(specs)
    # numpy engine consumes the plane set through the Sequence protocol
    from repro.engine import get_engine
    ref = get_engine("numpy").evaluate(
        SimulatedExtractor(ds).materialize(specs, CostLedger()),
        clauses, thetas)
    got = get_engine("numpy").evaluate(planes, clauses, thetas)
    assert got.candidates == ref.candidates


# --- device-side pack parity ------------------------------------------------

@pytest.mark.parametrize("mk", [
    lambda: _police(n=12),                 # scalar + semantic + word_overlap
    lambda: synth.citations(n_docs=37, seed=9),        # ragged, embed-only
], ids=["police_mixed_kinds", "citations_ragged"])
def test_device_pack_matches_host_pack(mk):
    ds = mk()
    _, ext, planes, specs, clauses, _, _ = _provided(ds)
    feats = SimulatedExtractor(ds).materialize(specs, CostLedger())
    for tl, tr in ((32, 64), (64, 128)):
        host = cnf_ops.pack_features(feats, clauses, tl=tl, tr=tr)
        dev = cnf_ops.pack_features_device(planes, clauses, tl=tl, tr=tr)
        for h, d in zip(host[:4], dev[:4]):            # the four plane stacks
            np.testing.assert_array_equal(np.asarray(h), np.asarray(d))
        assert host[4] == dev[4]                       # kclauses
        assert host[5:7] == dev[5:7]                   # (n_l, n_r)
    # assemblies are memoized per geometry on the plane set
    assert len(planes.pack_cache) == 2


def test_stage_planes_reports_zero_h2d_for_resident_planes():
    ds = _police()
    _, _, planes, specs, clauses, _, _ = _provided(ds)
    feats = SimulatedExtractor(ds).materialize(specs, CostLedger())
    cold = cnf_ops.stage_planes(feats, clauses, tl=32, tr=64)
    warm = cnf_ops.stage_planes(planes, clauses, tl=32, tr=64)
    assert cold.bytes_h2d > 0 and warm.bytes_h2d == 0
    assert cold.bytes_reshard == 0 and warm.bytes_reshard == 0


def test_slice_r_views_delta_columns():
    ds = _police()
    _, _, planes, *_ = _provided(ds)
    off = 5
    sub = planes.slice_r(off)
    for full, view in zip(planes.feats, sub.feats):
        np.testing.assert_array_equal(view.data_r, full.data_r[off:])
        np.testing.assert_array_equal(view.data_l, full.data_l)
    for i in range(len(planes)):
        np.testing.assert_array_equal(np.asarray(sub.device_r(i)),
                                      np.asarray(planes.device_r(i))[off:])


# --- LRU eviction -----------------------------------------------------------

def test_byte_budget_evicts_lru():
    spec_a = FeaturizationSpec("a", "", "word_overlap", "llm", "a")
    spec_b = FeaturizationSpec("b", "", "word_overlap", "llm", "b")
    spec_c = FeaturizationSpec("c", "", "word_overlap", "llm", "c")
    vals = [f"tok {i}" for i in range(16)]
    fd = vectorize(spec_a, vals, vals)
    nbytes = fd.data_l.nbytes
    store = FeaturePlaneStore(byte_budget=3 * nbytes)

    for spec in (spec_a, spec_b, spec_c):
        store.put(spec, "l", "fp", vals, fd.data_l, "embed", 1.0)
    assert store.resident_bytes == 3 * nbytes and store.evictions == 0

    store.get(spec_a, "l", "fp")           # refresh a's recency: b is now LRU
    store.put(spec_c, "r", "fp", vals, fd.data_r, "embed", 1.0)
    assert store.evictions == 1 and store.evicted_bytes == nbytes
    assert store.resident_bytes <= 3 * nbytes
    assert store.peek(spec_b, "l", "fp") is None       # b evicted
    assert store.peek(spec_a, "l", "fp") is not None   # a survived (recent)


def test_unbudgeted_store_never_evicts():
    store = FeaturePlaneStore()
    spec = FeaturizationSpec("a", "", "word_overlap", "llm", "a")
    fd = vectorize(spec, ["x"] * 8, ["x"] * 8)
    for i in range(20):
        store.put(spec, "l", f"fp{i}", ["x"] * 8, fd.data_l, "embed", 1.0)
    assert store.evictions == 0 and store.snapshot()["entries"] == 20


def test_counter_delta_between_snapshots():
    store = FeaturePlaneStore()
    spec = FeaturizationSpec("a", "", "word_overlap", "llm", "a")
    fd = vectorize(spec, ["x"] * 8, ["x"] * 8)
    store.put(spec, "l", "fp", ["x"] * 8, fd.data_l, "embed", 1.0)
    s0 = store.snapshot()
    store.get(spec, "l", "fp")
    store.get(spec, "l", "other")                      # miss
    d = FeaturePlaneStore.delta(s0, store.snapshot())
    assert d["hits"] == 1 and d["misses"] == 1 and d["bytes_to_device"] == 0
    assert d["resident_bytes"] == store.resident_bytes  # level, not flow
