"""Packed-bitmask invariants: round-trip, on-device compaction, ragged-tile
errors.

The packed uint32 mask (32 R-neighbours per word) is the wire format
between the fused kernel and candidate extraction; these tests pin down
its algebra: ``unpack(pack(x)) == x``, popcount/prefix-sum compaction
equals the ``np.nonzero`` oracle, and a non-multiple-of-32 R tile raises
instead of silently truncating.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.engine import extract
from repro.kernels.fused_cnf_join import ref as cnf_ref
from repro.kernels.fused_cnf_join.kernel import VEC, cnf_join_block


# --- round-trip -------------------------------------------------------------

@pytest.mark.parametrize("seed,shape", [
    (0, (8, 32)), (1, (33, 64)), (2, (5, 128)), (3, (1, 32)), (4, (64, 96)),
])
def test_pack_unpack_roundtrip(seed, shape):
    rng = np.random.default_rng(seed)
    ok = rng.random(shape) < rng.uniform(0.05, 0.9)
    packed = np.asarray(cnf_ref.pack_mask(jnp.asarray(ok)))
    assert packed.dtype == np.uint32
    assert packed.shape == (shape[0], shape[1] // 32)
    back = cnf_ref.unpack_mask(packed, shape[1])
    assert np.array_equal(back, ok)


def test_unpack_narrower_than_packed():
    """unpack_mask(p, n_r) drops padding columns beyond n_r."""
    ok = np.zeros((4, 64), bool)
    ok[2, 50] = True
    ok[1, 3] = True
    packed = np.asarray(cnf_ref.pack_mask(jnp.asarray(ok)))
    back = cnf_ref.unpack_mask(packed, 40)
    assert back.shape == (4, 40)
    assert back[1, 3] and not back.any(axis=1)[2]


# --- on-device compaction vs np.nonzero oracle ------------------------------

@pytest.mark.parametrize("seed", range(6))
def test_extraction_matches_nonzero_oracle(seed):
    rng = np.random.default_rng(100 + seed)
    nl = int(rng.integers(1, 40))
    nw = int(rng.integers(1, 6))
    ok = rng.random((nl, nw * 32)) < rng.uniform(0.0, 0.6)
    packed = jnp.asarray(np.asarray(cnf_ref.pack_mask(jnp.asarray(ok))))
    cap = int(ok.sum()) + 8
    buf, count = extract.extract_pairs(packed, capacity=cap)
    count = int(count)
    assert count == int(ok.sum())
    got = sorted(map(tuple, np.asarray(buf[:count]).tolist()))
    ii, jj = np.nonzero(ok)
    want = sorted(zip(ii.tolist(), jj.tolist()))
    assert got == want
    # filler untouched past count
    assert np.all(np.asarray(buf[count:]) == -1)


def test_extraction_applies_offsets():
    ok = np.zeros((4, 32), bool)
    ok[0, 0] = ok[3, 31] = True
    packed = jnp.asarray(np.asarray(cnf_ref.pack_mask(jnp.asarray(ok))))
    buf, count = extract.extract_pairs(packed, capacity=4,
                                       row_offset=100, col_offset=1000)
    got = sorted(map(tuple, np.asarray(buf[: int(count)]).tolist()))
    assert got == [(100, 1000), (103, 1031)]


def test_extraction_overflow_detected_not_silent():
    """count keeps growing past capacity so the caller can detect + retry."""
    ok = np.ones((8, 32), bool)                  # 256 candidates
    packed = jnp.asarray(np.asarray(cnf_ref.pack_mask(jnp.asarray(ok))))
    buf, count = extract.extract_pairs(packed, capacity=10)
    assert int(count) == 256                     # true total, not clamped
    # the first `capacity` slots hold valid pairs, nothing corrupted
    got = np.asarray(buf)
    assert got.shape == (10, 2)
    assert (got >= 0).all()


def test_extraction_append_across_chunks():
    """compact_append accumulates two chunks exactly like one big extract."""
    rng = np.random.default_rng(7)
    ok1 = rng.random((16, 64)) < 0.3
    ok2 = rng.random((16, 64)) < 0.3
    p1 = jnp.asarray(np.asarray(cnf_ref.pack_mask(jnp.asarray(ok1))))
    p2 = jnp.asarray(np.asarray(cnf_ref.pack_mask(jnp.asarray(ok2))))
    cap = int(ok1.sum() + ok2.sum()) + 4
    buf = jnp.full((cap, 2), -1, jnp.int32)
    buf, cnt = extract.compact_append(p1, buf, jnp.zeros((), jnp.int32),
                                      row_offset=0, col_offset=0)
    buf, cnt = extract.compact_append(p2, buf, cnt, row_offset=0, col_offset=64)
    got = sorted(map(tuple, np.asarray(buf[: int(cnt)]).tolist()))
    full = np.concatenate([ok1, ok2], axis=1)
    ii, jj = np.nonzero(full)
    assert got == sorted(zip(ii.tolist(), jj.tolist()))


# --- ragged-tile errors -----------------------------------------------------

def test_pack_mask_rejects_ragged_width():
    with pytest.raises(ValueError, match="multiple of 32"):
        cnf_ref.pack_mask(jnp.zeros((4, 40), bool))


def test_kernel_rejects_ragged_tr():
    el = jnp.zeros((1, 64, 128), jnp.float32)
    er = jnp.zeros((1, 48, 128), jnp.float32)
    sl = jnp.zeros((1, 64), jnp.float32)
    sr = jnp.zeros((1, 48), jnp.float32)
    with pytest.raises(ValueError, match="multiple of 32"):
        cnf_join_block(el, er, sl, sr, (((VEC, 0),),), (0.5,),
                       tl=64, tr=48, interpret=True)


def test_kernel_rejects_untiled_shapes():
    el = jnp.zeros((1, 60, 128), jnp.float32)    # 60 % 32 != 0
    er = jnp.zeros((1, 64, 128), jnp.float32)
    sl = jnp.zeros((1, 60), jnp.float32)
    sr = jnp.zeros((1, 64), jnp.float32)
    with pytest.raises(ValueError, match="pack_features"):
        cnf_join_block(el, er, sl, sr, (((VEC, 0),),), (0.5,),
                       tl=32, tr=32, interpret=True)


def test_sharded_engine_rejects_ragged_tr():
    from repro.engine.sharded import ShardedEngine
    with pytest.raises(ValueError, match="multiple of 32"):
        ShardedEngine(tr=48)
    with pytest.raises(ValueError, match="multiple of tr"):
        ShardedEngine(tr=32, r_chunk=40)
