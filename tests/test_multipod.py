"""Multi-pod mesh construction + the pod-axis sharded engine (DESIGN.md §3).

The pod code paths need more than the test process's single CPU device, so
the engine-level checks run ``launch/multipod_dryrun.py`` as a subprocess
(the XLA host-device override is applied only inside that entry point, per
the assignment contract — this process never sees fake devices).  Tier-1
drives small meshes: the (2, 2, 2) pod mesh end to end (parity, stream
disjointness, capacity-1 overflow, serving warm/delta, HLO pod locality),
the degenerate (1, N, 1) mesh, and the Pallas-kernel path.  The full
(2, 16, 16) dry-run mesh — 512 emulated devices — runs under ``-m slow``.

Pure-host units (mesh construction rules, replica-group parsing, the
pod-crossing classifier) run in-process.
"""

import pytest

from repro.launch.dryrun_client import run_dryrun


# --- in-process units -------------------------------------------------------

def test_make_join_mesh_always_carries_pod_axis():
    from repro.distributed.mesh import l_shard_axes, make_join_mesh
    mesh = make_join_mesh(1, 1, 1)              # single CPU device
    assert mesh.axis_names == ("pod", "data", "model")
    assert dict(mesh.shape) == {"pod": 1, "data": 1, "model": 1}
    assert l_shard_axes(mesh) == ("pod", "data")


def test_make_join_mesh_rejects_oversubscription():
    from repro.distributed.mesh import make_join_mesh
    with pytest.raises(ValueError, match="devices"):
        make_join_mesh(64, 64, 64)


def test_l_shard_axes_without_pod():
    from repro.distributed.mesh import l_shard_axes, make_host_mesh
    assert l_shard_axes(make_host_mesh()) == ("data",)


def test_sharded_engine_accepts_join_mesh_on_one_device():
    """The 3-axis pod code path must lower and agree with numpy even on a
    degenerate (1, 1, 1) mesh — no subprocess needed."""
    from repro.core.costs import CostLedger
    from repro.data.cnf_fixtures import representative_cnf
    from repro.data.simulated_llm import SimulatedExtractor
    from repro.data import synth
    from repro.distributed.mesh import make_join_mesh
    from repro.engine import get_engine

    ds = synth.police_records(n_incidents=20, reports_per_incident=2, seed=3)
    specs, clauses, thetas = representative_cnf(ds)
    feats = SimulatedExtractor(ds).materialize(specs, CostLedger())
    want = get_engine("numpy", block=64).evaluate(feats, clauses, thetas)
    got = get_engine("sharded", mesh=make_join_mesh(1, 1, 1), tl=32, tr=32,
                     r_chunk=64).evaluate(feats, clauses, thetas)
    assert got.candidates == want.candidates


def test_parse_replica_groups_explicit_and_iota():
    from repro.distributed.hlo_analysis import parse_replica_groups
    line = ("%ag = s32[8]{0} all-gather(s32[1]{0} %x), "
            "replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}")
    assert parse_replica_groups(line) == [[0, 1, 2, 3], [4, 5, 6, 7]]
    iota = "%ag = s32[2]{0} all-gather(s32[1]{0} %x), replica_groups=[2,4]<=[8]"
    assert parse_replica_groups(iota) == [[0, 1, 2, 3], [4, 5, 6, 7]]
    # transposed iota: iota over (2, 4), T(1,0) -> columns become groups
    t = ("%ag = s32[2]{0} all-gather(s32[1]{0} %x), "
         "replica_groups=[4,2]<=[2,4]T(1,0)")
    assert parse_replica_groups(t) == [[0, 4], [1, 5], [2, 6], [3, 7]]
    assert parse_replica_groups("%add = f32[2] add(f32[2] %a)") is None


def test_pod_crossing_stats_classifies_by_group_span():
    from repro.distributed.hlo_analysis import pod_crossing_stats
    hlo = (
        "\nHloModule m\n\n"
        "ENTRY %main (x: s32[1]) -> (s32[8], s32[2]) {\n"
        "  %x = s32[1]{0} parameter(0)\n"
        "  %intra = s32[8]{0} all-gather(s32[1]{0} %x), "
        "replica_groups={{0,1,2,3,4,5,6,7},"
        "{8,9,10,11,12,13,14,15}}, dimensions={0}\n"
        "  %cross = s32[2]{0} all-gather(s32[1]{0} %x), "
        "replica_groups={{0,8},{1,9},{2,10},{3,11},{4,12},{5,13},"
        "{6,14},{7,15}}, dimensions={0}\n"
        "}\n")
    st = pod_crossing_stats(hlo, pod_size=8)
    assert st.intra_pod_ops == 1 and st.cross_pod_ops == 1
    assert st.intra_pod_bytes == 32.0          # s32[8]
    assert st.cross_pod_bytes == 8.0           # s32[2] — counts only
    assert st.max_cross_op_bytes == 8.0
    assert st.cross_kinds == {"all-gather": 8.0}
    # with one 16-wide pod nothing crosses
    st1 = pod_crossing_stats(hlo, pod_size=16)
    assert st1.cross_pod_ops == 0 and st1.intra_pod_ops == 2


def test_sharded_engine_mesh_resolved_per_evaluation(monkeypatch):
    """Regression: an engine constructed without a mesh must resolve the
    mesh fresh on every evaluation (plane set's mesh, else the host mesh)
    — never pin the first plane set's mesh and silently reuse it for
    later stores/joins on different meshes."""
    from repro.core.costs import CostLedger
    from repro.data.cnf_fixtures import representative_cnf
    from repro.data.simulated_llm import SimulatedExtractor
    from repro.data import synth
    from repro.distributed.mesh import make_join_mesh
    from repro.engine import get_engine
    from repro.engine.sharded import ShardedEngine
    from repro.serving.planes import FeaturePlaneStore, corpus_fingerprint

    ds = synth.police_records(n_incidents=12, reports_per_incident=2, seed=3)
    ext = SimulatedExtractor(ds)
    specs, clauses, thetas = representative_cnf(ds)
    join_mesh = make_join_mesh(1, 1, 1)        # 3-axis mesh, 1 device
    store = FeaturePlaneStore(mesh=join_mesh)
    planes = store.provide(
        specs, ext, CostLedger(),
        fp_l=corpus_fingerprint(ds.name, "l", ds.texts_l, ds.fields_l),
        fp_r=corpus_fingerprint(ds.name, "r", ds.texts_r, ds.fields_r))
    feats = ext.materialize(specs, CostLedger())

    seen = []
    real_build = ShardedEngine._build

    def spy(self, mesh, *a, **k):
        seen.append(mesh)
        return real_build(self, mesh, *a, **k)

    monkeypatch.setattr(ShardedEngine, "_build", spy)
    eng = get_engine("sharded", tl=32, tr=32, r_chunk=64)
    r1 = eng.evaluate(planes, clauses, thetas)  # store's join mesh
    assert seen and all(m is join_mesh for m in seen)
    assert eng.mesh is None                     # nothing pinned

    seen.clear()
    r2 = eng.evaluate(feats, clauses, thetas)   # plain feats: host mesh
    assert seen and all(m is not join_mesh for m in seen), \
        "engine kept the first plane set's mesh for a mesh-less corpus"
    assert all("pod" not in m.axis_names for m in seen)  # host mesh
    assert r2.candidates == r1.candidates

    # a mesh passed at construction always wins, even over the plane
    # set's attached mesh
    host_style = make_join_mesh(1, 1, 1)
    seen.clear()
    pinned = get_engine("sharded", mesh=host_style, tl=32, tr=32, r_chunk=64)
    r3 = pinned.evaluate(planes, clauses, thetas)
    assert seen and all(m is host_style for m in seen)
    assert r3.candidates == r1.candidates


def test_fdjconfig_pods_threads_into_engine(monkeypatch):
    from repro.core.join import FDJConfig, _get_engine
    import repro.distributed.mesh as mesh_mod

    captured = {}
    real = mesh_mod.make_join_mesh

    def spy(n_pods=1, n_data=None, n_model=1):
        captured["n_pods"] = n_pods
        return real(n_pods, n_data, n_model)

    monkeypatch.setattr(mesh_mod, "make_join_mesh", spy)
    # pods=1: no mesh built, engine falls through to its default
    eng = _get_engine(FDJConfig(engine="sharded"))
    assert eng.mesh is None and "n_pods" not in captured
    with pytest.raises(ValueError, match="devices"):
        # pods=2 on a 1-device test process: the mesh build must be
        # attempted (threading works) and reject the oversubscription
        _get_engine(FDJConfig(engine="sharded", pods=2))
    assert captured["n_pods"] == 2


# --- subprocess pod meshes --------------------------------------------------

def test_pod_mesh_2x2x2_end_to_end():
    """(2, 2, 2): parity vs numpy, stream disjointness, capacity-1 retry,
    serving warm/delta invariants, and pod-local collective traffic."""
    rep = run_dryrun("2,2,2")
    assert rep["parity"]["candidates"] > 0
    assert rep["parity"]["bytes_to_host"] < rep["parity"]["plane_bytes"]
    assert rep["stream"]["chunks"] > 1
    assert rep["overflow"]["final_capacity"] >= 4
    s = rep["serving"]
    assert s["warm_extraction_cost"] == 0.0
    assert s["warm_h2d_bytes"] == 0
    assert s["warm_reshard_bytes"] == 0 and s["cold_reshard_bytes"] > 0
    h = rep["hlo"]
    assert h["cross_pod_ops"] >= 1
    assert h["max_cross_op_bytes"] <= h["cross_op_budget_bytes"]


def test_degenerate_pod_mesh_1xNx1():
    """(1, 4, 1): pod axis of width 1 — same output as numpy, and no
    pod-crossing collectives at all."""
    rep = run_dryrun("1,4,1", "--skip-serving")
    assert rep["parity"]["candidates"] > 0
    assert rep["overflow"]["candidates"] == 33 * 33
    assert rep["hlo"]["cross_pod_ops"] == 0


def test_pod_mesh_kernel_path():
    """The Pallas kernel (interpret mode) under the pod-axis shard_map."""
    rep = run_dryrun("2,2,1", "--kernel", "--skip-serving")
    assert rep["use_kernel"] is True
    assert rep["parity"]["candidates"] > 0
    assert rep["hlo"]["cross_pod_ops"] >= 1


@pytest.mark.slow
def test_dryrun_2x16x16_full():
    """The assignment's (2, 16, 16) dry-run mesh: 512 emulated devices,
    pod-axis L sharding end to end.  Acceptance: host traffic
    O(candidates), cross-pod collectives candidate-count sized, warm
    serving queries report zero plane reshard bytes."""
    rep = run_dryrun("2,16,16", timeout=560)
    assert rep["devices"] == 512
    p = rep["parity"]
    assert p["bytes_to_host"] < p["plane_bytes"]
    h = rep["hlo"]
    assert h["cross_pod_ops"] >= 1
    assert h["max_cross_op_bytes"] <= h["cross_op_budget_bytes"]
    assert h["cross_pod_bytes"] < h["staged_plane_bytes"] / 100
    s = rep["serving"]
    assert s["warm_reshard_bytes"] == 0 and s["cold_reshard_bytes"] > 0
    assert s["warm_extraction_cost"] == 0.0 and s["warm_h2d_bytes"] == 0
