"""End-to-end behaviour tests for FDJ (the paper's system).

These assert the paper's headline properties on seeded synthetic datasets:
guaranteed recall/precision, cost below naive on decomposable joins, the
Fig-9 breakdown structure, and numpy/pallas engine equivalence.
"""

import pytest

pytestmark = pytest.mark.slow          # end-to-end joins: minutes, not tier-1

from repro.core.costs import naive_join_cost
from repro.core.join import FDJConfig, fdj_join
from repro.data import synth
from repro.data.simulated_llm import SimulatedExtractor, SimulatedProposer


def _run(ds, **kw):
    cfg = FDJConfig(mc_trials=3000, block=1024, **kw)
    return fdj_join(ds, ds.make_oracle(), SimulatedProposer(ds),
                    SimulatedExtractor(ds), cfg)


@pytest.fixture(scope="module")
def police():
    return synth.police_records(n_incidents=150, reports_per_incident=3)


def test_fdj_meets_guarantees(police):
    res = _run(police)
    assert res.precision == 1.0                 # refinement guarantees T_P=1
    assert res.recall >= 0.9                    # recall target met (seeded)
    assert res.t_prime > 0.9                    # adjusted target applied


def test_fdj_cheaper_than_naive_on_decomposable_join(police):
    res = _run(police)
    naive = naive_join_cost(police.texts_l, police.texts_r)
    assert res.cost.total < 0.6 * naive
    bd = res.cost.breakdown()
    assert bd["refinement"] < 0.3 * naive       # featurization prunes hard
    assert res.candidate_count < police.n_l * police.n_r * 0.25


def test_fdj_builds_nonempty_scaffold(police):
    res = _run(police)
    assert res.scaffold.n_clauses >= 1
    assert len(res.specs) >= 2                  # iterative generation found several


def test_fdj_output_pairs_are_true_matches(police):
    res = _run(police)
    assert res.pairs <= police.truth_set        # precision 1 literally


def test_fdj_engines_agree():
    ds = synth.police_records(n_incidents=60, reports_per_incident=2)
    a = _run(ds, engine="numpy", seed=3)
    b = _run(ds, engine="pallas", seed=3)
    assert a.pairs == b.pairs


def test_fdj_relaxed_precision_target():
    ds = synth.citations(n_docs=250)
    res = _run(ds, precision_target=0.9)
    assert res.recall >= 0.85
    assert res.precision >= 0.8                 # w.h.p. >= 0.9; seeded margin


def test_fdj_degenerates_safely_without_features():
    """If no featurization helps, FDJ must still meet targets (refine all)."""
    ds = synth.biodex(n_notes=120, n_terms=30)
    # proposer that never proposes anything useful
    class NullProposer(SimulatedProposer):
        def propose(self, *a, **k):
            return []
    cfg = FDJConfig(mc_trials=2000, block=1024)
    res = fdj_join(ds, ds.make_oracle(), NullProposer(ds),
                   SimulatedExtractor(ds), cfg)
    assert res.precision == 1.0 and res.recall == 1.0   # refined everything


def test_oracle_label_cache_no_double_charge():
    ds = synth.products(n_products=60)
    oracle = ds.make_oracle()
    pairs = [(0, 0), (1, 1)]
    oracle.label_pairs(pairs, kind="labeling")
    oracle.label_pairs(pairs, kind="labeling")
    # SimulatedOracle itself charges again (no cache) — fdj_join's label()
    # wrapper is what dedupes; assert the wrapper behaviour instead:
    from repro.core.join import fdj_join as _  # noqa: F401
    assert oracle.calls == 4                    # raw oracle has no cache
