"""JoinService acceptance: the warm path re-pays nothing and changes
nothing, the delta path joins only the appended rows and equals a cold
evaluation of the grown corpus.

Invariants under test (ISSUE 3 acceptance criteria):
  * warm repeat query: zero extraction-ledger charges, zero plane H2D
    bytes, output pairs byte-identical to a cold ``fdj_join`` with the
    same config — on all three engines, including stream mode;
  * ``append_right(rows)`` + query ≡ cold join on the concatenated corpus:
    identical pairs/candidates under the served plan, and the recall
    guarantee holds in both the incremental and the replanned path.
"""

import pytest

from repro.core.join import (FDJConfig, QueryOptions, execute_join,
                             fdj_join)
from repro.data import synth
from repro.data.simulated_llm import SimulatedExtractor, SimulatedProposer
from repro.serving.join_service import (JoinService, hold_out_right,
                                        perturb_rows)
from repro.serving.planes import FeaturePlaneStore

# small tiles keep interpret-mode pallas fast on the test shape
_OPTS = {
    "numpy": dict(block=64),
    "pallas": dict(tl=32, tr=64),
    "sharded": dict(tl=32, tr=32, r_chunk=64),
}


def _ds(seed=3, n=15):
    return synth.police_records(n_incidents=n, reports_per_incident=2,
                                seed=seed)


def _movies(seed=3, n=25):
    # embed-only planes: no whole-corpus scale statistic, so appends keep
    # the incremental delta path (police's arithmetic date plane usually
    # rescales and falls back — covered separately below)
    return synth.movies_pages(n_movies=n, cast_size=4, filler_sentences=1,
                              seed=seed)


def _cfg(engine, stream=False, **kw):
    kw.setdefault("mc_trials", 4000)
    return FDJConfig(engine=engine, engine_opts=_OPTS[engine],
                     stream_refinement=stream, seed=0, **kw)


def _cold(ds, cfg):
    return fdj_join(ds, ds.make_oracle(), SimulatedProposer(ds),
                    SimulatedExtractor(ds, seed=0), cfg)


# --- warm path --------------------------------------------------------------

@pytest.mark.parametrize("engine", ["numpy", "pallas", "sharded"])
@pytest.mark.parametrize("stream", [False, True], ids=["barrier", "stream"])
def test_warm_repeat_is_free_and_identical(engine, stream):
    ds = _ds()
    cfg = _cfg(engine, stream)
    ref = _cold(ds, cfg)

    svc = JoinService(ds, cfg)
    cold = svc.query()
    assert not cold.plan_hit
    assert cold.pairs == ref.pairs           # service ≡ one-shot fdj_join

    warm = svc.query()
    assert warm.plan_hit
    assert warm.pairs == ref.pairs           # byte-identical output
    assert warm.cost.inference == 0.0        # zero extraction charges
    assert warm.cost.bytes_h2d == 0          # zero plane H2D bytes
    assert warm.store["misses"] == 0 and warm.store["hits"] > 0
    es = warm.join.engine_stats
    if es is not None:
        assert es.bytes_h2d == 0             # the engine moved no planes


def test_plan_and_planes_shared_across_engines():
    ds = _ds()
    # per-engine keyed opts: the override picks its own backend's kwargs
    # (flat numpy opts reaching PallasEngine would TypeError)
    svc = JoinService(ds, FDJConfig(engine="numpy", engine_opts=_OPTS,
                                    seed=0, mc_trials=4000))
    a = svc.query()
    b = svc.query(QueryOptions(engine="sharded"))
    c = svc.query(QueryOptions(engine="pallas"))
    assert b.plan_hit and c.plan_hit         # plan is engine-independent
    assert b.pairs == a.pairs == c.pairs
    assert b.cost.inference == 0.0 and b.cost.bytes_h2d == 0


def test_warm_path_precision_extension():
    """Appx-C (T_P < 1) queries run through the store too; a warm repeat
    is free and equal to the cold one-shot join with the same config."""
    ds = _ds()
    cfg = _cfg("numpy", precision_target=0.9)
    ref = _cold(ds, cfg)
    svc = JoinService(ds, cfg)
    assert svc.query().pairs == ref.pairs
    warm = svc.query()
    assert warm.pairs == ref.pairs
    assert warm.cost.inference == 0.0


def test_queries_use_fresh_ledgers_and_accumulate():
    ds = _ds()
    svc = JoinService(ds, _cfg("numpy"))
    c = svc.query()
    w = svc.query()
    assert c.cost is not w.cost
    assert c.cost.inference > 0 and w.cost.inference == 0.0
    # the service ledger absorbed both queries
    assert svc.ledger.total == pytest.approx(c.cost.total + w.cost.total)
    assert svc.ledger.plane_hits == c.cost.plane_hits + w.cost.plane_hits


# --- delta append -----------------------------------------------------------

@pytest.mark.parametrize("engine,stream", [
    ("numpy", False), ("numpy", True), ("pallas", False), ("sharded", True),
], ids=["numpy", "numpy-stream", "pallas", "sharded-stream"])
def test_append_then_query_equals_cold_concat(engine, stream):
    full = _movies()
    base, rows = hold_out_right(full, 10)
    cfg = _cfg(engine, stream)
    svc = JoinService(base, cfg)
    svc.query()

    cold_inference = svc.ledger.inference
    info = svc.append_right(rows)
    # the append extracted only the delta rows
    assert 0 < info["ledger"].inference < 0.5 * cold_inference

    dq = svc.query()
    assert dq.plan_hit and dq.delta_rows == 10

    # cold reference: fresh extractor materializes the grown corpus and the
    # same plan is evaluated end to end — must match pair for pair
    ref = execute_join(svc.dataset, svc.dataset.make_oracle(),
                       SimulatedExtractor(svc.dataset, seed=0), cfg,
                       svc._plans[svc._plan_key(cfg)], keep_candidates=True)
    assert dq.pairs == ref.pairs
    assert dq.join.candidates == ref.candidates
    assert dq.join.recall == ref.recall


def test_scalar_rescale_falls_back_to_full_eval():
    """An append that shifts a scalar plane's whole-corpus p95–p5 scale
    changes distances for the OLD rows too, so merging cached candidates
    would be wrong — the service must detect the shift and re-evaluate in
    full, staying pair-identical to a cold run of the grown corpus."""
    mismatches = 0
    for n, seed in ((40, 0), (40, 2), (60, 1)):
        full = synth.police_records(n_incidents=n, reports_per_incident=2,
                                    seed=seed)
        base, rows = hold_out_right(full, full.n_r // 5)
        cfg = _cfg("numpy")
        svc = JoinService(base, cfg)
        svc.query()
        svc.append_right(rows)
        dq = svc.query()
        ref = execute_join(svc.dataset, svc.dataset.make_oracle(),
                           SimulatedExtractor(svc.dataset, seed=0), cfg,
                           svc._plans[svc._plan_key(cfg)],
                           keep_candidates=True)
        assert dq.pairs == ref.pairs
        assert dq.join.candidates == ref.candidates
        mismatches += int(dq.delta_rows == 0)          # guard actually fired
    assert mismatches > 0, \
        "fixture never shifted the scale; pick one that does"


def test_delta_and_replan_paths_both_meet_guarantee():
    """Recall guarantee holds in both paths: the carried-forward plan on
    the grown corpus (delta join) and a full replan (cold fdj_join)."""
    full = _movies(seed=7)
    base, rows = hold_out_right(full, 8)
    cfg = _cfg("numpy")
    svc = JoinService(base, cfg)
    first = svc.query()
    assert first.join.met_target
    svc.append_right(rows)
    dq = svc.query()
    assert dq.delta_rows == 8
    assert dq.join.recall >= cfg.recall_target          # incremental path
    replan = svc.query(QueryOptions(refresh_plan=True))
    assert replan.delta_rows == 0 and not replan.plan_hit
    assert replan.join.recall >= cfg.recall_target      # replanned path
    # and the replanned service query equals a cold join of the grown corpus
    assert replan.pairs == _cold(svc.dataset, cfg).pairs


def test_append_extends_planes_without_rebuild():
    """Resident R planes grow by the delta: H2D for the append is far below
    re-uploading the full plane set (embed planes move only delta rows)."""
    full = _movies()
    base, rows = hold_out_right(full, 6)
    svc = JoinService(base, _cfg("numpy"))
    svc.query()
    full_upload = svc.store.bytes_to_device
    info = svc.append_right(rows)
    assert 0 < info["store"]["bytes_to_device"] < full_upload
    # and the extended planes serve the next query without extraction
    dq = svc.query()
    assert dq.cost.inference == 0.0


def test_tiny_byte_budget_still_correct():
    """Eviction hurts the hit rate, never correctness."""
    ds = _ds()
    cfg = _cfg("numpy")
    ref = _cold(ds, cfg)
    svc = JoinService(ds, cfg, store=FeaturePlaneStore(byte_budget=64))
    a = svc.query()
    b = svc.query()
    assert a.pairs == ref.pairs and b.pairs == ref.pairs
    assert svc.store.evictions > 0


def test_degenerate_plan_delta_refines_only_new_columns(monkeypatch):
    """Refine-everything fallback still appends incrementally: the delta
    query labels only L × ΔR, merges with the cached accepted pairs, and
    counts (without retaining) the full cross product."""
    from repro.core import scaffold as scaffold_lib
    from repro.core.scaffold import Scaffold

    monkeypatch.setattr(scaffold_lib, "get_logical_scaffold",
                        lambda *a, **k: Scaffold(clauses=[]))
    full = _movies()
    base, rows = hold_out_right(full, 10)
    cfg = _cfg("numpy")
    svc = JoinService(base, cfg)
    first = svc.query()
    assert first.join.candidates is None               # nothing pinned
    assert first.join.candidate_count == base.n_l * base.n_r
    svc.append_right(rows)
    dq = svc.query()
    assert dq.delta_rows == 10
    assert dq.join.candidate_count == svc.dataset.n_l * svc.dataset.n_r
    assert dq.pairs == svc.dataset.truth_set           # oracle precision 1
    # delta oracle work covers only the new columns, not the whole corpus
    assert 0 < dq.cost.refinement and dq.cost.total < 0.5 * first.cost.total


def test_precision_path_falls_back_to_full_eval_on_delta():
    """Appx-C needs whole-candidate-set quantiles: after an append those
    queries re-evaluate fully (delta_rows == 0) and still meet targets."""
    full = _movies()
    base, rows = hold_out_right(full, 6)
    cfg = _cfg("numpy", precision_target=0.9)
    svc = JoinService(base, cfg)
    svc.query()
    svc.append_right(rows)
    dq = svc.query()
    assert dq.delta_rows == 0                           # full re-evaluation
    assert dq.pairs == _cold_same_plan(svc, cfg).pairs


def _cold_same_plan(svc, cfg):
    return execute_join(svc.dataset, svc.dataset.make_oracle(),
                        SimulatedExtractor(svc.dataset, seed=0), cfg,
                        svc._plans[svc._plan_key(cfg)], keep_candidates=True)


# --- online guarantee recalibration (DESIGN.md §4a) -------------------------

def test_recalibration_restores_recall_after_shifted_append():
    """The serving-time invariant: a distribution-shifting append (junk
    tokens inflate the appended rows' clause distances) breaks the
    carried-forward theta; the reservoir recalibration must detect it,
    hot-swap theta via the device sweep, and restore recall >= T."""
    full = _movies()
    base, rows = hold_out_right(full, 10)
    shifted = perturb_rows(rows, seed=1)
    cfg = _cfg("numpy")
    target = cfg.recall_target

    # control: recalibration gated off — the historical carry-forward
    # behavior silently voids the guarantee under this shift
    ctl = JoinService(base, _cfg("numpy", recalibrate=False))
    ctl.query()
    ctl.append_right(shifted)
    broken = ctl.query()
    assert broken.cost.recalibrations == 0
    assert broken.join.recall < target, \
        "fixture too weak: the shift no longer breaks the cached theta"

    svc = JoinService(base, cfg)
    cold = svc.query()
    svc.append_right(shifted)
    post = svc.query()
    led = post.cost
    assert led.recalibrations == 1 and led.theta_swaps == 1
    assert led.theta_drift > 0.0
    assert led.reservoir_cost > 0.0          # top-up labels were charged
    assert post.join.recall >= target - 1e-12, \
        f"recalibrated recall {post.join.recall} < target {target}"
    assert post.join.met_target
    # the swap invalidated the cached evaluation: full re-eval, new theta
    assert post.delta_rows == 0
    assert not (post.join.theta == cold.join.theta).all()
    # replay under the swapped plan is the steady state again: no further
    # recalibration (reservoir extent matches the corpus), warm-path free
    again = svc.query()
    assert again.cost.recalibrations == 0
    assert again.pairs == post.pairs


def test_recalibration_keeps_delta_path_on_stable_append():
    """Same-distribution appends must pass the reservoir invariant check
    without swapping theta — the cheap incremental join survives."""
    full = _movies()
    base, rows = hold_out_right(full, 10)
    svc = JoinService(base, _cfg("numpy"))
    svc.query()
    svc.append_right(rows)
    dq = svc.query()
    assert dq.cost.recalibrations == 1
    assert dq.cost.theta_swaps == 0 and dq.cost.theta_drift == 0.0
    assert dq.delta_rows == 10               # eval cache survived the check
    assert dq.join.recall >= svc.cfg.recall_target - 1e-12


def test_recalibration_skipped_for_degenerate_and_gated_off():
    """Degenerate plans have no theta to calibrate; recalibrate=False is
    the explicit opt-out — neither path runs a check."""
    ds = _ds(n=8)
    base, rows = hold_out_right(ds, 3)
    svc = JoinService(base, _cfg("numpy", thresh_positives=1,
                                 gen_positives=1, max_iter=1, gamma=2.0))
    first = svc.query()
    if not first.join.theta.shape[0]:        # degenerate as intended
        svc.append_right(rows)
        dq = svc.query()
        assert dq.cost.recalibrations == 0
