"""Per-architecture smoke tests (assignment deliverable f).

Each assigned architecture instantiates its REDUCED config and runs one
forward + one train step on CPU, asserting output shapes and finiteness.
The FULL configs are exercised only via the dry-run (no allocation).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow          # minutes of XLA compiles: not tier-1

from repro.common.config import TrainConfig
from repro.configs import ARCH_IDS, get_config, get_smoke
from repro.models import steps, transformer as tr
from repro.optim import adamw


@pytest.fixture(scope="module")
def rngs():
    return jax.random.PRNGKey(0), jax.random.PRNGKey(1)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch, rngs):
    k0, k1 = rngs
    cfg = get_smoke(arch)
    params = tr.init_params(cfg, k0)
    b, s = 2, 32
    tokens = jax.random.randint(k1, (b, s), 0, cfg.vocab_size)
    memory = None
    if cfg.cross_attn_every:
        memory = jax.random.normal(
            k1, (b, cfg.cross_attn_memory_len, cfg.frontend_embed_dim)) * 0.1
    logits, _, aux = tr.forward(params, tokens, cfg, memory=memory)
    assert logits.shape == (b, s, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), "NaN/Inf in logits"

    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=1)}
    if memory is not None:
        batch["memory"] = memory
    ts = jax.jit(steps.make_train_step(cfg, TrainConfig(total_steps=4)))
    p2, opt2, met = ts(params, adamw.init_opt_state(params), batch)
    assert np.isfinite(float(met["loss"]))
    assert float(met["grad_norm"]) > 0.0
    # params actually changed
    delta = sum(float(jnp.sum(jnp.abs(a - b2)))
                for a, b2 in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert delta > 0.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The FULL config carries the exact assigned hyperparameters."""
    assigned = {
        "deepseek-v2-236b": dict(num_layers=60, d_model=5120, num_heads=128,
                                 vocab_size=102400),
        "llama4-maverick-400b-a17b": dict(num_layers=48, d_model=5120,
                                          num_heads=40, num_kv_heads=8,
                                          vocab_size=202048),
        "musicgen-medium": dict(num_layers=48, d_model=1536, num_heads=24,
                                num_kv_heads=24, d_ff=6144, vocab_size=2048),
        "mistral-nemo-12b": dict(num_layers=40, d_model=5120, num_heads=32,
                                 num_kv_heads=8, d_ff=14336, vocab_size=131072),
        "phi4-mini-3.8b": dict(num_layers=32, d_model=3072, num_heads=24,
                               num_kv_heads=8, d_ff=8192, vocab_size=200064),
        "minitron-8b": dict(num_layers=32, d_model=4096, num_heads=32,
                            num_kv_heads=8, d_ff=16384, vocab_size=256000),
        "starcoder2-3b": dict(num_layers=30, d_model=3072, num_heads=24,
                              num_kv_heads=2, d_ff=12288, vocab_size=49152),
        "llama-3.2-vision-90b": dict(num_layers=100, d_model=8192, num_heads=64,
                                     num_kv_heads=8, d_ff=28672,
                                     vocab_size=128256),
        "zamba2-1.2b": dict(num_layers=38, d_model=2048, num_heads=32,
                            d_ff=8192, vocab_size=32000),
        "xlstm-350m": dict(num_layers=24, d_model=1024, num_heads=4, d_ff=0,
                           vocab_size=50304),
    }[arch]
    cfg = get_config(arch)
    for k, v in assigned.items():
        assert getattr(cfg, k) == v, f"{arch}.{k}: {getattr(cfg, k)} != {v}"
    if arch == "deepseek-v2-236b":
        assert cfg.mla.kv_lora_rank == 512
        assert cfg.moe.num_experts == 160 and cfg.moe.top_k == 6
        assert cfg.moe.num_shared_experts == 2
    if arch == "llama4-maverick-400b-a17b":
        assert cfg.moe.num_experts == 128 and cfg.moe.top_k == 1
    if arch == "zamba2-1.2b":
        assert cfg.ssm.state_dim == 64


@pytest.mark.parametrize("arch", ["mistral-nemo-12b", "zamba2-1.2b",
                                  "xlstm-350m", "deepseek-v2-236b"])
def test_decode_matches_full_forward(arch, rngs):
    """prefill + decode == full forward (within cache-quantization noise)."""
    k0, k1 = rngs
    cfg = dataclasses.replace(get_smoke(arch), dtype="float32")
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = tr.init_params(cfg, k0)
    b, s = 2, 33
    tokens = jax.random.randint(k1, (b, s), 0, cfg.vocab_size)
    pf = steps.make_prefill_step(cfg, s + 4)
    dec = steps.make_decode_step(cfg)
    state, _ = pf(params, tokens[:, : s - 1])
    pos = jnp.full((b, 1), s - 1, jnp.int32)
    _, dec_logits = dec(params, state, tokens[:, s - 1 : s], pos)
    full, _, _ = tr.forward(params, tokens, cfg)
    scale = float(jnp.max(jnp.abs(full[:, -1]))) + 1e-9
    err = float(jnp.max(jnp.abs(dec_logits - full[:, -1]))) / scale
    assert err < 0.15, f"decode/full relative mismatch {err}"


def test_param_counts_match_published_sizes():
    expect = {"deepseek-v2-236b": (236, 0.10), "llama4-maverick-400b-a17b": (400, 0.12),
              "mistral-nemo-12b": (12, 0.15), "phi4-mini-3.8b": (3.8, 0.15),
              "zamba2-1.2b": (1.2, 0.3), "xlstm-350m": (0.35, 0.35),
              "llama-3.2-vision-90b": (90, 0.15)}
    for arch, (bn, tol) in expect.items():
        n = tr.count_params(get_config(arch)) / 1e9
        assert abs(n - bn) / bn < tol, f"{arch}: {n:.1f}B vs published {bn}B"


def test_moe_active_params():
    cfg = get_config("deepseek-v2-236b")
    na = tr.active_param_count(cfg) / 1e9
    assert 15 < na < 30, f"deepseek active params {na:.1f}B != ~21B"
