"""Tier-1 ServeEngine units: wave left-padding / ``valid_from`` masking and
eos early-exit truncation.

The slow suite exercises the engine through full-size smoke archs
(test_substrate.py); these tests pin the wave-scheduling semantics on a
tiny float32 transformer so they run in tier-1: pad positions must be
invisible end-to-end (a padded batched slot decodes exactly like a solo
run), and a slot that emits eos stops collecting tokens while the wave
drains — with the whole wave stopping early once every slot is done.
"""


import jax
import numpy as np
import pytest

from repro.common.config import ModelConfig
from repro.models import transformer as tr
from repro.serving.engine import Request, ServeEngine

_TINY = ModelConfig(
    name="tiny-serve", num_layers=2, d_model=32, num_heads=2, num_kv_heads=2,
    d_ff=64, vocab_size=61, max_seq_len=64, rope_theta=10000.0,
    dtype="float32", param_dtype="float32", remat=False)


@pytest.fixture(scope="module")
def tiny():
    params = tr.init_params(_TINY, jax.random.PRNGKey(0))
    return _TINY, params


def _solo(cfg, params, prompt, max_new, eos_id=-1):
    eng = ServeEngine(cfg, params, batch_slots=1, capacity=48)
    r = Request(prompt, max_new_tokens=max_new, eos_id=eos_id)
    eng.run([r])
    return r.out_tokens


def test_wave_left_padding_matches_solo_runs(tiny):
    """Ragged prompts share one left-padded wave; valid_from masking makes
    each slot's decode identical to an unpadded single-request run."""
    cfg, params = tiny
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (3, 7, 11)]                    # forces 8 & 4 pad cols
    eng = ServeEngine(cfg, params, batch_slots=3, capacity=48)
    reqs = [Request(p, max_new_tokens=6) for p in prompts]
    eng.run(reqs)
    for p, r in zip(prompts, reqs):
        assert r.out_tokens == _solo(cfg, params, p, 6), \
            "padded slot diverged from solo decode"


def test_partial_wave_ignores_empty_slots(tiny):
    """Empty slots (valid_from = all-pad) must not perturb live ones."""
    cfg, params = tiny
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (4, 9)]
    eng = ServeEngine(cfg, params, batch_slots=4, capacity=48)  # 2 empty
    reqs = [Request(p, max_new_tokens=5) for p in prompts]
    eng.run(reqs)
    for p, r in zip(prompts, reqs):
        assert r.out_tokens == _solo(cfg, params, p, 5)


def test_eos_truncates_and_wave_exits_early(tiny):
    """A slot whose last token is eos stops collecting; once every slot is
    done the wave stops stepping (greedy decode is deterministic, so the
    eos id is learned from an eos-free reference run)."""
    cfg, params = tiny
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab_size, size=5).astype(np.int32)
    free = _solo(cfg, params, prompt, 8)               # no eos: full budget
    assert len(free) == 8
    eos = free[2]                                      # truncate after 3 tokens

    eng = ServeEngine(cfg, params, batch_slots=1, capacity=48)
    r = Request(prompt, max_new_tokens=8, eos_id=eos)
    eng.run([r])
    cut = free.index(eos) + 1
    assert r.out_tokens == free[:cut]                  # truncated at first eos
    assert r.out_tokens[-1] == eos
    # early exit: the wave stopped decoding once the slot was done
    full_eng = ServeEngine(cfg, params, batch_slots=1, capacity=48)
    full_eng.run([Request(prompt, max_new_tokens=8)])
    assert eng.steps_executed < full_eng.steps_executed


def test_request_resubmission_does_not_leak_decode_state(tiny):
    """Regression: a Request run a second time (retry, or reuse across
    engines) must decode from scratch — stale out_tokens used to satisfy
    the max_new_tokens/eos checks immediately, so the rerun silently
    returned the old tokens plus one garbage prefill append."""
    cfg, params = tiny
    rng = np.random.default_rng(4)
    prompt = rng.integers(0, cfg.vocab_size, size=5).astype(np.int32)
    eng = ServeEngine(cfg, params, batch_slots=1, capacity=48)
    r = Request(prompt, max_new_tokens=6)
    eng.run([r])
    first = list(r.out_tokens)
    assert len(first) == 6
    eng.run([r])                                       # resubmit the object
    assert r.out_tokens == first                       # identical fresh run
    eng2 = ServeEngine(cfg, params, batch_slots=2, capacity=48)
    eng2.run([r])                                      # reuse across engines
    assert r.out_tokens == first


def test_mixed_budgets_truncate_per_slot(tiny):
    """A short-budget slot stops at max_new_tokens while the wave keeps
    decoding for its longer-budget peers."""
    cfg, params = tiny
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, size=6).astype(np.int32)
               for _ in range(2)]
    eng = ServeEngine(cfg, params, batch_slots=2, capacity=48)
    short = Request(prompts[0], max_new_tokens=2)
    long = Request(prompts[1], max_new_tokens=7)
    eng.run([short, long])
    assert len(short.out_tokens) == 2
    assert len(long.out_tokens) == 7
    assert short.out_tokens == _solo(cfg, params, prompts[0], 2)
    assert long.out_tokens == _solo(cfg, params, prompts[1], 7)
